//! Graph500-scale scenario: the memory wall (§IV-A).
//!
//! With the device budget enforced (scaled to the graph per DESIGN.md §6),
//! EP's COO arrays and NS's transient double-CSR no longer fit — exactly
//! the paper's "could not be executed due to insufficient memory" — while
//! hierarchical processing completes with a large win over the baseline.
//!
//! ```bash
//! cargo run --release --example large_graph_hierarchical
//! ```

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::graph500_kronecker;
use lonestar_lb::graph::stats::DegreeStats;
use lonestar_lb::graph::{traversal, Graph};
use lonestar_lb::sim::DeviceSpec;
use lonestar_lb::strategies::StrategyKind;
use std::sync::Arc;

fn main() -> lonestar_lb::Result<()> {
    // Graph500 Kronecker at a reduced scale, with the budget scaled by the
    // same ratio (paper: 16.78M nodes / 335M edges vs a 4.66 GB card).
    let scale = 16u32;
    let graph = Arc::new(graph500_kronecker(scale, 20170101)?);
    let device = DeviceSpec::k20c().scaled_budget(335_000_000, graph.num_edges() as u64);
    let stats = DegreeStats::of(&graph);
    println!(
        "Graph500 scale {scale}: {} nodes, {} edges, max degree {}, sigma {:.0}",
        graph.num_nodes(),
        graph.num_edges(),
        stats.max,
        stats.stddev
    );
    println!(
        "device budget: {:.1} MB (scaled from 4.66 GB by edge ratio)\n",
        device.memory_budget as f64 / (1024.0 * 1024.0)
    );

    let source = traversal::hub_source(&graph);
    let oracle = traversal::bfs_levels(&graph, source);

    let mut bs_ms = None;
    for kind in StrategyKind::ALL {
        let cfg = RunConfig {
            algo: AlgoKind::Bfs,
            strategy: kind,
            source,
            device: device.clone(),
            enforce_budget: true,
            ..Default::default()
        };
        match run(&graph, &cfg) {
            Ok(r) => {
                assert_eq!(r.dist, oracle, "{kind} mismatch");
                let total = r.metrics.total_ms(&cfg.device);
                let note = match (kind, bs_ms) {
                    (StrategyKind::BS, _) => {
                        bs_ms = Some(total);
                        String::new()
                    }
                    (_, Some(bs)) => {
                        format!("  ({:.0}% less than BS)", 100.0 * (1.0 - total / bs))
                    }
                    _ => String::new(),
                };
                println!(
                    "{:<4} total {:>9.2} ms  peak mem {:>6.1} MB{}",
                    kind.label(),
                    total,
                    r.metrics.peak_memory_bytes as f64 / (1024.0 * 1024.0),
                    note
                );
            }
            Err(e) if e.is_oom() => {
                println!("{:<4} OOM — {e}", kind.label());
            }
            Err(e) => return Err(e),
        }
    }
    println!("\npaper shape: EP and NS hit the memory wall; HP completes with");
    println!("a 48-75% reduction vs BS (>2x for BFS) — the scalability argument of SIII-C.");
    Ok(())
}
