//! Batched multi-query serving: answer a stream of BFS/SSSP queries over
//! one shared graph through the `serving` layer, compare against running
//! each query alone, and differentially verify the results.
//!
//! ```bash
//! cargo run --release --example serving_batch
//! ```

use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{rmat, RmatParams};
use lonestar_lb::graph::Graph;
use lonestar_lb::serving::{
    aggregate, replay_single, serve, synthetic_queries, ServeConfig,
};
use lonestar_lb::strategies::StrategyKind;
use std::sync::Arc;

fn main() -> lonestar_lb::Result<()> {
    let graph = Arc::new(rmat(12, 8 << 12, RmatParams::default(), 11)?);
    println!(
        "graph: {} nodes, {} edges, max degree {}",
        graph.num_nodes(),
        graph.num_edges(),
        graph.max_degree()
    );

    // A deterministic synthetic arrival stream: 16 queries, half BFS.
    let queries = synthetic_queries(&graph, 16, 0.5, 42);
    println!("serving {} mixed BFS/SSSP queries\n", queries.len());

    // 1. Batched: one inspection + one AD decision per batch iteration,
    //    sharded across two simulated devices.
    let cfg = ServeConfig {
        strategy: StrategyKind::AD,
        ..ServeConfig::with_shards(2)
    };
    let report = serve(&graph, &queries, &cfg)?;
    let batched = report.totals();
    println!(
        "batched-AD : wall {:>8.2} ms  total {:>8.2} ms  inspector passes {:>4}  \
         policy decisions {:>4}",
        report.wall_ms(),
        report.total_ms(),
        batched.inspector_passes,
        batched.policy_decisions
    );

    // 2. Independent: the status quo — every query pays its own
    //    per-iteration inspection and decision.
    let mut independent_metrics = Vec::new();
    for q in &queries {
        let r = run(
            &graph,
            &RunConfig {
                algo: q.algo,
                strategy: StrategyKind::AD,
                source: q.source,
                ..Default::default()
            },
        )?;
        assert_eq!(
            report.dist_of(q.id).expect("query served"),
            r.dist.as_slice(),
            "query {} diverged from the single-query engine",
            q.id
        );
        independent_metrics.push(r.metrics);
    }
    let independent = aggregate(independent_metrics.iter());
    println!(
        "independent: wall {:>8.2} ms  total {:>8.2} ms  inspector passes {:>4}  \
         policy decisions {:>4}",
        independent.wall_ms(&cfg.devices[0]),
        independent.total_ms(&cfg.devices[0]),
        independent.inspector_passes,
        independent.policy_decisions
    );
    let saved = 100.0
        * (1.0
            - (batched.inspector_passes + batched.policy_decisions) as f64
                / (independent.inspector_passes + independent.policy_decisions).max(1) as f64);
    println!("\namortization: {saved:.1}% of inspection + decision work eliminated");

    // 3. The baked-in differential oracle, per shard.
    for shard in &report.shards {
        replay_single(&graph, &shard.queries, StrategyKind::AD, &cfg.params, &shard.dists)?;
    }
    println!("differential replay through the single-query engine: OK ✓");
    Ok(())
}
