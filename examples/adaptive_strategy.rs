//! Adaptive strategy selection: run SSSP on a skewed graph with every
//! static strategy and the adaptive selector (`AD`), then show the
//! per-iteration decision trace the adaptive engine recorded.
//!
//! ```bash
//! cargo run --release --example adaptive_strategy
//! ```

use lonestar_lb::adaptive::AdaptivePolicyKind;
use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{rmat, RmatParams};
use lonestar_lb::graph::{traversal, Graph};
use lonestar_lb::strategies::{StrategyKind, StrategyParams};
use std::sync::Arc;

fn main() -> lonestar_lb::Result<()> {
    // A skewed RMAT graph: the regime where the strategy choice matters
    // most and no single scheme wins every iteration.
    let graph = Arc::new(rmat(13, 8 << 13, RmatParams::default(), 7)?);
    let source = traversal::hub_source(&graph);
    println!(
        "graph: {} nodes, {} edges, max degree {}, source {source}",
        graph.num_nodes(),
        graph.num_edges(),
        graph.max_degree()
    );
    let oracle = traversal::dijkstra(&graph, source);

    // 1. The static field.
    println!("\n{:<6} {:>12} {:>12} {:>12}", "", "kernel(ms)", "overhead(ms)", "total(ms)");
    let mut best: Option<(StrategyKind, f64)> = None;
    for kind in StrategyKind::ALL {
        let cfg = RunConfig {
            algo: AlgoKind::Sssp,
            strategy: kind,
            source,
            ..Default::default()
        };
        let r = run(&graph, &cfg)?;
        assert_eq!(r.dist, oracle, "{kind} disagrees with Dijkstra!");
        let total = r.metrics.total_ms(&cfg.device);
        if best.map_or(true, |(_, t)| total < t) {
            best = Some((kind, total));
        }
        println!(
            "{:<6} {:>12.3} {:>12.3} {:>12.3}",
            kind.label(),
            r.metrics.kernel_ms(&cfg.device),
            r.metrics.overhead_ms(&cfg.device),
            total
        );
    }

    // 2. The adaptive selector, with both production policies.
    for policy in [AdaptivePolicyKind::CostModel, AdaptivePolicyKind::Heuristic] {
        let cfg = RunConfig {
            algo: AlgoKind::Sssp,
            strategy: StrategyKind::AD,
            source,
            params: StrategyParams {
                adaptive_policy: policy,
                ..Default::default()
            },
            ..Default::default()
        };
        let r = run(&graph, &cfg)?;
        assert_eq!(r.dist, oracle, "AD disagrees with Dijkstra!");
        let total = r.metrics.total_ms(&cfg.device);
        let (bk, bt) = best.expect("static runs completed");
        println!(
            "\nAD ({policy:?}): {total:.3} ms — best static {} at {bt:.3} ms ({:+.1}%)",
            bk.label(),
            100.0 * (total / bt - 1.0)
        );
        println!("decision trace ({} iterations, {} switches):", r.metrics.decisions.len(), r.metrics.strategy_switches);
        for d in &r.metrics.decisions {
            println!(
                "  iter {:>3}: {}{}  frontier {:>6} nodes / {:>7} edges, skew {:>6.1}{}",
                d.iteration,
                d.strategy,
                if d.migrated { "*" } else { " " },
                d.frontier_nodes,
                d.frontier_edges,
                d.degree_skew,
                if d.predicted_cycles > 0 {
                    format!(", predicted {} cycles", d.predicted_cycles)
                } else {
                    String::new()
                }
            );
        }
        println!("  (* = strategy switch with worklist migration)");
    }

    println!("\nall strategies, static and adaptive, agree with the serial oracle ✓");
    Ok(())
}
