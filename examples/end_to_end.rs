//! End-to-end driver: proves all three layers compose on a real workload.
//!
//!   L1  Pallas relax kernel (interpret-lowered at build time)
//!   L2  JAX relax_step, AOT-compiled to artifacts/*.hlo.txt
//!   L3  this Rust coordinator, loading the artifacts via PJRT and driving
//!       every load-balancing strategy over the paper's workload classes
//!
//! For each (graph class, algorithm, strategy) the run executes its numeric
//! hot path on the **XLA runtime** (not the native fallback), validates the
//! result against the serial oracle, and reports simulated device time,
//! MTEPS and host-side throughput. The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::engine::Backend;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{erdos_renyi, rmat, road_grid, RmatParams};
use lonestar_lb::graph::{traversal, Csr, Graph};
use lonestar_lb::strategies::StrategyKind;
use std::sync::Arc;
use std::time::Instant;

fn main() -> lonestar_lb::Result<()> {
    let artifacts = std::env::var("LONESTAR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // Verify the AOT artifacts load before anything else.
    let relaxer = lonestar_lb::runtime::XlaRelaxer::load(&artifacts)?;
    println!(
        "PJRT platform: {} — artifacts loaded from {artifacts}/",
        relaxer.platform()
    );
    drop(relaxer);

    // Three real workload classes from the paper's intro.
    let workloads: Vec<(&str, Csr)> = vec![
        ("social (rmat14)", rmat(14, 8 << 14, RmatParams::default(), 99)?),
        ("road (128x128)", road_grid(128, 128, 100, 17)?),
        ("random (ER14)", erdos_renyi(1 << 14, 4 << 14, 100, 55)?),
    ];

    let wall = Instant::now();
    let mut total_relaxations = 0u64;
    let mut runs = 0u32;

    for (name, graph) in workloads {
        let graph = Arc::new(graph);
        let source = traversal::hub_source(&graph);
        println!(
            "\n=== {name}: {} nodes, {} edges, source {source} ===",
            graph.num_nodes(),
            graph.num_edges()
        );
        for algo in [AlgoKind::Bfs, AlgoKind::Sssp] {
            let oracle = algo.reference(&graph, source);
            for strategy in StrategyKind::ALL {
                let cfg = RunConfig {
                    algo,
                    strategy,
                    source,
                    backend: Backend::Xla {
                        dir: Some(artifacts.clone()),
                    },
                    ..Default::default()
                };
                let t0 = Instant::now();
                let r = run(&graph, &cfg)?;
                let host = t0.elapsed();
                assert_eq!(
                    r.dist, oracle,
                    "{name}/{algo:?}/{strategy}: XLA-backed run diverged from oracle"
                );
                let dev = &cfg.device;
                println!(
                    "{:<5} {:<4} sim {:>8.2} ms  {:>8.1} MTEPS  {:>9} relaxations  host {:>6.0} ms  ✓oracle",
                    algo.name(),
                    strategy.label(),
                    r.metrics.total_ms(dev),
                    r.metrics.mteps(dev),
                    r.metrics.edge_relaxations,
                    host.as_secs_f64() * 1e3,
                );
                total_relaxations += r.metrics.edge_relaxations;
                runs += 1;
            }
        }
    }

    let elapsed = wall.elapsed();
    println!(
        "\nend-to-end: {runs} XLA-backed runs, {total_relaxations} edge relaxations \
         in {:.1} s ({:.2} M relax/s host throughput), every result oracle-validated",
        elapsed.as_secs_f64(),
        total_relaxations as f64 / elapsed.as_secs_f64() / 1e6
    );
    println!("record: EXPERIMENTS.md §End-to-end");
    Ok(())
}
