//! Road-network SSSP: the large-diameter regime where node splitting (NS)
//! shines and per-iteration overheads (WD/HP) bite (§IV-A).
//!
//! Loads a DIMACS `.gr` file when given one, otherwise generates a
//! road-grid with the paper's degree profile. Demonstrates the automatic
//! MDT determination and the NS transform on a real routing workload.
//!
//! ```bash
//! cargo run --release --example road_network_sssp [-- path/to/road.gr]
//! ```

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::road_grid;
use lonestar_lb::graph::stats::DegreeStats;
use lonestar_lb::graph::{io, traversal, Csr, Graph};
use lonestar_lb::strategies::mdt::auto_mdt;
use lonestar_lb::strategies::node_split::split_graph;
use lonestar_lb::strategies::StrategyKind;
use std::sync::Arc;

fn main() -> lonestar_lb::Result<()> {
    let graph: Csr = match std::env::args().nth(1) {
        Some(path) => {
            println!("loading {path}");
            io::load(&path)?
        }
        None => road_grid(192, 192, 1000, 7)?,
    };
    let graph = Arc::new(graph);
    let stats = DegreeStats::of(&graph);
    println!(
        "road network: {} intersections, {} segments, degrees {}..{} (avg {:.1})",
        graph.num_nodes(),
        graph.num_edges(),
        stats.min,
        stats.max,
        stats.avg
    );
    let diam = traversal::diameter_lower_bound(&graph, 0);
    println!("diameter >= {diam} (the long-iteration regime)\n");

    // The automatic MDT and its effect (§III-B / Figure 10).
    let decision = auto_mdt(&graph, 10);
    let split = split_graph(&graph, decision);
    println!(
        "auto MDT = {} (paper band for road networks: 2-4); NS splits {} nodes ({:.1}%)",
        decision.mdt,
        split.split_nodes,
        100.0 * split.split_nodes as f64 / graph.num_nodes() as f64
    );
    let after = DegreeStats::of(&split.graph);
    println!(
        "degree sigma {:.2} -> {:.2} after splitting\n",
        stats.stddev, after.stddev
    );

    // Route from one corner (classic point-to-all query).
    let oracle = traversal::dijkstra(&graph, 0);
    println!(
        "{:<4} {:>10} {:>12} {:>10} {:>8}",
        "", "kernel(ms)", "overhead(ms)", "total(ms)", "iters"
    );
    let mut best: Option<(StrategyKind, f64)> = None;
    for kind in StrategyKind::ALL {
        let cfg = RunConfig {
            algo: AlgoKind::Sssp,
            strategy: kind,
            ..Default::default()
        };
        let r = run(&graph, &cfg)?;
        assert_eq!(r.dist, oracle, "{kind} SSSP mismatch");
        let dev = &cfg.device;
        let total = r.metrics.total_ms(dev);
        println!(
            "{:<4} {:>10.2} {:>12.2} {:>10.2} {:>8}",
            kind.label(),
            r.metrics.kernel_ms(dev),
            r.metrics.overhead_ms(dev),
            total,
            r.metrics.iterations
        );
        if kind != StrategyKind::EP {
            // among node-based strategies (the paper's road comparison)
            if best.map_or(true, |(_, t)| total < t) {
                best = Some((kind, total));
            }
        }
    }
    if let Some((k, _)) = best {
        println!(
            "\nbest node-based strategy on this road network: {} (paper: NS for large diameters)",
            k.label()
        );
    }
    Ok(())
}
