//! Quickstart: generate a small skewed graph, run SSSP under every
//! load-balancing strategy, and compare against the serial oracle.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{rmat, RmatParams};
use lonestar_lb::graph::{traversal, Graph};
use lonestar_lb::strategies::StrategyKind;
use std::sync::Arc;

fn main() -> lonestar_lb::Result<()> {
    // 1. A small RMAT graph: 4096 nodes, 32k edges, power-law degrees —
    //    the shape that breaks node-based load balancing.
    let graph = Arc::new(rmat(12, 8 << 12, RmatParams::default(), 42)?);
    println!(
        "graph: {} nodes, {} edges, max degree {}",
        graph.num_nodes(),
        graph.num_edges(),
        graph.max_degree()
    );

    // 2. The ground truth.
    let oracle = traversal::dijkstra(&graph, 0);

    // 3. Each strategy on the simulated K20c.
    println!("\n{:<4} {:>12} {:>12} {:>12} {:>10}", "", "kernel(ms)", "overhead(ms)", "total(ms)", "vs BS");
    let mut bs_total = None;
    for kind in StrategyKind::ALL {
        let cfg = RunConfig {
            algo: AlgoKind::Sssp,
            strategy: kind,
            ..Default::default()
        };
        let result = run(&graph, &cfg)?;
        assert_eq!(result.dist, oracle, "{kind} disagrees with Dijkstra!");
        let dev = &cfg.device;
        let total = result.metrics.total_ms(dev);
        let vs = match bs_total {
            None => {
                bs_total = Some(total);
                "1.00x".to_string()
            }
            Some(bs) => format!("{:.2}x", bs / total),
        };
        println!(
            "{:<4} {:>12.3} {:>12.3} {:>12.3} {:>10}",
            kind.label(),
            result.metrics.kernel_ms(dev),
            result.metrics.overhead_ms(dev),
            total,
            vs
        );
    }
    println!("\nall strategies agree with the serial oracle ✓");
    Ok(())
}
