//! Social-network BFS: the paper's motivating scenario (§I) — power-law
//! degree graphs where node-based task distribution collapses.
//!
//! Builds an RMAT "social network", inspects its skew, then shows how each
//! strategy copes with the hub-dominated frontier, including the per-warp
//! imbalance the simulator exposes.
//!
//! ```bash
//! cargo run --release --example social_network_bfs
//! ```

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::graph::generators::{rmat, RmatParams};
use lonestar_lb::graph::stats::{degree_frequency, DegreeStats};
use lonestar_lb::graph::{traversal, Graph};
use lonestar_lb::strategies::StrategyKind;
use std::sync::Arc;

fn main() -> lonestar_lb::Result<()> {
    // A "follower graph": 65k users, 0.5M follow edges, heavy tail.
    let graph = Arc::new(rmat(16, 8 << 16, RmatParams::default(), 2024)?);
    let stats = DegreeStats::of(&graph);
    println!("social graph: {} users, {} edges", graph.num_nodes(), graph.num_edges());
    println!(
        "degrees: max {} avg {:.1} sigma {:.1} -> imbalance {:.0}x",
        stats.max,
        stats.avg,
        stats.stddev,
        stats.imbalance()
    );

    // Show the heavy tail.
    let freq = degree_frequency(&graph);
    let above_100: u64 = freq.iter().filter(|(d, _)| *d > 100).map(|(_, c)| c).sum();
    println!(
        "{} accounts have > 100 followees (the warp-stalling hubs)\n",
        above_100
    );

    // BFS from the biggest hub (celebrity account).
    let source = traversal::hub_source(&graph);
    println!("BFS from hub {source} (degree {}):", graph.degree(source));
    let oracle = traversal::bfs_levels(&graph, source);
    let reached = oracle.iter().filter(|&&l| l != lonestar_lb::INF).count();
    println!("reachable: {reached} of {} users\n", graph.num_nodes());

    println!(
        "{:<4} {:>10} {:>12} {:>12} {:>14}",
        "", "total(ms)", "MTEPS", "launches", "atomic-confl"
    );
    for kind in StrategyKind::ALL {
        let cfg = RunConfig {
            algo: AlgoKind::Bfs,
            strategy: kind,
            source,
            ..Default::default()
        };
        let r = run(&graph, &cfg)?;
        assert_eq!(r.dist, oracle, "{kind} BFS mismatch");
        let dev = &cfg.device;
        println!(
            "{:<4} {:>10.3} {:>12.1} {:>12} {:>14}",
            kind.label(),
            r.metrics.total_ms(dev),
            r.metrics.mteps(dev),
            r.metrics.kernel_launches,
            r.metrics.atomic_conflicts
        );
    }
    println!("\npaper shape: EP wins big on small-diameter skewed graphs (48-68% vs BS);");
    println!("WD is the best node-based strategy; NS pays its split overhead here.");
    Ok(())
}
