"""L1 correctness: Pallas kernels vs the pure-jnp oracles.

Hypothesis sweeps shapes and values; int32 arithmetic is exact so the
assertion is equality, with assert_allclose kept for API parity."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

# All value-level randomness below goes through seeded np.random generators;
# derandomizing hypothesis pins the example choice too, so the sweep is
# bit-for-bit reproducible run to run.
settings.register_profile("deterministic", derandomize=True, deadline=None)
settings.load_profile("deterministic")

from compile.kernels import ref
from compile.kernels.relax import (
    DEFAULT_BLOCK,
    INF,
    relax,
    scan_block,
    vmem_bytes_per_tile,
)


def np_i32(xs):
    return np.asarray(xs, dtype=np.int32)


class TestRelaxBasics:
    def test_simple_add(self):
        out = relax(np_i32([0, 5, 10] + [0] * 1021), np_i32([7, 3, 1] + [0] * 1021))
        assert out[0] == 7 and out[1] == 8 and out[2] == 11

    def test_inf_is_preserved(self):
        ds = np_i32([INF] * DEFAULT_BLOCK)
        w = np_i32([100] * DEFAULT_BLOCK)
        out = np.asarray(relax(ds, w))
        assert (out == INF).all()

    def test_saturates_instead_of_wrapping(self):
        ds = np_i32([INF - 1] * DEFAULT_BLOCK)
        w = np_i32([100] * DEFAULT_BLOCK)
        out = np.asarray(relax(ds, w))
        assert (out == INF).all(), "must clamp at INF, not wrap negative"

    def test_rejects_unaligned_batch(self):
        with pytest.raises(AssertionError):
            relax(np_i32([1, 2, 3]), np_i32([1, 2, 3]))

    @pytest.mark.parametrize("block", [128, 256, 1024])
    @pytest.mark.parametrize("tiles", [1, 2, 4])
    def test_matches_ref_across_blockings(self, block, tiles):
        rng = np.random.default_rng(block * 31 + tiles)
        b = block * tiles
        ds = rng.integers(0, 2**30, size=b, dtype=np.int32)
        ds[rng.random(b) < 0.1] = INF
        w = rng.integers(0, 100, size=b, dtype=np.int32)
        got = np.asarray(relax(ds, w, block=block))
        want = np.asarray(ref.relax_ref(ds, w))
        assert_allclose(got, want)

    def test_vmem_footprint_fits_budget(self):
        # 16 MiB VMEM with generous headroom — DESIGN.md §Perf.
        assert vmem_bytes_per_tile(DEFAULT_BLOCK) < 1 << 20


@settings(max_examples=40, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    block_pow=st.integers(min_value=5, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    inf_frac=st.floats(min_value=0.0, max_value=1.0),
)
def test_relax_hypothesis_sweep(tiles, block_pow, seed, inf_frac):
    """Property: Pallas relax == oracle for arbitrary shapes/values."""
    block = 1 << block_pow
    b = tiles * block
    rng = np.random.default_rng(seed)
    ds = rng.integers(0, 2**31 - 1, size=b, dtype=np.int32)
    ds[rng.random(b) < inf_frac] = INF
    w = rng.integers(0, 2**16, size=b, dtype=np.int32)
    got = np.asarray(relax(ds, w, block=block))
    want = np.asarray(ref.relax_ref(ds, w))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_scan_hypothesis_sweep(tiles, seed):
    """Property: per-tile inclusive scan == oracle."""
    block = 256
    b = tiles * block
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1000, size=b, dtype=np.int32)
    got = np.asarray(scan_block(x, block=block))
    want = np.asarray(ref.scan_block_ref(x, block))
    np.testing.assert_array_equal(got, want)


class TestScanBasics:
    def test_single_tile(self):
        x = np_i32(list(range(256)))
        got = np.asarray(scan_block(x, block=256))
        assert got[0] == 0 and got[255] == sum(range(256))

    def test_tiles_are_independent(self):
        x = np_i32([1] * 512)
        got = np.asarray(scan_block(x, block=256))
        # each tile restarts: position 256 is 1, not 257
        assert got[255] == 256 and got[256] == 1
