"""L2/AOT tests: model step functions, HLO lowering, manifest shape."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import model
from compile.aot import lower_kernel, to_hlo_text
from compile.kernels.relax import INF

import jax


def np_i32(xs):
    return np.asarray(xs, dtype=np.int32)


class TestModelSteps:
    def test_relax_step_returns_tuple1(self):
        b = 1024
        out = model.relax_step(np_i32([3] * b), np_i32([4] * b))
        assert isinstance(out, tuple) and len(out) == 1
        assert np.asarray(out[0])[0] == 7

    def test_scan_step_returns_tuple1(self):
        out = model.scan_step(np_i32([2] * 1024))
        assert isinstance(out, tuple) and len(out) == 1
        assert np.asarray(out[0])[1023] == 2048

    def test_specs_match_function_signature(self):
        specs = model.relax_step_spec(2048)
        lowered = jax.jit(lambda a, b: model.relax_step(a, b)).lower(*specs)
        assert lowered is not None


class TestHloLowering:
    def test_relax_lowers_to_parseable_hlo_text(self):
        text = lower_kernel(
            "relax",
            lambda a, b: model.relax_step(a, b),
            model.relax_step_spec(1024),
        )
        assert "HloModule" in text
        # the tuple return convention the rust loader expects
        assert "ROOT" in text

    def test_lowered_hlo_contains_no_custom_calls(self):
        # interpret=True must lower to plain HLO; a Mosaic custom-call would
        # be unloadable by the CPU PJRT client.
        text = lower_kernel(
            "relax",
            lambda a, b: model.relax_step(a, b),
            model.relax_step_spec(1024),
        )
        assert "custom-call" not in text, "Mosaic leak: kernel not interpretable"

    def test_fixed_shapes_in_hlo(self):
        text = lower_kernel(
            "relax",
            lambda a, b: model.relax_step(a, b),
            model.relax_step_spec(2048),
        )
        assert "s32[2048]" in text


class TestAotCli:
    def test_aot_writes_artifacts_and_manifest(self, tmp_path):
        out = tmp_path / "artifacts"
        r = subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out-dir",
                str(out),
                "--batches",
                "1024",
                "--block",
                "256",
            ],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True,
            text=True,
        )
        assert r.returncode == 0, r.stderr
        manifest = json.loads((out / "manifest.json").read_text())
        names = {(a["name"], a["batch"]) for a in manifest["artifacts"]}
        assert ("relax", 1024) in names
        assert ("scan", 1024) in names
        for a in manifest["artifacts"]:
            assert (out / a["file"]).exists()
            assert "HloModule" in (out / a["file"]).read_text()[:200]

    def test_aot_rejects_misaligned_block(self, tmp_path):
        r = subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out-dir",
                str(tmp_path),
                "--batches",
                "1000",
                "--block",
                "256",
            ],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True,
            text=True,
        )
        assert r.returncode != 0


class TestNumericBoundary:
    """The i32 sentinel contract shared with rust/src/runtime/relaxer.rs."""

    def test_inf_is_i32_max(self):
        assert INF == 2**31 - 1

    def test_relax_step_honours_sentinel(self):
        b = 1024
        ds = np_i32([0, 5, INF] + [INF] * (b - 3))
        w = np_i32([7, 3, 1] + [0] * (b - 3))
        (out,) = model.relax_step(ds, w)
        out = np.asarray(out)
        assert out[0] == 7 and out[1] == 8 and out[2] == INF
