"""Layer-2 JAX step functions — the compute graphs the Rust coordinator
executes through PJRT.

Each function here is a *pure*, fixed-shape jax function that calls the
Layer-1 Pallas kernels from ``kernels/``. ``aot.py`` lowers them once per
batch size to HLO text; at runtime the Rust side gathers the inputs,
executes the compiled artifact, and applies the results under its own
scheduling (the paper's contribution lives there, not here).

The relax step is deliberately the *whole* numeric content of a processing
kernel launch: candidates for every edge of the batch. Scatter-min folding
into the distance array happens host-side under atomic-cost accounting, as
on the paper's GPU.
"""

import jax
import jax.numpy as jnp

from .kernels import relax as relax_kernels


def relax_step(dist_src, w, *, block=relax_kernels.DEFAULT_BLOCK):
    """The SSSP/BFS relaxation candidates for one batch of frontier edges.

    Wraps the L1 Pallas kernel so that the lowered HLO contains the tiled
    computation; returns a 1-tuple for the text-HLO calling convention
    (``to_tuple1`` on the Rust side).
    """
    return (relax_kernels.relax(dist_src, w, block=block),)


def scan_step(x, *, block=relax_kernels.DEFAULT_BLOCK):
    """Blocked inclusive scan used by the WD offsets path (1-tuple)."""
    return (relax_kernels.scan_block(x, block=block),)


def relax_step_spec(batch):
    """Example-argument specs for lowering ``relax_step`` at ``batch``."""
    s = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return (s, s)


def scan_step_spec(batch):
    """Example-argument specs for lowering ``scan_step`` at ``batch``."""
    return (jax.ShapeDtypeStruct((batch,), jnp.int32),)
