"""AOT lowering: jax -> HLO text -> artifacts/.

Run once by ``make artifacts``; Python never executes at request time.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--batches 1024,8192,65536]
                          [--block 1024]
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_kernel(name, fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--batches",
        default="1024,8192,65536",
        help="comma-separated static batch sizes to compile",
    )
    ap.add_argument(
        "--block",
        type=int,
        default=1024,
        help="Pallas VMEM tile size (must divide every batch)",
    )
    args = ap.parse_args()

    batches = [int(b) for b in args.batches.split(",")]
    for b in batches:
        if b % args.block != 0:
            ap.error(f"batch {b} not a multiple of block {args.block}")

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"jax_version": jax.__version__, "artifacts": []}

    for batch in batches:
        block = min(args.block, batch)
        for name, fn, specs in [
            (
                "relax",
                lambda ds, w, blk=block: model.relax_step(ds, w, block=blk),
                model.relax_step_spec(batch),
            ),
            (
                "scan",
                lambda x, blk=block: model.scan_step(x, block=blk),
                model.scan_step_spec(batch),
            ),
        ]:
            text = lower_kernel(name, fn, specs)
            fname = f"{name}_b{batch}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {"name": name, "batch": batch, "file": fname}
            )
            print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
