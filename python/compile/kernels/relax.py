"""Layer-1 Pallas kernel: batched edge relaxation.

The numeric hot spot of BFS/SSSP is the candidate computation

    cand[i] = sat_add(dist_src[i], w[i])        (INF stays INF)

over a fixed-size batch of frontier edges. On the paper's GPU this is the
per-thread body of the ``sssp_kernel``; on TPU we re-think it as a tiled
VPU kernel (DESIGN.md section "Hardware-Adaptation"):

* the batch is partitioned into ``block`` -sized tiles that stream through
  VMEM (``BlockSpec`` expresses the HBM->VMEM schedule that CUDA expressed
  with thread blocks);
* each tile is a vectorized saturating add with an INF guard — elementwise,
  so it maps onto the VPU's 8x128 lanes; there is no matmul, hence no MXU
  use, and the roofline is HBM bandwidth (see DESIGN.md §Perf);
* saturation stays in int32: for non-negative inputs,
  ``ds + min(w, INF - ds)`` can never wrap and maps ``INF -> INF``
  (``INF - INF = 0``), so no widening (and no x64 mode) is needed.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust runtime
(and any PJRT backend) can run. Real-TPU performance is *estimated* from
the VMEM footprint in DESIGN.md, not measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# i32 infinity sentinel — must match rust/src/runtime/relaxer.rs::INF_I32.
INF = jnp.iinfo(jnp.int32).max

# Default VMEM tile: 8 * 128 lanes * 4 B * 3 streams = 12 KiB per tile,
# far under the ~16 MiB VMEM budget; chosen to align with the VPU lane
# shape (see python/compile/aot.py --block to sweep).
DEFAULT_BLOCK = 1024


def _relax_tile(dist_src_ref, w_ref, cand_ref):
    """One VMEM tile: cand = min(dist_src + w, INF), INF-preserving.

    Precondition (enforced by the Rust boundary): ``0 <= ds, w <= INF``.
    ``ds + min(w, INF - ds)`` never exceeds INF, so the int32 add cannot
    wrap; ``ds == INF`` gives ``INF - ds == 0`` and stays INF.
    """
    ds = dist_src_ref[...]
    w = w_ref[...]
    cand_ref[...] = ds + jnp.minimum(w, INF - ds)


@functools.partial(jax.jit, static_argnames=("block",))
def relax(dist_src, w, *, block=DEFAULT_BLOCK):
    """Batched relaxation candidates.

    Args:
      dist_src: int32[B] — source distances (INF sentinel for unreached).
      w:        int32[B] — effective edge weights (1 for BFS).
      block:    VMEM tile size; must divide B.

    Returns:
      int32[B] candidates, saturated at INF.
    """
    (b,) = dist_src.shape
    assert b % block == 0, f"batch {b} not a multiple of block {block}"
    grid = (b // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _relax_tile,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,  # CPU-PJRT executable; see module docstring
    )(dist_src, w)


def _scan_tile(x_ref, out_ref):
    """Inclusive prefix sum of one tile (used by the WD offsets path)."""
    out_ref[...] = jnp.cumsum(x_ref[...], dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def scan_block(x, *, block=DEFAULT_BLOCK):
    """Per-tile inclusive scan: int32[B] -> int32[B].

    The host combines tile totals (carry propagation), mirroring how the
    paper offloads the WD prefix sums to Thrust's device scan while the
    host orchestrates.
    """
    (b,) = x.shape
    assert b % block == 0, f"batch {b} not a multiple of block {block}"
    grid = (b // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _scan_tile,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(x)


def vmem_bytes_per_tile(block: int) -> int:
    """VMEM footprint of one relax tile: 3 int32 streams (2 in + 1 out)."""
    return 3 * 4 * block
