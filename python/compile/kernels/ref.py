"""Pure-jnp correctness oracles for the Pallas kernels.

These are the specification: pytest asserts the Pallas implementations in
``relax.py`` match them exactly (int32 arithmetic is exact, so equality —
not allclose — is the right check)."""

import jax.numpy as jnp

INF = jnp.iinfo(jnp.int32).max


def relax_ref(dist_src, w):
    """cand = min(dist_src + w, INF); INF inputs stay INF.

    Computed in numpy int64 (host-side, exact) then clamped — deliberately
    a *different* formulation than the kernel's wrap-free int32 identity,
    so the test is a genuine cross-check."""
    import numpy as np

    wide = np.asarray(dist_src, dtype=np.int64) + np.asarray(w, dtype=np.int64)
    sat = np.minimum(wide, np.int64(INF)).astype(np.int32)
    return jnp.where(jnp.asarray(dist_src) == INF, INF, jnp.asarray(sat))


def scan_block_ref(x, block):
    """Per-tile inclusive prefix sums."""
    tiles = x.reshape(-1, block)
    return jnp.cumsum(tiles, axis=1, dtype=jnp.int32).reshape(-1)
