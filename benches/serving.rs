//! Bench: **batched multi-query serving** vs. N independent single-query
//! runs — the acceptance harness of the serving subsystem.
//!
//! Renders the `figserve` report (batched-AD vs. independent-AD per suite
//! graph) and then asserts the serving layer's contract:
//!
//! * batched-AD performs strictly fewer inspector passes + policy
//!   decisions than N independent AD runs at batch_size ≥ 8 (the
//!   amortization claim — the whole point of batching);
//! * batched distances are bit-identical to the single-query engine's
//!   (verified inside `fig_serving` and re-checked here through the
//!   differential replay oracle on a sharded batch);
//! * sharding (1/2/4 devices) changes wall-clock, never results.
//!
//! Env knobs: `LONESTAR_SCALE=tiny|small|paper`, `LONESTAR_BENCH_ITERS=N`.

use lonestar_lb::arena::GraphCache;
use lonestar_lb::figures::serving::FIGSERVE_QUERIES;
use lonestar_lb::figures::{fig_serving, FigureOpts};
use lonestar_lb::graph::Graph;
use lonestar_lb::serving::{
    replay_single, serve, serve_stream, synthetic_arrivals, synthetic_queries, FaultPlan,
    SchedulerConfig, ServeConfig,
};
use lonestar_lb::sim::DeviceSpec;
use lonestar_lb::strategies::StrategyKind;
use lonestar_lb::util::bench::{black_box, BenchSuite};
use std::sync::Arc;

#[path = "common/mod.rs"]
mod common;

fn main() {
    let scale = common::scale_from_env();
    let iters = common::iters_from_env();
    let opts = FigureOpts {
        scale,
        ..Default::default()
    };

    // The figserve report: batched-AD vs N independent AD runs per graph
    // (distances are differentially verified inside).
    let rows = fig_serving(&opts, &mut std::io::stdout()).expect("figserve report");
    assert!(!rows.is_empty(), "the report must cover the suite");
    let mut failures: Vec<String> = Vec::new();
    for r in &rows {
        assert!(
            r.queries >= 8,
            "{}: amortization is asserted at batch_size >= 8, got {}",
            r.graph,
            r.queries
        );
        let batched = r.batched.inspector_passes + r.batched.policy_decisions;
        let independent = r.independent.inspector_passes + r.independent.policy_decisions;
        if batched >= independent {
            failures.push(format!(
                "{}: batched {} inspector passes + decisions must undercut \
                 independent {}",
                r.graph, batched, independent
            ));
        }
    }

    // Host-timed serving throughput on the first suite graph, sharded.
    let suite_entries = lonestar_lb::graph::generators::paper_suite(scale);
    let entry = &suite_entries[0];
    let g = Arc::new(entry.spec.generate(opts.seed).expect("generate"));
    let queries = synthetic_queries(&g, FIGSERVE_QUERIES, 0.5, opts.seed);
    let mut suite = BenchSuite::new("batched serving (AD), shard sweep");
    for shards in [1usize, 2, 4] {
        let cfg = ServeConfig::with_shards(shards);
        let mut last = None;
        suite.case(
            &format!("{}/{}q/{}shard", entry.name, queries.len(), shards),
            0,
            iters.max(1),
            || {
                let report = serve(&g, &queries, &cfg).expect("serve");
                let totals = report.totals();
                let note = format!(
                    "wall {:.2} ms, inspect {}, decide {}",
                    report.wall_ms(),
                    totals.inspector_passes,
                    totals.policy_decisions
                );
                last = Some(report);
                note
            },
        );
        let report = last.expect("at least one iteration ran");
        black_box(report.query_count());
        // Differential replay: every shard's batched distances equal the
        // single-query engine's, regardless of shard count.
        for shard in &report.shards {
            replay_single(
                &g,
                &shard.queries,
                StrategyKind::AD,
                &cfg.params,
                &shard.dists,
            )
            .unwrap_or_else(|e| {
                panic!("{} with {shards} shard(s): {e}", entry.name)
            });
        }
    }
    // Admission-controlled scheduler case: a 100-query burst (0.1 µs mean
    // gaps) against a heterogeneous k20c+gtx680 pool — the queue backs up
    // past 64 behind the first singleton batches, so the freed shard
    // forms an 80-query batch and the multi-word tag path really runs.
    // The headline metric is *simulated* queries per simulated
    // millisecond — counter-derived, machine-independent, gated by the
    // bench baseline.
    let sched_cfg = SchedulerConfig {
        serve: ServeConfig {
            devices: vec![DeviceSpec::k20c(), DeviceSpec::gtx680()],
            max_batch: 80,
            ..Default::default()
        },
        queue_cap: 120,
        // Explicitly single-worker so `scheduler_sim_qps` times the
        // sequential coordinator path and the par case below is a true
        // contrast (the default 0 would auto-spawn one worker per shard).
        workers: 1,
        ..Default::default()
    };
    let cache = GraphCache::new();
    let mut sched_qps = 0.0f64;
    suite.case(
        &format!("scheduler/{}q-stream-2dev", 100),
        0,
        iters.max(1),
        || {
            let arrivals = synthetic_arrivals(&g, 100, 0.5, 100_000, opts.seed);
            let report = serve_stream(&g, arrivals, &sched_cfg, &cache).expect("serve_stream");
            assert_eq!(
                report.arrived,
                report.admitted + report.dropped.len() as u64,
                "scheduler conservation: arrived == admitted + dropped"
            );
            assert_eq!(report.admitted, report.served() as u64, "admitted == served at drain");
            for shard in &report.shards {
                replay_single(
                    &g,
                    &shard.queries,
                    StrategyKind::AD,
                    &sched_cfg.serve.params,
                    &shard.dists,
                )
                .expect("scheduler replay oracle");
            }
            assert!(
                report.queue_peak > 64,
                "the burst must back the queue up past one tag word \
                 (peak {})",
                report.queue_peak
            );
            sched_qps = report.served() as f64 / report.wall_ms().max(1e-9);
            format!(
                "{} served / {} dropped, {} batches, wall {:.2} ms, {:.2} q/ms",
                report.served(),
                report.dropped.len(),
                report.batches,
                report.wall_ms(),
                sched_qps
            )
        },
    );

    // Multi-worker scheduler case: the identical stream with one worker
    // thread per shard. Simulated output is byte-identical by the
    // determinism contract (asserted below) — what parallelism buys is
    // *host* wall-clock, so the headline `scheduler_par_qps` is served
    // queries per host millisecond with the full worker pool. Host-timed
    // ⇒ machine-dependent; the baseline gate's tolerance absorbs runner
    // noise.
    let n_devices = sched_cfg.serve.devices.len();
    let mut par_cfg = sched_cfg.clone();
    par_cfg.workers = n_devices;
    let baseline_json = {
        let arrivals = synthetic_arrivals(&g, 100, 0.5, 100_000, opts.seed);
        serve_stream(&g, arrivals, &sched_cfg, &cache)
            .expect("serve_stream baseline")
            .to_json()
            .to_string()
    };
    let mut par_qps = 0.0f64;
    suite.case(
        &format!("scheduler/{}q-stream-2dev-{}workers", 100, n_devices),
        0,
        iters.max(1),
        || {
            let arrivals = synthetic_arrivals(&g, 100, 0.5, 100_000, opts.seed);
            let t0 = std::time::Instant::now();
            let report =
                serve_stream(&g, arrivals, &par_cfg, &cache).expect("serve_stream parallel");
            let host_ms = t0.elapsed().as_secs_f64() * 1e3;
            assert_eq!(
                report.to_json().to_string(),
                baseline_json,
                "worker threads must not change the simulated schedule"
            );
            par_qps = report.served() as f64 / host_ms.max(1e-9);
            format!(
                "{} served, {} batches, host {:.2} ms, {:.1} q/host-ms",
                report.served(),
                report.batches,
                host_ms,
                par_qps
            )
        },
    );

    // Faulted scheduler case: the identical stream through a mid-stream
    // outage — shard 0 stalls for 0.2 ms and shard 1 runs 3x slow for
    // 0.3 ms while the burst is still backed up. Aborted batches land in
    // the retry buffer and re-dispatch after backoff, so every query is
    // still served; the headline `scheduler_faulted_qps` is *simulated*
    // q/ms through the outage (counter-derived, machine-independent) and
    // gates the recovery path: a regression that loses requeues or
    // inflates backoff shows up as a throughput cliff against the
    // baseline.
    let fault_plan = FaultPlan::parse(
        "stall:shard=0,at=0.02,for=0.2;slow:shard=1,at=0.05,factor=3,for=0.3",
        n_devices,
        opts.seed,
    )
    .expect("bench fault spec");
    let mut faulted_cfg = sched_cfg.clone();
    faulted_cfg.faults = Some(fault_plan);
    let mut faulted_qps = 0.0f64;
    suite.case(
        &format!("scheduler/{}q-stream-2dev-faulted", 100),
        0,
        iters.max(1),
        || {
            let arrivals = synthetic_arrivals(&g, 100, 0.5, 100_000, opts.seed);
            let report =
                serve_stream(&g, arrivals, &faulted_cfg, &cache).expect("serve_stream faulted");
            assert_eq!(
                report.arrived,
                report.served() as u64
                    + report.dropped.len() as u64
                    + report.deadline_expired.len() as u64
                    + report.failed.len() as u64,
                "faulted conservation: arrived == served + dropped + expired + failed"
            );
            assert!(
                report.failed.is_empty(),
                "transient faults must not exhaust retries ({} failed)",
                report.failed.len()
            );
            faulted_qps = report.served() as f64 / report.wall_ms().max(1e-9);
            format!(
                "{} served, {} requeued / {} retries, {} batches, wall {:.2} ms, {:.2} q/ms",
                report.served(),
                report.requeued,
                report.retries,
                report.batches,
                report.wall_ms(),
                faulted_qps
            )
        },
    );

    let results = suite.finish();
    // Fold the amortization claim into the shared bench baseline: the
    // inspection+decision work of batched-AD as a fraction of N
    // independent runs (machine-independent — simulated counters).
    let batched_work: u64 = rows
        .iter()
        .map(|r| r.batched.inspector_passes + r.batched.policy_decisions)
        .sum();
    let independent_work: u64 = rows
        .iter()
        .map(|r| r.independent.inspector_passes + r.independent.policy_decisions)
        .sum();
    let amortization = independent_work as f64 / (batched_work.max(1)) as f64;
    common::write_bench_json(
        "serving",
        &results,
        &[
            ("inspection_amortization", amortization),
            ("scheduler_sim_qps", sched_qps),
            ("scheduler_par_qps", par_qps),
            ("scheduler_faulted_qps", faulted_qps),
        ],
    );
    println!(
        "serving acceptance over {} graphs ({} nodes, {} edges on the timed one)",
        rows.len(),
        g.num_nodes(),
        g.num_edges()
    );
    assert!(
        failures.is_empty(),
        "serving acceptance violations:\n  {}",
        failures.join("\n  ")
    );
}
