//! Bench: regenerate **Figure 8** — BFS execution time for BS/EP/WD/NS/HP
//! over the paper suite. Same knobs as fig7_sssp.

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::figures::{fig8, FigureOpts};
use lonestar_lb::graph::generators::paper_suite;
use lonestar_lb::graph::traversal::hub_source;
use lonestar_lb::strategies::StrategyKind;
use lonestar_lb::util::bench::{black_box, BenchSuite};
use std::sync::Arc;

#[path = "common/mod.rs"]
mod common;

fn main() {
    let scale = common::scale_from_env();
    let iters = common::iters_from_env();
    let opts = FigureOpts {
        scale,
        ..Default::default()
    };

    let mut stdout = std::io::stdout().lock();
    let figure = fig8(&opts, &mut stdout).expect("fig8");
    drop(stdout);

    let mut suite = BenchSuite::new("fig8: BFS per-strategy runs (host time)");
    for entry in paper_suite(scale) {
        let g = Arc::new(entry.spec.generate(opts.seed).expect("generate"));
        let dev = opts.device_for(&entry, &g);
        let source = hub_source(&g);
        for k in StrategyKind::ALL {
            let cfg = RunConfig {
                algo: AlgoKind::Bfs,
                strategy: k,
                source,
                device: dev.clone(),
                enforce_budget: opts.enforce_budget,
                ..Default::default()
            };
            let name = format!("{}/{}", entry.name, k.label());
            suite.case(&name, 1, iters, || match run(&g, &cfg) {
                Ok(r) => {
                    let ms = r.metrics.total_ms(&dev);
                    black_box(&r.dist);
                    format!("sim {ms:.2} ms, {:.1} MTEPS", r.metrics.mteps(&dev))
                }
                Err(e) if e.is_oom() => "OOM".to_string(),
                Err(e) => panic!("{name}: {e}"),
            });
        }
    }
    suite.finish();

    // Paper headline: EP ~10% better on road BFS, 48-68% on small-diameter.
    for row in &figure.rows {
        if let Some(red) = row.reduction_vs_bs(StrategyKind::EP) {
            println!(
                "{} ({}): EP cuts BFS time by {red:.0}% vs BS",
                row.graph, row.skew_class
            );
        }
    }
}
