//! Bench: the **adaptive vs. best-static** comparison on the Table II graph
//! suite (SSSP, budget enforced like the paper's runs).
//!
//! For every suite graph this runs the five static strategies and the
//! adaptive selector, then checks the acceptance properties of the AD
//! subsystem:
//!
//! * AD's distances equal the BS oracle (serial Dijkstra) on every graph;
//! * AD never exceeds the device memory budget (it must complete where
//!   only a subset of static strategies fit);
//! * AD's simulated time is within 10% of the per-graph best static
//!   strategy, and strictly better than the worst where the static spread
//!   is meaningful.
//!
//! The decision-trace length and switch count are printed so regressions in
//! switching overhead stay visible.
//!
//! Env knobs: `LONESTAR_SCALE=tiny|small|paper`, `LONESTAR_BENCH_ITERS=N`.

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::figures::FigureOpts;
use lonestar_lb::graph::generators::paper_suite;
use lonestar_lb::graph::traversal::{dijkstra, hub_source};
use lonestar_lb::strategies::StrategyKind;
use lonestar_lb::util::bench::{black_box, BenchSuite};
use std::sync::Arc;

#[path = "common/mod.rs"]
mod common;

fn main() {
    let scale = common::scale_from_env();
    let iters = common::iters_from_env();
    let opts = FigureOpts {
        scale,
        ..Default::default()
    };

    let mut suite = BenchSuite::new("adaptive (AD) vs. static strategies, SSSP");
    let mut within_10 = 0usize;
    let mut graphs = 0usize;
    let mut failures: Vec<String> = Vec::new();

    for entry in paper_suite(scale) {
        let g = Arc::new(entry.spec.generate(opts.seed).expect("generate"));
        let dev = opts.device_for(&entry, &g);
        let source = hub_source(&g);
        let oracle = dijkstra(&g, source);

        // Static field: per-graph best and worst completed times.
        let mut static_times: Vec<(StrategyKind, f64)> = Vec::new();
        for k in StrategyKind::ALL {
            let cfg = RunConfig {
                algo: AlgoKind::Sssp,
                strategy: k,
                source,
                device: dev.clone(),
                enforce_budget: true,
                ..Default::default()
            };
            match run(&g, &cfg) {
                Ok(r) => static_times.push((k, r.metrics.total_ms(&dev))),
                Err(e) if e.is_oom() => {}
                Err(e) => panic!("{}/{k}: {e}", entry.name),
            }
        }

        // The adaptive run (host-timed via the bench harness).
        let ad_cfg = RunConfig {
            algo: AlgoKind::Sssp,
            strategy: StrategyKind::AD,
            source,
            device: dev.clone(),
            enforce_budget: true,
            ..Default::default()
        };
        let mut last = None;
        suite.case(&format!("{}/AD", entry.name), 0, iters.max(1), || {
            let r = run(&g, &ad_cfg)
                .unwrap_or_else(|e| panic!("{}: AD must fit the budget: {e}", entry.name));
            let note = format!(
                "sim {:.2} ms, {} iters, {} switches",
                r.metrics.total_ms(&dev),
                r.metrics.decisions.len(),
                r.metrics.strategy_switches
            );
            last = Some(r);
            note
        });
        let ad = last.expect("at least one iteration ran");
        black_box(&ad.dist);

        assert_eq!(
            ad.dist, oracle,
            "{}: AD distances must match the BS oracle",
            entry.name
        );

        let ad_ms = ad.metrics.total_ms(&dev);
        let best = static_times
            .iter()
            .cloned()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let worst = static_times
            .iter()
            .cloned()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        graphs += 1;
        if let (Some((bk, bt)), Some((wk, wt))) = (best, worst) {
            let vs_best = ad_ms / bt;
            println!(
                "{:<12} AD {ad_ms:>9.2} ms | best {} {bt:>9.2} ms ({:+.1}%) | worst {} {wt:>9.2} ms | \
                 trace {} decisions, {} switches",
                entry.name,
                bk.label(),
                100.0 * (vs_best - 1.0),
                wk.label(),
                ad.metrics.decisions.len(),
                ad.metrics.strategy_switches,
            );
            if vs_best <= 1.10 {
                within_10 += 1;
            } else {
                failures.push(format!(
                    "{}: AD {ad_ms:.2} ms is {:.1}% above best static {} ({bt:.2} ms)",
                    entry.name,
                    100.0 * (vs_best - 1.0),
                    bk.label()
                ));
            }
            // Strictly better than the worst static strategy wherever the
            // static spread is meaningful (>15%).
            if wt > bt * 1.15 && ad_ms >= wt {
                failures.push(format!(
                    "{}: AD {ad_ms:.2} ms must beat the worst static {} ({wt:.2} ms)",
                    entry.name,
                    wk.label()
                ));
            }
        }
    }

    suite.finish();
    println!("AD within 10% of best-static on {within_10}/{graphs} graphs");
    assert!(
        failures.is_empty(),
        "adaptive acceptance violations:\n  {}",
        failures.join("\n  ")
    );
}
