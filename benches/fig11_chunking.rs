//! Bench: regenerate **Figure 11** — EP work-chunking speedup over per-edge
//! append atomics (paper: 1.11–3.125×, average 1.82×).

use lonestar_lb::figures::{fig11, FigureOpts};

#[path = "common/mod.rs"]
mod common;

fn main() {
    let opts = FigureOpts {
        scale: common::scale_from_env(),
        ..Default::default()
    };
    let mut stdout = std::io::stdout().lock();
    let rows = fig11(&opts, &mut stdout).expect("fig11");
    drop(stdout);

    if rows.is_empty() {
        println!("no EP-runnable graphs at this scale");
        return;
    }
    let avg: f64 = rows.iter().map(|r| r.speedup).sum::<f64>() / rows.len() as f64;
    for r in &rows {
        assert!(
            r.speedup >= 1.0,
            "{}: chunking must never slow EP down (got {:.2}x)",
            r.graph,
            r.speedup
        );
    }
    println!(
        "work chunking: avg {avg:.2}x over {} graphs (paper: 1.11-3.125x, avg 1.82x)",
        rows.len()
    );
}
