//! Shared helpers for the bench binaries.
#![allow(dead_code)] // each bench binary uses a subset

use lonestar_lb::graph::generators::SuiteScale;
use lonestar_lb::util::bench::CaseResult;
use lonestar_lb::util::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// `LONESTAR_SCALE=tiny|small|paper` (default small).
pub fn scale_from_env() -> SuiteScale {
    match std::env::var("LONESTAR_SCALE").as_deref() {
        Ok("tiny") => SuiteScale::Tiny,
        Ok("paper") => SuiteScale::Paper,
        _ => SuiteScale::Small,
    }
}

/// `LONESTAR_BENCH_ITERS=N` (default 3).
pub fn iters_from_env() -> u32 {
    std::env::var("LONESTAR_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}

/// Where the machine-readable bench baseline goes: `BENCH_JSON_OUT` env
/// override, else `BENCH_hotpath.json` in the working directory (the
/// committed baseline the CI bench-smoke job diffs against).
pub fn bench_json_path() -> PathBuf {
    std::env::var("BENCH_JSON_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("BENCH_hotpath.json"))
}

/// Merge one suite's results plus derived, machine-independent ratio
/// metrics into the bench baseline JSON (read-modify-write keyed by suite
/// name, so `hotpath` and `serving` share one file). Raw nanoseconds are
/// recorded for trajectory plots; the regression gate compares the
/// *ratios*, which survive hardware changes.
pub fn write_bench_json(suite: &str, results: &[CaseResult], ratios: &[(&str, f64)]) {
    let path = bench_json_path();
    let mut root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .filter(|j| matches!(j, Json::Obj(_)))
        .unwrap_or_else(|| Json::Obj(BTreeMap::new()));

    let cases: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", r.name.as_str().into()),
                ("iters", r.iters.into()),
                ("mean_ns", r.mean_ns.into()),
                ("stddev_ns", r.stddev_ns.into()),
                ("min_ns", r.min_ns.into()),
                ("note", r.note.as_str().into()),
            ])
        })
        .collect();
    let suite_obj = Json::obj(vec![
        ("cases", Json::Arr(cases)),
        (
            "ratios",
            Json::Obj(
                ratios
                    .iter()
                    .map(|&(k, v)| (k.to_string(), Json::from(v)))
                    .collect(),
            ),
        ),
    ]);

    if let Json::Obj(m) = &mut root {
        m.insert("schema".into(), 1u64.into());
        m.remove("bootstrap"); // a real measurement replaces the stub
        let suites = m
            .entry("suites".to_string())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        if !matches!(suites, Json::Obj(_)) {
            *suites = Json::Obj(BTreeMap::new());
        }
        if let Json::Obj(sm) = suites {
            sm.insert(suite.to_string(), suite_obj);
        }
    }
    match std::fs::write(&path, format!("{root}\n")) {
        Ok(()) => println!("(bench baseline written to {})", path.display()),
        Err(e) => println!("(bench baseline NOT written to {}: {e})", path.display()),
    }
}
