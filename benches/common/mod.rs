//! Shared helpers for the bench binaries.
#![allow(dead_code)] // each bench binary uses a subset

use lonestar_lb::graph::generators::SuiteScale;

/// `LONESTAR_SCALE=tiny|small|paper` (default small).
pub fn scale_from_env() -> SuiteScale {
    match std::env::var("LONESTAR_SCALE").as_deref() {
        Ok("tiny") => SuiteScale::Tiny,
        Ok("paper") => SuiteScale::Paper,
        _ => SuiteScale::Small,
    }
}

/// `LONESTAR_BENCH_ITERS=N` (default 3).
pub fn iters_from_env() -> u32 {
    std::env::var("LONESTAR_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}
