//! Bench: regenerate **Figure 10** — degree distributions before/after node
//! splitting with the auto-MDT heuristic, timing the split transform
//! itself (NS's one-time preprocessing cost).

use lonestar_lb::figures::{fig10, FigureOpts};
use lonestar_lb::graph::generators::paper_suite;
use lonestar_lb::strategies::mdt::auto_mdt;
use lonestar_lb::strategies::node_split::split_graph;
use lonestar_lb::util::bench::{black_box, BenchSuite};

#[path = "common/mod.rs"]
mod common;

fn main() {
    let scale = common::scale_from_env();
    let iters = common::iters_from_env();
    let opts = FigureOpts {
        scale,
        ..Default::default()
    };

    let mut stdout = std::io::stdout().lock();
    let rows = fig10(&opts, &mut stdout).expect("fig10");
    drop(stdout);

    let mut suite = BenchSuite::new("fig10: split-transform cost");
    for entry in paper_suite(scale) {
        let g = entry.spec.generate(opts.seed).expect("generate");
        suite.case(&format!("mdt/{}", entry.name), 1, iters, || {
            let d = auto_mdt(&g, 10);
            black_box(d);
            format!("mdt={}", d.mdt)
        });
        let d = auto_mdt(&g, 10);
        suite.case(&format!("split/{}", entry.name), 1, iters, || {
            let s = split_graph(&g, d);
            let msg = format!("{} splits, +{} nodes", s.split_nodes, s.map.total_children());
            black_box(s);
            msg
        });
    }
    suite.finish();

    // Shape assertions mirrored from the paper's text.
    for r in &rows {
        assert!(
            r.max_after <= r.mdt,
            "{}: post-split max degree {} exceeds MDT {}",
            r.graph,
            r.max_after,
            r.mdt
        );
        // Splitting must tighten the distribution on the skewed graphs
        // (Figure 10's green-vs-red curves); road networks are already
        // near-uniform and may shift slightly.
        if r.max_before > 4 * r.mdt {
            assert!(
                r.sigma_after < r.sigma_before,
                "{}: splitting must reduce degree variance on skewed graphs",
                r.graph
            );
        }
    }
    println!("all {} graphs: max degree bounded by MDT after splitting", rows.len());
}
