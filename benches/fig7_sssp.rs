//! Bench: regenerate **Figure 7** — SSSP execution time (kernel + overhead)
//! for BS/EP/WD/NS/HP over the paper suite, plus host-time statistics per
//! (graph, strategy) cell.
//!
//! Env knobs: `LONESTAR_SCALE=tiny|small|paper`, `LONESTAR_BENCH_ITERS=N`.

use lonestar_lb::algorithms::AlgoKind;
use lonestar_lb::coordinator::{run, RunConfig};
use lonestar_lb::figures::{fig7, FigureOpts};
use lonestar_lb::graph::generators::paper_suite;
use lonestar_lb::graph::traversal::hub_source;
use lonestar_lb::strategies::StrategyKind;
use lonestar_lb::util::bench::{black_box, BenchSuite};
use std::sync::Arc;

#[path = "common/mod.rs"]
mod common;

fn main() {
    let scale = common::scale_from_env();
    let iters = common::iters_from_env();
    let opts = FigureOpts {
        scale,
        ..Default::default()
    };

    // The paper table itself (one full sweep through the shared harness).
    let mut stdout = std::io::stdout().lock();
    let figure = fig7(&opts, &mut stdout).expect("fig7");
    drop(stdout);

    // Host-timing statistics per cell (the L3 perf surface).
    let mut suite = BenchSuite::new("fig7: SSSP per-strategy runs (host time)");
    for entry in paper_suite(scale) {
        let g = Arc::new(entry.spec.generate(opts.seed).expect("generate"));
        let dev = opts.device_for(&entry, &g);
        let source = hub_source(&g);
        for k in StrategyKind::ALL {
            let cfg = RunConfig {
                algo: AlgoKind::Sssp,
                strategy: k,
                source,
                device: dev.clone(),
                enforce_budget: opts.enforce_budget,
                ..Default::default()
            };
            let name = format!("{}/{}", entry.name, k.label());
            suite.case(&name, 1, iters, || match run(&g, &cfg) {
                Ok(r) => {
                    let ms = r.metrics.total_ms(&dev);
                    black_box(&r.dist);
                    format!("sim {ms:.2} ms, {:.1} MTEPS", r.metrics.mteps(&dev))
                }
                Err(e) if e.is_oom() => "OOM".to_string(),
                Err(e) => panic!("{name}: {e}"),
            });
        }
    }
    suite.finish();

    // Paper headline: EP reduces SSSP time 60-80% vs BS.
    for row in &figure.rows {
        if let Some(red) = row.reduction_vs_bs(StrategyKind::EP) {
            println!("{}: EP cuts SSSP time by {red:.0}% vs BS (paper: 60-80%)", row.graph);
        }
    }
}
