//! Bench: micro-benchmarks of the L3 hot paths — the targets of the
//! performance pass recorded in EXPERIMENTS.md §Perf.
//!
//! Covers: frontier flattening, kernel interpretation (the launch inner
//! loop), WD offset computation, worklist condensing, NS split transform,
//! and the XLA relaxer batch path (skipped when artifacts are missing).

use lonestar_lb::algorithms::{AlgoKind, NativeRelaxer, Relaxer};
use lonestar_lb::coordinator::exec::flatten_frontier;
use lonestar_lb::coordinator::{Assignment, ExecCtx, KernelWork, PushTarget};
use lonestar_lb::graph::generators::{rmat, RmatParams};
use lonestar_lb::sim::{AccessPattern, DeviceSpec};
use lonestar_lb::strategies::workload_decomp::block_offsets;
use lonestar_lb::util::bench::{black_box, BenchSuite};
use lonestar_lb::worklist::NodeWorklist;
use lonestar_lb::INF;

#[path = "common/mod.rs"]
mod common;

fn main() {
    let iters = common::iters_from_env().max(5);
    let g = rmat(16, 8 << 16, RmatParams::default(), 7).expect("rmat16");
    let dev = DeviceSpec::k20c();
    let nodes: Vec<u32> = (0..65_536u32).collect();

    let mut suite = BenchSuite::new("L3 hot paths (rmat16 frontier = all nodes)");

    suite.case("flatten_frontier/524k-edges", 1, iters, || {
        let (src, eid) = flatten_frontier(&g, &nodes);
        let n = src.len();
        black_box((src, eid));
        format!("{n} positions")
    });

    let (src, eid) = flatten_frontier(&g, &nodes);
    let total = src.len();

    suite.case("block_offsets/524k-edges", 1, iters, || {
        let off = block_offsets(total, dev.max_resident_threads);
        let n = off.len();
        black_box(off);
        format!("{n} lanes")
    });

    suite.case("native_relax/524k-batch", 1, iters, || {
        let ds = vec![5u32; total];
        let w = vec![3u32; total];
        let c = NativeRelaxer.candidates(&ds, &w).unwrap();
        black_box(c);
        format!("{total} candidates")
    });

    suite.case("launch_interpret/bs-kernel", 1, iters, || {
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
        ctx.dist = vec![INF; g.num_nodes_pub()];
        ctx.dist[0] = 0;
        let mut offsets = Vec::with_capacity(nodes.len() + 1);
        offsets.push(0u32);
        let mut acc = 0u32;
        for &n in &nodes {
            acc += g.degree(n);
            offsets.push(acc);
        }
        let work = KernelWork {
            name: "bench",
            src: src.clone(),
            eid: eid.clone(),
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let r = ctx.launch(&g, &work, None).unwrap();
        let n = r.updated.len();
        black_box(r);
        format!("{n} updates")
    });

    suite.case("condense/524k-dupes", 1, iters, || {
        let mut wl = NodeWorklist::new();
        for e in 0..total as u32 {
            wl.push(e % 65_536, 8);
        }
        let removed = wl.condense();
        black_box(wl);
        format!("{removed} removed")
    });

    suite.case("ns_split/rmat16", 1, iters, || {
        let d = lonestar_lb::strategies::mdt::auto_mdt(&g, 10);
        let s = lonestar_lb::strategies::node_split::split_graph(&g, d);
        let msg = format!("{} splits", s.split_nodes);
        black_box(s);
        msg
    });

    // XLA relaxer (the production backend) — skipped without artifacts.
    match lonestar_lb::runtime::XlaRelaxer::load("artifacts") {
        Ok(mut xla) => {
            suite.case("xla_relax/524k-batch", 1, iters, || {
                let ds = vec![5u32; total];
                let w = vec![3u32; total];
                let c = xla.candidates(&ds, &w).unwrap();
                black_box(c);
                format!("{total} candidates via PJRT")
            });
        }
        Err(e) => println!("(xla_relax skipped: {e})"),
    }

    suite.finish();
}

/// Extension trait shim: Graph::num_nodes without importing the trait in
/// the closure above.
trait NumNodes {
    fn num_nodes_pub(&self) -> usize;
}
impl NumNodes for lonestar_lb::graph::Csr {
    fn num_nodes_pub(&self) -> usize {
        use lonestar_lb::graph::Graph;
        self.num_nodes()
    }
}
