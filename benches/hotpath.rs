//! Bench: micro-benchmarks of the L3 hot paths — the targets of the
//! performance pass recorded in EXPERIMENTS.md §Perf, and since the
//! scratch-arena PR also the source of the committed `BENCH_hotpath.json`
//! baseline (see README "Performance").
//!
//! Covers: frontier flattening (pre-arena two-pass vs. single-pass into
//! pooled scratch), the full per-iteration host overhead path
//! (legacy-allocating vs. pooled), the batched-serving merge/extract loop
//! (BTreeMap build vs. in-place sort builder), kernel interpretation, WD
//! offset computation, worklist condensing, NS split transform, and the
//! XLA relaxer batch path (skipped when artifacts are missing).
//!
//! The legacy halves call the pre-PR reference implementations that are
//! kept in-tree (`flatten_frontier_two_pass`,
//! `MergedWorklist::from_frontiers_btree`, the allocating wrappers), so
//! the speedup ratios in the JSON compare real code, not a strawman. Two
//! ratios carry in-bench floors (see the assert block at the bottom for
//! the exact thresholds and their rationale):
//!
//! * `iteration_overhead_speedup` — the flatten-centred per-iteration
//!   host path (clone + inspector re-sum + two-pass flatten + fresh
//!   offsets/worklist vs. cached-degree offsets + O(1) edge sum +
//!   single-pass flatten into warm scratch + double-buffered dedup);
//! * `serving_merge_speedup` — the batched-serving iteration loop's
//!   merge + per-query extract step (BTreeMap vs. in-place sort).

use lonestar_lb::algorithms::{AlgoKind, NativeRelaxer, Relaxer};
use lonestar_lb::arena::GraphCache;
use lonestar_lb::coordinator::exec::{
    flatten_frontier, flatten_frontier_into, flatten_frontier_two_pass,
};
use lonestar_lb::coordinator::{Assignment, ExecCtx, KernelWork, PushTarget};
use lonestar_lb::graph::generators::{rmat, RmatParams};
use lonestar_lb::graph::Graph;
use lonestar_lb::serving::{
    serve_with_cache, synthetic_queries, MergedBuilder, MergedWorklist, ServeConfig,
};
use lonestar_lb::sim::{AccessPattern, DeviceSpec};
use lonestar_lb::strategies::workload_decomp::{block_offsets, block_offsets_into};
use lonestar_lb::util::bench::{black_box, BenchSuite, CaseResult};
use lonestar_lb::worklist::NodeWorklist;
use lonestar_lb::INF;
use std::sync::Arc;

#[path = "common/mod.rs"]
mod common;

fn mean_of(results: &[CaseResult], name: &str) -> f64 {
    results
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.mean_ns)
        .unwrap_or_else(|| panic!("bench case {name} missing"))
}

fn main() {
    let iters = common::iters_from_env().max(5);
    let g = Arc::new(rmat(16, 8 << 16, RmatParams::default(), 7).expect("rmat16"));
    let dev = DeviceSpec::k20c();
    let nodes: Vec<u32> = (0..65_536u32).collect();
    let n_nodes = 65_536usize;

    // The worklist every frontier-shaped case flattens: all nodes, with
    // their degrees cached at push time (as the engine keeps them).
    let mut wl = NodeWorklist::new();
    for &n in &nodes {
        wl.push(n, g.degree(n));
    }

    let mut suite = BenchSuite::new("L3 hot paths (rmat16 frontier = all nodes)");

    // -- flatten micro: the two-pass reference vs. the single-pass pooled
    //    rewrite (same output, see exec.rs tests).
    suite.case("flatten/two-pass-legacy", 1, iters, || {
        let (src, eid) = flatten_frontier_two_pass(&g, &nodes);
        let n = src.len();
        black_box((src, eid));
        format!("{n} positions")
    });
    let mut fsrc: Vec<u32> = Vec::new();
    let mut feid: Vec<u32> = Vec::new();
    suite.case("flatten/single-pass-pooled", 1, iters, || {
        flatten_frontier_into(&g, &nodes, &mut fsrc, &mut feid);
        let n = black_box(fsrc.len());
        format!("{n} positions")
    });

    // -- the per-iteration host overhead around flatten_frontier as a BS
    //    iteration paid it pre-arena: worklist snapshot clone, the
    //    inspector's second O(n) sum pass over the degree array (now
    //    O(1) via the cached edge sum + inspect_with_edges), the two-pass
    //    flatten with fresh output arrays, per-node CSR degree lookups
    //    for the offsets, and a freshly allocated (push-growth) output
    //    worklist per advance. The dedup bitmap was persistent pre-PR
    //    too, so each half keeps its own (neither is charged for it).
    let mut lseen: Vec<u64> = vec![0u64; n_nodes.div_ceil(64)];
    suite.case("flatten_frontier/iteration-legacy", 1, iters, || {
        let active = wl.nodes().to_vec(); // worklist snapshot (pre-PR clone)
        let edges: u64 = wl.degrees().iter().map(|&d| d as u64).sum(); // inspector re-sum
        let (src, eid) = flatten_frontier_two_pass(&g, &active);
        let mut offsets = Vec::with_capacity(active.len() + 1);
        offsets.push(0u32);
        let mut acc = 0u32;
        for &n in &active {
            acc += g.degree(n); // CSR lookup per node (degrees not reused)
            offsets.push(acc);
        }
        // Worklist advance: dedup into a fresh output worklist.
        let mut next = NodeWorklist::new();
        for &s in &src {
            let (w, b) = (s as usize / 64, s as usize % 64);
            if lseen[w] & (1 << b) == 0 {
                lseen[w] |= 1 << b;
                next.push(s, g.degree(s));
            }
        }
        for &s in next.nodes() {
            lseen[s as usize / 64] = 0; // clear only touched words
        }
        black_box((eid, offsets));
        format!("{edges} edges, {} condensed", next.len())
    });
    // ...and as it pays it now: cached degrees, O(1) edge sum, single-pass
    // flatten into warm scratch, double-buffered dedup with a persistent
    // touched-word-cleared bitmap.
    let mut isrc: Vec<u32> = Vec::new();
    let mut ieid: Vec<u32> = Vec::new();
    let mut ioffsets: Vec<u32> = Vec::new();
    let mut iseen: Vec<u64> = vec![0u64; n_nodes.div_ceil(64)];
    let mut ispare = NodeWorklist::new();
    suite.case("flatten_frontier/iteration-pooled", 1, iters, || {
        let edges = wl.total_edges(); // O(1) cached sum
        flatten_frontier_into(&g, wl.nodes(), &mut isrc, &mut ieid);
        ioffsets.clear();
        ioffsets.push(0u32);
        let mut acc = 0u32;
        for &d in wl.degrees() {
            acc += d;
            ioffsets.push(acc);
        }
        ispare.clear();
        for &s in &isrc {
            let (w, b) = (s as usize / 64, s as usize % 64);
            if iseen[w] & (1 << b) == 0 {
                iseen[w] |= 1 << b;
                ispare.push(s, g.degree(s));
            }
        }
        for &s in ispare.nodes() {
            iseen[s as usize / 64] = 0; // clear only touched words
        }
        black_box((ieid.len(), ioffsets.len()));
        format!("{edges} edges, {} condensed", ispare.len())
    });

    let (src, eid) = flatten_frontier(&g, &nodes);
    let total = src.len();

    suite.case("block_offsets/524k-edges", 1, iters, || {
        let off = block_offsets(total, dev.max_resident_threads);
        let n = off.len();
        black_box(off);
        format!("{n} lanes")
    });
    let mut boff: Vec<u32> = Vec::new();
    suite.case("block_offsets_into/524k-edges", 1, iters, || {
        block_offsets_into(total, dev.max_resident_threads, &mut boff);
        let n = black_box(boff.len());
        format!("{n} lanes")
    });

    suite.case("native_relax/524k-batch", 1, iters, || {
        let ds = vec![5u32; total];
        let w = vec![3u32; total];
        let c = NativeRelaxer.candidates(&ds, &w).unwrap();
        black_box(c);
        format!("{total} candidates")
    });

    suite.case("launch_interpret/bs-kernel", 1, iters, || {
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
        ctx.dist = vec![INF; g.num_nodes()];
        ctx.dist[0] = 0;
        let mut offsets = Vec::with_capacity(nodes.len() + 1);
        offsets.push(0u32);
        let mut acc = 0u32;
        for &n in &nodes {
            acc += g.degree(n);
            offsets.push(acc);
        }
        let work = KernelWork {
            name: "bench",
            src: src.clone(),
            eid: eid.clone(),
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let r = ctx.launch(&g, &work, None).unwrap();
        let n = r.updated.len();
        black_box(r);
        format!("{n} updates")
    });

    suite.case("condense/524k-dupes", 1, iters, || {
        let mut cwl = NodeWorklist::new();
        for e in 0..total as u32 {
            cwl.push(e % 65_536, 8);
        }
        let removed = cwl.condense();
        black_box(cwl);
        format!("{removed} removed")
    });

    suite.case("ns_split/rmat16", 1, iters, || {
        let d = lonestar_lb::strategies::mdt::auto_mdt(&g, 10);
        let s = lonestar_lb::strategies::node_split::split_graph(&g, d);
        let msg = format!("{} splits", s.split_nodes);
        black_box(s);
        msg
    });

    // -- the batched-serving iteration loop's host step: merge B query
    //    frontiers and extract each query's view back out. Legacy built a
    //    BTreeMap and a fresh worklist per query per iteration; the pooled
    //    builder sorts a reused pair buffer in place.
    const B: usize = 16;
    let per = n_nodes / B;
    let frontiers: Vec<NodeWorklist> = (0..B)
        .map(|q| {
            let mut f = NodeWorklist::new();
            for n in (q * per) as u32..((q + 1) * per) as u32 {
                f.push(n, g.degree(n));
            }
            f
        })
        .collect();
    suite.case("serving-iter/merge+extract-legacy", 1, iters, || {
        let pairs: Vec<(usize, &NodeWorklist)> = frontiers.iter().enumerate().collect();
        let m = MergedWorklist::from_frontiers_btree(&g, &pairs);
        let mut extracted = 0usize;
        for q in 0..B {
            extracted += m.query_frontier(q).len();
        }
        black_box(extracted);
        format!("{} merged, {extracted} extracted", m.len())
    });
    let mut builder = MergedBuilder::new();
    let mut merged = MergedWorklist::default();
    let mut view = NodeWorklist::new();
    suite.case("serving-iter/merge+extract-pooled", 1, iters, || {
        builder.begin();
        for (q, f) in frontiers.iter().enumerate() {
            builder.add(q, f);
        }
        builder.finish_into(&g, &mut merged);
        let mut extracted = 0usize;
        for q in 0..B {
            merged.query_frontier_into(q, &mut view);
            extracted += view.len();
        }
        black_box(extracted);
        format!("{} merged, {extracted} extracted", merged.len())
    });

    // -- end-to-end serving on a smaller graph, warm graph-keyed cache
    //    (absolute number for the PR-over-PR trajectory).
    let gs = Arc::new(rmat(12, 8 << 12, RmatParams::default(), 11).expect("rmat12"));
    let queries = synthetic_queries(&gs, 8, 0.5, 7);
    let cache = GraphCache::new();
    let cfg = ServeConfig::default();
    suite.case("serving/serve-8q-warm-cache", 1, iters, || {
        let report = serve_with_cache(&gs, &queries, &cfg, &cache).expect("serve");
        let t = report.totals();
        black_box(report.query_count());
        format!(
            "{} iters, scratch {} reused / {} created",
            t.iterations, t.scratch_reused, t.scratch_created
        )
    });

    // XLA relaxer (the production backend) — skipped without artifacts.
    match lonestar_lb::runtime::XlaRelaxer::load("artifacts") {
        Ok(mut xla) => {
            suite.case("xla_relax/524k-batch", 1, iters, || {
                let ds = vec![5u32; total];
                let w = vec![3u32; total];
                let c = xla.candidates(&ds, &w).unwrap();
                black_box(c);
                format!("{total} candidates via PJRT")
            });
        }
        Err(e) => println!("(xla_relax skipped: {e})"),
    }

    let results = suite.finish();

    let flatten_micro = mean_of(&results, "flatten/two-pass-legacy")
        / mean_of(&results, "flatten/single-pass-pooled");
    let iteration_overhead = mean_of(&results, "flatten_frontier/iteration-legacy")
        / mean_of(&results, "flatten_frontier/iteration-pooled");
    let serving_merge = mean_of(&results, "serving-iter/merge+extract-legacy")
        / mean_of(&results, "serving-iter/merge+extract-pooled");
    println!(
        "ratios: flatten micro {flatten_micro:.2}x, iteration overhead \
         {iteration_overhead:.2}x, serving merge {serving_merge:.2}x"
    );
    common::write_bench_json(
        "hotpath",
        &results,
        &[
            ("flatten_micro_speedup", flatten_micro),
            ("iteration_overhead_speedup", iteration_overhead),
            ("serving_merge_speedup", serving_merge),
        ],
    );

    // The acceptance floors. The serving merge comparison is structural:
    // the legacy half builds a real BTreeMap (a heap node per distinct
    // frontier node, kept in-tree as `from_frontiers_btree`) plus a fresh
    // worklist per extracted query, where the pooled builder sorts a
    // reused flat buffer in place — asserted at the full 1.3x target.
    // The iteration-overhead comparison stacks a worklist clone, the
    // inspector re-sum, a second degree walk, per-node CSR lookups and
    // doubling-growth reallocations on top of fill work both halves
    // share; its in-bench floor is set conservatively at 1.1x (the fill
    // dilutes the ratio on fast allocators) and the 1.3x trajectory
    // target is arbitrated by the committed BENCH_hotpath.json baseline
    // + CI gate once a real measurement lands. `BENCH_SKIP_FLOORS=1`
    // bypasses both panics for exploratory runs on noisy machines.
    if std::env::var_os("BENCH_SKIP_FLOORS").is_none() {
        assert!(
            iteration_overhead >= 1.1,
            "per-iteration overhead speedup {iteration_overhead:.2}x fell below the 1.1x floor"
        );
        assert!(
            serving_merge >= 1.3,
            "serving merge+extract speedup {serving_merge:.2}x fell below the 1.3x floor"
        );
    }
}
