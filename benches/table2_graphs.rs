//! Bench: regenerate **Table II** — the graph suite with degree statistics,
//! timing generation and stats computation per graph (the substrate cost).

use lonestar_lb::figures::{table2, FigureOpts};
use lonestar_lb::graph::generators::paper_suite;
use lonestar_lb::graph::stats::DegreeStats;
use lonestar_lb::graph::Graph;
use lonestar_lb::util::bench::{black_box, BenchSuite};

#[path = "common/mod.rs"]
mod common;

fn main() {
    let scale = common::scale_from_env();
    let iters = common::iters_from_env();
    let opts = FigureOpts {
        scale,
        ..Default::default()
    };

    let mut stdout = std::io::stdout().lock();
    let rows = table2(&opts, &mut stdout).expect("table2");
    drop(stdout);

    let mut suite = BenchSuite::new("table2: generation + stats cost");
    for entry in paper_suite(scale) {
        suite.case(&format!("generate/{}", entry.name), 0, iters, || {
            let g = entry.spec.generate(opts.seed).expect("generate");
            let msg = format!("{} edges", g.num_edges());
            black_box(g);
            msg
        });
        let g = entry.spec.generate(opts.seed).expect("generate");
        suite.case(&format!("stats/{}", entry.name), 1, iters, || {
            let st = DegreeStats::of(&g);
            black_box(st);
            format!("max={} sigma={:.1}", st.max, st.stddev)
        });
    }
    suite.finish();

    // Shape: the skew ordering of Table II (road << ER << rmat <= Graph500).
    let sigma = |name: &str| {
        rows.iter()
            .find(|r| r.graph.contains(name))
            .map(|r| r.sigma)
            .unwrap_or(0.0)
    };
    assert!(sigma("road") < sigma("ER"), "road must be flatter than ER");
    assert!(sigma("ER") < sigma("rmat"), "ER must be flatter than rmat");
    assert!(
        sigma("rmat") < sigma("Graph500"),
        "rmat must be flatter than Graph500"
    );
    println!("Table II skew ordering holds: road < ER < rmat < Graph500");
}
