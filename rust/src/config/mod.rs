//! Experiment configuration files for the launcher.
//!
//! The format is a minimal `key = value` dialect (INI-like, `#` comments)
//! parsed in-repo — the offline build carries no TOML dependency. See
//! `configs/*.conf` for shipped examples:
//!
//! ```text
//! # sssp strategy sweep over the small rmat graph
//! name       = rmat-sweep
//! graph      = suite:rmat16            # or file:PATH, rmat:10x8, er:10x4, road:64x64, g500:10
//! scale      = small                   # tiny | small | paper (suite graphs)
//! seed       = 20170101
//! algos      = sssp,bfs
//! strategies = BS,EP,WD,NS,HP,AD      # or "all"; composed schedules
//!                                      #  (warp/merge-path, ...) mix in
//! schedule   = warp/merge-path         # shorthand: run exactly this
//!                                      #  composed schedule (overrides
//!                                      #  `strategies`)
//! adaptive_schedules = warp/merge-path,block/histogram-binned
//!                                      # composed candidates the AD policy
//!                                      #  weighs alongside the five
//! source     = 0
//! push_policy = chunked                # chunked | per-edge
//! enforce_budget = false
//! backend    = native                  # native | xla | xla:DIR
//! histogram_bins = 10
//! adaptive_policy = cost               # cost | heuristic | round-robin (AD only)
//! batch_size = 8                       # serve: queries per batch
//! shards     = 1                       # serve: simulated devices per batch
//! devices    = k20c,k40               # serve: one DeviceSpec per shard
//!                                      #  (overrides `shards`; heterogeneous OK)
//! max_batch  = 64                      # serve: concurrent queries per shard
//!                                      #  (>64 widens the merged-worklist tag)
//! arrival_rate = 2.0                   # serve: queries per simulated ms
//!                                      #  (> 0 switches on the scheduler)
//! queue_cap  = 64                      # serve: admission-queue bound
//! queue_policy = drop                  # drop | block at a full queue
//! workers    = 4                       # serve: shard worker threads (default: one per shard)
//! fault_spec = stall:shard=1,at=2ms,for=1ms  # serve: fault-injection plan
//!                                      #  (see serving::FaultPlan for the grammar)
//! deadline_ms = 20                     # serve: per-query deadline (0 = off)
//! max_retries = 3                      # serve: attempts after the first
//! retry_backoff_ms = 1                 # serve: base of the exponential backoff
//! trace_out  = trace.json              # write a Chrome trace-event file
//! metrics_out = metrics.prom           # write Prometheus text exposition
//! profile_out = profile.json           # write the load-imbalance profile
//! ```
//!
//! Unknown keys are rejected with the nearest valid key named in the
//! error (`unknown config key "queu_cap"; did you mean "queue_cap"?`), so
//! a typo never silently runs the default experiment.

use crate::algorithms::AlgoKind;
use crate::coordinator::engine::Backend;
use crate::coordinator::RunConfig;
use crate::error::{Error, Result};
use crate::graph::generators::{paper_suite, GraphSpec, SuiteScale};
use crate::strategies::{StrategyKind, StrategyParams};
use crate::worklist::chunking::PushPolicy;
use std::collections::BTreeMap;
use std::path::Path;

/// Where the input graph comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphSource {
    /// Load from a file (`.gr`, `.bin`, edge list).
    File(String),
    /// A named entry of the paper suite.
    Suite(String),
    /// An explicit recipe.
    Spec(GraphSpec),
}

impl GraphSource {
    /// Parse `file:PATH`, `suite:NAME`, `rmat:SxE`, `er:SxE`, `road:RxC`,
    /// `g500:S`.
    pub fn parse(text: &str) -> Result<Self> {
        let (kind, arg) = text
            .split_once(':')
            .ok_or_else(|| Error::Config(format!("graph spec {text:?} needs kind:arg")))?;
        let dims = |s: &str| -> Result<(usize, usize)> {
            let (a, b) = s
                .split_once('x')
                .ok_or_else(|| Error::Config(format!("expected AxB in {s:?}")))?;
            Ok((
                a.parse().map_err(|_| Error::Config(format!("bad number {a:?}")))?,
                b.parse().map_err(|_| Error::Config(format!("bad number {b:?}")))?,
            ))
        };
        match kind {
            "file" => Ok(GraphSource::File(arg.to_string())),
            "suite" => Ok(GraphSource::Suite(arg.to_string())),
            "rmat" => {
                let (s, e) = dims(arg)?;
                Ok(GraphSource::Spec(GraphSpec::Rmat {
                    scale: s as u32,
                    edge_factor: e,
                }))
            }
            "er" => {
                let (s, e) = dims(arg)?;
                Ok(GraphSource::Spec(GraphSpec::ErdosRenyi {
                    scale: s as u32,
                    edge_factor: e,
                }))
            }
            "road" => {
                let (r, c) = dims(arg)?;
                Ok(GraphSource::Spec(GraphSpec::Road { rows: r, cols: c }))
            }
            "g500" => Ok(GraphSource::Spec(GraphSpec::Graph500 {
                scale: arg
                    .parse()
                    .map_err(|_| Error::Config(format!("bad scale {arg:?}")))?,
                seed_offset: 0,
            })),
            other => Err(Error::Config(format!("unknown graph kind {other:?}"))),
        }
    }

    /// Materialize the graph.
    pub fn load(&self, scale: SuiteScale, seed: u64) -> Result<crate::graph::Csr> {
        match self {
            GraphSource::File(path) => crate::graph::io::load(path),
            GraphSource::Spec(spec) => spec.generate(seed),
            GraphSource::Suite(name) => {
                let suite = paper_suite(scale);
                let entry = suite
                    .iter()
                    .find(|e| e.name == *name)
                    .ok_or_else(|| {
                        Error::Config(format!(
                            "no suite graph named {name:?}; available: {}",
                            suite
                                .iter()
                                .map(|e| e.name.as_str())
                                .collect::<Vec<_>>()
                                .join(", ")
                        ))
                    })?;
                entry.spec.generate(seed)
            }
        }
    }
}

/// Parse a suite-scale name.
pub fn parse_scale(s: &str) -> Result<SuiteScale> {
    match s {
        "tiny" => Ok(SuiteScale::Tiny),
        "small" => Ok(SuiteScale::Small),
        "paper" => Ok(SuiteScale::Paper),
        other => Err(Error::Config(format!("unknown scale {other:?}"))),
    }
}

/// Parse an algorithm name.
pub fn parse_algo(s: &str) -> Result<AlgoKind> {
    match s {
        "bfs" => Ok(AlgoKind::Bfs),
        "sssp" => Ok(AlgoKind::Sssp),
        other => Err(Error::Config(format!("unknown algo {other:?}"))),
    }
}

/// Parse a strictly positive integer (the `batch_size` / `shards` config
/// keys and their CLI flags). `what` names the offending key in the error.
pub fn parse_positive(v: &str, what: &str) -> Result<usize> {
    v.parse()
        .ok()
        .filter(|&n: &usize| n > 0)
        .ok_or_else(|| Error::Config(format!("{what} expects a positive integer, got {v:?}")))
}

/// Parse and validate a comma-separated device list (the `devices`
/// config key and the CLI's `--devices`) into trimmed preset names —
/// every name is checked against [`crate::sim::DeviceSpec::by_name`]
/// here, once, so config parsing, flag handling and
/// [`ExperimentConfig::device_pool`] all share one validation site.
pub fn parse_device_names(v: &str) -> Result<Vec<String>> {
    let names: Vec<String> = v.split(',').map(|s| s.trim().to_string()).collect();
    if names.is_empty() {
        return Err(Error::Config("devices expects at least one name".into()));
    }
    for name in &names {
        crate::sim::DeviceSpec::by_name(name)?;
    }
    Ok(names)
}

/// Parse an adaptive-policy name (the `adaptive_policy` config key and the
/// CLI's `--adaptive-policy`).
pub fn parse_adaptive_policy(s: &str) -> Result<crate::adaptive::AdaptivePolicyKind> {
    use crate::adaptive::AdaptivePolicyKind;
    match s {
        "cost" | "cost-model" => Ok(AdaptivePolicyKind::CostModel),
        "heuristic" => Ok(AdaptivePolicyKind::Heuristic),
        "round-robin" => Ok(AdaptivePolicyKind::RoundRobin),
        other => Err(Error::Config(format!("unknown adaptive policy {other:?}"))),
    }
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub graph: GraphSource,
    pub scale: SuiteScale,
    pub seed: u64,
    pub algos: Vec<AlgoKind>,
    pub strategies: Vec<StrategyKind>,
    pub source: u32,
    pub push_policy: PushPolicy,
    pub enforce_budget: bool,
    pub backend: Backend,
    pub params: StrategyParams,
    /// Queries per serving batch (`serve` subcommand).
    pub batch_size: usize,
    /// Simulated devices each serving batch shards across (used when
    /// `devices` is not given: that many default K20c shards).
    pub shards: usize,
    /// Explicit per-shard device presets (heterogeneous pools); overrides
    /// `shards` when non-empty.
    pub devices: Vec<String>,
    /// Concurrent queries one shard's batch engine carries (the merged
    /// worklist grows one tag word per 64).
    pub max_batch: usize,
    /// Mean arrival rate of the continuous driver, queries per simulated
    /// millisecond. `0` keeps the legacy pre-materialized batch mode.
    pub arrival_rate: f64,
    /// Bound of the scheduler's admission queue.
    pub queue_cap: usize,
    /// Overflow policy at a full admission queue.
    pub queue_policy: crate::serving::OverflowPolicy,
    /// Worker threads running the scheduler's shard engines; `0` (the
    /// default) means one per shard. Any value yields byte-identical
    /// output — it only changes how many cores the pool uses.
    pub workers: usize,
    /// Fault-injection spec for the scheduler path (see
    /// [`crate::serving::FaultPlan::parse`] for the grammar); `None` runs
    /// fault-free. CLI `--fault-spec` overrides.
    pub fault_spec: Option<String>,
    /// Per-query deadline in simulated ms (`0` disables): a query not
    /// launched in time is shed with a counted outcome.
    pub deadline_ms: f64,
    /// Serving attempts after the first before a query is failed.
    pub max_retries: u32,
    /// Base of the exponential virtual-time retry backoff, ms.
    pub retry_backoff_ms: f64,
    /// Chrome trace-event JSON output path (`run`/`serve`); CLI
    /// `--trace-out` overrides.
    pub trace_out: Option<String>,
    /// Prometheus text-exposition output path (`run`/`serve`); CLI
    /// `--metrics-out` overrides.
    pub metrics_out: Option<String>,
    /// Load-imbalance profile JSON output path (`run`/`serve`); CLI
    /// `--profile-out` overrides. Setting it attaches a trace sink even
    /// when `trace_out` is absent.
    pub profile_out: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            graph: GraphSource::Suite("rmat16".into()),
            scale: SuiteScale::Small,
            seed: crate::graph::generators::suite::DEFAULT_SEED,
            algos: vec![AlgoKind::Sssp],
            strategies: StrategyKind::ALL.to_vec(),
            source: 0,
            push_policy: PushPolicy::Chunked,
            enforce_budget: false,
            backend: Backend::Native,
            params: StrategyParams::default(),
            batch_size: 8,
            shards: 1,
            devices: Vec::new(),
            max_batch: crate::serving::MAX_QUERIES_PER_SHARD,
            arrival_rate: 0.0,
            queue_cap: 64,
            queue_policy: crate::serving::OverflowPolicy::Drop,
            workers: 0,
            fault_spec: None,
            deadline_ms: 0.0,
            max_retries: 3,
            retry_backoff_ms: 1.0,
            trace_out: None,
            metrics_out: None,
            profile_out: None,
        }
    }
}

/// Every key [`ExperimentConfig::parse`] accepts — the suggestion list for
/// unknown-key errors. Aliases (`algo`, `strategy`) are included so a typo
/// near either form resolves to something typeable.
const KNOWN_KEYS: &[&str] = &[
    "name",
    "graph",
    "scale",
    "seed",
    "algos",
    "algo",
    "strategies",
    "strategy",
    "schedule",
    "adaptive_schedules",
    "source",
    "push_policy",
    "enforce_budget",
    "backend",
    "histogram_bins",
    "mdt",
    "max_threads",
    "adaptive_policy",
    "batch_size",
    "shards",
    "devices",
    "max_batch",
    "arrival_rate",
    "queue_cap",
    "queue_policy",
    "workers",
    "fault_spec",
    "deadline_ms",
    "max_retries",
    "retry_backoff_ms",
    "trace_out",
    "metrics_out",
    "profile_out",
];

/// Levenshtein distance, O(a·b) with two rows — fine for config-key-sized
/// strings, and only ever run on the error path.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The valid config key closest to `unknown` (ties go to the first in
/// [`KNOWN_KEYS`] order).
fn nearest_key(unknown: &str) -> &'static str {
    KNOWN_KEYS
        .iter()
        .min_by_key(|k| edit_distance(unknown, k))
        .copied()
        .unwrap_or("name")
}

impl ExperimentConfig {
    /// Parse the `key = value` config dialect.
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv: BTreeMap<String, String> = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            kv.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }

        let mut cfg = ExperimentConfig::default();
        // Applied after the loop: `schedule` must override `strategies`
        // regardless of the BTreeMap's key order.
        let mut schedule_override: Option<crate::strategies::Schedule> = None;
        for (k, v) in kv {
            match k.as_str() {
                "name" => cfg.name = v,
                "graph" => cfg.graph = GraphSource::parse(&v)?,
                "scale" => cfg.scale = parse_scale(&v)?,
                "seed" => {
                    cfg.seed = v
                        .parse()
                        .map_err(|_| Error::Config(format!("bad seed {v:?}")))?
                }
                "algos" | "algo" => {
                    cfg.algos = v
                        .split(',')
                        .map(|s| parse_algo(s.trim()))
                        .collect::<Result<_>>()?
                }
                "strategies" | "strategy" => {
                    cfg.strategies = if v == "all" {
                        StrategyKind::ALL_WITH_ADAPTIVE.to_vec()
                    } else {
                        v.split(',')
                            .map(|s| s.trim().parse())
                            .collect::<Result<_>>()?
                    }
                }
                "schedule" => {
                    // Shorthand for running exactly one composed schedule
                    // (the `--schedule` grammar); parses through the same
                    // `granularity/order` path as a `strategies` entry.
                    schedule_override = Some(v.parse()?);
                }
                "adaptive_schedules" => {
                    cfg.params.composed_candidates = v
                        .split(',')
                        .map(|s| s.trim().parse())
                        .collect::<Result<_>>()?
                }
                "source" => {
                    cfg.source = v
                        .parse()
                        .map_err(|_| Error::Config(format!("bad source {v:?}")))?
                }
                "push_policy" => {
                    cfg.push_policy = match v.as_str() {
                        "chunked" => PushPolicy::Chunked,
                        "per-edge" => PushPolicy::PerEdge,
                        other => {
                            return Err(Error::Config(format!("bad push_policy {other:?}")))
                        }
                    }
                }
                "enforce_budget" => {
                    cfg.enforce_budget = v
                        .parse()
                        .map_err(|_| Error::Config(format!("bad bool {v:?}")))?
                }
                "backend" => {
                    cfg.backend = match v.as_str() {
                        "native" => Backend::Native,
                        "xla" => Backend::Xla { dir: None },
                        other => match other.split_once(':') {
                            Some(("xla", dir)) => Backend::Xla {
                                dir: Some(dir.to_string()),
                            },
                            _ => return Err(Error::Config(format!("bad backend {other:?}"))),
                        },
                    }
                }
                "histogram_bins" => {
                    cfg.params.histogram_bins = v
                        .parse()
                        .map_err(|_| Error::Config(format!("bad histogram_bins {v:?}")))?
                }
                "mdt" => {
                    cfg.params.mdt_override = Some(
                        v.parse()
                            .map_err(|_| Error::Config(format!("bad mdt {v:?}")))?,
                    )
                }
                "max_threads" => {
                    cfg.params.max_threads = Some(
                        v.parse()
                            .map_err(|_| Error::Config(format!("bad max_threads {v:?}")))?,
                    )
                }
                "adaptive_policy" => {
                    cfg.params.adaptive_policy = parse_adaptive_policy(&v)?;
                }
                "batch_size" => cfg.batch_size = parse_positive(&v, "batch_size")?,
                "shards" => cfg.shards = parse_positive(&v, "shards")?,
                "devices" => cfg.devices = parse_device_names(&v)?,
                "max_batch" => cfg.max_batch = parse_positive(&v, "max_batch")?,
                "arrival_rate" => {
                    cfg.arrival_rate = v
                        .parse()
                        .ok()
                        .filter(|r: &f64| r.is_finite() && *r >= 0.0)
                        .ok_or_else(|| {
                            Error::Config(format!("bad arrival_rate {v:?} (queries/ms, >= 0)"))
                        })?
                }
                "queue_cap" => cfg.queue_cap = parse_positive(&v, "queue_cap")?,
                "queue_policy" => {
                    cfg.queue_policy = crate::serving::OverflowPolicy::parse(&v)?
                }
                "workers" => cfg.workers = parse_positive(&v, "workers")?,
                "fault_spec" => cfg.fault_spec = Some(v),
                "deadline_ms" => {
                    cfg.deadline_ms = v
                        .parse()
                        .ok()
                        .filter(|d: &f64| d.is_finite() && *d >= 0.0)
                        .ok_or_else(|| {
                            Error::Config(format!("bad deadline_ms {v:?} (ms, >= 0; 0 = off)"))
                        })?
                }
                "max_retries" => {
                    cfg.max_retries = v
                        .parse()
                        .map_err(|_| Error::Config(format!("bad max_retries {v:?}")))?
                }
                "retry_backoff_ms" => {
                    cfg.retry_backoff_ms = v
                        .parse()
                        .ok()
                        .filter(|d: &f64| d.is_finite() && *d >= 0.0)
                        .ok_or_else(|| {
                            Error::Config(format!("bad retry_backoff_ms {v:?} (ms, >= 0)"))
                        })?
                }
                "trace_out" => cfg.trace_out = Some(v),
                "metrics_out" => cfg.metrics_out = Some(v),
                "profile_out" => cfg.profile_out = Some(v),
                other => {
                    return Err(Error::Config(format!(
                        "unknown config key {other:?}; did you mean {:?}?",
                        nearest_key(other)
                    )))
                }
            }
        }
        if let Some(sched) = schedule_override {
            cfg.strategies = vec![StrategyKind::Composed(sched)];
        }
        Ok(cfg)
    }

    /// Parse from a file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Resolve the serving device pool: the explicit `devices` list when
    /// given, else `shards` copies of the default K20c.
    pub fn device_pool(&self) -> Result<Vec<crate::sim::DeviceSpec>> {
        if self.devices.is_empty() {
            Ok(vec![crate::sim::DeviceSpec::k20c(); self.shards.max(1)])
        } else {
            self.devices
                .iter()
                .map(|name| crate::sim::DeviceSpec::by_name(name))
                .collect()
        }
    }

    /// Expand into the individual runs.
    pub fn run_configs(&self) -> Vec<RunConfig> {
        let mut out = Vec::new();
        for &algo in &self.algos {
            for &strategy in &self.strategies {
                out.push(RunConfig {
                    algo,
                    strategy,
                    source: self.source,
                    push_policy: self.push_policy,
                    enforce_budget: self.enforce_budget,
                    backend: self.backend.clone(),
                    params: self.params.clone(),
                    ..Default::default()
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::parse(
            r#"
            # comment
            name = demo
            graph = rmat:10x8
            seed = 42
            algos = bfs,sssp
            strategies = BS,EP
            source = 3
            push_policy = per-edge
            enforce_budget = true
            backend = xla:my-artifacts
            histogram_bins = 16
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "demo");
        assert_eq!(cfg.algos, vec![AlgoKind::Bfs, AlgoKind::Sssp]);
        assert_eq!(cfg.strategies, vec![StrategyKind::BS, StrategyKind::EP]);
        assert_eq!(cfg.source, 3);
        assert_eq!(cfg.push_policy, PushPolicy::PerEdge);
        assert!(cfg.enforce_budget);
        assert_eq!(
            cfg.backend,
            Backend::Xla {
                dir: Some("my-artifacts".into())
            }
        );
        assert_eq!(cfg.params.histogram_bins, 16);
        assert_eq!(cfg.run_configs().len(), 4);
        use crate::graph::Graph;
        let g = cfg.graph.load(cfg.scale, cfg.seed).unwrap();
        assert_eq!(g.num_nodes(), 1024);
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(ExperimentConfig::parse("bogus = 1").is_err());
    }

    #[test]
    fn unknown_keys_name_themselves_and_the_nearest_valid_key() {
        let err = ExperimentConfig::parse("queu_cap = 8").unwrap_err().to_string();
        assert!(err.contains("queu_cap"), "must name the offender: {err}");
        assert!(err.contains("queue_cap"), "must suggest the fix: {err}");
        let err = ExperimentConfig::parse("falt_spec = kill:shard=0,at=1ms")
            .unwrap_err()
            .to_string();
        assert!(err.contains("fault_spec"), "suggestion off: {err}");
        let err = ExperimentConfig::parse("retry_backof_ms = 2")
            .unwrap_err()
            .to_string();
        assert!(err.contains("retry_backoff_ms"), "suggestion off: {err}");
    }

    #[test]
    fn parses_fault_and_recovery_keys() {
        let cfg = ExperimentConfig::parse("").unwrap();
        assert_eq!(cfg.fault_spec, None);
        assert_eq!(cfg.deadline_ms, 0.0);
        assert_eq!(cfg.max_retries, 3);
        assert_eq!(cfg.retry_backoff_ms, 1.0);
        let cfg = ExperimentConfig::parse(
            "fault_spec = stall:shard=0,at=1ms,for=2ms\ndeadline_ms = 20\n\
             max_retries = 5\nretry_backoff_ms = 0.5\n",
        )
        .unwrap();
        assert_eq!(
            cfg.fault_spec.as_deref(),
            Some("stall:shard=0,at=1ms,for=2ms")
        );
        assert_eq!(cfg.deadline_ms, 20.0);
        assert_eq!(cfg.max_retries, 5);
        assert_eq!(cfg.retry_backoff_ms, 0.5);
        // max_retries = 0 is legal (fail on the first re-attempt).
        assert_eq!(ExperimentConfig::parse("max_retries = 0").unwrap().max_retries, 0);
        assert!(ExperimentConfig::parse("deadline_ms = -1").is_err());
        assert!(ExperimentConfig::parse("retry_backoff_ms = nan").is_err());
        assert!(ExperimentConfig::parse("max_retries = -2").is_err());
    }

    #[test]
    fn graph_source_variants() {
        assert_eq!(
            GraphSource::parse("file:/tmp/x.gr").unwrap(),
            GraphSource::File("/tmp/x.gr".into())
        );
        assert!(matches!(
            GraphSource::parse("road:8x9").unwrap(),
            GraphSource::Spec(GraphSpec::Road { rows: 8, cols: 9 })
        ));
        assert!(matches!(
            GraphSource::parse("g500:12").unwrap(),
            GraphSource::Spec(GraphSpec::Graph500 { scale: 12, .. })
        ));
        assert!(GraphSource::parse("nope").is_err());
        assert!(GraphSource::parse("rmat:banana").is_err());
    }

    #[test]
    fn suite_source_resolves_names() {
        let src = GraphSource::Suite("rmat10".into());
        assert!(src.load(SuiteScale::Tiny, 3).is_ok());
        let bad = GraphSource::Suite("nope".into());
        assert!(bad.load(SuiteScale::Tiny, 3).is_err());
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = ExperimentConfig::parse("").unwrap();
        assert_eq!(cfg.strategies.len(), 5);
        assert_eq!(cfg.algos, vec![AlgoKind::Sssp]);
        assert!(!cfg.enforce_budget);
    }

    #[test]
    fn parses_adaptive_strategy_and_policy() {
        let cfg = ExperimentConfig::parse(
            "strategies = AD\nadaptive_policy = heuristic\n",
        )
        .unwrap();
        assert_eq!(cfg.strategies, vec![StrategyKind::AD]);
        assert_eq!(
            cfg.params.adaptive_policy,
            crate::adaptive::AdaptivePolicyKind::Heuristic
        );
        assert!(ExperimentConfig::parse("adaptive_policy = bogus").is_err());
        // "all" now includes the adaptive selector.
        let all = ExperimentConfig::parse("strategies = all").unwrap();
        assert!(all.strategies.contains(&StrategyKind::AD));
        assert_eq!(all.strategies.len(), 6);
    }

    #[test]
    fn parses_composed_schedule_keys() {
        use crate::strategies::Schedule;
        // `schedule` pins exactly one composed strategy, overriding
        // `strategies` no matter where it appears in the file.
        let cfg = ExperimentConfig::parse(
            "strategies = BS,EP\nschedule = warp/merge-path\n",
        )
        .unwrap();
        assert_eq!(
            cfg.strategies,
            vec![StrategyKind::Composed(Schedule::WARP_MERGE_PATH)]
        );
        // Composed spellings also mix into a plain strategies list.
        let cfg = ExperimentConfig::parse("strategies = BS,block/histogram-binned\n").unwrap();
        assert_eq!(
            cfg.strategies,
            vec![
                StrategyKind::BS,
                StrategyKind::Composed(Schedule::BLOCK_HISTOGRAM)
            ]
        );
        // Adaptive candidate set.
        let cfg = ExperimentConfig::parse(
            "strategies = AD\nadaptive_schedules = warp/merge-path, block/merge-path\n",
        )
        .unwrap();
        assert_eq!(
            cfg.params.composed_candidates,
            vec![Schedule::WARP_MERGE_PATH, Schedule::BLOCK_MERGE_PATH]
        );
        // Default: empty candidate set, decision traces unchanged.
        assert!(ExperimentConfig::parse("").unwrap().params.composed_candidates.is_empty());
        // Unlowered / malformed compositions are rejected.
        assert!(ExperimentConfig::parse("schedule = cta/merge-path").is_err());
        assert!(ExperimentConfig::parse("schedule = warp").is_err());
        assert!(ExperimentConfig::parse("adaptive_schedules = warp/zigzag").is_err());
    }

    #[test]
    fn parses_serving_keys_with_sane_defaults() {
        let cfg = ExperimentConfig::parse("").unwrap();
        assert_eq!(cfg.batch_size, 8);
        assert_eq!(cfg.shards, 1);
        assert_eq!(cfg.max_batch, 64);
        assert_eq!(cfg.arrival_rate, 0.0);
        assert_eq!(cfg.queue_cap, 64);
        assert_eq!(cfg.queue_policy, crate::serving::OverflowPolicy::Drop);
        let cfg = ExperimentConfig::parse("batch_size = 16\nshards = 4\n").unwrap();
        assert_eq!(cfg.batch_size, 16);
        assert_eq!(cfg.shards, 4);
        assert!(ExperimentConfig::parse("batch_size = 0").is_err());
        assert!(ExperimentConfig::parse("shards = zero").is_err());
    }

    #[test]
    fn parses_scheduler_keys_and_device_pools() {
        let cfg = ExperimentConfig::parse(
            "devices = k20c, k40 ,gtx680\nmax_batch = 150\narrival_rate = 2.5\n\
             queue_cap = 12\nqueue_policy = block\nworkers = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.devices, vec!["k20c", "k40", "gtx680"]);
        assert_eq!(cfg.max_batch, 150);
        assert_eq!(cfg.arrival_rate, 2.5);
        assert_eq!(cfg.queue_cap, 12);
        assert_eq!(cfg.queue_policy, crate::serving::OverflowPolicy::Block);
        assert_eq!(cfg.workers, 2);
        // Absent => 0 => one worker per shard at scheduler construction.
        assert_eq!(ExperimentConfig::parse("").unwrap().workers, 0);
        assert!(ExperimentConfig::parse("workers = 0").is_err());
        let pool = cfg.device_pool().unwrap();
        assert_eq!(pool.len(), 3);
        assert_eq!(pool[1].name, "k40");
        // `shards` drives the pool only when `devices` is absent.
        let homog = ExperimentConfig::parse("shards = 3").unwrap();
        let pool = homog.device_pool().unwrap();
        assert_eq!(pool.len(), 3);
        assert!(pool.iter().all(|d| d.name == "k20c"));
        assert!(ExperimentConfig::parse("devices = h100").is_err());
        assert!(ExperimentConfig::parse("arrival_rate = -1").is_err());
        assert!(ExperimentConfig::parse("queue_policy = spill").is_err());
        assert!(ExperimentConfig::parse("queue_cap = 0").is_err());
        assert!(ExperimentConfig::parse("max_batch = 0").is_err());
    }

    #[test]
    fn parses_telemetry_keys() {
        let cfg = ExperimentConfig::parse("").unwrap();
        assert_eq!(cfg.trace_out, None);
        assert_eq!(cfg.metrics_out, None);
        assert_eq!(cfg.profile_out, None);
        let cfg = ExperimentConfig::parse(
            "trace_out = out/trace.json\nmetrics_out = out/metrics.prom\n\
             profile_out = out/profile.json\n",
        )
        .unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("out/trace.json"));
        assert_eq!(cfg.metrics_out.as_deref(), Some("out/metrics.prom"));
        assert_eq!(cfg.profile_out.as_deref(), Some("out/profile.json"));
    }
}
