//! Kernel execution: SIMT interpretation of a strategy's thread assignment
//! with exact cycle accounting.
//!
//! A strategy expresses each GPU kernel as a [`KernelWork`]: a flat batch of
//! edges plus a per-lane [`Assignment`]. [`ExecCtx::launch`] interprets the
//! kernel warp-by-warp in lockstep — computing real distance updates (this
//! is also the correctness path) while charging cycles to the
//! [`crate::sim::KernelSim`] model. Candidates come from the pluggable
//! [`Relaxer`] backend, so the identical scheduling code runs against the
//! native Rust implementation or the AOT-compiled XLA artifact.

use crate::algorithms::{AlgoKind, Relaxer};
use crate::arena::ScratchArena;
use crate::error::Result;
use crate::graph::{Csr, NodeId};
use crate::metrics::RunMetrics;
use crate::sim::{AccessPattern, DeviceSpec, KernelSim, MemoryTracker};
use crate::telemetry::{TraceEvent, TraceEventKind, TraceSink, NO_ID};
use crate::worklist::chunking::PushPolicy;

/// How batch positions are distributed over lanes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Assignment {
    /// Lane `l` processes positions `offsets[l] .. offsets[l+1]`
    /// (contiguous spans: BS, WD, NS, HP).
    Blocked(Vec<u32>),
    /// `num_threads` lanes; lane `l` processes positions
    /// `l, l + T, l + 2T, …` (EP's round-robin, which coalesces accesses —
    /// §II-B).
    Strided { num_threads: u32 },
    /// Chunked-strided hybrid for the composed merge-path schedules
    /// ([`crate::strategies::schedule`]): the batch is cut into contiguous
    /// spans (`offsets`, one span per `width`-lane group — a warp, or the
    /// warps of one block). Within its span, the lane with local rank
    /// `r = l % width` processes `offsets[c] + r, offsets[c] + r + width, …`
    /// so at every step a group's active lanes read consecutive positions
    /// — coalesced like [`Assignment::Strided`], but with merge-path's
    /// equal-span balance instead of a single global stride.
    WarpStrided { offsets: Vec<u32>, width: u32 },
}

impl Assignment {
    /// Number of lanes the kernel launches.
    pub fn lanes(&self) -> usize {
        match self {
            Assignment::Blocked(offsets) => offsets.len().saturating_sub(1),
            Assignment::Strided { num_threads } => *num_threads as usize,
            Assignment::WarpStrided { offsets, width } => {
                offsets.len().saturating_sub(1) * *width as usize
            }
        }
    }

    /// Items assigned to `lane` given `total` batch positions.
    #[inline]
    fn lane_count(&self, lane: usize, total: usize) -> u32 {
        match self {
            Assignment::Blocked(offsets) => offsets[lane + 1] - offsets[lane],
            Assignment::Strided { num_threads } => {
                let t = *num_threads as usize;
                if lane < total {
                    ((total - lane - 1) / t + 1) as u32
                } else {
                    0
                }
            }
            Assignment::WarpStrided { offsets, width } => {
                let w = *width as usize;
                let (chunk, rank) = (lane / w, (lane % w) as u32);
                let span = offsets[chunk + 1] - offsets[chunk];
                if rank < span {
                    (span - rank - 1) / width + 1
                } else {
                    0
                }
            }
        }
    }

    /// Batch position of `lane`'s `step`-th item.
    #[inline]
    fn position(&self, lane: usize, step: u32) -> usize {
        match self {
            Assignment::Blocked(offsets) => offsets[lane] as usize + step as usize,
            Assignment::Strided { num_threads } => lane + step as usize * *num_threads as usize,
            Assignment::WarpStrided { offsets, width } => {
                let w = *width as usize;
                offsets[lane / w] as usize + lane % w + step as usize * w
            }
        }
    }
}

/// What a successful update appends to the output worklist — determines the
/// element count for chunked-append atomic accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushTarget {
    /// Node-based worklists push one `(node, degree)` entry.
    Node,
    /// EP pushes all outgoing edges of the updated node.
    Edges,
    /// The composed merge-path kernels write a dense per-edge candidate
    /// slot instead of appending: no in-kernel worklist atomics at all —
    /// a separate compaction kernel (charged by the strategy as an aux
    /// launch) folds the slots into the next frontier. This is the
    /// classic advance/filter two-phase formulation (Gunrock, merge-path
    /// SpMV); it trades a fixed per-iteration aux cost for structurally
    /// uniform per-warp cycles.
    Dense,
}

/// One kernel launch, fully described.
#[derive(Debug, Clone)]
pub struct KernelWork {
    /// Kernel label for tracing.
    pub name: &'static str,
    /// Source node of each batch position.
    pub src: Vec<NodeId>,
    /// Global CSR edge index of each batch position.
    pub eid: Vec<u32>,
    /// Lane distribution.
    pub assignment: Assignment,
    /// Warp-level access pattern of the edge reads.
    pub access: AccessPattern,
    /// Per-edge bookkeeping cycles (WD offset walking, HP cursor checks).
    pub extra_cycles_per_edge: u64,
    /// Worklist element pushed on successful update.
    pub push: PushTarget,
}

/// Parent → children map produced by node splitting (NS). Children ids are
/// `>= first_child`; `children(p)` yields the child clones whose attributes
/// mirror parent `p`.
#[derive(Debug, Clone, Default)]
pub struct SplitMap {
    /// For each original node, the contiguous range of its child ids
    /// (empty range when unsplit).
    ranges: Vec<(u32, u32)>,
}

impl SplitMap {
    /// Build from per-parent child ranges (children occupy ids `>= n`).
    pub fn new(ranges: Vec<(u32, u32)>) -> Self {
        SplitMap { ranges }
    }

    /// Child ids of `parent` (empty for unsplit nodes or child ids).
    #[inline]
    pub fn children(&self, parent: NodeId) -> std::ops::Range<u32> {
        match self.ranges.get(parent as usize) {
            Some(&(a, b)) => a..b,
            None => 0..0,
        }
    }

    /// Total child nodes created.
    pub fn total_children(&self) -> u64 {
        self.ranges.iter().map(|&(a, b)| (b - a) as u64).sum()
    }

    /// True if no node was split.
    pub fn is_trivial(&self) -> bool {
        self.ranges.iter().all(|&(a, b)| a == b)
    }
}

/// Result of one launch: the nodes whose distance improved, in update order
/// (duplicates possible — worklist condensing handles them later).
#[derive(Debug, Default)]
pub struct LaunchResult {
    pub updated: Vec<NodeId>,
}

/// Mutable run state threaded through a strategy's kernel launches.
pub struct ExecCtx<'d> {
    pub dev: &'d DeviceSpec,
    pub mem: MemoryTracker,
    pub metrics: RunMetrics,
    pub algo: AlgoKind,
    pub push_policy: PushPolicy,
    pub relaxer: Box<dyn Relaxer + 'd>,
    /// Distance / level array. Node-splitting strategies size it to the
    /// transformed node count; entries `0..original_n` hold the answer.
    pub dist: Vec<u32>,
    /// Pooled scratch buffers for the per-iteration hot path: strategies
    /// check out flatten/offset/staging buffers here and return them when
    /// the launch retires, so steady-state iterations allocate nothing
    /// (see [`crate::arena`]).
    pub scratch: ScratchArena,
    /// Optional telemetry sink (the `--trace-out` seam): when attached,
    /// kernel launches and adaptive decisions are recorded as
    /// [`TraceEvent`]s on the shared virtual timeline. `None` costs one
    /// branch per would-be event; recording never allocates.
    pub trace: Option<&'d mut TraceSink>,
    /// Virtual instant (ps) this context's timeline starts at — the
    /// scheduler sets it to the batch-launch instant so engine events land
    /// inside the shard's busy interval.
    pub trace_base_ps: u64,
    /// Cycle watermark paired with `trace_base_ps`: cycles accumulated
    /// before the sink was attached do not shift the timeline.
    pub trace_base_cycles: u64,
    /// Shard id stamped on this context's events ([`NO_ID`] outside the
    /// sharded serving path; single-run tracing uses shard 0).
    pub trace_shard: u32,
}

impl<'d> ExecCtx<'d> {
    /// Fresh context with an unlimited memory budget.
    pub fn new(dev: &'d DeviceSpec, algo: AlgoKind, relaxer: Box<dyn Relaxer + 'd>) -> Self {
        ExecCtx {
            dev,
            mem: MemoryTracker::unlimited(),
            metrics: RunMetrics::default(),
            algo,
            push_policy: PushPolicy::default(),
            relaxer,
            dist: Vec::new(),
            scratch: ScratchArena::new(),
            trace: None,
            trace_base_ps: 0,
            trace_base_cycles: 0,
            trace_shard: NO_ID,
        }
    }

    /// Position on the shared virtual timeline: the trace base plus the
    /// cycles accumulated since the sink was attached, converted on this
    /// device's own clock (heterogeneous pools stay clock-neutral).
    pub fn trace_now_ps(&self) -> u64 {
        self.trace_base_ps
            + self
                .metrics
                .total_cycles()
                .saturating_sub(self.trace_base_cycles)
                * self.dev.ps_per_cycle()
    }

    /// Record an engine-side telemetry event. No-op without an attached
    /// sink; never allocates. `label` is a static tag (strategy / kernel
    /// name), `a`/`b` the kind-specific payload.
    #[inline]
    pub fn record_trace(&mut self, kind: TraceEventKind, label: &'static str, a: u64, b: u64) {
        if self.trace.is_none() {
            return;
        }
        let at_ps = self.trace_now_ps();
        let shard = self.trace_shard;
        if let Some(sink) = self.trace.as_deref_mut() {
            sink.record(TraceEvent {
                shard,
                a,
                b,
                label,
                ..TraceEvent::new(kind, at_ps)
            });
        }
    }

    /// Use the device's memory budget (simulation runs).
    pub fn with_budget(mut self, budget: u64) -> Self {
        self.mem = MemoryTracker::new(budget);
        self
    }

    /// Interpret one processing kernel: compute updates, charge cycles.
    ///
    /// `graph` is whatever graph the strategy runs on (possibly its split
    /// version); `mirror` carries NS's parent→children map so parent
    /// updates propagate to child clones (extra atomics, §III-B).
    pub fn launch(
        &mut self,
        graph: &Csr,
        work: &KernelWork,
        mirror: Option<&SplitMap>,
    ) -> Result<LaunchResult> {
        let total = work.src.len();
        debug_assert_eq!(total, work.eid.len());
        let trace_start_cycles = if self.trace.is_some() {
            self.metrics.total_cycles()
        } else {
            0
        };

        // Batch candidate computation from a snapshot of `dist` (threads
        // read global memory without ordering guarantees; min-fold below
        // keeps monotonicity). All staging buffers come from the scratch
        // arena, so a warm launch performs no heap allocation.
        let mut dist_src = self.scratch.take_u32();
        let mut wts = self.scratch.take_u32();
        for p in 0..total {
            dist_src.push(self.dist[work.src[p] as usize]);
            wts.push(self.algo.effective_weight(graph.edge_wt(work.eid[p])));
        }
        let mut cand = self.scratch.take_u32();
        self.relaxer.candidates_into(&dist_src, &wts, &mut cand)?;

        let lanes = work.assignment.lanes();
        let warp = self.dev.warp_size as usize;
        let sm_a = self.scratch.take_u64();
        let sm_b = self.scratch.take_u64();
        let mut ksim = KernelSim::new_with(self.dev, sm_a, sm_b);
        let mut result = LaunchResult {
            updated: self.scratch.take_u32(),
        };
        let mut dsts_buf: Vec<u32> = self.scratch.take_u32();

        let mut lane_counts: Vec<u32> = self.scratch.take_u32();
        for warp_start in (0..lanes).step_by(warp) {
            let warp_end = (warp_start + warp).min(lanes);
            lane_counts.clear();
            lane_counts.extend(
                (warp_start..warp_end).map(|l| work.assignment.lane_count(l, total)),
            );
            let max_steps = lane_counts.iter().copied().max().unwrap_or(0);
            if max_steps == 0 {
                continue;
            }
            let mut wsim = ksim.warp();
            for step in 0..max_steps {
                let mut active = 0u32;
                let mut append_atomics = 0u64;
                dsts_buf.clear();
                for (i, lane) in (warp_start..warp_end).enumerate() {
                    if lane_counts[i] <= step {
                        continue;
                    }
                    active += 1;
                    let pos = work.assignment.position(lane, step);
                    let dst = graph.edge_dst(work.eid[pos]);
                    let c = cand[pos];
                    if c < self.dist[dst as usize] {
                        self.dist[dst as usize] = c;
                        result.updated.push(dst);
                        self.metrics.updates += 1;
                        match work.push {
                            PushTarget::Node => {
                                dsts_buf.push(dst);
                                append_atomics += self.push_policy.append_atomics(1);
                            }
                            PushTarget::Edges => {
                                dsts_buf.push(dst);
                                append_atomics += self
                                    .push_policy
                                    .append_atomics(graph.degree(dst) as u64);
                            }
                            // Dense: the candidate lands in its own slot —
                            // no contended dst write, no append atomic.
                            PushTarget::Dense => {}
                        }
                        if let Some(m) = mirror {
                            for child in m.children(dst) {
                                // Mirror the parent's attribute onto the
                                // child clone (§III-B): one extra atomic
                                // per child, and the child re-enters the
                                // worklist so its edges get reprocessed.
                                if c < self.dist[child as usize] {
                                    self.dist[child as usize] = c;
                                    result.updated.push(child);
                                    append_atomics +=
                                        self.push_policy.append_atomics(1);
                                    dsts_buf.push(child);
                                }
                            }
                        }
                    }
                }
                if active == 0 {
                    continue;
                }
                wsim.step(active, work.access);
                wsim.atomics(&mut dsts_buf);
                wsim.append_atomics(append_atomics);
                if work.extra_cycles_per_edge > 0 {
                    wsim.extra(work.extra_cycles_per_edge * active as u64);
                }
            }
            ksim.commit(wsim);
        }

        // Snapshot the per-warp distribution before the sim is consumed;
        // everything in it is inline stack state, so this never allocates.
        let warp_stats = ksim.warp_stats();
        let (t, sm_a, sm_b) = ksim.finish_into();
        self.scratch.put_u64(sm_a);
        self.scratch.put_u64(sm_b);
        self.scratch.put_u32(dist_src);
        self.scratch.put_u32(wts);
        self.scratch.put_u32(cand);
        self.scratch.put_u32(dsts_buf);
        self.scratch.put_u32(lane_counts);
        self.metrics
            .charge_processing(t, self.dev.launch_overhead);
        self.metrics.absorb_warp_profile(&warp_stats);
        if self.trace.is_some() {
            // A complete slice covering exactly the cycles this launch
            // charged, placed so it ends at the current virtual instant,
            // followed by its load-imbalance profile at the same instant.
            // CV and occupancy are fixed-point ×1e6: the exporter has no
            // DeviceSpec, so device-dependent ratios are resolved here.
            let dur_ps = self.metrics.total_cycles().saturating_sub(trace_start_cycles)
                * self.dev.ps_per_cycle();
            let end_ps = self.trace_now_ps();
            let shard = self.trace_shard;
            let cv_micro = (warp_stats.cv() * 1e6).round() as u64;
            let occ_micro = (warp_stats.occupancy(self.dev) * 1e6).round() as u64;
            if let Some(sink) = self.trace.as_deref_mut() {
                let start_ps = end_ps.saturating_sub(dur_ps);
                sink.record(TraceEvent {
                    shard,
                    a: dur_ps,
                    b: total as u64,
                    c: warp_stats.max_cycles,
                    d: warp_stats.sum_cycles,
                    label: work.name,
                    ..TraceEvent::new(TraceEventKind::Kernel, start_ps)
                });
                sink.record(TraceEvent {
                    shard,
                    a: warp_stats.warps,
                    b: t.mem_transactions,
                    c: cv_micro,
                    d: occ_micro,
                    label: work.name,
                    ..TraceEvent::new(TraceEventKind::KernelProfile, start_ps)
                });
            }
        }
        Ok(result)
    }

    /// Return a retired launch's `updated` buffer to the scratch pool.
    /// Callers that skip this merely fall back to allocate-and-drop.
    pub fn recycle(&mut self, r: LaunchResult) {
        self.scratch.put_u32(r.updated);
    }

    /// Return a retired kernel's staging buffers (`src`, `eid` and blocked
    /// offsets) to the scratch pool.
    pub fn recycle_work(&mut self, work: KernelWork) {
        let KernelWork {
            src,
            eid,
            assignment,
            ..
        } = work;
        self.scratch.put_u32(src);
        self.scratch.put_u32(eid);
        match assignment {
            Assignment::Blocked(offsets) | Assignment::WarpStrided { offsets, .. } => {
                self.scratch.put_u32(offsets)
            }
            Assignment::Strided { .. } => {}
        }
    }

    /// Charge an auxiliary (overhead) kernel touching `items` elements
    /// coalesced with `per_item` extra ALU cycles — scan, `find_offsets`,
    /// worklist condensing, split preprocessing. The cost formula lives on
    /// [`DeviceSpec::aux_kernel_cycles`] so the adaptive cost model
    /// predicts exactly what execution charges.
    pub fn charge_aux_kernel(&mut self, items: u64, per_item: u64) {
        let dev = self.dev;
        // items spread over the device: warps of 32, coalesced streaming
        let warps = (items + dev.warp_size as u64 - 1) / dev.warp_size as u64;
        let t = crate::sim::KernelTime {
            cycles: dev.aux_kernel_cycles(items, per_item),
            warps,
            edge_steps: 0,
            atomics: 0,
            atomic_conflicts: 0,
            mem_transactions: warps,
        };
        self.metrics.charge_aux(t);
    }

    /// Flat overhead cycles attributed to the device timeline (host-side
    /// preprocessing such as histogramming or graph rebuilding).
    pub fn charge_overhead(&mut self, cycles: u64) {
        self.metrics.charge_overhead(cycles);
    }

    /// Snapshot peak memory and the scratch-arena counters into the
    /// metrics (call before reporting).
    pub fn finalize_metrics(&mut self) {
        self.metrics.peak_memory_bytes = self.mem.peak();
        let c = self.scratch.counters();
        self.metrics.scratch_created = c.buffers_created;
        self.metrics.scratch_reused = c.buffers_reused;
        self.metrics.scratch_peak_bytes = c.peak_bytes_pooled;
    }
}

/// Flatten a node frontier into the parallel `(src, eid)` arrays every
/// node-based kernel consumes — the concatenated adjacencies of the active
/// nodes, in worklist order — writing into caller-provided scratch. One
/// pass over the active nodes (the degree array is never walked twice) and
/// zero allocations once the buffers are warm. Shared by BS, WD, NS and HP.
pub fn flatten_frontier_into(
    g: &Csr,
    nodes: &[NodeId],
    src: &mut Vec<NodeId>,
    eid: &mut Vec<u32>,
) {
    src.clear();
    eid.clear();
    for &n in nodes {
        let first = g.first_edge(n);
        let deg = g.degree(n);
        src.resize(src.len() + deg as usize, n);
        eid.extend(first..first + deg);
    }
}

/// Allocating convenience wrapper around [`flatten_frontier_into`].
pub fn flatten_frontier(g: &Csr, nodes: &[NodeId]) -> (Vec<NodeId>, Vec<u32>) {
    let mut src = Vec::new();
    let mut eid = Vec::new();
    flatten_frontier_into(g, nodes, &mut src, &mut eid);
    (src, eid)
}

/// The pre-arena reference implementation: walks the degrees twice (sum
/// pass, then fill pass) and allocates fresh arrays per call. Kept as the
/// baseline `benches/hotpath.rs` measures the single-pass rewrite against
/// and as a differential oracle for [`flatten_frontier_into`].
pub fn flatten_frontier_two_pass(g: &Csr, nodes: &[NodeId]) -> (Vec<NodeId>, Vec<u32>) {
    let total: usize = nodes.iter().map(|&n| g.degree(n) as usize).sum();
    let mut src = Vec::with_capacity(total);
    let mut eid = Vec::with_capacity(total);
    for &n in nodes {
        let first = g.first_edge(n);
        for e in first..first + g.degree(n) {
            src.push(n);
            eid.push(e);
        }
    }
    (src, eid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::NativeRelaxer;
    use crate::graph::Graph;
    use crate::graph::Edge;
    use crate::INF;

    fn ctx<'d>(dev: &'d DeviceSpec) -> ExecCtx<'d> {
        ExecCtx::new(dev, AlgoKind::Sssp, Box::new(NativeRelaxer))
    }

    fn diamond() -> Csr {
        Csr::from_edges(
            4,
            &[
                Edge::new(0, 1, 1),
                Edge::new(0, 2, 4),
                Edge::new(1, 3, 2),
                Edge::new(2, 3, 1),
            ],
        )
        .unwrap()
    }

    #[test]
    fn blocked_assignment_positions() {
        let a = Assignment::Blocked(vec![0, 2, 5]);
        assert_eq!(a.lanes(), 2);
        assert_eq!(a.lane_count(0, 5), 2);
        assert_eq!(a.lane_count(1, 5), 3);
        assert_eq!(a.position(1, 2), 4);
    }

    #[test]
    fn strided_assignment_positions() {
        let a = Assignment::Strided { num_threads: 4 };
        assert_eq!(a.lanes(), 4);
        // 10 items over 4 threads round robin: lane 0 gets 0,4,8 (3 items)
        assert_eq!(a.lane_count(0, 10), 3);
        assert_eq!(a.lane_count(2, 10), 2);
        assert_eq!(a.position(1, 2), 9);
    }

    #[test]
    fn strided_covers_all_positions_once() {
        let a = Assignment::Strided { num_threads: 7 };
        let total = 23;
        let mut seen = vec![false; total];
        for lane in 0..a.lanes() {
            for s in 0..a.lane_count(lane, total) {
                let p = a.position(lane, s);
                assert!(!seen[p]);
                seen[p] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn warp_strided_assignment_positions() {
        // Two 4-lane chunks over 10 positions: spans [0,6) and [6,10).
        let a = Assignment::WarpStrided {
            offsets: vec![0, 6, 10],
            width: 4,
        };
        assert_eq!(a.lanes(), 8);
        // Chunk 0, rank 0: positions 0, 4 (2 items).
        assert_eq!(a.lane_count(0, 10), 2);
        assert_eq!(a.position(0, 1), 4);
        // Chunk 0, rank 2: positions 2 only (span 6 → ranks 2,3 get 1).
        assert_eq!(a.lane_count(2, 10), 1);
        // Chunk 1, rank 3: span 4 → 1 item at position 6 + 3.
        assert_eq!(a.lane_count(7, 10), 1);
        assert_eq!(a.position(7, 0), 9);
    }

    #[test]
    fn warp_strided_covers_all_positions_once() {
        let total = 23;
        let mut offsets = Vec::new();
        crate::strategies::partition::merge_path_offsets_into(total, 3, &mut offsets);
        let a = Assignment::WarpStrided { offsets, width: 4 };
        let mut seen = vec![false; total];
        for lane in 0..a.lanes() {
            for s in 0..a.lane_count(lane, total) {
                let p = a.position(lane, s);
                assert!(!seen[p], "position {p} hit twice");
                seen[p] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn warp_strided_empty_spans_idle_their_lanes() {
        let a = Assignment::WarpStrided {
            offsets: vec![0, 0, 3, 3],
            width: 2,
        };
        assert_eq!(a.lanes(), 6);
        assert_eq!(a.lane_count(0, 3), 0);
        assert_eq!(a.lane_count(1, 3), 0);
        assert_eq!(a.lane_count(2, 3), 2); // span [0,3) rank 0 → 0, 2
        assert_eq!(a.lane_count(3, 3), 1);
        assert_eq!(a.lane_count(4, 3), 0);
    }

    #[test]
    fn dense_push_skips_worklist_atomics_but_still_updates() {
        let g = diamond();
        let dev = DeviceSpec::k20c();
        let mut ex = ctx(&dev);
        ex.dist = vec![INF; 4];
        ex.dist[0] = 0;
        let (src, eid) = flatten_frontier(&g, &[0]);
        let n = src.len();
        let mut offsets = Vec::new();
        crate::strategies::partition::merge_path_offsets_into(n, 1, &mut offsets);
        let work = KernelWork {
            name: "test",
            src,
            eid,
            assignment: Assignment::WarpStrided {
                offsets,
                width: dev.warp_size,
            },
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Dense,
        };
        let r = ex.launch(&g, &work, None).unwrap();
        assert_eq!(ex.dist, vec![0, 1, 4, INF]);
        assert_eq!(r.updated, vec![1, 2]);
        assert_eq!(ex.metrics.updates, 2);
        assert_eq!(
            ex.metrics.atomics, 0,
            "dense relax performs no worklist atomics in-kernel"
        );
    }

    #[test]
    fn launch_relaxes_frontier() {
        let g = diamond();
        let dev = DeviceSpec::k20c();
        let mut ex = ctx(&dev);
        ex.dist = vec![INF; 4];
        ex.dist[0] = 0;
        let (src, eid) = flatten_frontier(&g, &[0]);
        let work = KernelWork {
            name: "test",
            assignment: Assignment::Blocked(vec![0, src.len() as u32]),
            src,
            eid,
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let r = ex.launch(&g, &work, None).unwrap();
        assert_eq!(ex.dist, vec![0, 1, 4, INF]);
        assert_eq!(r.updated, vec![1, 2]);
        assert!(ex.metrics.kernel_cycles > 0);
        assert_eq!(ex.metrics.updates, 2);
    }

    #[test]
    fn bfs_uses_unit_weights() {
        let g = diamond();
        let dev = DeviceSpec::k20c();
        let mut ex = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
        ex.dist = vec![INF; 4];
        ex.dist[0] = 0;
        let (src, eid) = flatten_frontier(&g, &[0]);
        let n = src.len() as u32;
        let work = KernelWork {
            name: "test",
            src,
            eid,
            assignment: Assignment::Blocked(vec![0, n]),
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        ex.launch(&g, &work, None).unwrap();
        assert_eq!(ex.dist[1], 1);
        assert_eq!(ex.dist[2], 1, "BFS must ignore the weight 4");
    }

    #[test]
    fn mirror_propagates_to_children() {
        // graph: 0 -> 1; node 1 has child 2 (clone)
        let g = Csr::from_edges(3, &[Edge::new(0, 1, 5)]).unwrap();
        let dev = DeviceSpec::k20c();
        let mut ex = ctx(&dev);
        ex.dist = vec![0, INF, INF];
        let split = SplitMap::new(vec![(0, 0), (2, 3), (0, 0)]);
        let work = KernelWork {
            name: "test",
            src: vec![0],
            eid: vec![0],
            assignment: Assignment::Blocked(vec![0, 1]),
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let r = ex.launch(&g, &work, Some(&split)).unwrap();
        assert_eq!(ex.dist, vec![0, 5, 5]);
        assert_eq!(r.updated, vec![1, 2]);
    }

    #[test]
    fn stale_candidates_never_regress() {
        // Two positions updating the same dst: the second, worse candidate
        // must not overwrite the better one (min-fold with live dist).
        let g = Csr::from_edges(3, &[Edge::new(0, 2, 1), Edge::new(1, 2, 9)]).unwrap();
        let dev = DeviceSpec::k20c();
        let mut ex = ctx(&dev);
        ex.dist = vec![0, 0, INF];
        let work = KernelWork {
            name: "test",
            src: vec![0, 1],
            eid: vec![0, 1],
            assignment: Assignment::Blocked(vec![0, 1, 2]),
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        ex.launch(&g, &work, None).unwrap();
        assert_eq!(ex.dist[2], 1);
    }

    #[test]
    fn single_pass_flatten_matches_two_pass_reference() {
        let g = diamond();
        for nodes in [vec![], vec![0u32], vec![0, 1, 2], vec![2, 0, 3, 1]] {
            let (s1, e1) = flatten_frontier(&g, &nodes);
            let (s2, e2) = flatten_frontier_two_pass(&g, &nodes);
            assert_eq!(s1, s2, "src diverged on {nodes:?}");
            assert_eq!(e1, e2, "eid diverged on {nodes:?}");
        }
    }

    #[test]
    fn repeated_launches_reuse_scratch() {
        let g = diamond();
        let dev = DeviceSpec::k20c();
        let mut ex = ctx(&dev);
        ex.dist = vec![INF; 4];
        ex.dist[0] = 0;
        for _ in 0..5 {
            ex.dist.iter_mut().skip(1).for_each(|d| *d = INF);
            let (src, eid) = flatten_frontier(&g, &[0]);
            let n = src.len() as u32;
            let work = KernelWork {
                name: "test",
                src,
                eid,
                assignment: Assignment::Blocked(vec![0, n]),
                access: AccessPattern::Coalesced,
                extra_cycles_per_edge: 0,
                push: PushTarget::Node,
            };
            let r = ex.launch(&g, &work, None).unwrap();
            ex.recycle(r);
            ex.recycle_work(work);
        }
        let c = *ex.scratch.counters();
        assert!(
            c.buffers_reused > c.buffers_created,
            "steady-state launches must hit the pool (created {}, reused {})",
            c.buffers_created,
            c.buffers_reused
        );
        ex.finalize_metrics();
        assert_eq!(ex.metrics.scratch_created, c.buffers_created);
        assert_eq!(ex.metrics.scratch_reused, c.buffers_reused);
    }

    #[test]
    fn aux_kernel_charges_overhead_only() {
        let dev = DeviceSpec::k20c();
        let mut ex = ctx(&dev);
        ex.charge_aux_kernel(1000, 2);
        assert_eq!(ex.metrics.kernel_cycles, 0);
        assert!(ex.metrics.overhead_cycles >= dev.launch_overhead);
    }
}
