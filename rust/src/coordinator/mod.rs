//! The L3 coordinator: the execution context strategies launch kernels
//! through, and the runner that drives a full BFS/SSSP computation.
//!
//! This module is the paper's host-side code: the `while inputWl.size() > 0`
//! loops of Figures 2 and 4 live in [`engine`], and the per-kernel SIMT
//! interpretation + cycle accounting lives in [`exec`].

pub mod engine;
pub mod exec;

pub use engine::{run, run_traced, RunConfig, RunResult};
pub use exec::{Assignment, ExecCtx, KernelWork, LaunchResult, PushTarget, SplitMap};
