//! The run driver: the paper's outer `while inputWl.size() > 0` loop,
//! wrapped with configuration, backend selection and metric finalization.

use crate::algorithms::{AlgoKind, NativeRelaxer, Relaxer};
use crate::error::{Error, Result};
use crate::graph::{Csr, Graph, NodeId};
use crate::metrics::RunMetrics;
use crate::sim::DeviceSpec;
use crate::strategies::{build_strategy, StrategyKind, StrategyParams};
use crate::worklist::chunking::PushPolicy;
use std::sync::Arc;
use std::time::Instant;

use super::ExecCtx;

/// Which relaxation backend computes the numeric hot path.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust candidates (simulation + oracle).
    #[default]
    Native,
    /// AOT-compiled Pallas/JAX artifact executed on the XLA CPU runtime.
    Xla {
        /// Artifact directory (default `artifacts/`).
        dir: Option<String>,
    },
}

/// Everything needed to run one strategy × algorithm × graph computation.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub algo: AlgoKind,
    pub strategy: StrategyKind,
    /// Source node.
    pub source: NodeId,
    pub push_policy: PushPolicy,
    pub device: DeviceSpec,
    /// Enforce the device memory budget (off for correctness runs).
    pub enforce_budget: bool,
    pub backend: Backend,
    pub params: StrategyParams,
    /// Safety valve on outer iterations.
    pub max_iterations: u32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algo: AlgoKind::Sssp,
            strategy: StrategyKind::BS,
            source: 0,
            push_policy: PushPolicy::default(),
            device: DeviceSpec::k20c(),
            enforce_budget: false,
            backend: Backend::Native,
            params: StrategyParams::default(),
            max_iterations: 1_000_000,
        }
    }
}

/// Outcome of a run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Final distances/levels for the original node ids.
    pub dist: Vec<u32>,
    pub metrics: RunMetrics,
}

/// Drive `cfg` over `graph` to convergence.
pub fn run(graph: &Arc<Csr>, cfg: &RunConfig) -> Result<RunResult> {
    run_traced(graph, cfg, None, 0)
}

/// [`run`] with an optional telemetry sink: kernel launches (and, for the
/// adaptive engine, strategy decisions / migrations) are recorded on the
/// device's virtual ps timeline starting at `base_ps` — the CLI threads a
/// running base through consecutive strategies so one `--trace-out` file
/// lays them out back-to-back.
pub fn run_traced(
    graph: &Arc<Csr>,
    cfg: &RunConfig,
    mut trace: Option<&mut crate::telemetry::TraceSink>,
    base_ps: u64,
) -> Result<RunResult> {
    if graph.num_nodes() == 0 {
        return Err(Error::InvalidGraph("empty graph".into()));
    }
    if cfg.source as usize >= graph.num_nodes() {
        return Err(Error::Config(format!(
            "source {} out of range (n = {})",
            cfg.source,
            graph.num_nodes()
        )));
    }

    let relaxer: Box<dyn Relaxer> = match &cfg.backend {
        Backend::Native => Box::new(NativeRelaxer),
        Backend::Xla { dir } => Box::new(crate::runtime::XlaRelaxer::load(
            dir.as_deref().unwrap_or("artifacts"),
        )?),
    };

    let host_start = Instant::now();
    let mut ctx = ExecCtx::new(&cfg.device, cfg.algo, relaxer);
    ctx.trace = trace.as_deref_mut();
    ctx.trace_base_ps = base_ps;
    ctx.trace_shard = 0;
    ctx.push_policy = cfg.push_policy;
    if cfg.enforce_budget {
        ctx = ctx.with_budget(cfg.device.memory_budget);
    }

    let mut strategy = build_strategy(cfg.strategy, graph.clone(), cfg.params.clone());
    strategy.init(&mut ctx, cfg.source)?;

    let mut outer = 0u32;
    while strategy.pending() > 0 {
        strategy.run_iteration(&mut ctx)?;
        outer += 1;
        if outer >= cfg.max_iterations {
            return Err(Error::Config(format!(
                "exceeded max_iterations = {} (non-convergence?)",
                cfg.max_iterations
            )));
        }
    }

    let dist = strategy.finalize(&ctx);
    ctx.finalize_metrics();
    let mut metrics = ctx.metrics;
    metrics.host_ns = host_start.elapsed().as_nanos() as u64;
    Ok(RunResult { dist, metrics })
}

/// Convenience: run every strategy on the same problem, returning
/// `(kind, Result)` pairs — the inner loop of the figure harness. OOM
/// failures are data, not errors (the paper's missing bars).
pub fn run_all_strategies(
    graph: &Arc<Csr>,
    base: &RunConfig,
) -> Vec<(StrategyKind, Result<RunResult>)> {
    StrategyKind::ALL
        .iter()
        .map(|&k| {
            let cfg = RunConfig {
                strategy: k,
                ..base.clone()
            };
            (k, run(graph, &cfg))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::traversal;

    fn small_graph() -> Arc<Csr> {
        Arc::new(crate::graph::generators::erdos_renyi(128, 512, 10, 77).unwrap())
    }

    #[test]
    fn all_strategies_agree_with_oracle_sssp() {
        let g = small_graph();
        let oracle = traversal::dijkstra(&g, 0);
        for (kind, res) in run_all_strategies(&g, &RunConfig::default()) {
            let r = res.unwrap_or_else(|e| panic!("{kind} failed: {e}"));
            assert_eq!(r.dist, oracle, "{kind} SSSP mismatch");
        }
    }

    #[test]
    fn all_strategies_agree_with_oracle_bfs() {
        let g = small_graph();
        let oracle = traversal::bfs_levels(&g, 0);
        let cfg = RunConfig {
            algo: AlgoKind::Bfs,
            ..Default::default()
        };
        for (kind, res) in run_all_strategies(&g, &cfg) {
            let r = res.unwrap_or_else(|e| panic!("{kind} failed: {e}"));
            assert_eq!(r.dist, oracle, "{kind} BFS mismatch");
        }
    }

    #[test]
    fn rejects_bad_source() {
        let g = small_graph();
        let cfg = RunConfig {
            source: 10_000,
            ..Default::default()
        };
        assert!(run(&g, &cfg).is_err());
    }

    #[test]
    fn metrics_are_populated() {
        let g = small_graph();
        let r = run(&g, &RunConfig::default()).unwrap();
        assert!(r.metrics.kernel_cycles > 0);
        assert!(r.metrics.overhead_cycles > 0);
        assert!(r.metrics.iterations > 0);
        assert!(r.metrics.edge_relaxations > 0);
        assert!(r.metrics.host_ns > 0);
    }

    #[test]
    fn unreachable_nodes_stay_inf() {
        use crate::graph::Edge;
        let g = Arc::new(Csr::from_edges(4, &[Edge::new(0, 1, 2)]).unwrap());
        let r = run(&g, &RunConfig::default()).unwrap();
        assert_eq!(r.dist, vec![0, 2, crate::INF, crate::INF]);
    }
}
