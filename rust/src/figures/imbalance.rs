//! The load-imbalance figure: per-kernel imbalance factors of every
//! strategy on a skewed graph, measured by the warp-level profiler.
//!
//! This is the observability companion of Figures 7/8: where those report
//! *how long* each strategy took, this figure shows *why* — node-based
//! mapping (BS) rides the degree skew straight into straggler warps, while
//! edge-based mapping (EP) flattens per-warp work. The per-iteration series
//! is the profiler's reconstruction from the trace ring (one entry per
//! processing-kernel launch, in launch order), so the figure doubles as an
//! end-to-end check of the `Kernel`/`KernelProfile` event pairing.

use crate::algorithms::AlgoKind;
use crate::coordinator::{run_traced, RunConfig};
use crate::error::Result;
use crate::graph::generators::paper_suite;
use crate::strategies::{Schedule, StrategyKind};
use crate::telemetry::{kernel_records, TraceSink, DEFAULT_TRACE_CAPACITY};
use crate::util::Json;
use std::io::Write;
use std::sync::Arc;

use super::FigureOpts;

/// One strategy's measured imbalance on the skewed graph.
#[derive(Debug, Clone)]
pub struct ImbalanceRow {
    /// Strategy label ("BS", "EP", …, "AD").
    pub strategy: &'static str,
    /// Whether the run completed within the memory budget.
    pub completed: bool,
    /// Processing-kernel launches profiled (0 when `completed` is false).
    pub profiled_kernels: u64,
    /// Mean per-kernel imbalance factor (max-warp ÷ mean-warp cycles).
    pub mean_imbalance: f64,
    /// Worst single-kernel imbalance factor.
    pub peak_imbalance: f64,
    /// Σ straggler cycles across the run (max-warp − mean-warp per kernel).
    pub imbalance_overhead_cycles: u64,
    /// p95 of the per-warp busy-cycle distribution.
    pub warp_cycles_p95: u64,
    /// Per-kernel imbalance factors in launch order, reconstructed from
    /// the trace ring — the figure's x-axis is the launch index.
    pub series: Vec<f64>,
}

impl ImbalanceRow {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", self.strategy.into()),
            ("completed", self.completed.into()),
            ("profiled_kernels", self.profiled_kernels.into()),
            ("mean_imbalance", self.mean_imbalance.into()),
            ("peak_imbalance", self.peak_imbalance.into()),
            (
                "imbalance_overhead_cycles",
                self.imbalance_overhead_cycles.into(),
            ),
            ("warp_cycles_p95", self.warp_cycles_p95.into()),
            (
                "series",
                Json::Arr(self.series.iter().map(|&v| v.into()).collect()),
            ),
        ])
    }
}

/// Run the imbalance figure: the five static strategies plus AD plus the
/// new composed schedules on the suite's first skewed graph, each under a
/// fresh trace ring.
pub fn fig_imbalance(opts: &FigureOpts, out: &mut impl Write) -> Result<Vec<ImbalanceRow>> {
    let entry = paper_suite(opts.scale)
        .into_iter()
        .find(|e| e.spec.skew_class() == "skewed")
        .expect("the paper suite always carries a skewed graph");
    let g = Arc::new(entry.spec.generate(opts.seed)?);
    let dev = opts.device_for(&entry, &g);
    let source = crate::graph::traversal::hub_source(&g);

    writeln!(
        out,
        "\n== Load imbalance — per-kernel max/mean warp cycles, SSSP on {} ==",
        entry.name
    )?;
    writeln!(
        out,
        "{:<22} {:>8} {:>8} {:>8} {:>16} {:>14}",
        "strategy", "kernels", "mean", "peak", "straggler-cyc", "warp-cyc-p95"
    )?;

    let mut rows = Vec::new();
    // The five monolithic strategies + AD, then the composed schedules the
    // algebra adds beyond the paper's five (their aliases are already in
    // the first group — re-measuring them would duplicate rows).
    let kinds = StrategyKind::ALL_WITH_ADAPTIVE
        .into_iter()
        .chain(Schedule::NEW.into_iter().map(StrategyKind::Composed));
    for k in kinds {
        let cfg = RunConfig {
            algo: AlgoKind::Sssp,
            strategy: k,
            source,
            device: dev.clone(),
            enforce_budget: opts.enforce_budget,
            ..Default::default()
        };
        let mut sink = TraceSink::with_capacity(DEFAULT_TRACE_CAPACITY);
        let row = match run_traced(&g, &cfg, Some(&mut sink), 0) {
            Ok(r) => {
                let series: Vec<f64> = kernel_records(&sink)
                    .iter()
                    .filter(|rec| rec.warps > 0)
                    .map(|rec| rec.imbalance_factor())
                    .collect();
                ImbalanceRow {
                    strategy: k.label(),
                    completed: true,
                    profiled_kernels: r.metrics.profiled_kernels,
                    mean_imbalance: r.metrics.mean_imbalance(),
                    peak_imbalance: r.metrics.peak_imbalance(),
                    imbalance_overhead_cycles: r.metrics.imbalance_overhead_cycles,
                    warp_cycles_p95: r.metrics.warp_cycles_hist.percentile(95),
                    series,
                }
            }
            Err(e) if e.is_oom() => ImbalanceRow {
                strategy: k.label(),
                completed: false,
                profiled_kernels: 0,
                mean_imbalance: 1.0,
                peak_imbalance: 1.0,
                imbalance_overhead_cycles: 0,
                warp_cycles_p95: 0,
                series: Vec::new(),
            },
            Err(e) => return Err(e),
        };
        if row.completed {
            writeln!(
                out,
                "{:<22} {:>8} {:>8.3} {:>8.3} {:>16} {:>14}",
                row.strategy,
                row.profiled_kernels,
                row.mean_imbalance,
                row.peak_imbalance,
                row.imbalance_overhead_cycles,
                row.warp_cycles_p95
            )?;
        } else {
            writeln!(out, "{:<22} {:>8}", row.strategy, "OOM")?;
        }
        rows.push(row);
    }
    writeln!(
        out,
        "(mean/peak: per-kernel max-warp ÷ mean-warp busy cycles; \
         straggler-cyc: Σ cycles the device idled behind its slowest warp)"
    )?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::SuiteScale;

    #[test]
    fn node_based_is_more_imbalanced_than_edge_based_on_skew() {
        let opts = FigureOpts {
            scale: SuiteScale::Tiny,
            // Disable the budget so EP always completes — the comparison
            // needs both strategies to finish.
            enforce_budget: false,
            ..Default::default()
        };
        let mut out = Vec::new();
        let rows = fig_imbalance(&opts, &mut out).unwrap();
        assert_eq!(
            rows.len(),
            StrategyKind::ALL.len() + 1 + Schedule::NEW.len(),
            "5 static + AD + the composed schedules"
        );

        let get = |label: &str| rows.iter().find(|r| r.strategy == label).unwrap();
        let bs = get("BS");
        let ep = get("EP");
        assert!(bs.completed && ep.completed);
        assert!(bs.profiled_kernels > 0, "profiler saw BS kernels");
        assert_eq!(
            bs.series.len() as u64,
            bs.profiled_kernels,
            "trace series covers every profiled launch"
        );
        // The paper's core claim, measured: mapping a node per thread on a
        // skewed graph leaves warps waiting on hub stragglers, while
        // edge-based mapping levels the per-warp work.
        assert!(
            bs.mean_imbalance > ep.mean_imbalance,
            "BS ({}) must out-imbalance EP ({})",
            bs.mean_imbalance,
            ep.mean_imbalance
        );
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Load imbalance"));
    }

    #[test]
    fn composed_merge_path_flattens_peak_imbalance_below_every_monolithic() {
        let opts = FigureOpts {
            scale: SuiteScale::Tiny,
            // Same reasoning as above: the comparison needs every strategy
            // to finish, so the memory budget stays off.
            enforce_budget: false,
            ..Default::default()
        };
        let mut out = Vec::new();
        let rows = fig_imbalance(&opts, &mut out).unwrap();
        let get = |label: &str| rows.iter().find(|r| r.strategy == label).unwrap();

        // warp/merge-path runs its relaxation phase dense (no in-kernel
        // worklist appends) over even merge-path chunks, so every committed
        // warp costs the same flat coalesced step — the peak per-kernel
        // imbalance factor must undercut all five monolithic strategies,
        // whose warps diverge on degree skew and per-warp atomic traffic.
        let wmp = get(Schedule::WARP_MERGE_PATH.label());
        assert!(wmp.completed, "warp/merge-path must fit without the budget");
        assert!(wmp.profiled_kernels > 0, "profiler saw composed kernels");
        assert_eq!(
            wmp.series.len() as u64,
            wmp.profiled_kernels,
            "trace series covers every composed launch"
        );
        for k in StrategyKind::ALL {
            let m = get(k.label());
            assert!(m.completed, "{} must complete for the comparison", k.label());
            assert!(
                wmp.peak_imbalance < m.peak_imbalance,
                "warp/merge-path peak ({}) must undercut {} ({})",
                wmp.peak_imbalance,
                k.label(),
                m.peak_imbalance
            );
        }
    }
}
