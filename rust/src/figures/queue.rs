//! The admission-control figure (`figqueue`): served latency vs. arrival
//! rate under the bounded-queue scheduler.
//!
//! One suite graph, one heterogeneous device pool, one fixed query count —
//! only the arrival rate sweeps. At low rates every query gets an idle
//! device almost immediately (latency ≈ service time, batches of ~1); as
//! the rate approaches and passes the pool's service capacity, queueing
//! delay dominates, batches fill toward `max_batch`, the queue peaks at
//! its cap, and the drop policy starts shedding — the classic saturating
//! latency curve, here fully deterministic because both the arrival
//! process and the service process run on the simulator's virtual clock.

use crate::arena::GraphCache;
use crate::error::Result;
use crate::graph::generators::paper_suite;
use crate::graph::Graph;
use crate::serving::{
    serve_stream, synthetic_arrivals, SchedulerConfig, ServeConfig,
};
use crate::sim::DeviceSpec;
use crate::util::Json;
use std::io::Write;
use std::sync::Arc;

use super::FigureOpts;

/// Queries per sweep point.
pub const FIGQUEUE_QUERIES: usize = 48;

/// Arrival rates swept, queries per simulated millisecond.
pub const FIGQUEUE_RATES: &[f64] = &[0.25, 1.0, 4.0, 16.0, 64.0];

/// Admission-queue bound of the sweep (small enough that the top rates
/// shed load, so the figure shows the drop policy doing its job).
pub const FIGQUEUE_CAP: usize = 16;

/// One arrival rate's outcome.
#[derive(Debug, Clone)]
pub struct QueueRow {
    pub rate_per_ms: f64,
    pub arrived: u64,
    pub admitted: u64,
    pub dropped: u64,
    pub served: u64,
    pub queue_peak: u64,
    pub batches: u64,
    pub mean_latency_ms: f64,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub max_latency_ms: f64,
    pub p95_wait_ms: f64,
    pub wall_ms: f64,
}

impl QueueRow {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rate_per_ms", self.rate_per_ms.into()),
            ("arrived", self.arrived.into()),
            ("admitted", self.admitted.into()),
            ("dropped", self.dropped.into()),
            ("served", self.served.into()),
            ("queue_peak", self.queue_peak.into()),
            ("batches", self.batches.into()),
            ("mean_latency_ms", self.mean_latency_ms.into()),
            ("p50_latency_ms", self.p50_latency_ms.into()),
            ("p95_latency_ms", self.p95_latency_ms.into()),
            ("p99_latency_ms", self.p99_latency_ms.into()),
            ("max_latency_ms", self.max_latency_ms.into()),
            ("p95_wait_ms", self.p95_wait_ms.into()),
            ("wall_ms", self.wall_ms.into()),
        ])
    }
}

/// Run the latency-vs-arrival-rate sweep on the first suite graph over a
/// k20c + gtx680 pool (heterogeneous on purpose: placement must weight
/// load by device throughput for the curve to stay smooth).
pub fn fig_queue(opts: &FigureOpts, out: &mut impl Write) -> Result<Vec<QueueRow>> {
    let entry = &paper_suite(opts.scale)[0];
    let g = Arc::new(entry.spec.generate(opts.seed)?);
    let devices = vec![DeviceSpec::k20c(), DeviceSpec::gtx680()];
    writeln!(
        out,
        "\n== Serving under admission control: latency vs. arrival rate \
         ({}: {} nodes, {} edges; pool [k20c,gtx680], queue cap {FIGQUEUE_CAP}, \
         {FIGQUEUE_QUERIES} queries/point) ==",
        entry.name,
        g.num_nodes(),
        g.num_edges()
    )?;
    writeln!(
        out,
        "{:>9} {:>8} {:>8} {:>8} {:>7} {:>8} {:>12} {:>11} {:>11} {:>11} {:>10}",
        "q/ms", "admitted", "dropped", "served", "batches", "qpeak", "mean lat ms", "p95 lat ms", "p99 lat ms", "p95 wait ms", "wall ms"
    )?;
    let cache = GraphCache::new();
    let mut rows = Vec::new();
    for &rate in FIGQUEUE_RATES {
        let mean_gap_ps = (1e9 / rate).round().max(1.0) as u64;
        let arrivals =
            synthetic_arrivals(&g, FIGQUEUE_QUERIES, 0.5, mean_gap_ps, opts.seed);
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                devices: devices.clone(),
                enforce_budget: opts.enforce_budget,
                ..Default::default()
            },
            queue_cap: FIGQUEUE_CAP,
            // One worker per shard (the default). The figure's numbers are
            // identical for any worker count — the sweep just finishes
            // faster on multi-core machines.
            workers: 0,
            ..Default::default()
        };
        let report = serve_stream(&g, arrivals, &cfg, &cache)?;
        let row = QueueRow {
            rate_per_ms: rate,
            arrived: report.arrived,
            admitted: report.admitted,
            dropped: report.dropped.len() as u64,
            served: report.served() as u64,
            queue_peak: report.queue_peak,
            batches: report.batches,
            mean_latency_ms: report.mean_latency_ms(),
            p50_latency_ms: report.p50_latency_ms(),
            p95_latency_ms: report.p95_latency_ms(),
            p99_latency_ms: report.p99_latency_ms(),
            max_latency_ms: report.max_latency_ms(),
            p95_wait_ms: report.wait_ms_p95(),
            wall_ms: report.wall_ms(),
        };
        writeln!(
            out,
            "{:>9.2} {:>8} {:>8} {:>8} {:>7} {:>8} {:>12.3} {:>11.3} {:>11.3} {:>11.3} {:>10.3}",
            row.rate_per_ms,
            row.admitted,
            row.dropped,
            row.served,
            row.batches,
            row.queue_peak,
            row.mean_latency_ms,
            row.p95_latency_ms,
            row.p99_latency_ms,
            row.p95_wait_ms,
            row.wall_ms,
        )?;
        rows.push(row);
    }
    writeln!(
        out,
        "(latency over *served* queries — arrival to completion on the virtual \
         clock; percentiles are log2-bucket upper bounds clamped to the max. \
         Rising rate ⇒ queueing delay, fuller batches, then drops once the \
         {FIGQUEUE_CAP}-deep queue saturates.)"
    )?;
    Ok(rows)
}
