//! The fault-tolerance figure (`figavail`): served fraction and tail
//! latency vs. injected fault rate under the recovering scheduler.
//!
//! One suite graph, the heterogeneous k20c+k40+gtx680 pool, a fixed
//! arrival stream — only the synthetic fault rate sweeps. At rate 0 the
//! stream behaves exactly like `figserve`'s scheduler path; as faults
//! arrive, shards stall, die, slow down and lose memory headroom, the
//! retry/requeue machinery re-places the victims, and the served fraction
//! and p99 latency show what that recovery costs. Everything runs on the
//! virtual clock, so each point is bit-deterministic for any worker count.

use crate::arena::GraphCache;
use crate::error::Result;
use crate::graph::generators::paper_suite;
use crate::graph::Graph;
use crate::serving::{
    serve_stream, synthetic_arrivals, FaultPlan, SchedulerConfig, ServeConfig,
};
use crate::sim::DeviceSpec;
use crate::util::Json;
use std::io::Write;
use std::sync::Arc;

use super::FigureOpts;

/// Queries per sweep point.
pub const FIGAVAIL_QUERIES: usize = 48;

/// Synthetic fault rates swept, faults per simulated millisecond across
/// the whole pool (0 = the fault-free baseline).
pub const FIGAVAIL_RATES: &[f64] = &[0.0, 0.05, 0.1, 0.2];

/// Arrival rate of the stream (queries per simulated ms) — brisk enough
/// that an outage backs the queue up, slow enough that the fault-free
/// point serves everything.
pub const FIGAVAIL_ARRIVAL_PER_MS: f64 = 2.0;

/// Per-query deadline (ms): queries stranded by an outage longer than
/// this are shed as `deadline_expired` instead of waiting forever.
pub const FIGAVAIL_DEADLINE_MS: f64 = 20.0;

/// One fault rate's outcome.
#[derive(Debug, Clone)]
pub struct AvailRow {
    pub fault_rate_per_ms: f64,
    pub faults: u64,
    pub arrived: u64,
    pub served: u64,
    pub served_fraction: f64,
    pub failed: u64,
    pub deadline_expired: u64,
    pub dropped: u64,
    pub retries: u64,
    pub requeued: u64,
    pub p99_latency_ms: f64,
    /// Mean per-shard in-service fraction of the stream span.
    pub availability: f64,
    pub wall_ms: f64,
}

impl AvailRow {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fault_rate_per_ms", self.fault_rate_per_ms.into()),
            ("faults", self.faults.into()),
            ("arrived", self.arrived.into()),
            ("served", self.served.into()),
            ("served_fraction", self.served_fraction.into()),
            ("failed", self.failed.into()),
            ("deadline_expired", self.deadline_expired.into()),
            ("dropped", self.dropped.into()),
            ("retries", self.retries.into()),
            ("requeued", self.requeued.into()),
            ("p99_latency_ms", self.p99_latency_ms.into()),
            ("availability", self.availability.into()),
            ("wall_ms", self.wall_ms.into()),
        ])
    }
}

/// Run the served-fraction-vs-fault-rate sweep on the first suite graph
/// over the full heterogeneous pool.
pub fn fig_avail(opts: &FigureOpts, out: &mut impl Write) -> Result<Vec<AvailRow>> {
    let entry = &paper_suite(opts.scale)[0];
    let g = Arc::new(entry.spec.generate(opts.seed)?);
    let devices = vec![DeviceSpec::k20c(), DeviceSpec::k40(), DeviceSpec::gtx680()];
    writeln!(
        out,
        "\n== Serving under fault injection: served fraction vs. fault rate \
         ({}: {} nodes, {} edges; pool [k20c,k40,gtx680], \
         {FIGAVAIL_QUERIES} queries/point, deadline {FIGAVAIL_DEADLINE_MS} ms) ==",
        entry.name,
        g.num_nodes(),
        g.num_edges()
    )?;
    writeln!(
        out,
        "{:>10} {:>7} {:>7} {:>9} {:>7} {:>9} {:>8} {:>11} {:>7} {:>10}",
        "faults/ms", "faults", "served", "served-%", "failed", "deadline", "retries", "p99 lat ms", "avail", "wall ms"
    )?;
    let mean_gap_ps = (1e9 / FIGAVAIL_ARRIVAL_PER_MS).round() as u64;
    let cache = GraphCache::new();
    let mut rows = Vec::new();
    for &rate in FIGAVAIL_RATES {
        let arrivals =
            synthetic_arrivals(&g, FIGAVAIL_QUERIES, 0.5, mean_gap_ps, opts.seed);
        // Fault horizon: the arrival window plus slack, so late-stream
        // faults (and their recoveries) still land while work is in
        // flight.
        let horizon_ms =
            arrivals.last().map(|a| a.at_ps as f64 / 1e9).unwrap_or(0.0) + 10.0;
        let plan = FaultPlan::synthetic(devices.len(), rate, horizon_ms, opts.seed);
        let faults = plan.len() as u64;
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                devices: devices.clone(),
                enforce_budget: opts.enforce_budget,
                ..Default::default()
            },
            faults: (!plan.is_empty()).then_some(plan),
            deadline_ps: (FIGAVAIL_DEADLINE_MS * 1e9) as u64,
            ..Default::default()
        };
        let report = serve_stream(&g, arrivals, &cfg, &cache)?;
        let availability = if report.shards.is_empty() {
            1.0
        } else {
            report
                .shards
                .iter()
                .map(|s| s.availability(report.wall_ps))
                .sum::<f64>()
                / report.shards.len() as f64
        };
        let row = AvailRow {
            fault_rate_per_ms: rate,
            faults,
            arrived: report.arrived,
            served: report.served() as u64,
            served_fraction: if report.arrived == 0 {
                1.0
            } else {
                report.served() as f64 / report.arrived as f64
            },
            failed: report.failed.len() as u64,
            deadline_expired: report.deadline_expired.len() as u64,
            dropped: report.dropped.len() as u64,
            retries: report.retries,
            requeued: report.requeued,
            p99_latency_ms: report.p99_latency_ms(),
            availability,
            wall_ms: report.wall_ms(),
        };
        writeln!(
            out,
            "{:>10.2} {:>7} {:>7} {:>8.1}% {:>7} {:>9} {:>8} {:>11.3} {:>6.1}% {:>10.3}",
            row.fault_rate_per_ms,
            row.faults,
            row.served,
            row.served_fraction * 100.0,
            row.failed,
            row.deadline_expired,
            row.retries,
            row.p99_latency_ms,
            row.availability * 100.0,
            row.wall_ms,
        )?;
        rows.push(row);
    }
    writeln!(
        out,
        "(every arrival is accounted for: arrived == served + dropped + \
         deadline_expired + failed. Rising fault rate ⇒ more requeues, \
         longer tails, lower pool availability — the recovery machinery \
         trades latency for completeness until the deadline sheds the rest.)"
    )?;
    Ok(rows)
}
