//! Figure harness: regenerates every table and figure of the paper's
//! evaluation section (the experiment index of DESIGN.md §5).
//!
//! Each `figN` function returns structured results *and* renders the
//! paper-style rows to a writer, so the CLI, the criterion benches and the
//! integration tests share one implementation.

pub mod adaptive;
pub mod avail;
pub mod imbalance;
pub mod queue;
pub mod serving;
pub mod tradeoff;

use crate::algorithms::AlgoKind;
use crate::coordinator::{run, RunConfig, RunResult};
use crate::error::Result;
use crate::graph::generators::{paper_suite, suite::SuiteEntry, SuiteScale};
use crate::graph::stats::{degree_frequency, DegreeStats};
use crate::graph::{Csr, Graph};
use crate::sim::DeviceSpec;
use crate::strategies::node_split::split_graph;
use crate::strategies::{mdt::auto_mdt, StrategyKind, StrategyParams};
use crate::util::Json;
use crate::worklist::chunking::PushPolicy;
use std::io::Write;
use std::sync::Arc;

pub use adaptive::{fig_adaptive, AdaptiveRow};
pub use avail::{fig_avail, AvailRow};
pub use imbalance::{fig_imbalance, ImbalanceRow};
pub use queue::{fig_queue, QueueRow};
pub use serving::{fig_serving, ServingRow};
pub use tradeoff::{fig9, Fig9Row};

/// Common options of the figure harness.
#[derive(Debug, Clone)]
pub struct FigureOpts {
    /// Suite scale (small by default; `paper` for full Table II sizes).
    pub scale: SuiteScale,
    /// Generator seed.
    pub seed: u64,
    /// Enforce per-graph scaled memory budgets (reproduces the paper's OOM
    /// cells).
    pub enforce_budget: bool,
    /// Device model.
    pub device: DeviceSpec,
}

impl Default for FigureOpts {
    fn default() -> Self {
        FigureOpts {
            scale: SuiteScale::Small,
            seed: crate::graph::generators::suite::DEFAULT_SEED,
            enforce_budget: true,
            device: DeviceSpec::k20c(),
        }
    }
}

impl FigureOpts {
    /// Per-graph device: budget scaled so reduced-size graphs face the
    /// paper-equivalent memory pressure (DESIGN.md §6).
    pub fn device_for(&self, entry: &SuiteEntry, g: &Csr) -> DeviceSpec {
        self.device
            .clone()
            .scaled_budget(entry.paper_edges, g.num_edges() as u64)
    }
}

/// One strategy's outcome on one graph.
#[derive(Debug, Clone)]
pub enum Outcome {
    Ok {
        kernel_ms: f64,
        overhead_ms: f64,
        total_ms: f64,
        mteps: f64,
        peak_memory: u64,
    },
    /// The strategy could not run within the memory budget — rendered like
    /// the paper's missing bars.
    Oom,
}

impl Outcome {
    /// Total time if the run succeeded.
    pub fn total_ms(&self) -> Option<f64> {
        match self {
            Outcome::Ok { total_ms, .. } => Some(*total_ms),
            Outcome::Oom => None,
        }
    }

    /// Peak memory if the run succeeded.
    pub fn peak_memory(&self) -> Option<u64> {
        match self {
            Outcome::Ok { peak_memory, .. } => Some(*peak_memory),
            Outcome::Oom => None,
        }
    }

    fn from_run(res: Result<RunResult>, dev: &DeviceSpec) -> Result<Outcome> {
        match res {
            Ok(r) => Ok(Outcome::Ok {
                kernel_ms: r.metrics.kernel_ms(dev),
                overhead_ms: r.metrics.overhead_ms(dev),
                total_ms: r.metrics.total_ms(dev),
                mteps: r.metrics.mteps(dev),
                peak_memory: r.metrics.peak_memory_bytes,
            }),
            Err(e) if e.is_oom() => Ok(Outcome::Oom),
            Err(e) => Err(e),
        }
    }
}

/// Results of Figure 7 (SSSP) or Figure 8 (BFS): per graph, per strategy.
#[derive(Debug, Clone)]
pub struct ComparisonFigure {
    pub algo: AlgoKind,
    pub rows: Vec<ComparisonRow>,
}

/// One graph's row.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    pub graph: String,
    pub skew_class: String,
    pub nodes: usize,
    pub edges: usize,
    pub outcomes: Vec<(StrategyKind, Outcome)>,
}

impl ComparisonRow {
    /// Outcome of one strategy.
    pub fn outcome(&self, k: StrategyKind) -> &Outcome {
        &self
            .outcomes
            .iter()
            .find(|(s, _)| *s == k)
            .expect("all strategies present")
            .1
    }

    /// `1 - t(k)/t(BS)` as a percentage, if both ran.
    pub fn reduction_vs_bs(&self, k: StrategyKind) -> Option<f64> {
        let bs = self.outcome(StrategyKind::BS).total_ms()?;
        let t = self.outcome(k).total_ms()?;
        Some(100.0 * (1.0 - t / bs))
    }
}

/// Run the Figure 7/8 comparison: every strategy × every suite graph.
pub fn comparison_figure(
    algo: AlgoKind,
    opts: &FigureOpts,
    out: &mut impl Write,
) -> Result<ComparisonFigure> {
    let mut rows = Vec::new();
    writeln!(
        out,
        "\n== Figure {} — {} execution time (ms, simulated K20c), kernel+overhead ==",
        if algo == AlgoKind::Sssp { 7 } else { 8 },
        algo.name().to_uppercase()
    )?;
    writeln!(
        out,
        "{:<12} {:>10} {:>10}  {}",
        "graph",
        "nodes",
        "edges",
        StrategyKind::ALL
            .iter()
            .map(|k| format!("{:>16}", k.label()))
            .collect::<String>()
    )?;

    for entry in paper_suite(opts.scale) {
        let g = Arc::new(entry.spec.generate(opts.seed)?);
        let dev = opts.device_for(&entry, &g);
        // Source: the top hub — label permutation can make node 0
        // isolated on Graph500 inputs (see traversal::hub_source).
        let source = crate::graph::traversal::hub_source(&g);
        let mut outcomes = Vec::new();
        for k in StrategyKind::ALL {
            let cfg = RunConfig {
                algo,
                strategy: k,
                source,
                device: dev.clone(),
                enforce_budget: opts.enforce_budget,
                ..Default::default()
            };
            let outcome = Outcome::from_run(run(&g, &cfg), &dev)?;
            outcomes.push((k, outcome));
        }
        let cells: String = outcomes
            .iter()
            .map(|(_, o)| match o {
                Outcome::Ok {
                    kernel_ms,
                    overhead_ms,
                    ..
                } => format!("{:>8.2}+{:<7.2}", kernel_ms, overhead_ms),
                Outcome::Oom => format!("{:>16}", "OOM"),
            })
            .collect();
        writeln!(
            out,
            "{:<12} {:>10} {:>10}  {}",
            entry.name,
            g.num_nodes(),
            g.num_edges(),
            cells
        )?;
        rows.push(ComparisonRow {
            graph: entry.name.clone(),
            skew_class: entry.spec.skew_class().to_string(),
            nodes: g.num_nodes(),
            edges: g.num_edges(),
            outcomes,
        });
    }
    Ok(ComparisonFigure { algo, rows })
}

/// Figure 7: SSSP strategy comparison.
pub fn fig7(opts: &FigureOpts, out: &mut impl Write) -> Result<ComparisonFigure> {
    comparison_figure(AlgoKind::Sssp, opts, out)
}

/// Figure 8: BFS strategy comparison.
pub fn fig8(opts: &FigureOpts, out: &mut impl Write) -> Result<ComparisonFigure> {
    comparison_figure(AlgoKind::Bfs, opts, out)
}

/// Table II row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub graph: String,
    pub nodes: usize,
    pub edges: usize,
    pub max_deg: u32,
    pub avg_deg: f64,
    pub sigma: f64,
}

/// Table II: the graph suite with degree statistics.
pub fn table2(opts: &FigureOpts, out: &mut impl Write) -> Result<Vec<Table2Row>> {
    writeln!(out, "\n== Table II — graphs used in the experiments ==")?;
    writeln!(
        out,
        "{:<12} {:>10} {:>10} {:>8} {:>6} {:>10}",
        "graph", "nodes", "edges", "maxdeg", "avg", "sigma"
    )?;
    let mut rows = Vec::new();
    for entry in paper_suite(opts.scale) {
        let g = entry.spec.generate(opts.seed)?;
        let st = DegreeStats::of(&g);
        writeln!(
            out,
            "{:<12} {:>10} {:>10} {:>8} {:>6.1} {:>10.2}",
            entry.name,
            g.num_nodes(),
            g.num_edges(),
            st.max,
            st.avg,
            st.stddev
        )?;
        rows.push(Table2Row {
            graph: entry.name,
            nodes: g.num_nodes(),
            edges: g.num_edges(),
            max_deg: st.max,
            avg_deg: st.avg,
            sigma: st.stddev,
        });
    }
    Ok(rows)
}

/// Figure 1: degree distributions of a road network vs. a skewed graph.
pub fn fig1(opts: &FigureOpts, out: &mut impl Write) -> Result<()> {
    writeln!(out, "\n== Figure 1 — outdegree distributions ==")?;
    for entry in paper_suite(opts.scale) {
        let class = entry.spec.skew_class();
        if class != "road" && class != "skewed" {
            continue;
        }
        let g = entry.spec.generate(opts.seed)?;
        let freq = degree_frequency(&g);
        let st = DegreeStats::of(&g);
        writeln!(
            out,
            "\n{} ({}): min={} max={} avg={:.1}",
            entry.name, class, st.min, st.max, st.avg
        )?;
        // log-binned sparkline of the distribution
        let mut shown = 0;
        for (d, c) in &freq {
            if shown >= 12 {
                writeln!(out, "  ... ({} more degree values)", freq.len() - shown)?;
                break;
            }
            let bar = "#".repeat(((*c as f64).log10().max(0.0) * 6.0) as usize + 1);
            writeln!(out, "  deg {:>6}: {:>9} {}", d, c, bar)?;
            shown += 1;
        }
        if class == "road" {
            // paper: road networks have max degree ≤ 9
            debug_assert!(st.max <= 9);
        }
    }
    Ok(())
}

/// Figure 10 result for one graph: degree distribution before/after NS.
#[derive(Debug, Clone)]
pub struct Fig10Row {
    pub graph: String,
    pub mdt: u32,
    pub max_before: u32,
    pub max_after: u32,
    pub sigma_before: f64,
    pub sigma_after: f64,
    pub split_nodes: u64,
    pub nodes_before: usize,
    pub nodes_after: usize,
}

/// Figure 10: degree distributions before/after node splitting + auto-MDT.
pub fn fig10(opts: &FigureOpts, out: &mut impl Write) -> Result<Vec<Fig10Row>> {
    writeln!(
        out,
        "\n== Figure 10 — degree distribution before/after node splitting =="
    )?;
    writeln!(
        out,
        "{:<12} {:>6} {:>10} {:>10} {:>9} {:>9} {:>8}",
        "graph", "MDT", "max-before", "max-after", "σ-before", "σ-after", "splits"
    )?;
    let mut rows = Vec::new();
    for entry in paper_suite(opts.scale) {
        let g = entry.spec.generate(opts.seed)?;
        let before = DegreeStats::of(&g);
        let decision = auto_mdt(&g, 10);
        let split = split_graph(&g, decision);
        let after = DegreeStats::of(&split.graph);
        writeln!(
            out,
            "{:<12} {:>6} {:>10} {:>10} {:>9.2} {:>9.2} {:>8}",
            entry.name, decision.mdt, before.max, after.max, before.stddev, after.stddev,
            split.split_nodes
        )?;
        rows.push(Fig10Row {
            graph: entry.name,
            mdt: decision.mdt,
            max_before: before.max,
            max_after: after.max,
            sigma_before: before.stddev,
            sigma_after: after.stddev,
            split_nodes: split.split_nodes,
            nodes_before: g.num_nodes(),
            nodes_after: split.graph.num_nodes(),
        });
    }
    Ok(rows)
}

/// Figure 11 row: work-chunking speedup for one graph.
#[derive(Debug, Clone)]
pub struct Fig11Row {
    pub graph: String,
    pub chunked_ms: f64,
    pub per_edge_ms: f64,
    pub speedup: f64,
}

/// Figure 11: EP with work chunking vs. per-edge append atomics (SSSP, as
/// in the paper's EP experiments).
pub fn fig11(opts: &FigureOpts, out: &mut impl Write) -> Result<Vec<Fig11Row>> {
    writeln!(
        out,
        "\n== Figure 11 — work-chunking speedup in edge-based processing =="
    )?;
    writeln!(
        out,
        "{:<12} {:>12} {:>12} {:>9}",
        "graph", "chunked(ms)", "per-edge(ms)", "speedup"
    )?;
    let mut rows = Vec::new();
    for entry in paper_suite(opts.scale) {
        let g = Arc::new(entry.spec.generate(opts.seed)?);
        // Chunking is an EP ablation: skip graphs EP cannot hold (paper
        // measures chunking only where EP runs).
        let dev = opts.device_for(&entry, &g);
        let source = crate::graph::traversal::hub_source(&g);
        let mut times = Vec::new();
        let mut oom = false;
        for policy in [PushPolicy::Chunked, PushPolicy::PerEdge] {
            let cfg = RunConfig {
                algo: AlgoKind::Sssp,
                strategy: StrategyKind::EP,
                push_policy: policy,
                source,
                device: dev.clone(),
                enforce_budget: opts.enforce_budget,
                ..Default::default()
            };
            match Outcome::from_run(run(&g, &cfg), &dev)? {
                Outcome::Ok { total_ms, .. } => times.push(total_ms),
                Outcome::Oom => {
                    oom = true;
                    break;
                }
            }
        }
        if oom {
            writeln!(out, "{:<12} {:>12} {:>12} {:>9}", entry.name, "OOM", "OOM", "-")?;
            continue;
        }
        let speedup = times[1] / times[0];
        writeln!(
            out,
            "{:<12} {:>12.2} {:>12.2} {:>8.2}x",
            entry.name, times[0], times[1], speedup
        )?;
        rows.push(Fig11Row {
            graph: entry.name,
            chunked_ms: times[0],
            per_edge_ms: times[1],
            speedup,
        });
    }
    if !rows.is_empty() {
        let avg = rows.iter().map(|r| r.speedup).sum::<f64>() / rows.len() as f64;
        writeln!(out, "{:<12} {:>37.2}x  (paper: avg 1.82x)", "average", avg)?;
    }
    Ok(rows)
}

/// Default strategy params shared by the harness.
pub fn default_params() -> StrategyParams {
    StrategyParams::default()
}

impl Outcome {
    /// JSON rendering for the CLI's `--json` output.
    pub fn to_json(&self) -> Json {
        match self {
            Outcome::Ok {
                kernel_ms,
                overhead_ms,
                total_ms,
                mteps,
                peak_memory,
            } => Json::obj(vec![
                ("kernel_ms", (*kernel_ms).into()),
                ("overhead_ms", (*overhead_ms).into()),
                ("total_ms", (*total_ms).into()),
                ("mteps", (*mteps).into()),
                ("peak_memory", (*peak_memory).into()),
            ]),
            Outcome::Oom => Json::obj(vec![("oom", true.into())]),
        }
    }
}

impl ComparisonRow {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("graph", self.graph.as_str().into()),
            ("skew_class", self.skew_class.as_str().into()),
            ("nodes", self.nodes.into()),
            ("edges", self.edges.into()),
            (
                "outcomes",
                Json::Arr(
                    self.outcomes
                        .iter()
                        .map(|(k, o)| {
                            Json::obj(vec![
                                ("strategy", k.label().into()),
                                ("outcome", o.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl ComparisonFigure {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algo", self.algo.name().into()),
            (
                "rows",
                Json::Arr(self.rows.iter().map(ComparisonRow::to_json).collect()),
            ),
        ])
    }
}

impl Table2Row {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("graph", self.graph.as_str().into()),
            ("nodes", self.nodes.into()),
            ("edges", self.edges.into()),
            ("max_deg", self.max_deg.into()),
            ("avg_deg", self.avg_deg.into()),
            ("sigma", self.sigma.into()),
        ])
    }
}

impl Fig10Row {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("graph", self.graph.as_str().into()),
            ("mdt", self.mdt.into()),
            ("max_before", self.max_before.into()),
            ("max_after", self.max_after.into()),
            ("sigma_before", self.sigma_before.into()),
            ("sigma_after", self.sigma_after.into()),
            ("split_nodes", self.split_nodes.into()),
            ("nodes_before", self.nodes_before.into()),
            ("nodes_after", self.nodes_after.into()),
        ])
    }
}

impl Fig11Row {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("graph", self.graph.as_str().into()),
            ("chunked_ms", self.chunked_ms.into()),
            ("per_edge_ms", self.per_edge_ms.into()),
            ("speedup", self.speedup.into()),
        ])
    }
}
