//! The "adaptive vs. best-static" figure: for every Table II graph, run the
//! five static strategies *and* the adaptive selector on the same problem,
//! then report how close AD lands to the per-graph best static strategy
//! (which the user of a static system would have had to know in advance)
//! and how far from the worst (which they risk picking blind).

use crate::algorithms::AlgoKind;
use crate::coordinator::{run, RunConfig};
use crate::error::Result;
use crate::graph::generators::paper_suite;
use crate::graph::Graph;
use crate::strategies::{Schedule, StrategyKind, StrategyParams};
use crate::util::Json;
use std::io::Write;
use std::sync::Arc;

use super::{FigureOpts, Outcome};

/// One graph's adaptive-vs-static comparison.
#[derive(Debug, Clone)]
pub struct AdaptiveRow {
    pub graph: String,
    pub nodes: usize,
    pub edges: usize,
    /// The five static outcomes in paper order, followed by the composed
    /// schedules the algebra adds beyond the paper's five.
    pub outcomes: Vec<(StrategyKind, Outcome)>,
    /// The adaptive run's outcome.
    pub adaptive: Outcome,
    /// Strategy switches the adaptive engine performed.
    pub switches: u64,
    /// Outer iterations (= decision-trace length).
    pub decisions: usize,
    /// Distinct modes executed, in first-use order (e.g. "BS>EP").
    pub modes: String,
    /// `100 * (ad / best_static - 1)` — how far above the best static
    /// strategy AD landed (negative: AD beat every static strategy).
    pub vs_best_pct: Option<f64>,
    /// `100 * (1 - ad / worst_static)` — reduction vs. the worst static
    /// strategy that completed.
    pub vs_worst_pct: Option<f64>,
}

impl AdaptiveRow {
    /// Best completed static time, with its strategy.
    pub fn best_static(&self) -> Option<(StrategyKind, f64)> {
        self.outcomes
            .iter()
            .filter_map(|(k, o)| o.total_ms().map(|t| (*k, t)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// Worst completed static time, with its strategy.
    pub fn worst_static(&self) -> Option<(StrategyKind, f64)> {
        self.outcomes
            .iter()
            .filter_map(|(k, o)| o.total_ms().map(|t| (*k, t)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("graph", self.graph.as_str().into()),
            ("nodes", self.nodes.into()),
            ("edges", self.edges.into()),
            (
                "static",
                Json::Arr(
                    self.outcomes
                        .iter()
                        .map(|(k, o)| {
                            Json::obj(vec![
                                ("strategy", k.label().into()),
                                ("outcome", o.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("adaptive", self.adaptive.to_json()),
            ("switches", self.switches.into()),
            ("decisions", self.decisions.into()),
            ("modes", self.modes.as_str().into()),
            (
                "vs_best_pct",
                self.vs_best_pct.map_or(Json::Null, Json::Num),
            ),
            (
                "vs_worst_pct",
                self.vs_worst_pct.map_or(Json::Null, Json::Num),
            ),
        ])
    }
}

/// Distinct decision-trace modes in first-use order.
fn modes_used(decisions: &[crate::metrics::DecisionRecord]) -> String {
    let mut seen: Vec<&str> = Vec::new();
    for d in decisions {
        if !seen.contains(&d.strategy) {
            seen.push(d.strategy);
        }
    }
    seen.join(">")
}

/// Run the adaptive-vs-best-static comparison (SSSP, the paper's
/// computation-heavy case where load balancing matters most).
pub fn fig_adaptive(opts: &FigureOpts, out: &mut impl Write) -> Result<Vec<AdaptiveRow>> {
    writeln!(
        out,
        "\n== Adaptive (AD) vs. static strategies — SSSP total time (ms, simulated K20c) =="
    )?;
    writeln!(
        out,
        "{:<12} {:>10} {:>10} {:>10} {:>14} {:>14} {:>8} {:>9}  {}",
        "graph", "AD", "best", "worst", "best-static", "vs-best", "vs-worst", "switches", "modes"
    )?;
    let mut rows = Vec::new();
    for entry in paper_suite(opts.scale) {
        let g = Arc::new(entry.spec.generate(opts.seed)?);
        let dev = opts.device_for(&entry, &g);
        let source = crate::graph::traversal::hub_source(&g);

        let mut outcomes = Vec::new();
        let candidates = StrategyKind::ALL
            .into_iter()
            .chain(Schedule::NEW.into_iter().map(StrategyKind::Composed));
        for k in candidates {
            let cfg = RunConfig {
                algo: AlgoKind::Sssp,
                strategy: k,
                source,
                device: dev.clone(),
                enforce_budget: opts.enforce_budget,
                ..Default::default()
            };
            outcomes.push((k, Outcome::from_run(run(&g, &cfg), &dev)?));
        }

        // AD's candidate set gains the same composed schedules the static
        // table measures, so the figure compares like against like.
        let ad_cfg = RunConfig {
            algo: AlgoKind::Sssp,
            strategy: StrategyKind::AD,
            source,
            device: dev.clone(),
            enforce_budget: opts.enforce_budget,
            params: StrategyParams {
                composed_candidates: Schedule::NEW.to_vec(),
                ..Default::default()
            },
            ..Default::default()
        };
        let ad_run = run(&g, &ad_cfg);
        let (switches, decisions, modes) = match &ad_run {
            Ok(r) => (
                r.metrics.strategy_switches,
                r.metrics.decisions.len(),
                modes_used(&r.metrics.decisions),
            ),
            Err(_) => (0, 0, String::new()),
        };
        let adaptive = Outcome::from_run(ad_run, &dev)?;

        let mut row = AdaptiveRow {
            graph: entry.name.clone(),
            nodes: g.num_nodes(),
            edges: g.num_edges(),
            outcomes,
            adaptive,
            switches,
            decisions,
            modes,
            vs_best_pct: None,
            vs_worst_pct: None,
        };
        let best = row.best_static();
        let worst = row.worst_static();
        if let (Some(ad), Some((_, best_ms))) = (row.adaptive.total_ms(), best) {
            row.vs_best_pct = Some(100.0 * (ad / best_ms - 1.0));
        }
        if let (Some(ad), Some((_, worst_ms))) = (row.adaptive.total_ms(), worst) {
            row.vs_worst_pct = Some(100.0 * (1.0 - ad / worst_ms));
        }

        let fmt_ms = |o: Option<f64>| match o {
            Some(v) => format!("{v:.2}"),
            None => "OOM".to_string(),
        };
        writeln!(
            out,
            "{:<12} {:>10} {:>10} {:>10} {:>14} {:>13}% {:>7}% {:>9}  {}",
            row.graph,
            fmt_ms(row.adaptive.total_ms()),
            fmt_ms(best.map(|b| b.1)),
            fmt_ms(worst.map(|w| w.1)),
            best.map_or("-".to_string(), |b| b.0.label().to_string()),
            row.vs_best_pct.map_or("-".to_string(), |p| format!("{p:+.1}")),
            row.vs_worst_pct.map_or("-".to_string(), |p| format!("{p:.1}")),
            row.switches,
            row.modes,
        )?;
        rows.push(row);
    }
    writeln!(
        out,
        "(vs-best: how far AD lands above the per-graph best static strategy; \
         vs-worst: reduction against the worst)"
    )?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::SuiteScale;

    #[test]
    fn candidate_table_carries_the_composed_balancers() {
        let opts = FigureOpts {
            scale: SuiteScale::Tiny,
            // Budget off so every candidate (including EP's COO expansion)
            // completes and the table is fully populated.
            enforce_budget: false,
            ..Default::default()
        };
        let mut out = Vec::new();
        let rows = fig_adaptive(&opts, &mut out).unwrap();
        assert!(!rows.is_empty());
        for row in &rows {
            assert_eq!(
                row.outcomes.len(),
                StrategyKind::ALL.len() + Schedule::NEW.len(),
                "{}: five monolithic + the composed schedules",
                row.graph
            );
            for s in Schedule::NEW {
                let (_, o) = row
                    .outcomes
                    .iter()
                    .find(|(k, _)| *k == StrategyKind::Composed(s))
                    .unwrap_or_else(|| panic!("{}: missing {}", row.graph, s));
                assert!(
                    o.total_ms().is_some(),
                    "{}: composed {} must complete without the budget",
                    row.graph,
                    s
                );
            }
            // The adaptive run decides every outer iteration even with the
            // widened candidate set.
            assert!(row.decisions > 0, "{}: AD recorded decisions", row.graph);
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Adaptive (AD) vs. static strategies"));
    }
}
