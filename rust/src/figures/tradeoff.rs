//! Figure 9: the three-axis trade-off ranking (execution time, memory
//! requirement, implementation complexity).
//!
//! Time and memory ranks are *measured* (averaged over the Figure 7 + 8
//! runs); implementation complexity is the paper's qualitative assessment
//! (§IV-B): a strategy closer to the origin ranks better.

use super::{ComparisonFigure, FigureOpts};
use crate::error::Result;
use crate::strategies::StrategyKind;
use crate::util::Json;
use std::collections::HashMap;
use std::io::Write;

/// One strategy's position on the three axes (1 = best).
#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub strategy: StrategyKind,
    pub time_rank: usize,
    pub memory_rank: usize,
    pub impl_rank: usize,
    /// Mean total ms across graphs where the strategy ran.
    pub mean_time_ms: f64,
    /// Mean peak memory across graphs where the strategy ran.
    pub mean_peak_mem: f64,
    /// Graphs the strategy failed on (OOM) — worsens its memory rank.
    pub ooms: usize,
}

/// The paper's qualitative implementation-complexity ordering (§IV-B,
/// Table I): BS and EP are "simple to implement (static)", HP is moderate,
/// WD needs offset machinery, NS rewrites the graph. The adaptive selector
/// composes all five plus migration, so it ranks last on this axis.
pub fn impl_complexity_rank(k: StrategyKind) -> usize {
    match k {
        StrategyKind::BS => 1,
        StrategyKind::EP => 2,
        StrategyKind::HP => 3,
        StrategyKind::WD => 4,
        StrategyKind::NS => 5,
        StrategyKind::AD => 6,
        // A composed alias *is* its monolithic strategy; a genuinely new
        // composition layers the partitioner on the shared kernel
        // machinery, so it sits beyond NS but below the full selector.
        StrategyKind::Composed(s) => match s.alias() {
            Some(k) => impl_complexity_rank(k),
            None => 6,
        },
    }
}

/// Build Figure 9 from the Figure 7 and Figure 8 results.
pub fn fig9(
    _opts: &FigureOpts,
    sssp: &ComparisonFigure,
    bfs: &ComparisonFigure,
    out: &mut impl Write,
) -> Result<Vec<Fig9Row>> {
    let mut time_sum: HashMap<StrategyKind, (f64, usize)> = HashMap::new();
    let mut mem_sum: HashMap<StrategyKind, (f64, usize)> = HashMap::new();
    let mut ooms: HashMap<StrategyKind, usize> = HashMap::new();

    // The execution-time axis follows the SSSP comparison: the paper ranks
    // strategies by where load balancing matters ("load balancing becomes
    // very essential for computationally-intensive graph applications",
    // SVI), while BFS's overhead domination is reported separately.
    // Memory and OOM accounting cover both figures.
    for (fig, is_time_axis) in [(sssp, true), (bfs, false)] {
        for row in &fig.rows {
            // Normalize per graph against BS so large graphs don't dominate.
            let bs_ms = row.outcome(StrategyKind::BS).total_ms().unwrap_or(1.0);
            for (k, o) in &row.outcomes {
                match (o.total_ms(), o.peak_memory()) {
                    (Some(t), Some(m)) => {
                        if is_time_axis {
                            let e = time_sum.entry(*k).or_insert((0.0, 0));
                            e.0 += t / bs_ms;
                            e.1 += 1;
                        }
                        let e = mem_sum.entry(*k).or_insert((0.0, 0));
                        e.0 += m as f64;
                        e.1 += 1;
                    }
                    _ => *ooms.entry(*k).or_insert(0) += 1,
                }
            }
        }
    }

    let mean =
        |m: &HashMap<StrategyKind, (f64, usize)>, k: StrategyKind| -> f64 {
            m.get(&k).map_or(f64::INFINITY, |(s, n)| {
                if *n > 0 {
                    s / *n as f64
                } else {
                    f64::INFINITY
                }
            })
        };

    // Rank by mean normalized time; memory rank additionally penalizes OOMs
    // (a strategy that cannot fit is the worst memory citizen).
    let rank_of = |scores: Vec<(StrategyKind, f64)>| -> HashMap<StrategyKind, usize> {
        let mut sorted = scores;
        sorted.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        sorted
            .into_iter()
            .enumerate()
            .map(|(i, (k, _))| (k, i + 1))
            .collect()
    };

    let time_ranks = rank_of(
        StrategyKind::ALL
            .iter()
            .map(|&k| (k, mean(&time_sum, k)))
            .collect(),
    );
    let mem_ranks = rank_of(
        StrategyKind::ALL
            .iter()
            .map(|&k| {
                let oom_penalty = *ooms.get(&k).unwrap_or(&0) as f64 * 1e12;
                (k, mean(&mem_sum, k) + oom_penalty)
            })
            .collect(),
    );

    writeln!(
        out,
        "\n== Figure 9 — strategy rankings (1 = closest to origin = best) =="
    )?;
    writeln!(
        out,
        "{:<4} {:>10} {:>12} {:>12} {:>14} {:>6}",
        "", "time-rank", "memory-rank", "impl-rank", "mean-peak-MB", "OOMs"
    )?;
    let mut rows = Vec::new();
    for k in StrategyKind::ALL {
        let row = Fig9Row {
            strategy: k,
            time_rank: time_ranks[&k],
            memory_rank: mem_ranks[&k],
            impl_rank: impl_complexity_rank(k),
            mean_time_ms: mean(&time_sum, k),
            mean_peak_mem: mean(&mem_sum, k),
            ooms: *ooms.get(&k).unwrap_or(&0),
        };
        writeln!(
            out,
            "{:<4} {:>10} {:>12} {:>12} {:>14.1} {:>6}",
            k.label(),
            row.time_rank,
            row.memory_rank,
            row.impl_rank,
            row.mean_peak_mem / (1024.0 * 1024.0),
            row.ooms
        )?;
        rows.push(row);
    }
    writeln!(
        out,
        "(paper: EP best on time+impl axes; BS easy+lean but slowest; no overall winner)"
    )?;
    Ok(rows)
}

impl Fig9Row {
    /// JSON rendering.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", self.strategy.label().into()),
            ("time_rank", self.time_rank.into()),
            ("memory_rank", self.memory_rank.into()),
            ("impl_rank", self.impl_rank.into()),
            ("mean_time_ms", self.mean_time_ms.into()),
            ("mean_peak_mem", self.mean_peak_mem.into()),
            ("ooms", self.ooms.into()),
        ])
    }
}
