//! The serving figure (`figserve`): batched-AD multi-query serving vs. N
//! independent single-query AD runs on the same graph and query set.
//!
//! For each (non-Graph500) suite graph, Q synthetic queries are answered
//! twice: once through [`crate::serving::serve`] (one batch, per-batch
//! inspection + policy decision) and once as Q independent
//! [`crate::coordinator::run`] calls (per-run inspection + decision, the
//! status quo). Reported per graph: total simulated time of both, the
//! inspector-pass / policy-decision counts (the amortization the serving
//! layer exists for), and the throughput speedup. Distances are asserted
//! identical between the two paths — the differential oracle is part of the
//! figure, not just the test suite.

use crate::coordinator::{run, RunConfig};
use crate::error::{Error, Result};
use crate::graph::generators::paper_suite;
use crate::graph::Graph;
use crate::serving::{aggregate, serve, synthetic_queries, AggregateMetrics, ServeConfig};
use crate::strategies::StrategyKind;
use crate::util::Json;
use std::io::Write;
use std::sync::Arc;

use super::FigureOpts;

/// Queries per graph in the comparison (≥ 8 so the amortization claim in
/// `benches/serving.rs` is exercised at the documented batch size).
pub const FIGSERVE_QUERIES: usize = 8;

/// One graph's batched-vs-independent comparison.
#[derive(Debug, Clone)]
pub struct ServingRow {
    pub graph: String,
    pub nodes: usize,
    pub edges: usize,
    pub queries: usize,
    /// Aggregate of the batched run's shard metrics.
    pub batched: AggregateMetrics,
    /// Aggregate over the Q independent single-query runs.
    pub independent: AggregateMetrics,
    pub batched_ms: f64,
    pub independent_ms: f64,
    /// `independent_ms / batched_ms` (throughput).
    pub speedup: f64,
    /// `100 * (1 - batched/(independent))` over inspector passes + policy
    /// decisions — the amortization headline.
    pub inspection_savings_pct: f64,
}

impl ServingRow {
    /// JSON rendering.
    pub fn to_json(&self, dev: &crate::sim::DeviceSpec) -> Json {
        Json::obj(vec![
            ("graph", self.graph.as_str().into()),
            ("nodes", self.nodes.into()),
            ("edges", self.edges.into()),
            ("queries", self.queries.into()),
            ("batched", self.batched.to_json(dev)),
            ("independent", self.independent.to_json(dev)),
            ("batched_ms", self.batched_ms.into()),
            ("independent_ms", self.independent_ms.into()),
            ("speedup", self.speedup.into()),
            ("inspection_savings_pct", self.inspection_savings_pct.into()),
        ])
    }
}

/// Run the batched-vs-independent serving comparison (AD policy on both
/// sides; SSSP-weighted mixed traffic).
pub fn fig_serving(opts: &FigureOpts, out: &mut impl Write) -> Result<Vec<ServingRow>> {
    writeln!(
        out,
        "\n== Serving: batched-AD vs. {FIGSERVE_QUERIES} independent AD runs \
         (simulated K20c, mixed BFS/SSSP) =="
    )?;
    writeln!(
        out,
        "{:<12} {:>10} {:>12} {:>8} {:>14} {:>14} {:>10}",
        "graph", "batch ms", "indep ms", "speedup", "inspect b/i", "decide b/i", "saved"
    )?;
    let mut rows = Vec::new();
    for entry in paper_suite(opts.scale) {
        // Graph500 entries are the memory-wall study; the serving figure is
        // about inspection amortization, so skip them to keep it tractable.
        if entry.name.contains("Graph500") {
            continue;
        }
        let g = Arc::new(entry.spec.generate(opts.seed)?);
        let dev = opts.device_for(&entry, &g);
        let queries = synthetic_queries(&g, FIGSERVE_QUERIES, 0.5, opts.seed);

        let cfg = ServeConfig {
            strategy: StrategyKind::AD,
            devices: vec![dev.clone()],
            enforce_budget: opts.enforce_budget,
            ..Default::default()
        };
        let report = serve(&g, &queries, &cfg)?;
        let batched = report.totals();

        let mut independent_metrics = Vec::new();
        for q in &queries {
            let rc = RunConfig {
                algo: q.algo,
                strategy: StrategyKind::AD,
                source: q.source,
                device: dev.clone(),
                enforce_budget: opts.enforce_budget,
                ..Default::default()
            };
            let r = run(&g, &rc)?;
            // Differential check: batched distances equal independent ones.
            if report.dist_of(q.id) != Some(r.dist.as_slice()) {
                return Err(Error::Config(format!(
                    "{}: batched distances diverge from the single-query \
                     engine for query {} ({} from {})",
                    entry.name,
                    q.id,
                    q.algo.name(),
                    q.source
                )));
            }
            independent_metrics.push(r.metrics);
        }
        let independent = aggregate(independent_metrics.iter());

        let batched_ms = batched.total_ms(&dev);
        let independent_ms = independent.total_ms(&dev);
        let speedup = if batched_ms > 0.0 {
            independent_ms / batched_ms
        } else {
            0.0
        };
        let b_id = batched.inspector_passes + batched.policy_decisions;
        let i_id = independent.inspector_passes + independent.policy_decisions;
        let inspection_savings_pct = if i_id > 0 {
            100.0 * (1.0 - b_id as f64 / i_id as f64)
        } else {
            0.0
        };

        writeln!(
            out,
            "{:<12} {:>10.2} {:>12.2} {:>7.2}x {:>6}/{:<7} {:>6}/{:<7} {:>9.1}%",
            entry.name,
            batched_ms,
            independent_ms,
            speedup,
            batched.inspector_passes,
            independent.inspector_passes,
            batched.policy_decisions,
            independent.policy_decisions,
            inspection_savings_pct,
        )?;
        rows.push(ServingRow {
            graph: entry.name.clone(),
            nodes: g.num_nodes(),
            edges: g.num_edges(),
            queries: queries.len(),
            batched,
            independent,
            batched_ms,
            independent_ms,
            speedup,
            inspection_savings_pct,
        });
    }
    writeln!(
        out,
        "(inspect/decide b/i: inspector passes and policy decisions, batched vs. \
         independent; saved: reduction of their sum — the amortization the \
         serving layer buys. Distances are verified identical between paths.)"
    )?;
    Ok(rows)
}
