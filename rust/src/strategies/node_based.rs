//! BS — the node-based baseline (§II-A), modelling LonestarGPU-1.02's
//! data-driven BFS/SSSP.
//!
//! One thread per active worklist node; the thread walks the node's entire
//! adjacency list. Work per thread is proportional to out-degree, so warps
//! containing a high-degree node stall all 32 lanes — the load imbalance
//! that motivates the paper. Strengths: CSR format (low memory), trivially
//! simple. Weakness: high load-imbalance on skewed graphs (Table I).

use super::common::{charge_graph_and_dist, init_dist, NodeFrontier};
use super::{Strategy, StrategyKind};
use crate::coordinator::{exec::flatten_frontier_into, Assignment, ExecCtx, KernelWork, PushTarget};
use crate::error::Result;
use crate::graph::{Csr, Graph, NodeId};
use crate::sim::AccessPattern;
use std::sync::Arc;

/// The node-based baseline strategy.
pub struct NodeBaseline {
    graph: Arc<Csr>,
    frontier: Option<NodeFrontier>,
}

impl NodeBaseline {
    /// New baseline over `graph`.
    pub fn new(graph: Arc<Csr>) -> Self {
        NodeBaseline {
            graph,
            frontier: None,
        }
    }
}

impl Strategy for NodeBaseline {
    fn kind(&self) -> StrategyKind {
        StrategyKind::BS
    }

    fn init(&mut self, ctx: &mut ExecCtx, source: NodeId) -> Result<()> {
        charge_graph_and_dist(ctx, &self.graph, "csr")?;
        init_dist(ctx, self.graph.num_nodes(), source);
        // BS worklists hold node ids only: 4 B per entry.
        self.frontier = Some(NodeFrontier::seeded(ctx, &self.graph, source, "bs-wl", 4)?);
        Ok(())
    }

    fn pending(&self) -> usize {
        self.frontier.as_ref().map_or(0, |f| f.len())
    }

    fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let mut offsets = ctx.scratch.take_u32();
        {
            let wl = self.frontier.as_ref().expect("init first").worklist();
            flatten_frontier_into(&g, wl.nodes(), &mut src, &mut eid);
            // One lane per node: lane l owns the contiguous span of node
            // l's edges — per-lane offsets are the prefix sums of the
            // worklist's cached degrees.
            offsets.push(0u32);
            let mut acc = 0u32;
            for &d in wl.degrees() {
                acc += d;
                offsets.push(acc);
            }
        }

        let work = KernelWork {
            name: "bs_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            // Lanes walk disjoint adjacency lists: uncoalesced.
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&g, &work, None)?;
        self.frontier
            .as_mut()
            .expect("init first")
            .advance(ctx, &g, &result.updated)?;
        ctx.recycle(result);
        ctx.recycle_work(work);
        ctx.metrics.iterations += 1;
        Ok(())
    }

    fn finalize(&self, ctx: &ExecCtx) -> Vec<u32> {
        ctx.dist.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    #[test]
    fn bs_sssp_matches_dijkstra_on_random_graph() {
        let g = Arc::new(crate::graph::generators::erdos_renyi(128, 512, 10, 3).unwrap());
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
        let mut s = NodeBaseline::new(g.clone());
        s.init(&mut ctx, 0).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        assert_eq!(s.finalize(&ctx), traversal::dijkstra(&g, 0));
    }

    #[test]
    fn bs_bfs_matches_reference() {
        let g = Arc::new(crate::graph::generators::road_grid(12, 12, 9, 5).unwrap());
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
        let mut s = NodeBaseline::new(g.clone());
        s.init(&mut ctx, 0).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        assert_eq!(s.finalize(&ctx), traversal::bfs_levels(&g, 0));
    }
}
