//! EP — edge-based task distribution (§II-B, Figure 2).
//!
//! The worklist holds *edges*; the kernel launches the maximum number of
//! resident threads and assigns edges round-robin, which both balances load
//! (each thread gets ⌈W/T⌉ edges) and coalesces memory access (consecutive
//! threads read consecutive worklist slots). Requires the COO-denormalized
//! form: 3·E·4 bytes of device memory versus CSR's (N+2E)·4 — the reason
//! EP cannot run the Graph500 graphs (§IV-A).

use super::common::init_dist;
use super::{Strategy, StrategyKind, StrategyParams};
use crate::coordinator::{Assignment, ExecCtx, KernelWork, PushTarget};
use crate::error::Result;
use crate::graph::{Csr, Graph, NodeId};
use crate::sim::AccessPattern;
use crate::worklist::EdgeWorklist;
use std::sync::Arc;

/// The edge-based parallelism strategy.
pub struct EdgeParallel {
    graph: Arc<Csr>,
    params: StrategyParams,
    input: EdgeWorklist,
    /// The other half of the double buffer: the raw (duplicate-laden)
    /// output worklist is built here and swapped in, retaining capacity
    /// across iterations.
    spare: EdgeWorklist,
    charged: u64,
}

impl EdgeParallel {
    /// New EP instance over `graph`.
    pub fn new(graph: Arc<Csr>, params: StrategyParams) -> Self {
        EdgeParallel {
            graph,
            params,
            input: EdgeWorklist::new(),
            spare: EdgeWorklist::new(),
            charged: 0,
        }
    }

    fn num_threads(&self, ctx: &ExecCtx) -> u32 {
        self.params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads)
    }
}

impl Strategy for EdgeParallel {
    fn kind(&self) -> StrategyKind {
        StrategyKind::EP
    }

    fn init(&mut self, ctx: &mut ExecCtx, source: NodeId) -> Result<()> {
        // EP stores the graph in COO: source endpoints duplicated per edge.
        // This is the allocation that OOMs on Graph500-scale graphs.
        let coo_bytes = 4 * 3 * self.graph.num_edges() as u64;
        ctx.mem.charge("coo", coo_bytes)?;
        ctx.mem.charge("dist", 4 * self.graph.num_nodes() as u64)?;
        // Converting CSR → COO is a one-time streaming pass (overhead).
        ctx.charge_aux_kernel(self.graph.num_edges() as u64, 1);

        init_dist(ctx, self.graph.num_nodes(), source);
        self.input = EdgeWorklist::seeded(&self.graph, source);
        self.charged = self.input.memory_bytes();
        ctx.mem.charge("ep-wl", self.charged)?;
        Ok(())
    }

    fn pending(&self) -> usize {
        self.input.len()
    }

    fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let total = self.input.len();
        let threads = (self.num_threads(ctx) as usize).min(total).max(1) as u32;

        // Stage the input worklist into pooled kernel buffers.
        let mut src = ctx.scratch.take_u32();
        src.extend_from_slice(self.input.srcs());
        let mut eid = ctx.scratch.take_u32();
        eid.extend_from_slice(self.input.edges());
        let work = KernelWork {
            name: "ep_relax",
            src,
            eid,
            assignment: Assignment::Strided {
                num_threads: threads,
            },
            // Round-robin assignment: consecutive lanes touch consecutive
            // worklist slots — coalesced (§II-B).
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Edges,
        };
        let result = ctx.launch(&self.graph, &work, None)?;
        ctx.recycle_work(work);

        // Build the next edge worklist into the spare half of the double
        // buffer: all outgoing edges of every updated node (duplicates
        // included — the worklist explosion of §II-B).
        self.spare.clear();
        for &n in &result.updated {
            self.spare.push_node_edges(&self.graph, n);
        }
        ctx.recycle(result);
        let raw_entries = self.spare.len() as u64;
        ctx.metrics.peak_worklist_entries =
            ctx.metrics.peak_worklist_entries.max(raw_entries);

        // Double buffer: input + raw output simultaneously resident.
        ctx.mem.charge("ep-wl", self.spare.memory_bytes())?;

        // Condense when the worklist outgrows the edge count (§II-B's
        // condensing overhead).
        if self.spare.len() > self.graph.num_edges() {
            let removed = self.spare.condense();
            ctx.metrics.condensed_away += removed as u64;
            ctx.charge_aux_kernel(raw_entries, 2);
        }

        let keep = self.spare.memory_bytes();
        ctx.mem
            .release("ep-wl", self.charged + 8 * raw_entries - keep);
        self.charged = keep;
        std::mem::swap(&mut self.input, &mut self.spare);
        ctx.metrics.iterations += 1;
        Ok(())
    }

    fn finalize(&self, ctx: &ExecCtx) -> Vec<u32> {
        ctx.dist.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    fn run_ep(g: &Arc<Csr>, algo: AlgoKind) -> Vec<u32> {
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, algo, Box::new(NativeRelaxer));
        let mut s = EdgeParallel::new(g.clone(), StrategyParams::default());
        s.init(&mut ctx, 0).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        s.finalize(&ctx)
    }

    #[test]
    fn ep_sssp_matches_dijkstra() {
        let g = Arc::new(
            crate::graph::generators::rmat(
                8,
                2048,
                crate::graph::generators::RmatParams::default(),
                5,
            )
            .unwrap(),
        );
        assert_eq!(run_ep(&g, AlgoKind::Sssp), traversal::dijkstra(&g, 0));
    }

    #[test]
    fn ep_bfs_matches_reference() {
        let g = Arc::new(crate::graph::generators::erdos_renyi(200, 800, 10, 2).unwrap());
        assert_eq!(run_ep(&g, AlgoKind::Bfs), traversal::bfs_levels(&g, 0));
    }

    #[test]
    fn ep_ooms_when_coo_exceeds_budget() {
        let g = Arc::new(crate::graph::generators::erdos_renyi(200, 800, 10, 2).unwrap());
        let dev = DeviceSpec::k20c();
        // budget big enough for CSR but not COO
        let budget = g.memory_bytes() + 100;
        assert!(3 * 4 * g.num_edges() as u64 > budget);
        let mut ctx =
            ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer)).with_budget(budget);
        let mut s = EdgeParallel::new(g.clone(), StrategyParams::default());
        let err = s.init(&mut ctx, 0).unwrap_err();
        assert!(err.is_oom());
    }
}
