//! Automatic maximum-out-degree-threshold (MDT) determination (§III-B).
//!
//! The histogram heuristic: bin the out-degrees into `HistogramBinCount`
//! bins, find the tallest bin, and set
//! `MDT = ((binIndex + 1) / HistogramBinCount) × maxDegree` — the upper
//! edge of the most populous degree range. Choosing the bin where most
//! nodes already sit maximizes the number of nodes with ≈MDT out-degree
//! while minimizing the number of splits.
//!
//! The paper reports MDT = 2–4 for road networks and random graphs, and
//! MDT = 118 for the RMAT graph (Figure 10) — reproduced by the unit tests
//! below and the `fig10` harness.

use crate::graph::stats::DegreeHistogram;
use crate::graph::Csr;

/// Result of the MDT computation, kept for reporting (Figure 10 labels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MdtDecision {
    /// The chosen threshold (≥ 1).
    pub mdt: u32,
    /// Tallest bin index.
    pub peak_bin: usize,
    /// Bin count used.
    pub bins: usize,
    /// Maximum out-degree of the input graph.
    pub max_degree: u32,
}

/// Compute the MDT for `g` using `bins` histogram bins.
///
/// MDT is the *highest degree inside the peak bin*: the heuristic's goal is
/// to "maximize the number of nodes (parent and child) with MDT outdegrees"
/// (§III-B), so the modal nodes themselves must sit at or below MDT —
/// taking the bin's lower edge (or truncating `(binIndex/bins)·maxDegree`)
/// would split the mode itself. Equivalent to the paper's formula up to
/// rounding when bin widths are large (the skewed graphs), and strictly
/// better behaved when the histogram resolves individual degrees (the road
/// networks).
pub fn auto_mdt(g: &Csr, bins: usize) -> MdtDecision {
    let h = DegreeHistogram::of(g, bins);
    let peak = h.peak_bin();
    // Top degree covered by the peak bin; clamped to >= 1 so splitting
    // always terminates.
    let mdt = ((peak as u64 + 1) * h.bin_width as u64 - 1).max(1) as u32;
    MdtDecision {
        mdt,
        peak_bin: peak,
        bins,
        max_degree: h.max_degree,
    }
}

/// Simulated device cycles for computing the histogram + peak scan: one
/// pass over N degrees (histogram build) and one over the bins.
pub fn mdt_overhead_items(g: &Csr) -> u64 {
    use crate::graph::Graph;
    g.num_nodes() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{rmat, road_grid, RmatParams};

    #[test]
    fn mdt_is_at_least_one() {
        let g = road_grid(8, 8, 10, 1).unwrap();
        let d = auto_mdt(&g, 10);
        assert!(d.mdt >= 1);
    }

    #[test]
    fn road_networks_get_small_mdt() {
        // Paper: "for road networks and random graphs, MDT is 2–4".
        let g = road_grid(100, 100, 100, 21).unwrap();
        let d = auto_mdt(&g, 10);
        assert!(
            (2..=4).contains(&d.mdt),
            "road MDT {} outside the paper's 2-4 band (max degree {})",
            d.mdt,
            d.max_degree
        );
    }

    #[test]
    fn rmat_mdt_scales_with_max_degree() {
        // Paper: rmat20 (max degree 1181) gets MDT 118 — exactly one bin
        // width when the mass sits in the lowest of 10 bins.
        let g = rmat(14, 8 << 14, RmatParams::default(), 42).unwrap();
        let d = auto_mdt(&g, 10);
        assert_eq!(
            d.peak_bin, 0,
            "power-law mass must sit in the lowest bin"
        );
        let expected = d.max_degree / 10;
        assert!(
            d.mdt.abs_diff(expected) <= 1,
            "rmat MDT {} should be ~max/10 = {}",
            d.mdt,
            expected
        );
    }

    #[test]
    fn mdt_not_biased_by_graph_size() {
        // The same generative model at two sizes must land MDT in the same
        // *relative* position (the paper's argument for histogramming over
        // avg/max-based rules).
        let small = rmat(10, 8 << 10, RmatParams::default(), 7).unwrap();
        let large = rmat(13, 8 << 13, RmatParams::default(), 7).unwrap();
        let ds = auto_mdt(&small, 10);
        let dl = auto_mdt(&large, 10);
        let rel_s = ds.mdt as f64 / ds.max_degree as f64;
        let rel_l = dl.mdt as f64 / dl.max_degree as f64;
        assert!(
            (rel_s - rel_l).abs() < 0.15,
            "relative MDT drifted: {rel_s} vs {rel_l}"
        );
    }
}
