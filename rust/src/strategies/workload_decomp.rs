//! WD — workload decomposition (§III-A, Figures 3 and 4).
//!
//! The worklist still holds nodes (CSR format survives), but the frontier's
//! edges are block-partitioned: with `W` frontier edges and `T` threads,
//! each thread takes a contiguous chunk of `⌈W/T⌉` edges, which may span
//! node boundaries. The per-thread starting (node, edge) offsets are found
//! by a `find_offsets` kernel that binary-searches the prefix sums of the
//! active nodes' out-degrees (the paper uses Thrust's inclusive scan).
//!
//! Costs charged per iteration, as the paper describes: the scan kernel,
//! the `find_offsets` kernel, the offsets array (8 B × T), the degree
//! array in the worklist (8 B entries), per-edge node-boundary bookkeeping
//! in the main kernel, and uncoalesced access (a node's edges split across
//! threads).

use super::common::{charge_graph_and_dist, init_dist, NodeFrontier};
use super::{Strategy, StrategyKind, StrategyParams};
use crate::coordinator::{exec::flatten_frontier_into, Assignment, ExecCtx, KernelWork, PushTarget};
use crate::error::Result;
use crate::graph::{Csr, Graph, NodeId};
use crate::sim::AccessPattern;
use std::sync::Arc;

/// The workload-decomposition strategy.
pub struct WorkloadDecomposition {
    graph: Arc<Csr>,
    params: StrategyParams,
    frontier: Option<NodeFrontier>,
    offsets_charged: u64,
}

impl WorkloadDecomposition {
    /// New WD instance over `graph`.
    pub fn new(graph: Arc<Csr>, params: StrategyParams) -> Self {
        WorkloadDecomposition {
            graph,
            params,
            frontier: None,
            offsets_charged: 0,
        }
    }

    fn num_threads(&self, ctx: &ExecCtx) -> u32 {
        self.params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads)
    }
}

/// Compute the blocked per-lane offsets for `total` edges over at most
/// `max_threads` lanes — `⌈total/T⌉` edges per lane (the last lane may get
/// fewer) — into a caller-provided scratch buffer (zero allocations once
/// the buffer is warm).
pub fn block_offsets_into(total: usize, max_threads: u32, offsets: &mut Vec<u32>) {
    offsets.clear();
    offsets.push(0);
    if total == 0 {
        return;
    }
    let threads = (max_threads as usize).min(total).max(1);
    let per = (total + threads - 1) / threads;
    let mut at = 0usize;
    while at < total {
        at = (at + per).min(total);
        offsets.push(at as u32);
    }
}

/// Allocating convenience wrapper around [`block_offsets_into`].
pub fn block_offsets(total: usize, max_threads: u32) -> Vec<u32> {
    let mut offsets = Vec::new();
    block_offsets_into(total, max_threads, &mut offsets);
    offsets
}

impl Strategy for WorkloadDecomposition {
    fn kind(&self) -> StrategyKind {
        StrategyKind::WD
    }

    fn init(&mut self, ctx: &mut ExecCtx, source: NodeId) -> Result<()> {
        charge_graph_and_dist(ctx, &self.graph, "csr")?;
        init_dist(ctx, self.graph.num_nodes(), source);
        // WD worklists carry (node, outdegree): 8 B per entry (§III-A's
        // "two associative arrays").
        self.frontier = Some(NodeFrontier::seeded(ctx, &self.graph, source, "wd-wl", 8)?);
        // Persistent offsets array-of-struct: 8 B per thread.
        let t = self.num_threads(ctx) as u64;
        ctx.mem.charge("wd-offsets", 8 * t)?;
        self.offsets_charged = 8 * t;
        Ok(())
    }

    fn pending(&self) -> usize {
        self.frontier.as_ref().map_or(0, |f| f.len())
    }

    fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let max_threads = self.num_threads(ctx);
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let wl_len = {
            let wl = self.frontier.as_ref().expect("init first").worklist();
            flatten_frontier_into(&g, wl.nodes(), &mut src, &mut eid);
            wl.len() as u64
        };
        let total = src.len();

        // Overhead kernel 1: inclusive scan of the worklist's degree array
        // (Thrust API in the paper, Line 10 of Fig. 4). The prefix-sum
        // array is a transient allocation of 4 B per worklist entry.
        ctx.mem.charge("wd-prefix", 4 * wl_len)?;
        ctx.charge_aux_kernel(wl_len, 1);

        // Overhead kernel 2: find_offsets — each of T threads binary
        // searches the prefix sums for its starting (node, edge) pair.
        let threads = (max_threads as usize).min(total.max(1)) as u64;
        let log_wl = (64 - wl_len.leading_zeros() as u64).max(1);
        ctx.charge_aux_kernel(threads, 4 * log_wl);

        let mut offsets = ctx.scratch.take_u32();
        block_offsets_into(total, max_threads, &mut offsets);
        let work = KernelWork {
            name: "wd_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            // A node's edges are separated across threads; lanes read
            // disjoint chunk starts — uncoalesced (§III-A).
            access: AccessPattern::Scattered,
            // The while-loop checking node boundaries (Fig. 4, line 18).
            extra_cycles_per_edge: 4,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&g, &work, None)?;

        ctx.mem.release("wd-prefix", 4 * wl_len);
        self.frontier
            .as_mut()
            .expect("init first")
            .advance(ctx, &g, &result.updated)?;
        ctx.recycle(result);
        ctx.recycle_work(work);
        ctx.metrics.iterations += 1;
        Ok(())
    }

    fn finalize(&self, ctx: &ExecCtx) -> Vec<u32> {
        ctx.dist.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    #[test]
    fn block_offsets_cover_everything_contiguously() {
        for total in [0usize, 1, 7, 100, 1000] {
            for t in [1u32, 3, 32, 1024] {
                let off = block_offsets(total, t);
                assert_eq!(*off.first().unwrap(), 0);
                assert_eq!(*off.last().unwrap() as usize, total);
                assert!(off.windows(2).all(|w| w[0] <= w[1]));
                // chunk sizes differ by at most per
                if total > 0 {
                    let per = (total + (t as usize).min(total) - 1) / (t as usize).min(total);
                    assert!(off.windows(2).all(|w| (w[1] - w[0]) as usize <= per));
                }
            }
        }
    }

    #[test]
    fn wd_sssp_matches_dijkstra_on_skewed_graph() {
        let g = Arc::new(
            crate::graph::generators::rmat(
                9,
                4096,
                crate::graph::generators::RmatParams::default(),
                13,
            )
            .unwrap(),
        );
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
        let mut s = WorkloadDecomposition::new(g.clone(), StrategyParams::default());
        s.init(&mut ctx, 0).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        assert_eq!(s.finalize(&ctx), traversal::dijkstra(&g, 0));
        // WD must have paid scan + find_offsets overheads
        assert!(ctx.metrics.overhead_cycles > 0);
    }

    #[test]
    fn wd_bfs_matches_reference() {
        let g = Arc::new(crate::graph::generators::road_grid(10, 10, 5, 8).unwrap());
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
        let mut s = WorkloadDecomposition::new(g.clone(), StrategyParams::default());
        s.init(&mut ctx, 0).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        assert_eq!(s.finalize(&ctx), traversal::bfs_levels(&g, 0));
    }

    #[test]
    fn wd_balances_better_than_bs_on_star() {
        // a star graph: BS puts all edges on one lane; WD spreads them.
        use crate::graph::Edge;
        let edges: Vec<Edge> = (1..257u32).map(|v| Edge::new(0, v, 1)).collect();
        let g = Arc::new(Csr::from_edges(257, &edges).unwrap());
        let dev = DeviceSpec::k20c();

        let mut ctx_bs = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
        let mut bs = crate::strategies::NodeBaseline::new(g.clone());
        bs.init(&mut ctx_bs, 0).unwrap();
        while bs.pending() > 0 {
            bs.run_iteration(&mut ctx_bs).unwrap();
        }

        let mut ctx_wd = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
        let mut wd = WorkloadDecomposition::new(g.clone(), StrategyParams::default());
        wd.init(&mut ctx_wd, 0).unwrap();
        while wd.pending() > 0 {
            wd.run_iteration(&mut ctx_wd).unwrap();
        }

        assert!(
            ctx_wd.metrics.kernel_cycles < ctx_bs.metrics.kernel_cycles,
            "WD kernel {} should beat BS kernel {} on a star",
            ctx_wd.metrics.kernel_cycles,
            ctx_bs.metrics.kernel_cycles
        );
    }
}
