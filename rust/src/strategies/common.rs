//! Shared node-frontier bookkeeping for the node-based strategies
//! (BS, WD, NS, HP): double-buffered worklists, memory charging, and the
//! condensing pass.

use crate::coordinator::ExecCtx;
use crate::error::Result;
use crate::graph::{Csr, Graph, NodeId};
use crate::worklist::NodeWorklist;

/// Double-buffered node frontier with device-memory accounting.
///
/// `entry_bytes` differs by strategy: BS/NS/HP keep only node ids (4 B),
/// WD additionally keeps the cached out-degree array for its prefix sums
/// (8 B) — this is part of why WD exhausts memory on Graph500-scale inputs
/// where BS squeaks by (DESIGN.md §5).
#[derive(Debug)]
pub struct NodeFrontier {
    label: &'static str,
    entry_bytes: u64,
    charged: u64,
    wl: NodeWorklist,
    /// The other half of the double buffer: [`NodeFrontier::advance`]
    /// builds the next frontier here and swaps, so steady-state iterations
    /// reuse both buffers' capacity instead of reallocating (`inputWl` /
    /// `outputWl` in the paper's pseudocode, finally represented as such
    /// host-side too).
    spare: NodeWorklist,
    /// Reusable dedup bitset (one bit per node): turns the host-side
    /// condensing pass from `O(n log n)` sort into `O(n)` — see
    /// EXPERIMENTS.md §Perf (the simulated *device* cost of condensing is
    /// charged separately and unchanged).
    seen: Vec<u64>,
}

impl NodeFrontier {
    /// Frontier seeded with `source`, charging its initial allocation.
    pub fn seeded(
        ctx: &mut ExecCtx,
        g: &Csr,
        source: NodeId,
        label: &'static str,
        entry_bytes: u64,
    ) -> Result<Self> {
        let wl = NodeWorklist::seeded(g, source);
        let charged = entry_bytes * wl.len() as u64;
        ctx.mem.charge(label, charged)?;
        Ok(NodeFrontier {
            label,
            entry_bytes,
            charged,
            wl,
            spare: NodeWorklist::new(),
            seen: vec![0u64; g.num_nodes().div_ceil(64)],
        })
    }

    /// Frontier adopting an already-built worklist (the adaptive engine's
    /// migration path), charging its allocation.
    pub fn from_worklist(
        ctx: &mut ExecCtx,
        g: &Csr,
        wl: NodeWorklist,
        label: &'static str,
        entry_bytes: u64,
    ) -> Result<Self> {
        let charged = entry_bytes * wl.len() as u64;
        ctx.mem.charge(label, charged)?;
        Ok(NodeFrontier {
            label,
            entry_bytes,
            charged,
            wl,
            spare: NodeWorklist::new(),
            seen: vec![0u64; g.num_nodes().div_ceil(64)],
        })
    }

    /// Current worklist.
    pub fn worklist(&self) -> &NodeWorklist {
        &self.wl
    }

    /// Entries pending.
    pub fn len(&self) -> usize {
        self.wl.len()
    }

    /// True when converged.
    pub fn is_empty(&self) -> bool {
        self.wl.is_empty()
    }

    /// Swap in the next iteration's frontier built from the raw update
    /// stream: charge the raw (duplicate-laden) output buffer alongside
    /// the input buffer (double buffering), run the condensing pass
    /// (charged as an auxiliary kernel), then release the old buffer.
    pub fn advance(&mut self, ctx: &mut ExecCtx, g: &Csr, updated: &[NodeId]) -> Result<()> {
        let raw_entries = updated.len() as u64;
        ctx.metrics.peak_worklist_entries =
            ctx.metrics.peak_worklist_entries.max(raw_entries);

        // Double buffer: input stays allocated while the raw output fills.
        let raw_bytes = self.entry_bytes * raw_entries;
        ctx.mem.charge(self.label, raw_bytes)?;

        // Host-side: O(n) bitset dedup into the spare buffer (the simulated
        // device still pays the condensing kernel below); capacity of both
        // double-buffer halves is retained across iterations.
        self.spare.clear();
        if self.seen.len() * 64 < g.num_nodes() {
            self.seen.resize(g.num_nodes().div_ceil(64), 0);
        }
        for &n in updated {
            let (w, b) = (n as usize / 64, n as usize % 64);
            if self.seen[w] & (1 << b) == 0 {
                self.seen[w] |= 1 << b;
                self.spare.push(n, g.degree(n));
            }
        }
        for &n in self.spare.nodes() {
            self.seen[n as usize / 64] = 0; // clear only touched words
        }
        let removed = updated.len() - self.spare.len();
        ctx.metrics.condensed_away += removed as u64;
        if raw_entries > 0 {
            // Condensing = sort + dedup over the raw buffer.
            ctx.charge_aux_kernel(raw_entries, 2);
        }

        // Old input buffer + the duplicate tail are released; the condensed
        // buffer remains charged.
        let keep = self.entry_bytes * self.spare.len() as u64;
        ctx.mem.release(self.label, self.charged + raw_bytes - keep);
        self.charged = keep;
        std::mem::swap(&mut self.wl, &mut self.spare);
        Ok(())
    }

    /// Release everything (end of run).
    pub fn release(&mut self, ctx: &mut ExecCtx) {
        ctx.mem.release(self.label, self.charged);
        self.charged = 0;
        self.wl.clear();
    }
}

/// Charge the CSR graph storage and the distance array at `init` time.
pub fn charge_graph_and_dist(ctx: &mut ExecCtx, g: &Csr, label: &'static str) -> Result<()> {
    use crate::graph::Graph;
    ctx.mem.charge(label, g.memory_bytes())?;
    ctx.mem.charge("dist", 4 * g.num_nodes() as u64)?;
    Ok(())
}

/// Initialize `ctx.dist` to INF except the source.
pub fn init_dist(ctx: &mut ExecCtx, n: usize, source: NodeId) {
    ctx.dist = vec![crate::INF; n];
    if (source as usize) < n {
        ctx.dist[source as usize] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::Edge;
    use crate::sim::DeviceSpec;

    fn chain() -> Csr {
        Csr::from_edges(3, &[Edge::new(0, 1, 1), Edge::new(1, 2, 1)]).unwrap()
    }

    #[test]
    fn advance_condenses_duplicates() {
        let g = chain();
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
        let mut f = NodeFrontier::seeded(&mut ctx, &g, 0, "wl", 4).unwrap();
        f.advance(&mut ctx, &g, &[1, 1, 2, 1]).unwrap();
        assert_eq!(f.len(), 2);
        assert_eq!(ctx.metrics.condensed_away, 2);
        assert_eq!(ctx.metrics.peak_worklist_entries, 4);
    }

    #[test]
    fn memory_tracks_peak_raw_buffer() {
        let g = chain();
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer));
        let mut f = NodeFrontier::seeded(&mut ctx, &g, 0, "wl", 8).unwrap();
        f.advance(&mut ctx, &g, &[1, 1, 1, 1, 1]).unwrap();
        // peak = input (1 entry) + raw output (5 entries) at 8 B
        assert_eq!(ctx.mem.peak(), 8 * 6);
        // after condensing only 1 entry remains charged
        assert_eq!(ctx.mem.current(), 8);
        f.release(&mut ctx);
        assert_eq!(ctx.mem.current(), 0);
    }

    #[test]
    fn budget_violation_surfaces_as_oom() {
        let g = chain();
        let dev = DeviceSpec::k20c();
        let mut ctx =
            ExecCtx::new(&dev, AlgoKind::Bfs, Box::new(NativeRelaxer)).with_budget(16);
        let mut f = NodeFrontier::seeded(&mut ctx, &g, 0, "wl", 4).unwrap();
        let err = f.advance(&mut ctx, &g, &[1; 100]).unwrap_err();
        assert!(err.is_oom());
    }
}
