//! Partitioners backing the composed schedules ([`super::schedule`]):
//! merge-path equal-span chunking and histogram-binned degree ordering.
//!
//! Both are `_into` functions writing caller-provided scratch (the
//! [`crate::arena`] zero-alloc convention, like
//! [`super::workload_decomp::block_offsets_into`]), and both are pinned by
//! property tests in `rust/tests/strategy_properties.rs`: coverage of every
//! position exactly once, disjoint monotone chunk boundaries, and per-chunk
//! work within the algebra's balance bound.

/// Cap on lanes a composed kernel launches at once (a grid-dimension
/// limit). Below it, merge-path chunks are one `width`-sized span per
/// group; past it, spans grow while staying within ±1 of each other.
pub const MAX_GRID_LANES: usize = 1 << 20;

/// Number of chunks the merge-path partitioner cuts `total` positions
/// into, for `width`-lane groups: one span per group until the grid cap,
/// then the cap. Always at least 1.
pub fn merge_path_chunks(total: usize, width: u32) -> u32 {
    let width = width.max(1) as usize;
    let max_chunks = (MAX_GRID_LANES / width).max(1);
    total.div_ceil(width).clamp(1, max_chunks) as u32
}

/// Equal split of `total` contiguous positions into `chunks` spans whose
/// sizes differ by at most one — the merge-path balance bound. Writes
/// `chunks + 1` monotone boundaries into `out` (`out[0] == 0`,
/// `out[chunks] == total`).
pub fn merge_path_offsets_into(total: usize, chunks: u32, out: &mut Vec<u32>) {
    out.clear();
    let chunks = chunks.max(1) as usize;
    let base = total / chunks;
    let rem = total % chunks;
    out.push(0);
    let mut acc = 0usize;
    for i in 0..chunks {
        acc += base + usize::from(i < rem);
        out.push(acc as u32);
    }
}

/// Log₂ bin of a degree: 0 only for isolated nodes, else the bit length.
/// Within one bin the heaviest node carries less than 2× the lightest —
/// the histogram-binned balance bound.
#[inline]
pub fn degree_bin(degree: u32) -> u32 {
    u32::BITS - degree.leading_zeros()
}

/// Stable counting sort of worklist slots by [`degree_bin`]: writes into
/// `out` a permutation of `0..degrees.len()` ordered bin-ascending, equal
/// bins keeping their original (frontier) order — so a binned kernel walks
/// near-uniform-work groups without perturbing determinism. `counts` is
/// scratch for the 33-entry histogram.
pub fn histogram_bin_order_into(degrees: &[u32], counts: &mut Vec<u32>, out: &mut Vec<u32>) {
    counts.clear();
    counts.resize(u32::BITS as usize + 1, 0);
    for &d in degrees {
        counts[degree_bin(d) as usize] += 1;
    }
    let mut acc = 0u32;
    for c in counts.iter_mut() {
        let v = *c;
        *c = acc;
        acc += v;
    }
    out.clear();
    out.resize(degrees.len(), 0);
    for (i, &d) in degrees.iter().enumerate() {
        let b = degree_bin(d) as usize;
        out[counts[b] as usize] = i as u32;
        counts[b] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_path_covers_and_balances() {
        for (total, chunks) in [(0usize, 1u32), (1, 1), (10, 3), (100, 7), (32, 32)] {
            let mut out = Vec::new();
            merge_path_offsets_into(total, chunks, &mut out);
            assert_eq!(out.len(), chunks as usize + 1);
            assert_eq!(out[0], 0);
            assert_eq!(*out.last().unwrap() as usize, total);
            let spans: Vec<u32> = out.windows(2).map(|w| w[1] - w[0]).collect();
            let (min, max) = (
                spans.iter().min().copied().unwrap(),
                spans.iter().max().copied().unwrap(),
            );
            assert!(max - min <= 1, "spans must differ by at most one");
        }
    }

    #[test]
    fn chunk_count_tracks_width_until_grid_cap() {
        assert_eq!(merge_path_chunks(0, 32), 1);
        assert_eq!(merge_path_chunks(1, 32), 1);
        assert_eq!(merge_path_chunks(33, 32), 2);
        assert_eq!(merge_path_chunks(4096, 1024), 4);
        // Past the cap the count saturates (spans grow instead).
        let huge = MAX_GRID_LANES * 3;
        assert_eq!(merge_path_chunks(huge, 32) as usize, MAX_GRID_LANES / 32);
    }

    #[test]
    fn degree_bins_bound_skew_by_two() {
        assert_eq!(degree_bin(0), 0);
        assert_eq!(degree_bin(1), 1);
        assert_eq!(degree_bin(2), 2);
        assert_eq!(degree_bin(3), 2);
        assert_eq!(degree_bin(4), 3);
        for d in 1u32..1000 {
            let b = degree_bin(d);
            assert!(d >= 1 << (b - 1) && d < (1u64 << b) as u32);
        }
    }

    #[test]
    fn histogram_order_is_stable_bin_ascending_permutation() {
        let degrees = [5u32, 1, 9, 1, 0, 3, 8, 2];
        let (mut counts, mut order) = (Vec::new(), Vec::new());
        histogram_bin_order_into(&degrees, &mut counts, &mut order);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..degrees.len() as u32).collect::<Vec<_>>());
        // Bin-ascending, stable within bins.
        for w in order.windows(2) {
            let (a, b) = (degrees[w[0] as usize], degrees[w[1] as usize]);
            assert!(
                degree_bin(a) < degree_bin(b) || (degree_bin(a) == degree_bin(b) && w[0] < w[1])
            );
        }
    }
}
