//! NS — node splitting (§III-B, Figure 5).
//!
//! A preprocessing pass splits every node with out-degree > MDT into a
//! parent plus ⌈degree/MDT⌉−1 child clones, distributing the outgoing
//! edges evenly; MDT comes from the histogram heuristic ([`super::mdt`]).
//! Incoming edges stay on the parent, which mirrors attribute updates onto
//! its children (extra atomics in the processing kernel). The graph stays
//! in CSR and the kernel is plain node-based processing — but no thread
//! ever walks more than MDT edges.
//!
//! Charged costs: the histogram pass, the split rebuild (which transiently
//! holds *two* CSRs on the device — the allocation that breaks NS on
//! Graph500-scale graphs), the parent→child map, and the per-update child
//! mirroring atomics.

use super::common::{init_dist, NodeFrontier};
use super::mdt::{auto_mdt, MdtDecision};
use super::{Strategy, StrategyKind, StrategyParams};
use crate::coordinator::{exec::flatten_frontier_into, Assignment, ExecCtx, KernelWork, PushTarget, SplitMap};
use crate::error::Result;
use crate::graph::{Csr, Edge, Graph, NodeId};
use crate::sim::AccessPattern;
use std::sync::Arc;

/// Result of the split transform.
#[derive(Debug, Clone)]
pub struct SplitGraph {
    /// The rebuilt graph: original ids `0..n` (parents keep their id),
    /// children appended at `n..n'`.
    pub graph: Csr,
    /// Parent → children ranges.
    pub map: SplitMap,
    /// The MDT decision used.
    pub decision: MdtDecision,
    /// Number of nodes that were split.
    pub split_nodes: u64,
}

/// Split every node of `g` with out-degree > `mdt`, distributing its edges
/// evenly over parent + children (each ending with ≤ `mdt` edges).
pub fn split_graph(g: &Csr, decision: MdtDecision) -> SplitGraph {
    let n = g.num_nodes();
    let mdt = decision.mdt.max(1);
    let mut next_id = n as u32;
    let mut ranges = vec![(0u32, 0u32); n];
    let mut split_nodes = 0u64;
    let mut edges: Vec<Edge> = Vec::with_capacity(g.num_edges());

    for u in 0..n as u32 {
        let deg = g.degree(u);
        let nbrs = g.neighbors(u);
        let wts = g.edge_weights(u);
        if deg <= mdt {
            for i in 0..deg as usize {
                edges.push(Edge::new(u, nbrs[i], wts[i]));
            }
            continue;
        }
        split_nodes += 1;
        let pieces = ((deg + mdt - 1) / mdt) as usize;
        let children = pieces - 1;
        let first_child = next_id;
        next_id += children as u32;
        ranges[u as usize] = (first_child, next_id);
        // Distribute edges evenly: piece i gets deg/pieces (+1 for the
        // first deg%pieces pieces) — every piece ends ≤ MDT.
        let base = deg as usize / pieces;
        let extra = deg as usize % pieces;
        let mut at = 0usize;
        for piece in 0..pieces {
            let take = base + usize::from(piece < extra);
            let owner = if piece == 0 {
                u
            } else {
                first_child + (piece as u32 - 1)
            };
            for i in at..at + take {
                edges.push(Edge::new(owner, nbrs[i], wts[i]));
            }
            at += take;
        }
        debug_assert_eq!(at, deg as usize);
    }

    let graph = Csr::from_edges(next_id as usize, &edges).expect("split preserves validity");
    SplitGraph {
        graph,
        map: SplitMap::new(ranges),
        decision,
        split_nodes,
    }
}

/// The node-splitting strategy.
pub struct NodeSplitting {
    original: Arc<Csr>,
    params: StrategyParams,
    split: Option<SplitGraph>,
    frontier: Option<NodeFrontier>,
}

impl NodeSplitting {
    /// New NS instance over `graph`.
    pub fn new(graph: Arc<Csr>, params: StrategyParams) -> Self {
        NodeSplitting {
            original: graph,
            params,
            split: None,
            frontier: None,
        }
    }

    /// The split result (after `init`), for Figure 10 reporting.
    pub fn split_result(&self) -> Option<&SplitGraph> {
        self.split.as_ref()
    }
}

impl Strategy for NodeSplitting {
    fn kind(&self) -> StrategyKind {
        StrategyKind::NS
    }

    fn init(&mut self, ctx: &mut ExecCtx, source: NodeId) -> Result<()> {
        let g = &self.original;
        let n = g.num_nodes();

        // Histogram + MDT determination (overhead, §III-B).
        let decision = match self.params.mdt_override {
            Some(mdt) => MdtDecision {
                mdt,
                peak_bin: 0,
                bins: self.params.histogram_bins,
                max_degree: g.max_degree(),
            },
            None => auto_mdt(g, self.params.histogram_bins),
        };
        ctx.charge_aux_kernel(n as u64, 2);

        // The split rebuild: old and new CSR transiently coexist on the
        // device. Charge both, then release the old one.
        ctx.mem.charge("csr-old", g.memory_bytes())?;
        let split = split_graph(g, decision);
        ctx.mem.charge("csr", split.graph.memory_bytes())?;
        ctx.mem.release("csr-old", g.memory_bytes());
        // Rebuild pass streams every edge once (overhead kernel).
        ctx.charge_aux_kernel(g.num_edges() as u64 + n as u64, 2);

        let n_split = split.graph.num_nodes();
        // Parent→child map: 8 B per original node.
        ctx.mem.charge("ns-map", 8 * n as u64)?;
        ctx.mem.charge("dist", 4 * n_split as u64)?;
        init_dist(ctx, n_split, source);

        // Seed: the source parent and its children (their dist mirrors 0).
        let mut seeds = vec![source];
        for child in split.map.children(source) {
            ctx.dist[child as usize] = 0;
            seeds.push(child);
        }
        let mut frontier = NodeFrontier::seeded(ctx, &split.graph, seeds[0], "ns-wl", 4)?;
        if seeds.len() > 1 {
            frontier.advance(ctx, &split.graph, &seeds)?;
        }
        self.split = Some(split);
        self.frontier = Some(frontier);
        Ok(())
    }

    fn pending(&self) -> usize {
        self.frontier.as_ref().map_or(0, |f| f.len())
    }

    fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let mut offsets = ctx.scratch.take_u32();
        let split = self.split.as_ref().expect("init first");
        let g = &split.graph;
        {
            let wl = self.frontier.as_ref().expect("init first").worklist();
            flatten_frontier_into(g, wl.nodes(), &mut src, &mut eid);
            // One lane per (possibly child) node — bounded by MDT edges;
            // offsets are the prefix sums of the cached degrees.
            offsets.push(0u32);
            let mut acc = 0u32;
            for &d in wl.degrees() {
                acc += d;
                offsets.push(acc);
            }
        }

        let work = KernelWork {
            name: "ns_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let result = ctx.launch(g, &work, Some(&split.map))?;
        self.frontier
            .as_mut()
            .expect("init first")
            .advance(ctx, g, &result.updated)?;
        ctx.recycle(result);
        ctx.recycle_work(work);
        ctx.metrics.iterations += 1;
        Ok(())
    }

    fn finalize(&self, ctx: &ExecCtx) -> Vec<u32> {
        // Children are clones; the original ids hold the answer.
        ctx.dist[..self.original.num_nodes()].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::stats::DegreeStats;
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    fn decision(mdt: u32, max_degree: u32) -> MdtDecision {
        MdtDecision {
            mdt,
            peak_bin: 0,
            bins: 10,
            max_degree,
        }
    }

    #[test]
    fn paper_figure5_example() {
        // A node with 7 outgoing edges, MDT = 4 → parent keeps 4, one child
        // gets 3 (even distribution: 4+3).
        let edges: Vec<Edge> = (1..8u32).map(|v| Edge::new(0, v, 1)).collect();
        let g = Csr::from_edges(8, &edges).unwrap();
        let s = split_graph(&g, decision(4, 7));
        assert_eq!(s.split_nodes, 1);
        assert_eq!(s.graph.num_nodes(), 9);
        assert_eq!(s.graph.degree(0), 4);
        assert_eq!(s.graph.degree(8), 3);
        assert_eq!(s.map.children(0).collect::<Vec<_>>(), vec![8]);
        assert_eq!(s.graph.num_edges(), g.num_edges());
    }

    #[test]
    fn split_bounds_max_degree_by_mdt() {
        let g = crate::graph::generators::rmat(
            10,
            8 << 10,
            crate::graph::generators::RmatParams::default(),
            3,
        )
        .unwrap();
        let d = auto_mdt(&g, 10);
        let s = split_graph(&g, d);
        let st = DegreeStats::of(&s.graph);
        assert!(
            st.max <= d.mdt,
            "post-split max degree {} exceeds MDT {}",
            st.max,
            d.mdt
        );
        assert_eq!(s.graph.num_edges(), g.num_edges(), "no edges added/lost");
    }

    #[test]
    fn few_nodes_split_in_practice() {
        // Paper: "less than 5% of the nodes undergo split".
        let g = crate::graph::generators::rmat(
            12,
            8 << 12,
            crate::graph::generators::RmatParams::default(),
            4,
        )
        .unwrap();
        let d = auto_mdt(&g, 10);
        let s = split_graph(&g, d);
        let frac = s.split_nodes as f64 / g.num_nodes() as f64;
        assert!(frac < 0.05, "{:.1}% of nodes split", frac * 100.0);
    }

    #[test]
    fn unsplit_graph_is_identity() {
        let g = crate::graph::generators::road_grid(8, 8, 5, 2).unwrap();
        let s = split_graph(&g, decision(100, 8));
        assert_eq!(s.graph, g);
        assert!(s.map.is_trivial());
    }

    fn run_ns(g: &Arc<Csr>, algo: AlgoKind, source: NodeId) -> Vec<u32> {
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, algo, Box::new(NativeRelaxer));
        let mut s = NodeSplitting::new(g.clone(), StrategyParams::default());
        s.init(&mut ctx, source).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        s.finalize(&ctx)
    }

    #[test]
    fn ns_sssp_matches_dijkstra_on_skewed_graph() {
        let g = Arc::new(
            crate::graph::generators::rmat(
                9,
                4096,
                crate::graph::generators::RmatParams::default(),
                17,
            )
            .unwrap(),
        );
        assert_eq!(run_ns(&g, AlgoKind::Sssp, 0), traversal::dijkstra(&g, 0));
    }

    #[test]
    fn ns_bfs_matches_reference_with_split_source() {
        // Source is itself a high-degree (split) node.
        let mut edges: Vec<Edge> = (1..64u32).map(|v| Edge::new(0, v, 1)).collect();
        edges.extend((1..63u32).map(|v| Edge::new(v, v + 1, 1)));
        let g = Arc::new(Csr::from_edges(64, &edges).unwrap());
        assert_eq!(run_ns(&g, AlgoKind::Bfs, 0), traversal::bfs_levels(&g, 0));
    }
}
