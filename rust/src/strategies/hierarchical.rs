//! HP — hierarchical processing (§III-C, Figure 6).
//!
//! Time-decomposition of the workload: each outer iteration over the super
//! worklist runs *sub-iterations*, each a kernel where every remaining node
//! relaxes at most MDT of its unprocessed edges. Threads are thus
//! load-balanced within MDT per kernel without creating child nodes (NS)
//! or separating a node's edges across threads mid-kernel (WD).
//!
//! When the (sub-)worklist shrinks below the block size the strategy
//! switches to workload decomposition to keep occupancy up — the hybrid
//! described in §III-C ("twenty more sub-iterations would spawn one GPU
//! thread each").

use super::common::{charge_graph_and_dist, init_dist, NodeFrontier};
use super::mdt::{auto_mdt, MdtDecision};
use super::workload_decomp::block_offsets_into;
use super::{Strategy, StrategyKind, StrategyParams};
use crate::coordinator::exec::flatten_frontier_into;
use crate::coordinator::{Assignment, ExecCtx, KernelWork, PushTarget};
use crate::error::Result;
use crate::graph::{Csr, Graph, NodeId};
use crate::sim::AccessPattern;
use crate::worklist::hierarchy::SubList;
use std::sync::Arc;

/// The hierarchical-processing strategy.
pub struct Hierarchical {
    graph: Arc<Csr>,
    params: StrategyParams,
    frontier: Option<NodeFrontier>,
    decision: Option<MdtDecision>,
    /// Persistent sub-list, rebuilt in place each outer iteration so its
    /// cursor storage is reused (zero steady-state allocation).
    sub: SubList,
    /// Sub-iteration kernels launched (reported in EXPERIMENTS.md).
    pub sub_iterations: u64,
    /// Times the WD fallback engaged.
    pub wd_switches: u64,
}

impl Hierarchical {
    /// New HP instance over `graph`.
    pub fn new(graph: Arc<Csr>, params: StrategyParams) -> Self {
        Hierarchical {
            graph,
            params,
            frontier: None,
            decision: None,
            sub: SubList::default(),
            sub_iterations: 0,
            wd_switches: 0,
        }
    }

    /// The MDT in use (after `init`).
    pub fn mdt(&self) -> Option<u32> {
        self.decision.map(|d| d.mdt)
    }

    /// WD-style fallback kernel over an explicit edge batch. `src`/`eid`
    /// are consumed and returned to the scratch pool; the returned update
    /// list is pooled too — the caller gives it back with `put_u32` once
    /// folded into its update stream.
    fn launch_wd_style(
        &mut self,
        ctx: &mut ExecCtx,
        src: Vec<NodeId>,
        eid: Vec<u32>,
        wl_len: u64,
    ) -> Result<Vec<NodeId>> {
        self.wd_switches += 1;
        let total = src.len();
        // WD's scan + find_offsets overheads apply to the fallback too.
        ctx.mem.charge("hp-prefix", 4 * wl_len)?;
        ctx.charge_aux_kernel(wl_len, 1);
        let threads = ctx.dev.max_resident_threads;
        let log_wl = (64 - wl_len.leading_zeros() as u64).max(1);
        ctx.charge_aux_kernel((threads as u64).min(total as u64), 4 * log_wl);
        let mut offsets = ctx.scratch.take_u32();
        block_offsets_into(total, threads, &mut offsets);
        let work = KernelWork {
            name: "hp_wd_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 4,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&self.graph, &work, None)?;
        ctx.recycle_work(work);
        ctx.mem.release("hp-prefix", 4 * wl_len);
        Ok(result.updated)
    }
}

impl Strategy for Hierarchical {
    fn kind(&self) -> StrategyKind {
        StrategyKind::HP
    }

    fn init(&mut self, ctx: &mut ExecCtx, source: NodeId) -> Result<()> {
        charge_graph_and_dist(ctx, &self.graph, "csr")?;
        init_dist(ctx, self.graph.num_nodes(), source);
        let decision = match self.params.mdt_override {
            Some(mdt) => MdtDecision {
                mdt,
                peak_bin: 0,
                bins: self.params.histogram_bins,
                max_degree: self.graph.max_degree(),
            },
            None => auto_mdt(&self.graph, self.params.histogram_bins),
        };
        // Histogram pass (overhead), as in NS.
        ctx.charge_aux_kernel(self.graph.num_nodes() as u64, 2);
        self.decision = Some(decision);
        // HP super-worklist entries are node ids: 4 B.
        self.frontier = Some(NodeFrontier::seeded(ctx, &self.graph, source, "hp-wl", 4)?);
        Ok(())
    }

    fn pending(&self) -> usize {
        self.frontier.as_ref().map_or(0, |f| f.len())
    }

    fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let decision = self.decision.expect("init first");
        let mdt = decision.mdt.max(1);
        let block = ctx.dev.block_size as usize;
        let g = self.graph.clone();
        let mut all_updates: Vec<NodeId> = ctx.scratch.take_u32();
        let frontier_len = self.frontier.as_ref().expect("init first").len();

        if frontier_len < block {
            // Small super list → straight to workload decomposition.
            let mut src = ctx.scratch.take_u32();
            let mut eid = ctx.scratch.take_u32();
            {
                let wl = self.frontier.as_ref().expect("init first").worklist();
                flatten_frontier_into(&g, wl.nodes(), &mut src, &mut eid);
            }
            if src.is_empty() {
                ctx.scratch.put_u32(src);
                ctx.scratch.put_u32(eid);
            } else {
                let ups = self.launch_wd_style(ctx, src, eid, frontier_len as u64)?;
                all_updates.extend_from_slice(&ups);
                ctx.scratch.put_u32(ups);
            }
        } else {
            // Sub-iterations over the shrinking sub-list (persistent
            // cursor storage, rebuilt in place).
            {
                let wl = self.frontier.as_ref().expect("init first").worklist();
                self.sub.reset(wl.nodes(), wl.degrees());
            }
            let sub_bytes = self.sub.memory_bytes();
            ctx.mem.charge("hp-sublist", sub_bytes)?;

            while !self.sub.is_empty() {
                if self.sub.len() < block {
                    // Residual tail → WD fallback over the remaining edges.
                    let mut src = ctx.scratch.take_u32();
                    let mut eid = ctx.scratch.take_u32();
                    for c in self.sub.cursors() {
                        let first = g.first_edge(c.node) + c.processed;
                        for e in first..first + c.remaining() {
                            src.push(c.node);
                            eid.push(e);
                        }
                    }
                    let wl_len = self.sub.len() as u64;
                    let ups = self.launch_wd_style(ctx, src, eid, wl_len)?;
                    all_updates.extend_from_slice(&ups);
                    ctx.scratch.put_u32(ups);
                    break;
                }

                // One sub-iteration: lane per node, ≤ MDT edges each.
                self.sub_iterations += 1;
                let mut src = ctx.scratch.take_u32();
                let mut eid = ctx.scratch.take_u32();
                let mut offsets = ctx.scratch.take_u32();
                offsets.push(0u32);
                let mut acc = 0u32;
                for c in self.sub.cursors() {
                    let take = c.remaining().min(mdt);
                    let first = g.first_edge(c.node) + c.processed;
                    for e in first..first + take {
                        src.push(c.node);
                        eid.push(e);
                    }
                    acc += take;
                    offsets.push(acc);
                }
                let work = KernelWork {
                    name: "hp_relax",
                    src,
                    eid,
                    assignment: Assignment::Blocked(offsets),
                    access: AccessPattern::Scattered,
                    // cursor bookkeeping per edge
                    extra_cycles_per_edge: 2,
                    push: PushTarget::Node,
                };
                let result = ctx.launch(&g, &work, None)?;
                all_updates.extend_from_slice(&result.updated);
                ctx.recycle(result);
                ctx.recycle_work(work);
                self.sub.advance(mdt);
                // Sub-list compaction between sub-iterations (overhead).
                ctx.charge_aux_kernel(self.sub.len() as u64 + 1, 1);
            }
            ctx.mem.release("hp-sublist", sub_bytes);
        }

        self.frontier
            .as_mut()
            .expect("init first")
            .advance(ctx, &g, &all_updates)?;
        ctx.scratch.put_u32(all_updates);
        ctx.metrics.iterations += 1;
        Ok(())
    }

    fn finalize(&self, ctx: &ExecCtx) -> Vec<u32> {
        ctx.dist.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    fn run_hp(g: &Arc<Csr>, algo: AlgoKind, params: StrategyParams) -> (Vec<u32>, Hierarchical) {
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, algo, Box::new(NativeRelaxer));
        let mut s = Hierarchical::new(g.clone(), params);
        s.init(&mut ctx, 0).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        let dist = s.finalize(&ctx);
        (dist, s)
    }

    #[test]
    fn hp_sssp_matches_dijkstra() {
        let g = Arc::new(
            crate::graph::generators::rmat(
                9,
                4096,
                crate::graph::generators::RmatParams::default(),
                23,
            )
            .unwrap(),
        );
        let (dist, _) = run_hp(&g, AlgoKind::Sssp, StrategyParams::default());
        assert_eq!(dist, traversal::dijkstra(&g, 0));
    }

    #[test]
    fn hp_bfs_matches_reference() {
        let g = Arc::new(crate::graph::generators::erdos_renyi(300, 1200, 10, 6).unwrap());
        let (dist, _) = run_hp(&g, AlgoKind::Bfs, StrategyParams::default());
        assert_eq!(dist, traversal::bfs_levels(&g, 0));
    }

    #[test]
    fn small_frontiers_use_wd_fallback() {
        // A tiny graph never reaches block_size nodes → every iteration
        // falls back to WD.
        let g = Arc::new(crate::graph::generators::road_grid(8, 8, 5, 9).unwrap());
        let (dist, s) = run_hp(&g, AlgoKind::Bfs, StrategyParams::default());
        assert_eq!(dist, traversal::bfs_levels(&g, 0));
        assert!(s.wd_switches > 0);
        assert_eq!(s.sub_iterations, 0);
    }

    #[test]
    fn large_frontiers_run_sub_iterations() {
        // Frontier > 1024 nodes with degree > MDT forces sub-iterations.
        use crate::graph::Edge;
        let mut edges = Vec::new();
        // source fans out to 2000 hubs; each hub fans out to 8 leaves
        for h in 1..=2000u32 {
            edges.push(Edge::new(0, h, 1));
        }
        let mut next = 2001u32;
        for h in 1..=2000u32 {
            for _ in 0..8 {
                edges.push(Edge::new(h, next, 1));
                next += 1;
            }
        }
        let g = Arc::new(Csr::from_edges(next as usize, &edges).unwrap());
        let (dist, s) = run_hp(
            &g,
            AlgoKind::Bfs,
            StrategyParams {
                mdt_override: Some(3),
                ..Default::default()
            },
        );
        assert_eq!(dist, traversal::bfs_levels(&g, 0));
        assert!(
            s.sub_iterations >= 2,
            "8-degree hubs at MDT 3 need ≥3 sub-iterations, got {}",
            s.sub_iterations
        );
    }
}
