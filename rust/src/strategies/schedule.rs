//! The composable schedule algebra: load balancers as points in
//! *work-aggregation granularity* × *traversal order* instead of monolithic
//! kernels (after Osama's "A Programming Model for GPU Load Balancing";
//! GraphIt's `load_balance.h` and HyperGef's histogram-binned balancer are
//! the two concrete precedents in SNIPPETS.md).
//!
//! The paper's five strategies are named compositions — thin aliases that
//! build the original monolithic implementation, so nothing downstream
//! changes and the differential suite (`rust/tests/schedule_algebra.rs`)
//! can pin bit-identity:
//!
//! | composition              | strategy | reading |
//! |--------------------------|----------|---------|
//! | `thread/sorted`          | BS       | one thread walks one node's whole adjacency, frontier order |
//! | `cta/sorted`             | EP       | the whole cooperative grid strides the flat edge list |
//! | `thread/merge-path`      | WD       | threads take equal edge chunks from the degree prefix sums |
//! | `block/sorted`           | NS       | split nodes bounded by MDT, block-cooperative |
//! | `warp/sorted`            | HP       | warp-level hierarchy with thread fallback |
//!
//! Three compositions are genuinely new balancers with their own lowering
//! ([`composed_step`]):
//!
//! - **`warp/merge-path`** — equal contiguous edge spans per *warp*, found
//!   by diagonal binary search over the frontier's degree prefix sums; at
//!   each step a warp's active lanes read consecutive positions
//!   (coalesced). Successful relaxations write a *dense* per-edge candidate
//!   slot (no append atomics inside the kernel); a separate compaction
//!   kernel — charged as overhead — folds the slots into the next frontier.
//!   This trades a fixed per-iteration aux cost for structurally flat
//!   per-warp cycles: the profiler's peak imbalance factor stays at 1.0
//!   while every monolithic strategy carries straggler warps.
//! - **`block/merge-path`** — the same partition at block granularity
//!   (1024-lane spans): fewer, cheaper diagonal searches, same flat
//!   per-warp profile.
//! - **`block/histogram-binned`** — the frontier is stably counting-sorted
//!   by log₂-degree bin ([`super::partition::histogram_bin_order_into`])
//!   so each warp processes near-uniform-degree nodes (within a bin the
//!   heaviest node is < 2× the lightest). Lowers total lane-idle steps
//!   versus BS's frontier-order warps, at the cost of two binning passes —
//!   and *concentrates* the hubs into dedicated warps, so its imbalance
//!   factor is honestly worse while its cycles are better: the algebra
//!   expresses real trade-offs, not strict wins.

use super::common::{charge_graph_and_dist, init_dist, NodeFrontier};
use super::partition;
use super::{Strategy, StrategyKind};
use crate::coordinator::{
    exec::flatten_frontier_into, Assignment, ExecCtx, KernelWork, LaunchResult, PushTarget,
};
use crate::error::{Error, Result};
use crate::graph::{Csr, Graph, NodeId};
use crate::sim::AccessPattern;
use crate::worklist::NodeWorklist;
use std::sync::Arc;

/// Work-aggregation granularity: which lane group owns one unit of the
/// partitioned work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Granularity {
    /// One thread per work unit.
    Thread,
    /// One 32-lane warp per work unit.
    Warp,
    /// One 1024-lane block per work unit.
    Block,
    /// The whole cooperative grid strides the work.
    Cta,
}

/// Traversal order: how the frontier's work is laid out before lanes are
/// assigned.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Order {
    /// Frontier (worklist) order, node adjacencies contiguous.
    Sorted,
    /// Equal edge spans located by diagonal search over the degree prefix
    /// sums (merge-path).
    MergePath,
    /// Stable log₂-degree binning, bin-ascending.
    HistogramBinned,
}

/// One point in the schedule algebra: `granularity/order`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Schedule {
    pub granularity: Granularity,
    pub order: Order,
}

/// Shorthand constructor used by the tables below.
const fn sched(granularity: Granularity, order: Order) -> Schedule {
    Schedule { granularity, order }
}

impl Schedule {
    /// Warp-granularity merge-path — the flagship new balancer.
    pub const WARP_MERGE_PATH: Schedule = sched(Granularity::Warp, Order::MergePath);
    /// Block-granularity merge-path.
    pub const BLOCK_MERGE_PATH: Schedule = sched(Granularity::Block, Order::MergePath);
    /// Block-granularity histogram-binned.
    pub const BLOCK_HISTOGRAM: Schedule = sched(Granularity::Block, Order::HistogramBinned);

    /// The compositions that are new balancers (no monolithic equivalent),
    /// in reporting order — the rows `figimbalance`/`figad` append after
    /// the paper's strategies.
    pub const NEW: [Schedule; 3] = [
        Schedule::WARP_MERGE_PATH,
        Schedule::BLOCK_MERGE_PATH,
        Schedule::BLOCK_HISTOGRAM,
    ];

    /// The monolithic strategy this composition is a thin alias of, if any.
    /// [`super::build_strategy`] delegates alias compositions to the
    /// original implementation, which is what makes the differential
    /// bit-identity pin hold by construction.
    pub fn alias(&self) -> Option<StrategyKind> {
        match (self.granularity, self.order) {
            (Granularity::Thread, Order::Sorted) => Some(StrategyKind::BS),
            (Granularity::Cta, Order::Sorted) => Some(StrategyKind::EP),
            (Granularity::Thread, Order::MergePath) => Some(StrategyKind::WD),
            (Granularity::Block, Order::Sorted) => Some(StrategyKind::NS),
            (Granularity::Warp, Order::Sorted) => Some(StrategyKind::HP),
            _ => None,
        }
    }

    /// Whether this composition has a lowering (alias or new balancer).
    /// The algebra has 12 points; the four remaining combinations (e.g.
    /// `cta/merge-path`) are rejected at parse time until someone writes
    /// their lowering.
    pub fn supported(&self) -> bool {
        self.alias().is_some() || Schedule::NEW.contains(self)
    }

    /// Canonical `granularity/order` spelling (also the `StrategyKind`
    /// label and the `--schedule` grammar).
    pub fn label(&self) -> &'static str {
        match (self.granularity, self.order) {
            (Granularity::Thread, Order::Sorted) => "thread/sorted",
            (Granularity::Thread, Order::MergePath) => "thread/merge-path",
            (Granularity::Thread, Order::HistogramBinned) => "thread/histogram-binned",
            (Granularity::Warp, Order::Sorted) => "warp/sorted",
            (Granularity::Warp, Order::MergePath) => "warp/merge-path",
            (Granularity::Warp, Order::HistogramBinned) => "warp/histogram-binned",
            (Granularity::Block, Order::Sorted) => "block/sorted",
            (Granularity::Block, Order::MergePath) => "block/merge-path",
            (Granularity::Block, Order::HistogramBinned) => "block/histogram-binned",
            (Granularity::Cta, Order::Sorted) => "cta/sorted",
            (Granularity::Cta, Order::MergePath) => "cta/merge-path",
            (Granularity::Cta, Order::HistogramBinned) => "cta/histogram-binned",
        }
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for Schedule {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        let (g, o) = s
            .split_once('/')
            .ok_or_else(|| Error::Config(format!("schedule {s:?} is not granularity/order")))?;
        let granularity = match g.trim().to_ascii_lowercase().as_str() {
            "thread" => Granularity::Thread,
            "warp" => Granularity::Warp,
            "block" => Granularity::Block,
            "cta" => Granularity::Cta,
            other => {
                return Err(Error::Config(format!(
                    "unknown granularity {other:?} (thread|warp|block|cta)"
                )))
            }
        };
        let order = match o.trim().to_ascii_lowercase().as_str() {
            "sorted" => Order::Sorted,
            "merge-path" => Order::MergePath,
            "histogram-binned" => Order::HistogramBinned,
            other => {
                return Err(Error::Config(format!(
                    "unknown order {other:?} (sorted|merge-path|histogram-binned)"
                )))
            }
        };
        let sched = Schedule { granularity, order };
        if !sched.supported() {
            return Err(Error::Config(format!(
                "composition {} has no lowering yet; supported: the five aliases \
                 (thread/sorted=BS, cta/sorted=EP, thread/merge-path=WD, \
                 block/sorted=NS, warp/sorted=HP) plus warp/merge-path, \
                 block/merge-path, block/histogram-binned",
                sched.label()
            )));
        }
        Ok(sched)
    }
}

/// Which subsystem is launching a composed kernel — picks the static
/// kernel/memory labels so composed launches are distinguishable in
/// Chrome-trace slices across the run / adaptive / serving paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Realm {
    Run,
    Adaptive,
    Serving,
}

/// Static kernel name for a composed launch (trace slice label).
pub(crate) fn kernel_name(s: Schedule, realm: Realm) -> &'static str {
    match (realm, s.granularity, s.order) {
        (Realm::Run, Granularity::Warp, Order::MergePath) => "cs_wmp_relax",
        (Realm::Run, Granularity::Block, Order::MergePath) => "cs_bmp_relax",
        (Realm::Run, Granularity::Block, Order::HistogramBinned) => "cs_bhist_relax",
        (Realm::Adaptive, Granularity::Warp, Order::MergePath) => "ad_cs_wmp_relax",
        (Realm::Adaptive, Granularity::Block, Order::MergePath) => "ad_cs_bmp_relax",
        (Realm::Adaptive, Granularity::Block, Order::HistogramBinned) => "ad_cs_bhist_relax",
        (Realm::Serving, Granularity::Warp, Order::MergePath) => "srv_cs_wmp_relax",
        (Realm::Serving, Granularity::Block, Order::MergePath) => "srv_cs_bmp_relax",
        (Realm::Serving, Granularity::Block, Order::HistogramBinned) => "srv_cs_bhist_relax",
        (Realm::Run, ..) => "cs_relax",
        (Realm::Adaptive, ..) => "ad_cs_relax",
        (Realm::Serving, ..) => "srv_cs_relax",
    }
}

/// Memory-tracker label for a composed step's transient buffers.
pub(crate) fn scratch_label(realm: Realm) -> &'static str {
    match realm {
        Realm::Run => "cs-scratch",
        Realm::Adaptive => "ad-cs-scratch",
        Realm::Serving => "srv-cs-scratch",
    }
}

/// Transient device bytes one composed step of `schedule` needs on top of
/// the frontier itself: the degree prefix sums / bin order (4 B per
/// frontier node) plus, for merge-path, the dense candidate slots (4 B per
/// frontier edge). The adaptive feasibility check and the cost model both
/// call this so prediction matches execution exactly.
pub fn step_scratch_bytes(schedule: Schedule, frontier_nodes: u64, frontier_edges: u64) -> u64 {
    match schedule.order {
        Order::MergePath => 4 * frontier_nodes + 4 * frontier_edges,
        Order::HistogramBinned => 4 * frontier_nodes,
        Order::Sorted => 0,
    }
}

/// One processing step of a composed (non-alias) schedule over a node
/// frontier: flatten, partition per the algebra, launch, charge the
/// order's aux kernels. Shared verbatim by the standalone strategy, the
/// adaptive engine's composed mode and the serving batch engine — the
/// `realm` only changes labels. Returns the raw update stream; the caller
/// advances its frontier and recycles the result.
pub(crate) fn composed_step(
    ctx: &mut ExecCtx,
    g: &Csr,
    wl: &NodeWorklist,
    schedule: Schedule,
    realm: Realm,
) -> Result<LaunchResult> {
    match (schedule.granularity, schedule.order) {
        (Granularity::Warp | Granularity::Block, Order::MergePath) => {
            merge_path_step(ctx, g, wl, schedule, realm)
        }
        (Granularity::Block, Order::HistogramBinned) => {
            histogram_step(ctx, g, wl, schedule, realm)
        }
        _ => Err(Error::Config(format!(
            "composition {} has no direct lowering (aliases run their \
             monolithic strategy)",
            schedule.label()
        ))),
    }
}

/// Merge-path lowering (warp or block granularity): equal contiguous edge
/// spans per lane group, coalesced per-step access, dense relax →
/// compaction epilogue.
fn merge_path_step(
    ctx: &mut ExecCtx,
    g: &Csr,
    wl: &NodeWorklist,
    schedule: Schedule,
    realm: Realm,
) -> Result<LaunchResult> {
    let width = match schedule.granularity {
        Granularity::Warp => ctx.dev.warp_size,
        _ => ctx.dev.block_size,
    };
    let mut src = ctx.scratch.take_u32();
    let mut eid = ctx.scratch.take_u32();
    flatten_frontier_into(g, wl.nodes(), &mut src, &mut eid);
    let total = src.len();
    let wl_len = wl.len() as u64;
    let label = scratch_label(realm);

    // Transient device state: the degree prefix sums (the merge-path work
    // descriptor) and the dense per-edge candidate slots.
    let transient = step_scratch_bytes(schedule, wl_len, total as u64);
    ctx.mem.charge(label, transient)?;
    // Prefix-sum kernel over the frontier degrees.
    ctx.charge_aux_kernel(wl_len, 1);

    let chunks = partition::merge_path_chunks(total, width);
    let mut offsets = ctx.scratch.take_u32();
    partition::merge_path_offsets_into(total, chunks, &mut offsets);
    if total > 0 {
        // One diagonal binary search per chunk boundary locates the span
        // starts in the work descriptor.
        let search_steps = (usize::BITS - total.leading_zeros()) as u64;
        ctx.charge_aux_kernel(chunks as u64 + 1, search_steps);
    }

    let work = KernelWork {
        name: kernel_name(schedule, realm),
        src,
        eid,
        assignment: Assignment::WarpStrided { offsets, width },
        // Each step, a group's active lanes read consecutive positions of
        // its contiguous span.
        access: AccessPattern::Coalesced,
        extra_cycles_per_edge: 0,
        push: PushTarget::Dense,
    };
    let result = ctx.launch(g, &work, None)?;
    if total > 0 {
        // Compaction kernel folds the dense candidate slots into the next
        // frontier (the append atomics the relax kernel skipped).
        ctx.charge_aux_kernel(total as u64, 1);
    }
    ctx.mem.release(label, transient);
    ctx.recycle_work(work);
    Ok(result)
}

/// Histogram-binned lowering: stable log₂-degree counting sort of the
/// frontier, then one lane per node in binned order (near-uniform work per
/// warp).
fn histogram_step(
    ctx: &mut ExecCtx,
    g: &Csr,
    wl: &NodeWorklist,
    schedule: Schedule,
    realm: Realm,
) -> Result<LaunchResult> {
    let wl_len = wl.len() as u64;
    let label = scratch_label(realm);
    let mut counts = ctx.scratch.take_u32();
    let mut order = ctx.scratch.take_u32();
    partition::histogram_bin_order_into(wl.degrees(), &mut counts, &mut order);

    // Transient device state: the binned permutation.
    let transient = step_scratch_bytes(schedule, wl_len, 0);
    ctx.mem.charge(label, transient)?;
    // Counting pass + stable scatter.
    ctx.charge_aux_kernel(wl_len, 1);
    ctx.charge_aux_kernel(wl_len, 1);

    let mut src = ctx.scratch.take_u32();
    let mut eid = ctx.scratch.take_u32();
    let mut offsets = ctx.scratch.take_u32();
    offsets.push(0);
    let mut acc = 0u32;
    for &i in &order {
        let n = wl.nodes()[i as usize];
        let first = g.first_edge(n);
        let deg = g.degree(n);
        src.resize(src.len() + deg as usize, n);
        eid.extend(first..first + deg);
        acc += deg;
        offsets.push(acc);
    }

    let work = KernelWork {
        name: kernel_name(schedule, realm),
        src,
        eid,
        // One lane per node, binned order; lanes still walk disjoint
        // adjacency lists, so access stays scattered — binning narrows the
        // step-count spread inside each warp, not the access pattern.
        assignment: Assignment::Blocked(offsets),
        access: AccessPattern::Scattered,
        extra_cycles_per_edge: 0,
        push: PushTarget::Node,
    };
    let result = ctx.launch(g, &work, None)?;
    ctx.mem.release(label, transient);
    ctx.scratch.put_u32(counts);
    ctx.scratch.put_u32(order);
    ctx.recycle_work(work);
    Ok(result)
}

/// A composed (non-alias) schedule driven as a standalone [`Strategy`]:
/// node frontier in, [`composed_step`] per iteration — structurally the
/// node-based baseline with the algebra's partitioner in place of
/// one-thread-per-node.
pub struct ComposedStrategy {
    graph: Arc<Csr>,
    schedule: Schedule,
    frontier: Option<NodeFrontier>,
}

impl ComposedStrategy {
    /// New composed strategy over `graph`.
    pub fn new(graph: Arc<Csr>, schedule: Schedule) -> Self {
        ComposedStrategy {
            graph,
            schedule,
            frontier: None,
        }
    }
}

impl Strategy for ComposedStrategy {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Composed(self.schedule)
    }

    fn init(&mut self, ctx: &mut ExecCtx, source: NodeId) -> Result<()> {
        charge_graph_and_dist(ctx, &self.graph, "csr")?;
        init_dist(ctx, self.graph.num_nodes(), source);
        // Composed frontiers hold node ids only: 4 B per entry (degrees
        // and prefix sums are rebuilt per step and charged transiently).
        self.frontier = Some(NodeFrontier::seeded(ctx, &self.graph, source, "cs-wl", 4)?);
        Ok(())
    }

    fn pending(&self) -> usize {
        self.frontier.as_ref().map_or(0, |f| f.len())
    }

    fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let result = {
            let wl = self.frontier.as_ref().expect("init first").worklist();
            composed_step(ctx, &g, wl, self.schedule, Realm::Run)?
        };
        self.frontier
            .as_mut()
            .expect("init first")
            .advance(ctx, &g, &result.updated)?;
        ctx.recycle(result);
        ctx.metrics.iterations += 1;
        Ok(())
    }

    fn finalize(&self, ctx: &ExecCtx) -> Vec<u32> {
        ctx.dist.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    #[test]
    fn aliases_map_to_the_five_paper_strategies() {
        let pairs = [
            ("thread/sorted", StrategyKind::BS),
            ("cta/sorted", StrategyKind::EP),
            ("thread/merge-path", StrategyKind::WD),
            ("block/sorted", StrategyKind::NS),
            ("warp/sorted", StrategyKind::HP),
        ];
        for (text, kind) in pairs {
            let s: Schedule = text.parse().unwrap();
            assert_eq!(s.alias(), Some(kind), "{text}");
        }
        for s in Schedule::NEW {
            assert_eq!(s.alias(), None, "{s} must not be an alias");
            assert!(s.supported());
        }
    }

    #[test]
    fn parse_roundtrips_and_rejects_unlowered_points() {
        for s in Schedule::NEW {
            let back: Schedule = s.label().parse().unwrap();
            assert_eq!(back, s);
        }
        // Case/whitespace tolerant.
        assert_eq!(
            "Warp / Merge-Path".parse::<Schedule>().unwrap(),
            Schedule::WARP_MERGE_PATH
        );
        // Valid algebra points without a lowering are rejected with the
        // supported set in the message.
        assert!("cta/merge-path".parse::<Schedule>().is_err());
        assert!("warp/histogram-binned".parse::<Schedule>().is_err());
        // Malformed grammar.
        assert!("warp".parse::<Schedule>().is_err());
        assert!("warp/zigzag".parse::<Schedule>().is_err());
        assert!("lane/sorted".parse::<Schedule>().is_err());
    }

    fn drive(schedule: Schedule, algo: AlgoKind, g: &Arc<Csr>) -> Vec<u32> {
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, algo, Box::new(NativeRelaxer));
        let mut s = ComposedStrategy::new(g.clone(), schedule);
        s.init(&mut ctx, 0).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        s.finalize(&ctx)
    }

    #[test]
    fn new_compositions_match_oracles() {
        let g = Arc::new(crate::graph::generators::erdos_renyi(128, 512, 10, 3).unwrap());
        let sssp = traversal::dijkstra(&g, 0);
        let bfs = traversal::bfs_levels(&g, 0);
        for s in Schedule::NEW {
            assert_eq!(drive(s, AlgoKind::Sssp, &g), sssp, "{s} SSSP");
            assert_eq!(drive(s, AlgoKind::Bfs, &g), bfs, "{s} BFS");
        }
    }

    #[test]
    fn scratch_bytes_cover_each_order() {
        assert_eq!(
            step_scratch_bytes(Schedule::WARP_MERGE_PATH, 10, 100),
            4 * 10 + 4 * 100
        );
        assert_eq!(step_scratch_bytes(Schedule::BLOCK_HISTOGRAM, 10, 100), 40);
        assert_eq!(
            step_scratch_bytes(sched(Granularity::Thread, Order::Sorted), 10, 100),
            0
        );
    }
}
