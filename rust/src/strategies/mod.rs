//! The five load-balancing strategies (Table I) plus the adaptive selector.
//!
//! | Kind | Name                    | Origin   | Module |
//! |------|-------------------------|----------|--------|
//! | `BS` | node-based baseline     | existing (LonestarGPU) | [`node_based`] |
//! | `EP` | edge-based              | existing | [`edge_based`] |
//! | `WD` | workload decomposition  | proposed | [`workload_decomp`] |
//! | `NS` | node splitting          | proposed | [`node_split`] |
//! | `HP` | hierarchical processing | proposed | [`hierarchical`] |
//! | `AD` | adaptive per-iteration selection | this repo (after arXiv:1911.09135) | [`crate::adaptive`] |
//!
//! A [`Strategy`] owns its worklists and (for NS) its transformed graph; the
//! engine drives `init` → `run_iteration` until [`Strategy::pending`] hits
//! zero, then reads the answer back via [`Strategy::finalize`]. `AD` wraps
//! the five static strategies, re-deciding per outer iteration from online
//! frontier statistics and migrating the worklist across representations.

pub mod common;
pub mod edge_based;
pub mod hierarchical;
pub mod mdt;
pub mod node_based;
pub mod node_split;
pub mod partition;
pub mod schedule;
pub mod workload_decomp;

pub use edge_based::EdgeParallel;
pub use hierarchical::Hierarchical;
pub use node_based::NodeBaseline;
pub use node_split::NodeSplitting;
pub use schedule::{ComposedStrategy, Granularity, Order, Schedule};
pub use workload_decomp::WorkloadDecomposition;

use crate::coordinator::ExecCtx;
use crate::error::Result;
use crate::graph::{Csr, NodeId};
use std::sync::Arc;

/// Strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// Node-based baseline (LonestarGPU style).
    BS,
    /// Edge-based parallelism.
    EP,
    /// Workload decomposition.
    WD,
    /// Node splitting.
    NS,
    /// Hierarchical processing.
    HP,
    /// Adaptive per-iteration selection over the five static strategies
    /// ([`crate::adaptive`]).
    AD,
    /// A point in the composable schedule algebra ([`schedule`]):
    /// granularity × order. Compositions aliasing a paper strategy build
    /// the monolithic implementation; the rest lower through
    /// [`schedule::ComposedStrategy`].
    Composed(Schedule),
}

impl StrategyKind {
    /// The paper's five *static* strategies in its reporting order (the
    /// Figure 7/8 bar order; `AD` is this repo's addition and reported
    /// separately — see [`StrategyKind::ALL_WITH_ADAPTIVE`]).
    pub const ALL: [StrategyKind; 5] = [
        StrategyKind::BS,
        StrategyKind::EP,
        StrategyKind::WD,
        StrategyKind::NS,
        StrategyKind::HP,
    ];

    /// Every selectable strategy, adaptive included.
    pub const ALL_WITH_ADAPTIVE: [StrategyKind; 6] = [
        StrategyKind::BS,
        StrategyKind::EP,
        StrategyKind::WD,
        StrategyKind::NS,
        StrategyKind::HP,
        StrategyKind::AD,
    ];

    /// Short label used in figures.
    pub fn label(&self) -> &'static str {
        match self {
            StrategyKind::BS => "BS",
            StrategyKind::EP => "EP",
            StrategyKind::WD => "WD",
            StrategyKind::NS => "NS",
            StrategyKind::HP => "HP",
            StrategyKind::AD => "AD",
            StrategyKind::Composed(s) => s.label(),
        }
    }

    /// Whether the paper classifies it as one of the proposed dynamic
    /// strategies.
    pub fn is_proposed(&self) -> bool {
        matches!(self, StrategyKind::WD | StrategyKind::NS | StrategyKind::HP)
    }

    /// Whether this is the adaptive meta-strategy rather than one of the
    /// paper's five static schemes.
    pub fn is_adaptive(&self) -> bool {
        matches!(self, StrategyKind::AD)
    }

    /// Whether this is a composed schedule rather than a named strategy.
    pub fn is_composed(&self) -> bool {
        matches!(self, StrategyKind::Composed(_))
    }
}

impl std::str::FromStr for StrategyKind {
    type Err = crate::Error;
    fn from_str(s: &str) -> Result<Self> {
        // Compositions spell themselves `granularity/order` (the
        // `--schedule` grammar); the named strategies keep their
        // case-insensitive two-letter codes.
        if s.contains('/') {
            return Ok(StrategyKind::Composed(s.parse()?));
        }
        match s.to_ascii_uppercase().as_str() {
            "BS" => Ok(StrategyKind::BS),
            "EP" => Ok(StrategyKind::EP),
            "WD" => Ok(StrategyKind::WD),
            "NS" => Ok(StrategyKind::NS),
            "HP" => Ok(StrategyKind::HP),
            "AD" => Ok(StrategyKind::AD),
            other => Err(crate::Error::Config(format!("unknown strategy {other:?}"))),
        }
    }
}

impl std::fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Tunables shared across strategies.
#[derive(Debug, Clone)]
pub struct StrategyParams {
    /// `HistogramBinCount` of the MDT heuristic (§III-B).
    pub histogram_bins: usize,
    /// Cap on simultaneously launched threads (defaults to the device's
    /// maximum resident threads; EP always launches this many).
    pub max_threads: Option<u32>,
    /// Explicit MDT override (bypasses the histogram heuristic).
    pub mdt_override: Option<u32>,
    /// Which decision policy the adaptive (`AD`) engine uses.
    pub adaptive_policy: crate::adaptive::AdaptivePolicyKind,
    /// Composed schedules the adaptive policy considers alongside the five
    /// monolithic strategies (`--adaptive-schedules` / the
    /// `adaptive_schedules` config key). Empty by default so existing
    /// decision traces are byte-identical to pre-algebra runs.
    pub composed_candidates: Vec<Schedule>,
}

impl Default for StrategyParams {
    fn default() -> Self {
        StrategyParams {
            histogram_bins: 10,
            max_threads: None,
            mdt_override: None,
            adaptive_policy: crate::adaptive::AdaptivePolicyKind::default(),
            composed_candidates: Vec::new(),
        }
    }
}

/// A load-balancing strategy driving one BFS/SSSP computation.
pub trait Strategy {
    /// Which strategy this is.
    fn kind(&self) -> StrategyKind;

    /// One-time preparation and worklist seeding. Graph storage and any
    /// transformation (NS's split, EP's COO build) is charged to memory and
    /// overhead here. Sizes `ctx.dist` and sets `dist[source] = 0`.
    fn init(&mut self, ctx: &mut ExecCtx, source: NodeId) -> Result<()>;

    /// Entries remaining in the input worklist (0 ⇒ converged).
    fn pending(&self) -> usize;

    /// One outer-loop iteration: process the input worklist, produce the
    /// next one.
    fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()>;

    /// Distances for the *original* node ids (NS truncates its clones).
    fn finalize(&self, ctx: &ExecCtx) -> Vec<u32>;
}

/// Instantiate a strategy over `graph`.
pub fn build_strategy(
    kind: StrategyKind,
    graph: Arc<Csr>,
    params: StrategyParams,
) -> Box<dyn Strategy> {
    match kind {
        StrategyKind::BS => Box::new(NodeBaseline::new(graph)),
        StrategyKind::EP => Box::new(EdgeParallel::new(graph, params)),
        StrategyKind::WD => Box::new(WorkloadDecomposition::new(graph, params)),
        StrategyKind::NS => Box::new(NodeSplitting::new(graph, params)),
        StrategyKind::HP => Box::new(Hierarchical::new(graph, params)),
        StrategyKind::AD => Box::new(crate::adaptive::Adaptive::new(graph, params)),
        StrategyKind::Composed(s) => match s.alias() {
            // Thin alias: the composition *is* the monolithic strategy, so
            // build the original implementation — distances and metrics are
            // identical by construction (pinned in
            // `rust/tests/schedule_algebra.rs`).
            Some(k) => build_strategy(k, graph, params),
            None => Box::new(ComposedStrategy::new(graph, s)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_through_str() {
        for k in StrategyKind::ALL_WITH_ADAPTIVE {
            let parsed: StrategyKind = k.label().parse().unwrap();
            assert_eq!(parsed, k);
        }
        for s in Schedule::NEW {
            let k = StrategyKind::Composed(s);
            let parsed: StrategyKind = k.label().parse().unwrap();
            assert_eq!(parsed, k);
            assert!(k.is_composed() && !k.is_adaptive() && !k.is_proposed());
        }
        // Alias compositions parse to Composed; build_strategy resolves
        // them to the monolithic strategy.
        let parsed: StrategyKind = "thread/sorted".parse().unwrap();
        assert!(matches!(
            parsed,
            StrategyKind::Composed(s) if s.alias() == Some(StrategyKind::BS)
        ));
        assert!("XX".parse::<StrategyKind>().is_err());
        assert!("cta/merge-path".parse::<StrategyKind>().is_err());
    }

    #[test]
    fn proposed_classification() {
        assert!(!StrategyKind::BS.is_proposed());
        assert!(!StrategyKind::EP.is_proposed());
        assert!(StrategyKind::WD.is_proposed());
        assert!(StrategyKind::NS.is_proposed());
        assert!(StrategyKind::HP.is_proposed());
        assert!(!StrategyKind::AD.is_proposed());
        assert!(StrategyKind::AD.is_adaptive());
    }

    #[test]
    fn all_keeps_paper_order_and_excludes_adaptive() {
        assert_eq!(StrategyKind::ALL.len(), 5);
        assert!(!StrategyKind::ALL.contains(&StrategyKind::AD));
        assert_eq!(
            StrategyKind::ALL_WITH_ADAPTIVE.last(),
            Some(&StrategyKind::AD)
        );
    }
}
