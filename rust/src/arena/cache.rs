//! Graph-keyed artifact cache: derived structures that depend only on the
//! graph (and a few parameters), built once and reused across iterations,
//! across the queries of a batch, and across serving batches.
//!
//! Three artifacts qualify today:
//!
//! * the MDT histogram decision ([`crate::strategies::mdt::auto_mdt`] — an
//!   `O(n)` host pass re-run per batch before this cache existed),
//! * NS's split graph + parent table ([`SplitArtifact`] — an `O(E)` rebuild
//!   and the single most expensive host-side transform in the engine),
//! * EP's CSR→COO conversion flag (the conversion itself is simulated, but
//!   a cache hit means the device-side streaming pass is not re-charged).
//!
//! The cache is keyed by graph *identity*: serving holds graphs in
//! `Arc<Csr>` and never mutates them, so `Arc::ptr_eq` is exactly "same
//! graph". The key is held as a [`Weak`] — the weak reference keeps the
//! `ArcInner` allocation alive, so a dropped graph's address can never be
//! recycled into a false match (no ABA), and a failed upgrade resets the
//! cache. [`GraphCache`] is a cheap clonable handle (`Arc<Mutex<..>>`) so
//! one cache can be threaded through every shard of a batch and across
//! repeated [`crate::serving::serve_with_cache`] calls.
//!
//! Memory accounting stays honest on two axes. A host-side artifact hit
//! skips the *rebuild* (the `build` closure). The simulated *build kernel*
//! charge is tracked per **scope** ([`GraphCache::scoped`]) — one scope
//! per simulated device — because an artifact built on shard 0's device
//! is *not* resident on shard 1's: every scope pays the build kernel once,
//! then retains the artifact across its batches. The artifact's resident
//! bytes are still charged to every context that uses it.

use crate::graph::{Csr, NodeId};
use crate::strategies::mdt::MdtDecision;
use crate::strategies::node_split::SplitGraph;
use std::collections::BTreeSet;
use std::sync::{Arc, Mutex, Weak};

/// NS's shared split-graph artifact: the rebuilt CSR plus the
/// clone-id → parent-id table every result fold-back consults.
#[derive(Debug)]
pub struct SplitArtifact {
    /// The split graph (parents keep their ids, clones appended).
    pub split: SplitGraph,
    /// `parent_of[x]` for every split-graph id (identity for originals).
    pub parent_of: Vec<NodeId>,
}

#[derive(Debug, Default)]
struct CacheInner {
    /// Identity of the graph the entries below belong to. The `Weak` pins
    /// the allocation, so address reuse cannot alias a dead key.
    graph_key: Option<Weak<Csr>>,
    /// `(histogram_bins, mdt_override)` → decision.
    mdt: Option<(usize, Option<u32>, MdtDecision)>,
    /// Scopes (simulated devices) that already paid the MDT histogram
    /// kernel for the current `mdt` entry.
    mdt_scopes: BTreeSet<usize>,
    /// `(mdt used)` → artifact.
    split: Option<(u32, Arc<SplitArtifact>)>,
    /// Scopes that already paid the split rebuild kernel.
    split_scopes: BTreeSet<usize>,
    /// Scopes whose device already ran the CSR→COO streaming conversion.
    coo_scopes: BTreeSet<usize>,
}

impl CacheInner {
    fn rekey(&mut self, g: &Arc<Csr>) {
        let same = self
            .graph_key
            .as_ref()
            .and_then(Weak::upgrade)
            .is_some_and(|live| Arc::ptr_eq(&live, g));
        if !same {
            *self = CacheInner {
                graph_key: Some(Arc::downgrade(g)),
                ..CacheInner::default()
            };
        }
    }
}

/// Clonable handle to a graph-keyed artifact cache. Handles carry a
/// *scope* (default 0) identifying the simulated device they charge build
/// kernels to — see [`GraphCache::scoped`].
#[derive(Debug, Clone, Default)]
pub struct GraphCache {
    inner: Arc<Mutex<CacheInner>>,
    scope: usize,
}

impl GraphCache {
    /// Fresh, empty cache (scope 0).
    pub fn new() -> Self {
        Self::default()
    }

    /// A handle sharing this cache's artifacts under a different charge
    /// scope. Host-side builds are shared across scopes (the artifact is
    /// computed once), but each scope — one per simulated device, e.g.
    /// one per [`crate::serving::DeviceShard`] — pays the device build
    /// kernel the first time it touches an artifact: shard 1's device
    /// does not get shard 0's resident copy for free.
    pub fn scoped(&self, scope: usize) -> GraphCache {
        GraphCache {
            inner: self.inner.clone(),
            scope,
        }
    }

    /// The MDT decision for `g` under `(bins, override)`, built with
    /// `build` on a host miss. Charge accounting is deliberately separate
    /// — [`GraphCache::mark_mdt_charged`] is called at the site that
    /// actually charges the histogram kernel, so a batch that is
    /// constructed but never initialized cannot exempt its device from a
    /// charge that was never simulated.
    pub fn mdt(
        &self,
        g: &Arc<Csr>,
        bins: usize,
        mdt_override: Option<u32>,
        build: impl FnOnce() -> MdtDecision,
    ) -> MdtDecision {
        let mut inner = self.inner.lock().expect("graph cache poisoned");
        inner.rekey(g);
        let host_hit =
            matches!(inner.mdt, Some((b, o, _)) if b == bins && o == mdt_override);
        if !host_hit {
            inner.mdt = Some((bins, mdt_override, build()));
            inner.mdt_scopes.clear();
        }
        inner.mdt.expect("just ensured").2
    }

    /// Record that this handle's scope charged the MDT histogram kernel;
    /// returns whether that device had already paid it (a hit ⇒ skip
    /// re-charging). A rebuild of the MDT entry (new parameterization or
    /// new graph) clears the marks.
    pub fn mark_mdt_charged(&self, g: &Arc<Csr>) -> bool {
        let mut inner = self.inner.lock().expect("graph cache poisoned");
        inner.rekey(g);
        !inner.mdt_scopes.insert(self.scope)
    }

    /// The split artifact for `g` at threshold `mdt`, built with `build`
    /// on a host miss. Returns `(artifact, device_hit)` — as with
    /// [`GraphCache::mdt`], `device_hit` is per scope.
    pub fn split(
        &self,
        g: &Arc<Csr>,
        mdt: u32,
        build: impl FnOnce() -> SplitArtifact,
    ) -> (Arc<SplitArtifact>, bool) {
        let mut inner = self.inner.lock().expect("graph cache poisoned");
        inner.rekey(g);
        let host_hit = matches!(&inner.split, Some((m, _)) if *m == mdt);
        if !host_hit {
            inner.split = Some((mdt, Arc::new(build())));
            inner.split_scopes.clear();
        }
        let art = inner.split.as_ref().expect("just ensured").1.clone();
        let device_hit = !inner.split_scopes.insert(self.scope);
        (art, device_hit)
    }

    /// Mark the CSR→COO conversion done for `g` on this handle's scope;
    /// returns whether that scope's device had already run it (a hit ⇒
    /// skip re-charging the streaming pass).
    pub fn mark_coo(&self, g: &Arc<Csr>) -> bool {
        let mut inner = self.inner.lock().expect("graph cache poisoned");
        inner.rekey(g);
        !inner.coo_scopes.insert(self.scope)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;
    use crate::strategies::mdt::auto_mdt;
    use crate::strategies::node_split::split_graph;

    fn hub(n_extra: u32) -> Arc<Csr> {
        let edges: Vec<Edge> = (1..=n_extra).map(|v| Edge::new(0, v, 1)).collect();
        Arc::new(Csr::from_edges(n_extra as usize + 1, &edges).unwrap())
    }

    #[test]
    fn mdt_caches_per_parameterization() {
        let g = hub(16);
        let cache = GraphCache::new();
        let d1 = cache.mdt(&g, 10, None, || auto_mdt(&g, 10));
        let d2 = cache.mdt(&g, 10, None, || panic!("must not rebuild"));
        assert_eq!(d1, d2);
        assert!(!cache.mark_mdt_charged(&g), "first charge is a miss");
        assert!(cache.mark_mdt_charged(&g), "second charge is a hit");
        // Different bins ⇒ rebuild, and the charge marks reset with it.
        let _ = cache.mdt(&g, 5, None, || auto_mdt(&g, 5));
        assert!(
            !cache.mark_mdt_charged(&g),
            "a rebuilt entry must be charged afresh"
        );
    }

    #[test]
    fn scopes_share_artifacts_but_charge_separately() {
        let g = hub(16);
        let shard0 = GraphCache::new();
        let shard1 = shard0.scoped(1);
        let d0 = shard0.mdt(&g, 10, None, || auto_mdt(&g, 10));
        // Shard 1 reuses the host-side artifact (the build closure must
        // not run)...
        let d1 = shard1.mdt(&g, 10, None, || panic!("host artifact is shared"));
        assert_eq!(d0, d1);
        // ...but each simulated device pays its own histogram kernel once.
        assert!(!shard0.mark_mdt_charged(&g));
        assert!(!shard1.mark_mdt_charged(&g), "shard 1 pays its own kernel");
        assert!(shard1.mark_mdt_charged(&g), "then retains it across batches");
        // Same per-device story for the split artifact and the COO pass.
        let build = || {
            let d = auto_mdt(&g, 10);
            let split = split_graph(&g, d);
            let parent_of = crate::adaptive::migrate::parent_of_table(&split, 17);
            SplitArtifact { split, parent_of }
        };
        let (a0, hit0) = shard0.split(&g, 4, build);
        assert!(!hit0);
        let (a1, hit1) = shard1.split(&g, 4, || panic!("host artifact is shared"));
        assert!(!hit1, "shard 1's device pays the split rebuild kernel");
        assert!(Arc::ptr_eq(&a0, &a1), "one shared artifact");
        let (_, hit1b) = shard1.split(&g, 4, || panic!("host artifact is shared"));
        assert!(hit1b);
        assert!(!shard0.mark_coo(&g));
        assert!(!shard1.mark_coo(&g));
        assert!(shard1.mark_coo(&g));
    }

    #[test]
    fn dropped_graph_can_never_alias_a_new_one() {
        let cache = GraphCache::new();
        let d_old = {
            let g1 = hub(16);
            let d = cache.mdt(&g1, 10, None, || auto_mdt(&g1, 10));
            assert!(!cache.mark_mdt_charged(&g1));
            d
        }; // g1 dropped — the Weak key pins its address, upgrade now fails
        let g2 = hub(20);
        let d_new = cache.mdt(&g2, 10, None, || auto_mdt(&g2, 10));
        assert!(
            !cache.mark_mdt_charged(&g2),
            "a new graph must never hit a dead key"
        );
        assert_ne!(d_old.max_degree, d_new.max_degree);
    }

    #[test]
    fn split_caches_and_shares() {
        let g = hub(16);
        let cache = GraphCache::new();
        let build = || {
            let d = auto_mdt(&g, 10);
            let split = split_graph(&g, d);
            let parent_of = crate::adaptive::migrate::parent_of_table(&split, 17);
            SplitArtifact { split, parent_of }
        };
        let (a1, hit1) = cache.split(&g, 4, build);
        assert!(!hit1);
        let (a2, hit2) = cache.split(&g, 4, || panic!("must not rebuild"));
        assert!(hit2);
        assert!(Arc::ptr_eq(&a1, &a2), "one shared artifact");
    }

    #[test]
    fn different_graph_resets() {
        let g1 = hub(8);
        let g2 = hub(8);
        let cache = GraphCache::new();
        assert!(!cache.mark_coo(&g1));
        assert!(cache.mark_coo(&g1), "second mark is a hit");
        assert!(!cache.mark_coo(&g2), "new graph resets the cache");
        // ... and the reset dropped g1's entries too.
        assert!(!cache.mark_coo(&g1));
    }

    #[test]
    fn clones_share_state() {
        let g = hub(4);
        let cache = GraphCache::new();
        let handle = cache.clone();
        assert!(!cache.mark_coo(&g));
        assert!(handle.mark_coo(&g), "clone sees the same entries");
    }
}
