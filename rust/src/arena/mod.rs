//! The scratch-arena / buffer-pool subsystem: zero-allocation steady state
//! for the per-iteration hot path.
//!
//! The paper's central trade-off is per-iteration overhead vs. load balance
//! — WD pays a prefix-sum per iteration, EP pays worklist condensing, NS
//! pays a split-graph transform. Those are *simulated device* costs; this
//! module eliminates their *host-side* analogue: before it existed, every
//! outer iteration heap-allocated fresh flattened frontiers, block offsets,
//! worklists and per-launch staging buffers. Osama et al. (arXiv:2301.04792)
//! make the same observation for real GPU schedules — they are cheap only
//! when their intermediate buffers are reused across launches.
//!
//! Two facilities:
//!
//! * [`ScratchArena`] — a pool of reusable `Vec<u32>` / `Vec<u64>` buffers
//!   (node ids, edge ids, degrees, lane offsets, bitmap words) checked out
//!   at the top of a hot path and returned when the launch retires.
//!   Capacity is retained across round-trips, so steady-state iterations
//!   perform **zero heap allocations** (`rust/tests/alloc_regression.rs`
//!   proves it with a counting global allocator). [`PerfCounters`] records
//!   the pool traffic and is folded into
//!   [`crate::metrics::RunMetrics`] at finalization.
//! * [`GraphCache`] ([`cache`]) — graph-keyed artifacts that depend only on
//!   the graph (the MDT histogram decision, NS's split graph + parent map,
//!   EP's COO conversion flag), shared across iterations, across the
//!   queries of a batch, and across serving batches (the ROADMAP's
//!   "cross-batch reuse" item).

pub mod cache;

pub use cache::{GraphCache, SplitArtifact};

/// Pool-traffic counters: how many buffer checkouts hit the pool, how many
/// had to create a fresh buffer, and how much heap the pool is holding.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PerfCounters {
    /// `take_*` calls served by allocating a fresh (empty) buffer.
    pub buffers_created: u64,
    /// `take_*` calls served from the pool (the steady-state path).
    pub buffers_reused: u64,
    /// Buffers currently parked in the pool.
    pub buffers_pooled: u64,
    /// Capacity bytes currently parked in the pool.
    pub bytes_pooled: u64,
    /// High-water mark of [`PerfCounters::bytes_pooled`] — the arena's heap
    /// footprint, the price paid for zero steady-state allocation.
    pub peak_bytes_pooled: u64,
}

impl PerfCounters {
    fn on_take(&mut self, cap_bytes: u64, from_pool: bool) {
        if from_pool {
            self.buffers_reused += 1;
            self.buffers_pooled -= 1;
            self.bytes_pooled = self.bytes_pooled.saturating_sub(cap_bytes);
        } else {
            self.buffers_created += 1;
        }
    }

    fn on_put(&mut self, cap_bytes: u64) {
        self.buffers_pooled += 1;
        self.bytes_pooled += cap_bytes;
        self.peak_bytes_pooled = self.peak_bytes_pooled.max(self.bytes_pooled);
    }
}

/// A pool of reusable scratch buffers.
///
/// Buffers come back cleared but with their capacity intact; after the
/// first few (warm-up) iterations of a traversal every checkout is a pool
/// hit and no heap traffic occurs. Two element widths cover every hot-path
/// buffer in the engine: `u32` (node ids, edge ids, degrees, offsets,
/// cursors) and `u64` (dedup bitmap words, per-SM cycle accumulators).
///
/// Checkout is not RAII: a caller that errors out mid-launch simply drops
/// its buffers instead of returning them. That is deliberate — every such
/// error (`OutOfMemory`, a backend failure) aborts the whole run, so the
/// pool never needs to survive it; the cost of the simpler contract is
/// only that `buffers_created` counts a few extra warm-ups if a caller
/// ever recovers.
#[derive(Debug, Default)]
pub struct ScratchArena {
    u32s: Vec<Vec<u32>>,
    u64s: Vec<Vec<u64>>,
    counters: PerfCounters,
}

impl ScratchArena {
    /// Empty arena.
    pub fn new() -> Self {
        Self::default()
    }

    /// Check out a cleared `u32` buffer (node/edge ids, degrees, offsets).
    pub fn take_u32(&mut self) -> Vec<u32> {
        match self.u32s.pop() {
            Some(v) => {
                self.counters.on_take(4 * v.capacity() as u64, true);
                v
            }
            None => {
                self.counters.on_take(0, false);
                Vec::new()
            }
        }
    }

    /// Return a `u32` buffer to the pool (cleared here, capacity kept).
    pub fn put_u32(&mut self, mut v: Vec<u32>) {
        v.clear();
        self.counters.on_put(4 * v.capacity() as u64);
        self.u32s.push(v);
    }

    /// Check out a cleared `u64` buffer (bitmap words, cycle accumulators).
    pub fn take_u64(&mut self) -> Vec<u64> {
        match self.u64s.pop() {
            Some(v) => {
                self.counters.on_take(8 * v.capacity() as u64, true);
                v
            }
            None => {
                self.counters.on_take(0, false);
                Vec::new()
            }
        }
    }

    /// Return a `u64` buffer to the pool (cleared here, capacity kept).
    pub fn put_u64(&mut self, mut v: Vec<u64>) {
        v.clear();
        self.counters.on_put(8 * v.capacity() as u64);
        self.u64s.push(v);
    }

    /// Pool-traffic counters (folded into
    /// [`crate::metrics::RunMetrics`] by
    /// [`crate::coordinator::ExecCtx::finalize_metrics`]).
    pub fn counters(&self) -> &PerfCounters {
        &self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_retains_capacity() {
        let mut a = ScratchArena::new();
        let mut v = a.take_u32();
        assert_eq!(a.counters().buffers_created, 1);
        v.extend(0..1000);
        let cap = v.capacity();
        a.put_u32(v);
        assert_eq!(a.counters().bytes_pooled, 4 * cap as u64);
        let v2 = a.take_u32();
        assert!(v2.is_empty(), "buffers come back cleared");
        assert_eq!(v2.capacity(), cap, "capacity survives the round trip");
        assert_eq!(a.counters().buffers_reused, 1);
        assert_eq!(a.counters().bytes_pooled, 0);
    }

    #[test]
    fn pools_are_per_width() {
        let mut a = ScratchArena::new();
        let mut w = a.take_u64();
        w.push(7);
        a.put_u64(w);
        let _ = a.take_u32(); // must not steal the u64 buffer
        assert_eq!(a.counters().buffers_created, 2);
        let w2 = a.take_u64();
        assert!(w2.capacity() >= 1);
        assert_eq!(a.counters().buffers_reused, 1);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut a = ScratchArena::new();
        let mut v = a.take_u32();
        v.extend(0..100);
        a.put_u32(v);
        let peak = a.counters().peak_bytes_pooled;
        assert!(peak >= 400);
        let _ = a.take_u32();
        assert_eq!(a.counters().peak_bytes_pooled, peak, "peak is sticky");
    }

    #[test]
    fn counters_balance() {
        let mut a = ScratchArena::new();
        let bufs: Vec<Vec<u32>> = (0..4).map(|_| a.take_u32()).collect();
        for b in bufs {
            a.put_u32(b);
        }
        let c = *a.counters();
        assert_eq!(c.buffers_created, 4);
        assert_eq!(c.buffers_pooled, 4);
        let _ = a.take_u32();
        assert_eq!(a.counters().buffers_pooled, 3);
    }
}
