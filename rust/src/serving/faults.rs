//! Deterministic fault injection for the serving scheduler.
//!
//! A [`FaultPlan`] is a pre-compiled list of shard-health transitions fired
//! at exact virtual-clock instants. Faults are *simulation events*, not
//! races: the plan is fixed before the run starts, every transition is
//! stamped in integer picoseconds, and the scheduler applies them on the
//! coordinator thread in deterministic order — so a faulted run produces
//! byte-identical reports/traces/profiles for every worker count, exactly
//! like a healthy one.
//!
//! ## Spec grammar (`--fault-spec` / `fault_spec` config key)
//!
//! Clauses separated by `;`, each `kind:key=value,...`. Times are virtual
//! milliseconds (floats allowed); 1 ms = 10⁹ ps.
//!
//! | clause | keys | meaning |
//! |---|---|---|
//! | `stall`  | `shard`, `at`, `for`            | shard leaves service at `at`, returns at `at + for` |
//! | `kill`   | `shard`, `at`                   | shard dies permanently at `at` |
//! | `slow`   | `shard`, `at`, `factor`, [`for`]| ps-per-cycle multiplied by integer `factor` (≥ 1); with `for`, restored to 1 afterwards |
//! | `shrink` | `shard`, `at`, `factor`         | device memory budget divided by integer `factor` (≥ 1); `factor=1` restores it |
//! | `random` | `rate`, `until`                 | seeded synthetic fault stream: `rate` faults per virtual ms until `until` |
//!
//! Example: `stall:shard=0,at=0.5,for=2;slow:shard=1,at=1,factor=4`.
//!
//! The `random` clause (and [`FaultPlan::synthetic`]) draws exponential
//! inter-fault gaps and a weighted kind mix (stalls common, kills rare;
//! kills are capped at `n_shards − 1` so the pool never goes irrecoverably
//! dark) from the run seed — the same inverse-CDF idiom as
//! `synthetic_arrivals`, so the plan is a pure function of
//! `(spec, n_shards, seed)`.

use crate::error::{Error, Result};
use crate::util::Rng;

/// Picoseconds per millisecond (the virtual clock is integer ps).
const PS_PER_MS: f64 = 1e9;

/// Seed-mixing constant for fault streams (cf. `synthetic_arrivals`).
const FAULT_SEED_MIX: u64 = 0xfa17_0b5e_11a5_7a11;

/// One primitive shard-health transition. Composite spec clauses are
/// expanded at parse time (`stall` → `Down` + `Up`; `slow` with `for` →
/// two absolute `Slow` events).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Shard leaves service. `permanent` means it never returns (kill).
    Down {
        /// True for `kill`: no later `Up` can revive the shard.
        permanent: bool,
    },
    /// A transient outage lifts; the shard re-enters placement.
    Up,
    /// Absolute throughput degradation: effective ps-per-cycle is the
    /// device's times `factor` (1 restores full speed).
    Slow {
        /// Integer multiplier on the device's ps-per-cycle (≥ 1).
        factor: u64,
    },
    /// Absolute memory-budget shrink: the worker serves this shard's
    /// batches under `device_budget / divisor` (1 restores the default).
    Shrink {
        /// Integer divisor of the device memory budget (≥ 1).
        divisor: u64,
    },
}

impl FaultKind {
    /// Stable code for trace payloads (`FaultInject.a`).
    pub fn code(self) -> u64 {
        match self {
            FaultKind::Down { permanent: false } => 0,
            FaultKind::Down { permanent: true } => 1,
            FaultKind::Up => 2,
            FaultKind::Slow { .. } => 3,
            FaultKind::Shrink { .. } => 4,
        }
    }

    /// Kind-specific parameter for trace payloads (`FaultInject.b`).
    pub fn param(self) -> u64 {
        match self {
            FaultKind::Slow { factor } => factor,
            FaultKind::Shrink { divisor } => divisor,
            _ => 0,
        }
    }
}

/// A [`FaultKind`] bound to a shard and a virtual instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Virtual instant the transition fires, integer picoseconds.
    pub at_ps: u64,
    /// Target shard index.
    pub shard: usize,
    /// What happens to the shard.
    pub kind: FaultKind,
}

/// A compiled, time-sorted fault schedule. `Default` is the empty
/// (fault-free) plan.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a `--fault-spec` string (grammar in the module docs) against a
    /// pool of `n_shards` shards. `seed` feeds `random:` clauses only.
    pub fn parse(spec: &str, n_shards: usize, seed: u64) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (kind, params) = clause.split_once(':').ok_or_else(|| {
                Error::Config(format!(
                    "fault clause {clause:?} has no kind (want kind:key=value,...)"
                ))
            })?;
            let kind = kind.trim();
            let mut shard = None;
            let mut at_ms = None;
            let mut for_ms = None;
            let mut factor = None;
            let mut rate = None;
            let mut until_ms = None;
            for pair in params.split(',') {
                let pair = pair.trim();
                if pair.is_empty() {
                    continue;
                }
                let (k, v) = pair.split_once('=').ok_or_else(|| {
                    Error::Config(format!("fault parameter {pair:?} in {clause:?} is not key=value"))
                })?;
                let (k, v) = (k.trim(), v.trim());
                match k {
                    "shard" => shard = Some(parse_u64(v, clause, "shard")? as usize),
                    "at" => at_ms = Some(parse_ms(v, clause, "at")?),
                    "for" => for_ms = Some(parse_ms(v, clause, "for")?),
                    "factor" => factor = Some(parse_u64(v, clause, "factor")?),
                    "rate" => rate = Some(parse_ms(v, clause, "rate")?),
                    "until" => until_ms = Some(parse_ms(v, clause, "until")?),
                    other => {
                        return Err(Error::Config(format!(
                            "unknown fault parameter {other:?} in {clause:?}"
                        )))
                    }
                }
            }
            if kind == "random" {
                let rate = rate.ok_or_else(|| missing(clause, "rate"))?;
                let until = until_ms.ok_or_else(|| missing(clause, "until"))?;
                synthesize_into(&mut events, n_shards, rate, until, seed)?;
                continue;
            }
            let shard = shard.ok_or_else(|| missing(clause, "shard"))?;
            if shard >= n_shards {
                return Err(Error::Config(format!(
                    "fault clause {clause:?} targets shard {shard} but the pool has {n_shards}"
                )));
            }
            let at_ps = ms_to_ps(at_ms.ok_or_else(|| missing(clause, "at"))?);
            match kind {
                "stall" => {
                    let dur = for_ms.ok_or_else(|| missing(clause, "for"))?;
                    if dur <= 0.0 {
                        return Err(Error::Config(format!(
                            "fault clause {clause:?}: stall duration must be positive"
                        )));
                    }
                    events.push(FaultEvent {
                        at_ps,
                        shard,
                        kind: FaultKind::Down { permanent: false },
                    });
                    events.push(FaultEvent {
                        at_ps: at_ps + ms_to_ps(dur).max(1),
                        shard,
                        kind: FaultKind::Up,
                    });
                }
                "kill" => events.push(FaultEvent {
                    at_ps,
                    shard,
                    kind: FaultKind::Down { permanent: true },
                }),
                "slow" => {
                    let factor = factor.ok_or_else(|| missing(clause, "factor"))?.max(1);
                    events.push(FaultEvent {
                        at_ps,
                        shard,
                        kind: FaultKind::Slow { factor },
                    });
                    if let Some(dur) = for_ms {
                        events.push(FaultEvent {
                            at_ps: at_ps + ms_to_ps(dur).max(1),
                            shard,
                            kind: FaultKind::Slow { factor: 1 },
                        });
                    }
                }
                "shrink" => {
                    let divisor = factor.ok_or_else(|| missing(clause, "factor"))?.max(1);
                    events.push(FaultEvent {
                        at_ps,
                        shard,
                        kind: FaultKind::Shrink { divisor },
                    });
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown fault kind {other:?} in {clause:?} \
                         (want stall, kill, slow, shrink or random)"
                    )))
                }
            }
        }
        Ok(FaultPlan::from_events(events))
    }

    /// A seeded synthetic fault stream: `rate_per_ms` faults per virtual
    /// millisecond over `[0, horizon_ms)`, exponential gaps, weighted kind
    /// mix. Used by the `figavail` figure and `random:` spec clauses.
    pub fn synthetic(n_shards: usize, rate_per_ms: f64, horizon_ms: f64, seed: u64) -> FaultPlan {
        let mut events = Vec::new();
        // Parameters are pre-validated by construction here.
        synthesize_into(&mut events, n_shards, rate_per_ms, horizon_ms, seed)
            .expect("synthetic fault stream parameters are valid");
        FaultPlan::from_events(events)
    }

    /// Build a plan from raw transitions (sorted into firing order).
    pub fn from_events(mut events: Vec<FaultEvent>) -> FaultPlan {
        // Stable sort: equal (instant, shard) pairs keep spec order.
        events.sort_by_key(|e| (e.at_ps, e.shard));
        FaultPlan { events }
    }

    /// Compiled transitions in firing order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of compiled transitions.
    pub fn len(&self) -> usize {
        self.events.len()
    }
}

fn missing(clause: &str, key: &str) -> Error {
    Error::Config(format!("fault clause {clause:?} is missing {key}="))
}

fn parse_u64(v: &str, clause: &str, key: &str) -> Result<u64> {
    v.parse::<u64>().map_err(|_| {
        Error::Config(format!(
            "fault parameter {key}={v:?} in {clause:?} is not a non-negative integer"
        ))
    })
}

fn parse_ms(v: &str, clause: &str, key: &str) -> Result<f64> {
    let v = v.strip_suffix("ms").unwrap_or(v).trim();
    let x = v.parse::<f64>().map_err(|_| {
        Error::Config(format!("fault parameter {key}={v:?} in {clause:?} is not a number"))
    })?;
    if !x.is_finite() || x < 0.0 {
        return Err(Error::Config(format!(
            "fault parameter {key}={v:?} in {clause:?} must be finite and non-negative"
        )));
    }
    Ok(x)
}

fn ms_to_ps(ms: f64) -> u64 {
    (ms * PS_PER_MS).round() as u64
}

/// The shared synthetic generator behind [`FaultPlan::synthetic`] and
/// `random:` clauses. Exponential inter-fault gaps (inverse CDF, min 1 ps)
/// and a weighted kind mix: 50% transient stalls, 25% slowdowns (with
/// recovery), 17% budget shrinks, 8% kills — kills capped at
/// `n_shards − 1` (excess kills degrade to stalls).
fn synthesize_into(
    events: &mut Vec<FaultEvent>,
    n_shards: usize,
    rate_per_ms: f64,
    horizon_ms: f64,
    seed: u64,
) -> Result<()> {
    if !(rate_per_ms.is_finite() && rate_per_ms >= 0.0) {
        return Err(Error::Config(format!(
            "synthetic fault rate {rate_per_ms} must be finite and non-negative"
        )));
    }
    if !(horizon_ms.is_finite() && horizon_ms >= 0.0) {
        return Err(Error::Config(format!(
            "synthetic fault horizon {horizon_ms} ms must be finite and non-negative"
        )));
    }
    if rate_per_ms == 0.0 || horizon_ms == 0.0 || n_shards == 0 {
        return Ok(());
    }
    let mut rng = Rng::seed_from_u64(seed ^ FAULT_SEED_MIX);
    let mean_gap_ps = PS_PER_MS / rate_per_ms;
    let horizon_ps = ms_to_ps(horizon_ms);
    let mut killed = vec![false; n_shards];
    let mut kills = 0usize;
    let mut at_ps = 0u64;
    loop {
        let u = rng.gen_f64();
        let gap = (-(1.0 - u).ln() * mean_gap_ps).round() as u64;
        at_ps = at_ps.saturating_add(gap.max(1));
        if at_ps >= horizon_ps {
            return Ok(());
        }
        let shard = rng.gen_index(n_shards);
        let mut pick = rng.gen_f64();
        // A dead shard can only be hit again by a no-op; degrade everything
        // aimed at it to a (harmless) transient stall.
        if killed[shard] {
            pick = 0.0;
        }
        if pick < 0.50 {
            // Transient stall, exponential duration (mean 1 ms, clamped).
            let d = rng.gen_f64();
            let dur_ms = (-(1.0 - d).ln()).clamp(0.05, 5.0);
            events.push(FaultEvent {
                at_ps,
                shard,
                kind: FaultKind::Down { permanent: false },
            });
            events.push(FaultEvent {
                at_ps: at_ps + ms_to_ps(dur_ms).max(1),
                shard,
                kind: FaultKind::Up,
            });
        } else if pick < 0.75 {
            // Degradation with recovery after an exponential interval
            // (mean 2 ms).
            let factor = 2 + rng.next_u64() % 7;
            let d = rng.gen_f64();
            let dur_ms = (-(1.0 - d).ln() * 2.0).clamp(0.1, 8.0);
            events.push(FaultEvent {
                at_ps,
                shard,
                kind: FaultKind::Slow { factor },
            });
            events.push(FaultEvent {
                at_ps: at_ps + ms_to_ps(dur_ms).max(1),
                shard,
                kind: FaultKind::Slow { factor: 1 },
            });
        } else if pick < 0.92 {
            let divisor = 2u64 << (rng.next_u64() % 3); // 2, 4 or 8
            events.push(FaultEvent {
                at_ps,
                shard,
                kind: FaultKind::Shrink { divisor },
            });
        } else if kills + 1 < n_shards {
            killed[shard] = true;
            kills += 1;
            events.push(FaultEvent {
                at_ps,
                shard,
                kind: FaultKind::Down { permanent: true },
            });
        } else {
            // Kill budget exhausted: degrade to a short stall instead.
            events.push(FaultEvent {
                at_ps,
                shard,
                kind: FaultKind::Down { permanent: false },
            });
            events.push(FaultEvent {
                at_ps: at_ps + ms_to_ps(0.5),
                shard,
                kind: FaultKind::Up,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_clause_kind_and_sorts() {
        let plan = FaultPlan::parse(
            "slow:shard=1,at=1,factor=4,for=2; stall:shard=0,at=0.5,for=2; \
             kill:shard=2,at=3; shrink:shard=0,at=0.25,factor=8",
            3,
            7,
        )
        .expect("valid spec");
        let ev = plan.events();
        assert_eq!(ev.len(), 6, "stall and bounded slow expand to two events");
        assert!(ev.windows(2).all(|w| (w[0].at_ps, w[0].shard) <= (w[1].at_ps, w[1].shard)));
        assert_eq!(ev[0].at_ps, 250_000_000);
        assert_eq!(ev[0].kind, FaultKind::Shrink { divisor: 8 });
        assert_eq!(ev[1].kind, FaultKind::Down { permanent: false });
        assert_eq!(ev[2].at_ps, 1_000_000_000);
        assert_eq!(ev[2].kind, FaultKind::Slow { factor: 4 });
        assert!(ev.iter().any(|e| e.kind == FaultKind::Up && e.at_ps == 2_500_000_000));
        assert!(ev
            .iter()
            .any(|e| e.kind == FaultKind::Slow { factor: 1 } && e.at_ps == 3_000_000_000));
        assert!(ev
            .iter()
            .any(|e| e.kind == FaultKind::Down { permanent: true } && e.shard == 2));
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "stall:shard=0,at=1",          // missing for=
            "stall:shard=9,at=1,for=1",    // shard out of range
            "warp:shard=0,at=1",           // unknown kind
            "slow:shard=0,at=1",           // missing factor
            "stall:shard=0,at=x,for=1",    // non-numeric time
            "stall:shard=0,at=1,oops=2",   // unknown key
            "shard=0,at=1",                // no kind
            "random:rate=1",               // missing until
        ] {
            assert!(FaultPlan::parse(bad, 2, 0).is_err(), "{bad:?} must be rejected");
        }
        assert!(FaultPlan::parse("", 2, 0).expect("empty spec").is_empty());
    }

    #[test]
    fn synthetic_is_seed_deterministic_and_caps_kills() {
        let a = FaultPlan::synthetic(3, 2.0, 20.0, 42);
        let b = FaultPlan::synthetic(3, 2.0, 20.0, 42);
        assert_eq!(a, b, "same seed, same plan");
        assert_ne!(a, FaultPlan::synthetic(3, 2.0, 20.0, 43), "seed matters");
        assert!(!a.is_empty(), "2 faults/ms over 20 ms should fire");
        let kills = a
            .events()
            .iter()
            .filter(|e| e.kind == FaultKind::Down { permanent: true })
            .count();
        assert!(kills < 3, "kills capped below the pool size, got {kills}");
        // Recovery events trail the horizon by at most the clamped
        // maximum outage/degradation duration (8 ms).
        assert!(a.events().iter().all(|e| e.at_ps <= ms_to_ps(20.0) + ms_to_ps(8.0)));
        assert_eq!(FaultPlan::synthetic(3, 0.0, 20.0, 42).len(), 0);
    }

    #[test]
    fn random_clause_matches_synthetic() {
        let spec = FaultPlan::parse("random:rate=1.5,until=10", 2, 99).expect("random clause");
        let direct = FaultPlan::synthetic(2, 1.5, 10.0, 99);
        assert_eq!(spec, direct);
    }
}
