//! The batched multi-query engine: N concurrent BFS/SSSP queries over one
//! shared CSR, with the frontier inspection and the AD policy decision
//! amortized across the whole batch.
//!
//! Per outer iteration the batch (1) builds the bitmask-tagged
//! [`MergedWorklist`] from the per-query frontiers, (2) runs **one**
//! [`FrontierInspector`] pass over the merged degree array, (3) asks the
//! policy for **one** strategy choice (restricted to the memory-feasible
//! candidates, exactly like the single-query [`crate::adaptive::Adaptive`]
//! engine), then (4) executes one iteration *per active query* in that
//! strategy's kernel style, swapping each query's `dist` array into the
//! [`ExecCtx`]. Structures that depend only on the graph — the MDT
//! histogram, EP's COO materialization, NS's split graph and parent map —
//! are built **once per batch** and shared by every query, which is the
//! second amortization the serving layer exists for.
//!
//! Because every per-query relaxation is an exact min-propagation, a
//! batched run converges to the same distance arrays as running each query
//! alone; [`replay_single`] is the baked-in differential oracle that
//! asserts exactly that through the existing single-query engine.

use crate::adaptive::engine::{hp_wd_fallback, INSPECT_BASE_CYCLES};
use crate::adaptive::inspect::{FrontierInspector, FrontierSnapshot};
use crate::adaptive::migrate;
use crate::adaptive::policy::{build_policy, requires_migration, Feasibility, Policy, PolicyInput};
use crate::arena::{GraphCache, SplitArtifact};
use crate::coordinator::exec::flatten_frontier_into;
use crate::coordinator::{run, Assignment, ExecCtx, KernelWork, PushTarget, RunConfig};
use crate::error::{Error, Result};
use crate::graph::{Csr, Graph, NodeId};
use crate::metrics::DecisionRecord;
use crate::sim::AccessPattern;
use crate::strategies::mdt::{auto_mdt, MdtDecision};
use crate::strategies::node_split::split_graph;
use crate::strategies::schedule::{composed_step, step_scratch_bytes, Realm};
use crate::strategies::workload_decomp::block_offsets_into;
use crate::strategies::{Schedule, StrategyKind, StrategyParams};
use crate::telemetry::TraceEventKind;
use crate::worklist::hierarchy::SubList;
use crate::worklist::NodeWorklist;
use std::sync::Arc;

use super::merged::{MergedBuilder, MergedWorklist, MAX_SUPPORTED_QUERIES_PER_SHARD};
use super::query::Query;

// Device-memory labels of the batch engine's allocations.
const SRV_CSR: &str = "srv-csr";
const SRV_DIST: &str = "srv-dist";
const SRV_WL: &str = "srv-wl";
const SRV_MERGED: &str = "srv-merged";
const SRV_COO: &str = "srv-coo";
const SRV_EP_WL: &str = "srv-ep-wl";
const SRV_NS_CSR: &str = "srv-ns-csr";
const SRV_NS_MAP: &str = "srv-ns-map";
const SRV_WD_PREFIX: &str = "srv-wd-prefix";
const SRV_WD_OFFSETS: &str = "srv-wd-offsets";
const SRV_HP_SUBLIST: &str = "srv-hp-sublist";

/// One query's live state inside a batch: its own distance array and node
/// frontier (canonical original-graph node space between iterations; the
/// chosen strategy's representation is materialized per iteration through
/// [`crate::adaptive::migrate`]).
#[derive(Debug)]
struct QueryState {
    query: Query,
    dist: Vec<u32>,
    frontier: NodeWorklist,
    /// The other half of the frontier double buffer:
    /// [`QueryBatch::advance`] dedups the update stream here and swaps,
    /// so steady-state iterations reuse both halves' capacity.
    spare: NodeWorklist,
    iterations: u32,
}

/// A batch of concurrent queries over one shared CSR.
pub struct QueryBatch {
    graph: Arc<Csr>,
    params: StrategyParams,
    /// The configured strategy: a static kind runs every iteration in that
    /// style; [`StrategyKind::AD`] re-decides per batch iteration.
    strategy: StrategyKind,
    policy: Option<Box<dyn Policy>>,
    /// Graph-keyed artifact cache (MDT decision, split graph, COO flag) —
    /// shared across the batches of a [`crate::serving::serve_with_cache`]
    /// sweep, which is where the cross-batch reuse happens.
    cache: GraphCache,
    mdt: MdtDecision,
    split: Option<Arc<SplitArtifact>>,
    coo_charged: bool,
    /// The mode the previous iteration ran in (AD hysteresis/migration).
    mode: StrategyKind,
    states: Vec<QueryState>,
    /// Retired per-query states parked between batches: a smaller batch
    /// [`QueryBatch::reset`] leaves surplus states (and their warm dist /
    /// frontier capacity) here for the next larger one.
    parked: Vec<QueryState>,
    /// Σ `SRV_DIST` bytes currently charged (released whole by
    /// [`QueryBatch::retire`] so a persistent context's accounting stays
    /// balanced across batches).
    dist_charged: u64,
    /// Reusable dedup bitset for [`QueryBatch::advance`] (queries step
    /// sequentially, so one buffer serves the whole batch); only touched
    /// words are cleared between uses, as in
    /// [`crate::strategies::common::NodeFrontier`]. Drawn from the arena
    /// in [`QueryBatch::init`], returned by [`QueryBatch::recycle`].
    seen: Vec<u64>,
    /// Persistent merge scratch: the pair builder and the merged list it
    /// fills, rebuilt in place every AD batch iteration.
    builder: MergedBuilder,
    merged_buf: MergedWorklist,
    /// Per-query frontier view scratch (original node space), rebuilt in
    /// place for every stepped query.
    view: NodeWorklist,
    /// NS's split-space frontier scratch.
    split_view: NodeWorklist,
    /// HP's persistent sub-list.
    sub: SubList,
    /// Active slot indices of the current iteration.
    active: Vec<usize>,
}

impl QueryBatch {
    /// New batch over `graph`. At most
    /// [`MAX_SUPPORTED_QUERIES_PER_SHARD`] queries (the merged worklist's
    /// tag grows one `u64` word per 64 slots); every source must be in
    /// range. The per-shard *policy* cap is the serving config's
    /// `max_batch`, enforced by the shard/scheduler layer.
    pub fn new(
        graph: Arc<Csr>,
        queries: &[Query],
        strategy: StrategyKind,
        params: StrategyParams,
    ) -> Result<Self> {
        Self::with_cache(graph, queries, strategy, params, GraphCache::new())
    }

    /// [`QueryBatch::new`] sharing a [`GraphCache`]: graph-keyed artifacts
    /// (the MDT histogram decision, NS's split graph + parent table, the
    /// COO conversion flag) built by an earlier batch on the same graph
    /// are reused instead of rebuilt — the cross-batch amortization the
    /// serving layer exists for. Distances are unaffected; the one-time
    /// build kernels are skipped only when the cache handle's *scope*
    /// (simulated device — see [`GraphCache::scoped`]) already paid them,
    /// so shards never get another device's residency for free.
    pub fn with_cache(
        graph: Arc<Csr>,
        queries: &[Query],
        strategy: StrategyKind,
        params: StrategyParams,
        cache: GraphCache,
    ) -> Result<Self> {
        Self::validate(&graph, queries)?;
        // Alias compositions serve as the monolithic strategy they name
        // (same normalization as `build_strategy`).
        let strategy = match strategy {
            StrategyKind::Composed(s) => s.alias().unwrap_or(strategy),
            _ => strategy,
        };
        let policy = if strategy == StrategyKind::AD {
            Some(build_policy(params.adaptive_policy))
        } else {
            None
        };
        let mdt = cache.mdt(&graph, params.histogram_bins, params.mdt_override, || {
            match params.mdt_override {
                Some(mdt) => MdtDecision {
                    mdt,
                    peak_bin: 0,
                    bins: params.histogram_bins,
                    max_degree: graph.max_degree(),
                },
                None => auto_mdt(&graph, params.histogram_bins),
            }
        });
        let states = queries
            .iter()
            .map(|&query| QueryState {
                query,
                dist: Vec::new(),
                frontier: NodeWorklist::new(),
                spare: NodeWorklist::new(),
                iterations: 0,
            })
            .collect();
        Ok(QueryBatch {
            graph,
            params,
            strategy,
            policy,
            cache,
            mdt,
            split: None,
            coo_charged: false,
            mode: StrategyKind::BS,
            states,
            parked: Vec::new(),
            dist_charged: 0,
            seen: Vec::new(),
            builder: MergedBuilder::new(),
            merged_buf: MergedWorklist::default(),
            view: NodeWorklist::new(),
            split_view: NodeWorklist::new(),
            sub: SubList::default(),
            active: Vec::new(),
        })
    }

    /// Source / size validation shared by [`QueryBatch::with_cache`] and
    /// [`QueryBatch::reset`]. The per-shard *policy* limit (`max_batch`)
    /// is enforced by the callers that own a config — here only the
    /// structural mask ceiling applies.
    fn validate(graph: &Csr, queries: &[Query]) -> Result<()> {
        if queries.len() > MAX_SUPPORTED_QUERIES_PER_SHARD {
            return Err(Error::Config(format!(
                "batch of {} queries exceeds the {MAX_SUPPORTED_QUERIES_PER_SHARD}-query \
                 mask ceiling",
                queries.len()
            )));
        }
        for q in queries {
            if q.source as usize >= graph.num_nodes() {
                return Err(Error::Config(format!(
                    "query {}: source {} out of range (n = {})",
                    q.id,
                    q.source,
                    graph.num_nodes()
                )));
            }
        }
        Ok(())
    }

    /// Charge shared storage and seed every query's frontier. The dist
    /// arrays and the dedup bitmap are drawn from the context's scratch
    /// arena, so a caller that [`QueryBatch::recycle`]s a retired batch
    /// serves the next one without re-allocating them.
    pub fn init(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        self.seed(ctx)
    }

    /// Re-arm a retired batch engine for a new query set, reusing every
    /// internal buffer (per-slot dist arrays, frontiers, merge scratch,
    /// the dedup bitmap). This is the serving scheduler's steady-state
    /// path: one engine per shard, [`QueryBatch::retire`]d and reset per
    /// batch, allocating nothing once its high-water batch size has been
    /// seen. Call [`QueryBatch::retire`] first when a previous batch ran
    /// on the same context, or the memory accounting double-charges.
    pub fn reset(&mut self, ctx: &mut ExecCtx, queries: &[Query]) -> Result<()> {
        Self::validate(&self.graph, queries)?;
        while self.states.len() > queries.len() {
            self.parked.push(self.states.pop().expect("len checked"));
        }
        while self.states.len() < queries.len() {
            self.states.push(self.parked.pop().unwrap_or_else(|| QueryState {
                query: queries[0],
                dist: Vec::new(),
                frontier: NodeWorklist::new(),
                spare: NodeWorklist::new(),
                iterations: 0,
            }));
        }
        for (st, &query) in self.states.iter_mut().zip(queries) {
            st.query = query;
            st.iterations = 0;
        }
        self.seed(ctx)
    }

    /// Shared (re)initialization: charge the batch's resident storage and
    /// seed every query. Per-slot buffers are reused when present, drawn
    /// from the arena when not.
    fn seed(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let n = g.num_nodes();
        // One CSR for the whole batch, and one MDT histogram pass unless
        // this device (cache scope) already paid it for an earlier batch.
        // The mark happens here, at the charge site, so a batch whose
        // init never ran cannot exempt a later one.
        ctx.mem.charge(SRV_CSR, g.memory_bytes())?;
        if !self.cache.mark_mdt_charged(&g) {
            ctx.charge_aux_kernel(n as u64, 2);
        }
        for st in &mut self.states {
            ctx.mem.charge(SRV_DIST, 4 * n as u64)?;
            self.dist_charged += 4 * n as u64;
            if st.dist.capacity() == 0 {
                st.dist = ctx.scratch.take_u32();
            }
            st.dist.clear();
            st.dist.resize(n, crate::INF);
            st.dist[st.query.source as usize] = 0;
            st.frontier.clear();
            st.frontier.push(st.query.source, g.degree(st.query.source));
            ctx.mem.charge(SRV_WL, 8 * st.frontier.len() as u64)?;
            st.spare.clear();
        }
        if self.seen.capacity() == 0 {
            self.seen = ctx.scratch.take_u64();
        }
        self.seen.clear();
        self.seen.resize(n.div_ceil(64), 0);
        // Mode and per-batch residency restart with the new query set; the
        // graph-keyed cache still exempts the rebuild *kernels*.
        self.mode = StrategyKind::BS;
        self.coo_charged = false;
        self.split = None;
        Ok(())
    }

    /// Release every resident byte this batch charged to `ctx` (CSR,
    /// per-query dist arrays, worklists, COO / split residency), keeping
    /// the internal buffers for a later [`QueryBatch::reset`]. Call after
    /// extracting results when the context outlives the batch — the
    /// serving scheduler does, between every batch of a shard's stream.
    pub fn retire(&mut self, ctx: &mut ExecCtx) {
        let g = &self.graph;
        ctx.mem.release(SRV_CSR, g.memory_bytes());
        ctx.mem.release(SRV_DIST, self.dist_charged);
        self.dist_charged = 0;
        for st in &self.states {
            ctx.mem.release(SRV_WL, 8 * st.frontier.len() as u64);
        }
        if self.coo_charged {
            ctx.mem.release(SRV_COO, 12 * g.num_edges() as u64);
            self.coo_charged = false;
        }
        if let Some(art) = self.split.take() {
            ctx.mem.release(SRV_NS_CSR, art.split.graph.memory_bytes());
            ctx.mem.release(SRV_NS_MAP, 8 * g.num_nodes() as u64);
        }
    }

    /// Return the batch's pooled buffers (per-query dist arrays, the dedup
    /// bitmap) to the context's scratch arena. Call after the results have
    /// been extracted; the next batch served on the same context then
    /// starts warm.
    pub fn recycle(self, ctx: &mut ExecCtx) {
        for st in self.states.into_iter().chain(self.parked) {
            ctx.scratch.put_u32(st.dist);
        }
        ctx.scratch.put_u64(self.seen);
    }

    /// Total frontier entries pending across every query (0 ⇒ converged).
    pub fn pending(&self) -> usize {
        self.states.iter().map(|s| s.frontier.len()).sum()
    }

    /// The queries, in slot order.
    pub fn queries(&self) -> Vec<Query> {
        self.states.iter().map(|s| s.query).collect()
    }

    /// Per-query outer iterations executed so far, in slot order.
    pub fn query_iterations(&self) -> Vec<u32> {
        self.states.iter().map(|s| s.iterations).collect()
    }

    /// Final distances of query slot `i` for the original node ids.
    pub fn distances(&self, i: usize) -> Vec<u32> {
        self.states[i].dist[..self.graph.num_nodes()].to_vec()
    }

    /// Drive the batch to convergence.
    pub fn run(&mut self, ctx: &mut ExecCtx, max_iterations: u32) -> Result<()> {
        let mut outer = 0u32;
        while self.pending() > 0 {
            self.run_iteration(ctx)?;
            outer += 1;
            if outer >= max_iterations {
                return Err(Error::Config(format!(
                    "batch exceeded max_iterations = {max_iterations} (non-convergence?)"
                )));
            }
        }
        Ok(())
    }

    /// One batch iteration: merge → inspect once → decide once → step every
    /// active query in the chosen style. Every per-iteration structure —
    /// the active list, the merged worklist, the per-query frontier views —
    /// is rebuilt in place from persistent scratch, so a warm iteration
    /// performs no heap allocation.
    pub fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        self.active.clear();
        for i in 0..self.states.len() {
            if !self.states[i].frontier.is_empty() {
                self.active.push(i);
            }
        }
        if self.active.is_empty() {
            return Ok(());
        }
        // The tagged merged worklist exists to feed the shared inspection
        // and decision, so static batch modes — which have nothing to
        // decide — skip building (and paying for) it entirely.
        let use_merged = self.strategy == StrategyKind::AD;
        if use_merged {
            // Tag stride follows the live batch size: ≤ 64 queries keep
            // the single-word layout, wider batches grow a word per 64.
            self.builder.begin_with_capacity(self.states.len());
            for &i in &self.active {
                self.builder.add(i, &self.states[i].frontier);
            }
            self.builder.finish_into(&g, &mut self.merged_buf);
            // The merged list is device-resident for the iteration (node,
            // degree, tag per entry); charge it so feasibility and peak
            // memory see it.
            ctx.mem.charge(SRV_MERGED, self.merged_buf.memory_bytes())?;
        }

        // One inspection + one policy decision for the whole batch (AD).
        let choice = if use_merged {
            let snap = FrontierInspector::inspect_with_edges(
                self.merged_buf.degrees(),
                self.merged_buf.total_edges(),
                ctx.dev,
            );
            ctx.metrics.inspector_passes += 1;
            ctx.charge_overhead(INSPECT_BASE_CYCLES + snap.nodes / 32);
            let feas = self.feasibility(ctx, &snap);
            let decision = {
                let input = PolicyInput {
                    snapshot: &snap,
                    degrees: self.merged_buf.degrees(),
                    current: self.mode,
                    feasibility: feas,
                    dev: ctx.dev,
                    params: &self.params,
                    mdt: self.mdt.mdt,
                    graph_edges: g.num_edges() as u64,
                    graph_nodes: g.num_nodes() as u64,
                };
                self.policy.as_mut().expect("AD batch has a policy").decide(&input)
            };
            ctx.metrics.policy_decisions += 1;
            let choice = if feas.allows(decision.choice) {
                decision.choice
            } else {
                StrategyKind::BS
            };
            // Alias candidates execute (and report) as the monolithic
            // strategy they name, exactly like the single-query engine.
            let choice = match choice {
                StrategyKind::Composed(s) => s.alias().unwrap_or(choice),
                _ => choice,
            };
            let migrated = choice != self.mode;
            if requires_migration(self.mode, choice) {
                // One conversion kernel over the merged frontier — the
                // representation switch is paid once, not per query. Mode
                // changes inside node space (e.g. BS↔HP) are free, exactly
                // as in the single-query engine.
                ctx.charge_aux_kernel(self.merged_buf.len() as u64 + 1, 2);
            }
            ctx.metrics.record_decision(DecisionRecord {
                iteration: ctx.metrics.iterations,
                strategy: choice.label(),
                migrated,
                frontier_nodes: snap.nodes,
                frontier_edges: snap.edges,
                degree_skew: snap.skew,
                predicted_cycles: decision.predicted_cycles,
            });
            ctx.record_trace(TraceEventKind::FrontierSize, "", snap.nodes, snap.edges);
            ctx.record_trace(
                TraceEventKind::StrategyDecision,
                choice.label(),
                snap.nodes,
                snap.edges,
            );
            if migrated {
                ctx.record_trace(TraceEventKind::Migration, choice.label(), snap.nodes, snap.edges);
            }
            self.mode = choice;
            choice
        } else {
            if ctx.trace.is_some() {
                // Static batch modes skip the merged inspection, so sample
                // the frontier counter from the per-query worklists
                // directly (both sums are O(active) reads).
                let mut nodes = 0u64;
                let mut edges = 0u64;
                for &i in &self.active {
                    nodes += self.states[i].frontier.len() as u64;
                    edges += self.states[i].frontier.total_edges();
                }
                ctx.record_trace(TraceEventKind::FrontierSize, "", nodes, edges);
            }
            self.mode = self.strategy;
            self.strategy
        };

        // Shared structures for the chosen mode, built once per batch (or
        // fetched from the graph-keyed cache when an earlier batch on the
        // same graph already built them).
        if choice == StrategyKind::EP && !self.coo_charged {
            ctx.mem.charge(SRV_COO, 12 * g.num_edges() as u64)?;
            if !self.cache.mark_coo(&g) {
                ctx.charge_aux_kernel(g.num_edges() as u64, 1);
            }
            self.coo_charged = true;
        }
        if choice == StrategyKind::NS {
            self.ensure_split(ctx)?;
        }

        // Per-query execution, each against its own dist array. AD modes
        // step from the merged list's tagged view; static modes step from
        // the per-query frontier directly (identical content — the merge
        // only reorders by node id). The view is rebuilt into persistent
        // scratch and borrowed out of `self` for the step (take/restore
        // keeps its capacity without cloning).
        let active = std::mem::take(&mut self.active);
        for &slot in &active {
            if use_merged {
                self.merged_buf.query_frontier_into(slot, &mut self.view);
            } else {
                self.view.copy_from(&self.states[slot].frontier);
            }
            let view = std::mem::take(&mut self.view);
            let res = self.step_query(ctx, slot, choice, &view);
            self.view = view;
            res?;
            self.states[slot].iterations += 1;
        }
        self.active = active;
        if use_merged {
            ctx.mem.release(SRV_MERGED, self.merged_buf.memory_bytes());
        }
        ctx.metrics.iterations += 1;
        Ok(())
    }

    /// Memory feasibility of the candidates under the remaining budget —
    /// the single-query engine's bounds, with per-query costs (NS's dist
    /// extension) multiplied across the batch.
    fn feasibility(&self, ctx: &ExecCtx, snap: &FrontierSnapshot) -> Feasibility {
        let headroom = ctx.mem.budget().saturating_sub(ctx.mem.current());
        let e = self.graph.num_edges() as u64;
        let n = self.graph.num_nodes() as u64;
        let q = self.states.len() as u64;
        let w = snap.edges;
        let t = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads) as u64;
        let coo_extra = if self.coo_charged { 0 } else { 12 * e };
        let ep = coo_extra + 8 * w + 8 * e <= headroom;
        let wd = 12 * snap.nodes + 8 * w + 8 * t <= headroom;
        let mdt = self.mdt.mdt.max(1) as u64;
        let ns_extra = if self.split.is_some() {
            4 * w
        } else {
            // Split CSR + parent map + every query's dist extension.
            self.graph.memory_bytes() + 8 * n + q * 4 * (e / mdt + 1) + 4 * w
        };
        let ns = ns_extra <= headroom;
        // Composed schedules run on the per-query node views the batch
        // already holds; the bound is the merge-path orders' per-step
        // transient scratch, like the single-query engine.
        let composed =
            step_scratch_bytes(Schedule::WARP_MERGE_PATH, snap.nodes, w) <= headroom;
        Feasibility {
            ep,
            wd,
            ns,
            coo_resident: self.coo_charged,
            split_built: self.split.is_some(),
            composed,
        }
    }

    /// Build the shared split graph (the host transform runs once per
    /// graph; the device rebuild kernel is charged once per cache scope —
    /// an earlier batch on the *same* device retains it, another shard's
    /// device does not) and extend every query's dist array to the split
    /// node count. The artifact's resident bytes are charged to this
    /// context either way: retention is not free residency.
    fn ensure_split(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        if self.split.is_some() {
            return Ok(());
        }
        let n = self.graph.num_nodes();
        let (art, was_cached) = self.cache.split(&self.graph, self.mdt.mdt, || {
            let split = split_graph(&self.graph, self.mdt);
            let parent_of = migrate::parent_of_table(&split, n);
            SplitArtifact { split, parent_of }
        });
        ctx.mem.charge(SRV_NS_CSR, art.split.graph.memory_bytes())?;
        ctx.mem.charge(SRV_NS_MAP, 8 * n as u64)?;
        if !was_cached {
            ctx.charge_aux_kernel(self.graph.num_edges() as u64 + n as u64, 2);
        }
        let n_split = art.split.graph.num_nodes();
        if n_split > n {
            for st in &mut self.states {
                ctx.mem.charge(SRV_DIST, 4 * (n_split - n) as u64)?;
                self.dist_charged += 4 * (n_split - n) as u64;
                st.dist.resize(n_split, crate::INF);
            }
        }
        self.split = Some(art);
        Ok(())
    }

    /// Run one iteration of query `slot` in `mode`'s kernel style, with the
    /// query's dist array and algorithm swapped into the context.
    fn step_query(
        &mut self,
        ctx: &mut ExecCtx,
        slot: usize,
        mode: StrategyKind,
        view: &NodeWorklist,
    ) -> Result<()> {
        let saved_algo = ctx.algo;
        ctx.algo = self.states[slot].query.algo;
        std::mem::swap(&mut ctx.dist, &mut self.states[slot].dist);
        let res = match mode {
            StrategyKind::BS => self.step_bs(ctx, slot, view),
            StrategyKind::EP => self.step_ep(ctx, slot, view),
            StrategyKind::WD => self.step_wd(ctx, slot, view),
            StrategyKind::NS => self.step_ns(ctx, slot, view),
            StrategyKind::HP => self.step_hp(ctx, slot, view),
            StrategyKind::AD => unreachable!("the batch decision is a static kind"),
            StrategyKind::Composed(s) => self.step_composed(ctx, slot, s, view),
        };
        std::mem::swap(&mut ctx.dist, &mut self.states[slot].dist);
        ctx.algo = saved_algo;
        res
    }

    /// Replace query `slot`'s frontier with the condensed update stream
    /// (mirrors [`crate::strategies::common::NodeFrontier::advance`]).
    ///
    /// Worklist bytes are charged at a flat 8 B/entry in every mode: the
    /// batch's canonical frontier always carries the (node, degree) pair
    /// arrays, unlike the single-query engine's mode-shaped buffers (4 B
    /// in BS/HP) — a deliberate accounting difference, documented here
    /// like the engine documents its own CSR-residency choice.
    fn advance(&mut self, ctx: &mut ExecCtx, slot: usize, updated: &[NodeId]) -> Result<()> {
        let g = self.graph.clone();
        let raw = updated.len() as u64;
        ctx.metrics.peak_worklist_entries = ctx.metrics.peak_worklist_entries.max(raw);
        // Double buffer: the raw (duplicate-laden) output alongside the
        // input worklist. The dedup writes into the state's spare half, so
        // both halves' capacity survives across iterations.
        ctx.mem.charge(SRV_WL, 8 * raw)?;
        let st = &mut self.states[slot];
        st.spare.clear();
        for &nd in updated {
            let (w, b) = (nd as usize / 64, nd as usize % 64);
            if self.seen[w] & (1 << b) == 0 {
                self.seen[w] |= 1 << b;
                st.spare.push(nd, g.degree(nd));
            }
        }
        for &nd in st.spare.nodes() {
            self.seen[nd as usize / 64] = 0; // clear only touched words
        }
        ctx.metrics.condensed_away += raw - st.spare.len() as u64;
        if raw > 0 {
            ctx.charge_aux_kernel(raw, 2);
        }
        let old = 8 * st.frontier.len() as u64;
        let keep = 8 * st.spare.len() as u64;
        ctx.mem.release(SRV_WL, old + 8 * raw - keep);
        std::mem::swap(&mut st.frontier, &mut st.spare);
        Ok(())
    }

    /// Composed style: the shared schedule-algebra lowering
    /// ([`composed_step`]) over the query's node view, with serving kernel
    /// labels (mirrors the single-query `cs_*_relax` kernels).
    fn step_composed(
        &mut self,
        ctx: &mut ExecCtx,
        slot: usize,
        schedule: Schedule,
        view: &NodeWorklist,
    ) -> Result<()> {
        let g = self.graph.clone();
        let result = composed_step(ctx, &g, view, schedule, Realm::Serving)?;
        self.advance(ctx, slot, &result.updated)?;
        ctx.recycle(result);
        Ok(())
    }

    /// BS style: one lane per node (mirrors `ad_bs_relax`).
    fn step_bs(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let g = self.graph.clone();
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let mut offsets = ctx.scratch.take_u32();
        flatten_frontier_into(&g, view.nodes(), &mut src, &mut eid);
        offsets.push(0u32);
        let mut acc = 0u32;
        for &d in view.degrees() {
            acc += d;
            offsets.push(acc);
        }
        let work = KernelWork {
            name: "srv_bs_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&g, &work, None)?;
        self.advance(ctx, slot, &result.updated)?;
        ctx.recycle(result);
        ctx.recycle_work(work);
        Ok(())
    }

    /// WD style: scan + `find_offsets` + evenly blocked edges (mirrors
    /// `ad_wd_relax`).
    fn step_wd(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let g = self.graph.clone();
        let max_threads = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads);
        let wl_len = view.len() as u64;
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        flatten_frontier_into(&g, view.nodes(), &mut src, &mut eid);
        let total = src.len();

        ctx.mem.charge(SRV_WD_PREFIX, 4 * wl_len)?;
        ctx.charge_aux_kernel(wl_len, 1);
        let threads = (max_threads as usize).min(total.max(1)) as u64;
        let log_wl = (64 - wl_len.leading_zeros() as u64).max(1);
        ctx.charge_aux_kernel(threads, 4 * log_wl);
        let offsets_bytes = 8 * max_threads as u64;
        ctx.mem.charge(SRV_WD_OFFSETS, offsets_bytes)?;

        let mut offsets = ctx.scratch.take_u32();
        block_offsets_into(total, max_threads, &mut offsets);
        let work = KernelWork {
            name: "srv_wd_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 4,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&g, &work, None)?;
        ctx.mem.release(SRV_WD_OFFSETS, offsets_bytes);
        ctx.mem.release(SRV_WD_PREFIX, 4 * wl_len);
        self.advance(ctx, slot, &result.updated)?;
        ctx.recycle(result);
        ctx.recycle_work(work);
        Ok(())
    }

    /// EP style: the frontier exploded to edges over the shared COO
    /// (mirrors `ad_ep_relax`); the output returns to node space, so the
    /// transient edge worklist lives only for the launch.
    fn step_ep(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let g = self.graph.clone();
        // Exploding the node view to edge granularity writes the same
        // (src, eid) arrays an [`crate::worklist::EdgeWorklist`] would
        // carry, directly into pooled kernel staging.
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        flatten_frontier_into(&g, view.nodes(), &mut src, &mut eid);
        let total = src.len();
        let charged = 8 * total as u64;
        ctx.mem.charge(SRV_EP_WL, charged)?;
        let max_threads = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads);
        let threads = (max_threads as usize).min(total).max(1) as u32;
        let work = KernelWork {
            name: "srv_ep_relax",
            src,
            eid,
            assignment: Assignment::Strided {
                num_threads: threads,
            },
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Edges,
        };
        let result = ctx.launch(&g, &work, None);
        ctx.mem.release(SRV_EP_WL, charged);
        ctx.recycle_work(work);
        let result = result?;
        self.advance(ctx, slot, &result.updated)?;
        ctx.recycle(result);
        Ok(())
    }

    /// NS style: the query frontier migrated into the shared split graph,
    /// clone attributes refreshed from their parents, results folded back
    /// to original ids (mirrors `ad_ns_relax`).
    fn step_ns(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let st = self.split.clone().expect("ensure_split ran");
        let sg = &st.split.graph;
        // Refresh the clones of the active parents so the mirror
        // invariant holds when entering split space.
        let mut children = 0u64;
        for &u in view.nodes() {
            let du = ctx.dist[u as usize];
            for c in st.split.map.children(u) {
                ctx.dist[c as usize] = du;
                children += 1;
            }
        }
        if children > 0 {
            ctx.charge_aux_kernel(children, 1);
        }
        migrate::nodes_to_split_into(&st.split, view, &mut self.split_view);
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let mut offsets = ctx.scratch.take_u32();
        flatten_frontier_into(sg, self.split_view.nodes(), &mut src, &mut eid);
        offsets.push(0u32);
        let mut acc = 0u32;
        for &d in self.split_view.degrees() {
            acc += d;
            offsets.push(acc);
        }
        let work = KernelWork {
            name: "srv_ns_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let result = ctx.launch(sg, &work, Some(&st.split.map))?;
        ctx.recycle_work(work);
        // Fold the split-space updates back to parent ids in place, then
        // advance from the pooled buffer.
        let mut parents = result.updated;
        for x in parents.iter_mut() {
            *x = st.parent_of[*x as usize];
        }
        self.advance(ctx, slot, &parents)?;
        ctx.scratch.put_u32(parents);
        Ok(())
    }

    /// HP style: sub-iterations of ≤ MDT edges per node with the WD
    /// fallback on small residues (mirrors `ad_hp_relax`).
    fn step_hp(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let g = self.graph.clone();
        let mdt = self.mdt.mdt.max(1);
        let block = ctx.dev.block_size as usize;
        let mut all_updates: Vec<NodeId> = ctx.scratch.take_u32();

        if view.len() < block {
            let mut src = ctx.scratch.take_u32();
            let mut eid = ctx.scratch.take_u32();
            flatten_frontier_into(&g, view.nodes(), &mut src, &mut eid);
            if src.is_empty() {
                ctx.scratch.put_u32(src);
                ctx.scratch.put_u32(eid);
            } else {
                let ups = hp_wd_fallback(ctx, &g, src, eid, view.len() as u64)?;
                all_updates.extend_from_slice(&ups);
                ctx.scratch.put_u32(ups);
            }
        } else {
            // Persistent sub-list, rebuilt in place each outer iteration.
            self.sub.reset(view.nodes(), view.degrees());
            let sub_bytes = self.sub.memory_bytes();
            ctx.mem.charge(SRV_HP_SUBLIST, sub_bytes)?;

            while !self.sub.is_empty() {
                if self.sub.len() < block {
                    let mut src = ctx.scratch.take_u32();
                    let mut eid = ctx.scratch.take_u32();
                    for c in self.sub.cursors() {
                        let first = g.first_edge(c.node) + c.processed;
                        for e in first..first + c.remaining() {
                            src.push(c.node);
                            eid.push(e);
                        }
                    }
                    let wl_len = self.sub.len() as u64;
                    let ups = hp_wd_fallback(ctx, &g, src, eid, wl_len)?;
                    all_updates.extend_from_slice(&ups);
                    ctx.scratch.put_u32(ups);
                    break;
                }

                let mut src = ctx.scratch.take_u32();
                let mut eid = ctx.scratch.take_u32();
                let mut offsets = ctx.scratch.take_u32();
                offsets.push(0u32);
                let mut acc = 0u32;
                for c in self.sub.cursors() {
                    let take = c.remaining().min(mdt);
                    let first = g.first_edge(c.node) + c.processed;
                    for e in first..first + take {
                        src.push(c.node);
                        eid.push(e);
                    }
                    acc += take;
                    offsets.push(acc);
                }
                let work = KernelWork {
                    name: "srv_hp_relax",
                    src,
                    eid,
                    assignment: Assignment::Blocked(offsets),
                    access: AccessPattern::Scattered,
                    extra_cycles_per_edge: 2,
                    push: PushTarget::Node,
                };
                let result = ctx.launch(&g, &work, None)?;
                all_updates.extend_from_slice(&result.updated);
                ctx.recycle(result);
                ctx.recycle_work(work);
                self.sub.advance(mdt);
                ctx.charge_aux_kernel(self.sub.len() as u64 + 1, 1);
            }
            ctx.mem.release(SRV_HP_SUBLIST, sub_bytes);
        }
        self.advance(ctx, slot, &all_updates)?;
        ctx.scratch.put_u32(all_updates);
        Ok(())
    }
}

/// The differential oracle: replay every query of a batched run through the
/// existing single-query engine ([`crate::coordinator::run`]) with the same
/// strategy and parameters, and require distance-array equality. Returns
/// the first mismatch as a [`Error::Config`] describing the query.
pub fn replay_single(
    graph: &Arc<Csr>,
    queries: &[Query],
    strategy: StrategyKind,
    params: &StrategyParams,
    batched: &[Vec<u32>],
) -> Result<()> {
    if queries.len() != batched.len() {
        return Err(Error::Config(format!(
            "replay: {} queries but {} batched results",
            queries.len(),
            batched.len()
        )));
    }
    for (q, got) in queries.iter().zip(batched) {
        let cfg = RunConfig {
            algo: q.algo,
            strategy,
            source: q.source,
            params: params.clone(),
            ..Default::default()
        };
        let single = run(graph, &cfg)?;
        if &single.dist != got {
            let diverged = single
                .dist
                .iter()
                .zip(got)
                .position(|(a, b)| a != b)
                .unwrap_or(0);
            return Err(Error::Config(format!(
                "query {} ({} from {}): batched dist diverges from the single-query \
                 engine at node {diverged} (single {} vs batched {})",
                q.id,
                q.algo.name(),
                q.source,
                single.dist[diverged],
                got[diverged],
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::generators::{erdos_renyi, rmat, RmatParams};
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    fn batch_run(
        g: &Arc<Csr>,
        queries: &[Query],
        strategy: StrategyKind,
    ) -> (Vec<Vec<u32>>, crate::metrics::RunMetrics) {
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
        let mut batch =
            QueryBatch::new(g.clone(), queries, strategy, StrategyParams::default()).unwrap();
        batch.init(&mut ctx).unwrap();
        batch.run(&mut ctx, 1_000_000).unwrap();
        ctx.finalize_metrics();
        let dists = (0..queries.len()).map(|i| batch.distances(i)).collect();
        (dists, ctx.metrics)
    }

    fn queries(sources: &[NodeId], algo: AlgoKind) -> Vec<Query> {
        sources
            .iter()
            .enumerate()
            .map(|(id, &source)| Query {
                id: id as u32,
                algo,
                source,
            })
            .collect()
    }

    #[test]
    fn batched_ad_matches_oracles() {
        let g = Arc::new(rmat(9, 4096, RmatParams::default(), 5).unwrap());
        let qs = queries(&[0, 7, 19, 101], AlgoKind::Sssp);
        let (dists, metrics) = batch_run(&g, &qs, StrategyKind::AD);
        for (q, d) in qs.iter().zip(&dists) {
            assert_eq!(d, &traversal::dijkstra(&g, q.source), "query {}", q.id);
        }
        assert!(metrics.inspector_passes > 0);
        assert_eq!(metrics.inspector_passes, metrics.policy_decisions);
        assert_eq!(
            metrics.inspector_passes,
            metrics.decisions.len() as u64,
            "one shared decision per batch iteration"
        );
    }

    #[test]
    fn amortization_beats_independent_inspection() {
        let g = Arc::new(rmat(9, 4096, RmatParams::default(), 5).unwrap());
        let qs = queries(&[0, 7, 19, 101, 33, 64, 90, 110], AlgoKind::Sssp);
        let (_, batched) = batch_run(&g, &qs, StrategyKind::AD);
        let mut independent = 0u64;
        for q in &qs {
            let r = run(
                &g,
                &RunConfig {
                    strategy: StrategyKind::AD,
                    source: q.source,
                    ..Default::default()
                },
            )
            .unwrap();
            independent += r.metrics.inspector_passes + r.metrics.policy_decisions;
        }
        assert!(
            batched.inspector_passes + batched.policy_decisions < independent,
            "batched {} + {} must undercut independent {independent}",
            batched.inspector_passes,
            batched.policy_decisions
        );
    }

    #[test]
    fn every_static_mode_matches_oracles() {
        let g = Arc::new(erdos_renyi(200, 900, 12, 3).unwrap());
        let qs = queries(&[0, 5, 50], AlgoKind::Bfs);
        for strategy in StrategyKind::ALL {
            let (dists, _) = batch_run(&g, &qs, strategy);
            for (q, d) in qs.iter().zip(&dists) {
                assert_eq!(
                    d,
                    &traversal::bfs_levels(&g, q.source),
                    "{strategy} query {}",
                    q.id
                );
            }
        }
    }

    #[test]
    fn composed_schedules_match_oracles_in_batches() {
        let g = Arc::new(erdos_renyi(200, 900, 12, 3).unwrap());
        let qs = queries(&[0, 5, 50], AlgoKind::Bfs);
        for s in Schedule::NEW {
            let (dists, _) = batch_run(&g, &qs, StrategyKind::Composed(s));
            for (q, d) in qs.iter().zip(&dists) {
                assert_eq!(
                    d,
                    &traversal::bfs_levels(&g, q.source),
                    "{s} query {}",
                    q.id
                );
            }
        }
        // An alias composition serves exactly as the strategy it names.
        let (dists, _) = batch_run(&g, &qs, "thread/sorted".parse().unwrap());
        for (q, d) in qs.iter().zip(&dists) {
            assert_eq!(d, &traversal::bfs_levels(&g, q.source), "alias query {}", q.id);
        }
    }

    #[test]
    fn mixed_algo_batch_keeps_queries_separate() {
        let g = Arc::new(erdos_renyi(150, 600, 9, 11).unwrap());
        let qs = vec![
            Query { id: 0, algo: AlgoKind::Bfs, source: 3 },
            Query { id: 1, algo: AlgoKind::Sssp, source: 3 },
        ];
        let (dists, _) = batch_run(&g, &qs, StrategyKind::AD);
        assert_eq!(dists[0], traversal::bfs_levels(&g, 3));
        assert_eq!(dists[1], traversal::dijkstra(&g, 3));
    }

    #[test]
    fn replay_single_flags_divergence() {
        let g = Arc::new(erdos_renyi(80, 300, 5, 2).unwrap());
        let qs = queries(&[1, 2], AlgoKind::Sssp);
        let (mut dists, _) = batch_run(&g, &qs, StrategyKind::BS);
        replay_single(&g, &qs, StrategyKind::BS, &StrategyParams::default(), &dists)
            .expect("faithful results must verify");
        dists[1][3] ^= 1;
        assert!(
            replay_single(&g, &qs, StrategyKind::BS, &StrategyParams::default(), &dists)
                .is_err(),
            "corrupted results must be rejected"
        );
    }

    #[test]
    fn rejects_oversized_and_out_of_range() {
        let g = Arc::new(erdos_renyi(50, 200, 5, 1).unwrap());
        let many = queries(&vec![0; MAX_SUPPORTED_QUERIES_PER_SHARD + 1], AlgoKind::Bfs);
        assert!(QueryBatch::new(
            g.clone(),
            &many,
            StrategyKind::BS,
            StrategyParams::default()
        )
        .is_err());
        let bad = queries(&[10_000], AlgoKind::Bfs);
        assert!(QueryBatch::new(g, &bad, StrategyKind::BS, StrategyParams::default()).is_err());
    }

    #[test]
    fn over_64_queries_match_oracles_via_multiword_tags() {
        // 70 concurrent queries on one shard: the tag must spill into a
        // second mask word and distances must still be exact.
        let g = Arc::new(erdos_renyi(120, 500, 7, 8).unwrap());
        let sources: Vec<NodeId> = (0..70).map(|i| (i * 7) % 120).collect();
        let qs = queries(&sources, AlgoKind::Bfs);
        for strategy in [StrategyKind::AD, StrategyKind::BS] {
            let (dists, _) = batch_run(&g, &qs, strategy);
            for (q, d) in qs.iter().zip(&dists) {
                assert_eq!(
                    d,
                    &traversal::bfs_levels(&g, q.source),
                    "{strategy} query {}",
                    q.id
                );
            }
        }
    }

    #[test]
    fn reset_reuses_engine_across_batches() {
        let g = Arc::new(erdos_renyi(150, 600, 9, 11).unwrap());
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
        let mut engine =
            QueryBatch::new(g.clone(), &[], StrategyKind::AD, StrategyParams::default()).unwrap();
        let batches: [&[NodeId]; 3] = [&[0, 5, 50], &[7, 8], &[3, 9, 20, 40]];
        for sources in batches {
            let qs = queries(sources, AlgoKind::Sssp);
            engine.reset(&mut ctx, &qs).unwrap();
            engine.run(&mut ctx, 1_000_000).unwrap();
            for (i, q) in qs.iter().enumerate() {
                assert_eq!(
                    engine.distances(i),
                    traversal::dijkstra(&g, q.source),
                    "query {} after engine reuse",
                    q.id
                );
            }
            let before = ctx.mem.current();
            engine.retire(&mut ctx);
            assert!(
                ctx.mem.current() < before,
                "retire must release the batch's resident bytes"
            );
        }
        assert_eq!(
            ctx.mem.current(),
            0,
            "a fully retired stream leaves nothing charged"
        );
    }
}
