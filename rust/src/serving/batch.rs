//! The batched multi-query engine: N concurrent BFS/SSSP queries over one
//! shared CSR, with the frontier inspection and the AD policy decision
//! amortized across the whole batch.
//!
//! Per outer iteration the batch (1) builds the bitmask-tagged
//! [`MergedWorklist`] from the per-query frontiers, (2) runs **one**
//! [`FrontierInspector`] pass over the merged degree array, (3) asks the
//! policy for **one** strategy choice (restricted to the memory-feasible
//! candidates, exactly like the single-query [`crate::adaptive::Adaptive`]
//! engine), then (4) executes one iteration *per active query* in that
//! strategy's kernel style, swapping each query's `dist` array into the
//! [`ExecCtx`]. Structures that depend only on the graph — the MDT
//! histogram, EP's COO materialization, NS's split graph and parent map —
//! are built **once per batch** and shared by every query, which is the
//! second amortization the serving layer exists for.
//!
//! Because every per-query relaxation is an exact min-propagation, a
//! batched run converges to the same distance arrays as running each query
//! alone; [`replay_single`] is the baked-in differential oracle that
//! asserts exactly that through the existing single-query engine.

use crate::adaptive::engine::{hp_wd_fallback, INSPECT_BASE_CYCLES};
use crate::adaptive::inspect::{FrontierInspector, FrontierSnapshot};
use crate::adaptive::migrate;
use crate::adaptive::policy::{build_policy, requires_migration, Feasibility, Policy, PolicyInput};
use crate::coordinator::exec::flatten_frontier;
use crate::coordinator::{run, Assignment, ExecCtx, KernelWork, PushTarget, RunConfig};
use crate::error::{Error, Result};
use crate::graph::{Csr, Graph, NodeId};
use crate::metrics::DecisionRecord;
use crate::sim::AccessPattern;
use crate::strategies::mdt::{auto_mdt, MdtDecision};
use crate::strategies::node_split::{split_graph, SplitGraph};
use crate::strategies::workload_decomp::block_offsets;
use crate::strategies::{StrategyKind, StrategyParams};
use crate::worklist::hierarchy::SubList;
use crate::worklist::NodeWorklist;
use std::sync::Arc;

use super::merged::{MergedWorklist, MAX_QUERIES_PER_SHARD};
use super::query::Query;

// Device-memory labels of the batch engine's allocations.
const SRV_CSR: &str = "srv-csr";
const SRV_DIST: &str = "srv-dist";
const SRV_WL: &str = "srv-wl";
const SRV_MERGED: &str = "srv-merged";
const SRV_COO: &str = "srv-coo";
const SRV_EP_WL: &str = "srv-ep-wl";
const SRV_NS_CSR: &str = "srv-ns-csr";
const SRV_NS_MAP: &str = "srv-ns-map";
const SRV_WD_PREFIX: &str = "srv-wd-prefix";
const SRV_WD_OFFSETS: &str = "srv-wd-offsets";
const SRV_HP_SUBLIST: &str = "srv-hp-sublist";

/// One query's live state inside a batch: its own distance array and node
/// frontier (canonical original-graph node space between iterations; the
/// chosen strategy's representation is materialized per iteration through
/// [`crate::adaptive::migrate`]).
#[derive(Debug)]
struct QueryState {
    query: Query,
    dist: Vec<u32>,
    frontier: NodeWorklist,
    iterations: u32,
}

/// Shared node-splitting state (one split graph for the whole batch).
struct SplitShared {
    split: SplitGraph,
    parent_of: Vec<NodeId>,
}

/// A batch of concurrent queries over one shared CSR.
pub struct QueryBatch {
    graph: Arc<Csr>,
    params: StrategyParams,
    /// The configured strategy: a static kind runs every iteration in that
    /// style; [`StrategyKind::AD`] re-decides per batch iteration.
    strategy: StrategyKind,
    policy: Option<Box<dyn Policy>>,
    mdt: MdtDecision,
    split: Option<SplitShared>,
    coo_charged: bool,
    /// The mode the previous iteration ran in (AD hysteresis/migration).
    mode: StrategyKind,
    states: Vec<QueryState>,
    /// Reusable dedup bitset for [`QueryBatch::advance`] (queries step
    /// sequentially, so one buffer serves the whole batch); only touched
    /// words are cleared between uses, as in
    /// [`crate::strategies::common::NodeFrontier`].
    seen: Vec<u64>,
}

impl QueryBatch {
    /// New batch over `graph`. At most [`MAX_QUERIES_PER_SHARD`] queries
    /// (the merged worklist's tag is a `u64` bitmask); every source must be
    /// in range.
    pub fn new(
        graph: Arc<Csr>,
        queries: &[Query],
        strategy: StrategyKind,
        params: StrategyParams,
    ) -> Result<Self> {
        if queries.len() > MAX_QUERIES_PER_SHARD {
            return Err(Error::Config(format!(
                "batch of {} queries exceeds the {MAX_QUERIES_PER_SHARD}-query shard limit",
                queries.len()
            )));
        }
        for q in queries {
            if q.source as usize >= graph.num_nodes() {
                return Err(Error::Config(format!(
                    "query {}: source {} out of range (n = {})",
                    q.id,
                    q.source,
                    graph.num_nodes()
                )));
            }
        }
        let policy = if strategy == StrategyKind::AD {
            Some(build_policy(params.adaptive_policy))
        } else {
            None
        };
        let mdt = match params.mdt_override {
            Some(mdt) => MdtDecision {
                mdt,
                peak_bin: 0,
                bins: params.histogram_bins,
                max_degree: graph.max_degree(),
            },
            None => auto_mdt(&graph, params.histogram_bins),
        };
        let states = queries
            .iter()
            .map(|&query| QueryState {
                query,
                dist: Vec::new(),
                frontier: NodeWorklist::new(),
                iterations: 0,
            })
            .collect();
        let seen = vec![0u64; graph.num_nodes().div_ceil(64)];
        Ok(QueryBatch {
            graph,
            params,
            strategy,
            policy,
            mdt,
            split: None,
            coo_charged: false,
            mode: StrategyKind::BS,
            states,
            seen,
        })
    }

    /// Charge shared storage and seed every query's frontier.
    pub fn init(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let n = g.num_nodes();
        // One CSR and one MDT histogram for the whole batch.
        ctx.mem.charge(SRV_CSR, g.memory_bytes())?;
        ctx.charge_aux_kernel(n as u64, 2);
        for st in &mut self.states {
            ctx.mem.charge(SRV_DIST, 4 * n as u64)?;
            st.dist = vec![crate::INF; n];
            st.dist[st.query.source as usize] = 0;
            st.frontier = NodeWorklist::seeded(&g, st.query.source);
            ctx.mem.charge(SRV_WL, 8 * st.frontier.len() as u64)?;
        }
        Ok(())
    }

    /// Total frontier entries pending across every query (0 ⇒ converged).
    pub fn pending(&self) -> usize {
        self.states.iter().map(|s| s.frontier.len()).sum()
    }

    /// The queries, in slot order.
    pub fn queries(&self) -> Vec<Query> {
        self.states.iter().map(|s| s.query).collect()
    }

    /// Per-query outer iterations executed so far, in slot order.
    pub fn query_iterations(&self) -> Vec<u32> {
        self.states.iter().map(|s| s.iterations).collect()
    }

    /// Final distances of query slot `i` for the original node ids.
    pub fn distances(&self, i: usize) -> Vec<u32> {
        self.states[i].dist[..self.graph.num_nodes()].to_vec()
    }

    /// Drive the batch to convergence.
    pub fn run(&mut self, ctx: &mut ExecCtx, max_iterations: u32) -> Result<()> {
        let mut outer = 0u32;
        while self.pending() > 0 {
            self.run_iteration(ctx)?;
            outer += 1;
            if outer >= max_iterations {
                return Err(Error::Config(format!(
                    "batch exceeded max_iterations = {max_iterations} (non-convergence?)"
                )));
            }
        }
        Ok(())
    }

    /// One batch iteration: merge → inspect once → decide once → step every
    /// active query in the chosen style.
    pub fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let active: Vec<usize> = (0..self.states.len())
            .filter(|&i| !self.states[i].frontier.is_empty())
            .collect();
        if active.is_empty() {
            return Ok(());
        }
        // The tagged merged worklist exists to feed the shared inspection
        // and decision, so static batch modes — which have nothing to
        // decide — skip building (and paying for) it entirely.
        let merged = if self.strategy == StrategyKind::AD {
            let frontiers: Vec<(usize, &NodeWorklist)> = active
                .iter()
                .map(|&i| (i, &self.states[i].frontier))
                .collect();
            let m = MergedWorklist::from_frontiers(&g, &frontiers);
            // The merged list is device-resident for the iteration (node,
            // degree, tag per entry); charge it so feasibility and peak
            // memory see it.
            ctx.mem.charge(SRV_MERGED, m.memory_bytes())?;
            Some(m)
        } else {
            None
        };

        // One inspection + one policy decision for the whole batch (AD).
        let choice = if let Some(merged) = &merged {
            let snap = FrontierInspector::inspect(merged.degrees(), ctx.dev);
            ctx.metrics.inspector_passes += 1;
            ctx.charge_overhead(INSPECT_BASE_CYCLES + snap.nodes / 32);
            let feas = self.feasibility(ctx, &snap);
            let decision = {
                let input = PolicyInput {
                    snapshot: &snap,
                    degrees: merged.degrees(),
                    current: self.mode,
                    feasibility: feas,
                    dev: ctx.dev,
                    params: &self.params,
                    mdt: self.mdt.mdt,
                    graph_edges: g.num_edges() as u64,
                    graph_nodes: g.num_nodes() as u64,
                };
                self.policy.as_mut().expect("AD batch has a policy").decide(&input)
            };
            ctx.metrics.policy_decisions += 1;
            let choice = if feas.allows(decision.choice) {
                decision.choice
            } else {
                StrategyKind::BS
            };
            let migrated = choice != self.mode;
            if requires_migration(self.mode, choice) {
                // One conversion kernel over the merged frontier — the
                // representation switch is paid once, not per query. Mode
                // changes inside node space (e.g. BS↔HP) are free, exactly
                // as in the single-query engine.
                ctx.charge_aux_kernel(merged.len() as u64 + 1, 2);
            }
            ctx.metrics.record_decision(DecisionRecord {
                iteration: ctx.metrics.iterations,
                strategy: choice.label(),
                migrated,
                frontier_nodes: snap.nodes,
                frontier_edges: snap.edges,
                degree_skew: snap.skew,
                predicted_cycles: decision.predicted_cycles,
            });
            self.mode = choice;
            choice
        } else {
            self.mode = self.strategy;
            self.strategy
        };

        // Shared structures for the chosen mode, built once per batch.
        if choice == StrategyKind::EP && !self.coo_charged {
            ctx.mem.charge(SRV_COO, 12 * g.num_edges() as u64)?;
            ctx.charge_aux_kernel(g.num_edges() as u64, 1);
            self.coo_charged = true;
        }
        if choice == StrategyKind::NS {
            self.ensure_split(ctx)?;
        }

        // Per-query execution, each against its own dist array. AD modes
        // step from the merged list's tagged view; static modes step from
        // the per-query frontier directly (identical content — the merge
        // only reorders by node id).
        for &slot in &active {
            let view = match &merged {
                Some(m) => m.query_frontier(slot),
                None => self.states[slot].frontier.clone(),
            };
            self.step_query(ctx, slot, choice, &view)?;
            self.states[slot].iterations += 1;
        }
        if let Some(m) = &merged {
            ctx.mem.release(SRV_MERGED, m.memory_bytes());
        }
        ctx.metrics.iterations += 1;
        Ok(())
    }

    /// Memory feasibility of the candidates under the remaining budget —
    /// the single-query engine's bounds, with per-query costs (NS's dist
    /// extension) multiplied across the batch.
    fn feasibility(&self, ctx: &ExecCtx, snap: &FrontierSnapshot) -> Feasibility {
        let headroom = ctx.mem.budget().saturating_sub(ctx.mem.current());
        let e = self.graph.num_edges() as u64;
        let n = self.graph.num_nodes() as u64;
        let q = self.states.len() as u64;
        let w = snap.edges;
        let t = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads) as u64;
        let coo_extra = if self.coo_charged { 0 } else { 12 * e };
        let ep = coo_extra + 8 * w + 8 * e <= headroom;
        let wd = 12 * snap.nodes + 8 * w + 8 * t <= headroom;
        let mdt = self.mdt.mdt.max(1) as u64;
        let ns_extra = if self.split.is_some() {
            4 * w
        } else {
            // Split CSR + parent map + every query's dist extension.
            self.graph.memory_bytes() + 8 * n + q * 4 * (e / mdt + 1) + 4 * w
        };
        let ns = ns_extra <= headroom;
        Feasibility {
            ep,
            wd,
            ns,
            coo_resident: self.coo_charged,
            split_built: self.split.is_some(),
        }
    }

    /// Build the shared split graph (once) and extend every query's dist
    /// array to the split node count.
    fn ensure_split(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        if self.split.is_some() {
            return Ok(());
        }
        let n = self.graph.num_nodes();
        let split = split_graph(&self.graph, self.mdt);
        ctx.mem.charge(SRV_NS_CSR, split.graph.memory_bytes())?;
        ctx.mem.charge(SRV_NS_MAP, 8 * n as u64)?;
        ctx.charge_aux_kernel(self.graph.num_edges() as u64 + n as u64, 2);
        let n_split = split.graph.num_nodes();
        if n_split > n {
            for st in &mut self.states {
                ctx.mem.charge(SRV_DIST, 4 * (n_split - n) as u64)?;
                st.dist.resize(n_split, crate::INF);
            }
        }
        let parent_of = migrate::parent_of_table(&split, n);
        self.split = Some(SplitShared { split, parent_of });
        Ok(())
    }

    /// Run one iteration of query `slot` in `mode`'s kernel style, with the
    /// query's dist array and algorithm swapped into the context.
    fn step_query(
        &mut self,
        ctx: &mut ExecCtx,
        slot: usize,
        mode: StrategyKind,
        view: &NodeWorklist,
    ) -> Result<()> {
        let saved_algo = ctx.algo;
        ctx.algo = self.states[slot].query.algo;
        std::mem::swap(&mut ctx.dist, &mut self.states[slot].dist);
        let res = match mode {
            StrategyKind::BS => self.step_bs(ctx, slot, view),
            StrategyKind::EP => self.step_ep(ctx, slot, view),
            StrategyKind::WD => self.step_wd(ctx, slot, view),
            StrategyKind::NS => self.step_ns(ctx, slot, view),
            StrategyKind::HP => self.step_hp(ctx, slot, view),
            StrategyKind::AD => unreachable!("the batch decision is a static kind"),
        };
        std::mem::swap(&mut ctx.dist, &mut self.states[slot].dist);
        ctx.algo = saved_algo;
        res
    }

    /// Replace query `slot`'s frontier with the condensed update stream
    /// (mirrors [`crate::strategies::common::NodeFrontier::advance`]).
    ///
    /// Worklist bytes are charged at a flat 8 B/entry in every mode: the
    /// batch's canonical frontier always carries the (node, degree) pair
    /// arrays, unlike the single-query engine's mode-shaped buffers (4 B
    /// in BS/HP) — a deliberate accounting difference, documented here
    /// like the engine documents its own CSR-residency choice.
    fn advance(&mut self, ctx: &mut ExecCtx, slot: usize, updated: &[NodeId]) -> Result<()> {
        let g = &self.graph;
        let raw = updated.len() as u64;
        ctx.metrics.peak_worklist_entries = ctx.metrics.peak_worklist_entries.max(raw);
        // Double buffer: the raw (duplicate-laden) output alongside the
        // input worklist.
        ctx.mem.charge(SRV_WL, 8 * raw)?;
        let mut next = NodeWorklist::new();
        for &nd in updated {
            let (w, b) = (nd as usize / 64, nd as usize % 64);
            if self.seen[w] & (1 << b) == 0 {
                self.seen[w] |= 1 << b;
                next.push(nd, g.degree(nd));
            }
        }
        for &nd in next.nodes() {
            self.seen[nd as usize / 64] = 0; // clear only touched words
        }
        ctx.metrics.condensed_away += raw - next.len() as u64;
        if raw > 0 {
            ctx.charge_aux_kernel(raw, 2);
        }
        let old = 8 * self.states[slot].frontier.len() as u64;
        let keep = 8 * next.len() as u64;
        ctx.mem.release(SRV_WL, old + 8 * raw - keep);
        self.states[slot].frontier = next;
        Ok(())
    }

    /// BS style: one lane per node (mirrors `ad_bs_relax`).
    fn step_bs(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let g = self.graph.clone();
        let nodes = view.nodes().to_vec();
        let (src, eid) = flatten_frontier(&g, &nodes);
        let mut offsets = Vec::with_capacity(nodes.len() + 1);
        offsets.push(0u32);
        let mut acc = 0u32;
        for &n in &nodes {
            acc += g.degree(n);
            offsets.push(acc);
        }
        let work = KernelWork {
            name: "srv_bs_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&g, &work, None)?;
        self.advance(ctx, slot, &result.updated)
    }

    /// WD style: scan + `find_offsets` + evenly blocked edges (mirrors
    /// `ad_wd_relax`).
    fn step_wd(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let g = self.graph.clone();
        let max_threads = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads);
        let nodes = view.nodes().to_vec();
        let wl_len = nodes.len() as u64;
        let (src, eid) = flatten_frontier(&g, &nodes);
        let total = src.len();

        ctx.mem.charge(SRV_WD_PREFIX, 4 * wl_len)?;
        ctx.charge_aux_kernel(wl_len, 1);
        let threads = (max_threads as usize).min(total.max(1)) as u64;
        let log_wl = (64 - wl_len.leading_zeros() as u64).max(1);
        ctx.charge_aux_kernel(threads, 4 * log_wl);
        let offsets_bytes = 8 * max_threads as u64;
        ctx.mem.charge(SRV_WD_OFFSETS, offsets_bytes)?;

        let work = KernelWork {
            name: "srv_wd_relax",
            src,
            eid,
            assignment: Assignment::Blocked(block_offsets(total, max_threads)),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 4,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&g, &work, None)?;
        ctx.mem.release(SRV_WD_OFFSETS, offsets_bytes);
        ctx.mem.release(SRV_WD_PREFIX, 4 * wl_len);
        self.advance(ctx, slot, &result.updated)
    }

    /// EP style: the frontier exploded to edges over the shared COO
    /// (mirrors `ad_ep_relax`); the output returns to node space, so the
    /// transient edge worklist lives only for the launch.
    fn step_ep(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let g = self.graph.clone();
        let wl = migrate::nodes_to_edges(&g, view);
        let charged = wl.memory_bytes();
        ctx.mem.charge(SRV_EP_WL, charged)?;
        let max_threads = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads);
        let total = wl.len();
        let threads = (max_threads as usize).min(total).max(1) as u32;
        let work = KernelWork {
            name: "srv_ep_relax",
            src: wl.srcs().to_vec(),
            eid: wl.edges().to_vec(),
            assignment: Assignment::Strided {
                num_threads: threads,
            },
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Edges,
        };
        let result = ctx.launch(&g, &work, None);
        ctx.mem.release(SRV_EP_WL, charged);
        let result = result?;
        self.advance(ctx, slot, &result.updated)
    }

    /// NS style: the query frontier migrated into the shared split graph,
    /// clone attributes refreshed from their parents, results folded back
    /// to original ids (mirrors `ad_ns_relax`).
    fn step_ns(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let parents: Vec<NodeId> = {
            let st = self.split.as_ref().expect("ensure_split ran");
            let sg = &st.split.graph;
            // Refresh the clones of the active parents so the mirror
            // invariant holds when entering split space.
            let mut children = 0u64;
            for &u in view.nodes() {
                let du = ctx.dist[u as usize];
                for c in st.split.map.children(u) {
                    ctx.dist[c as usize] = du;
                    children += 1;
                }
            }
            if children > 0 {
                ctx.charge_aux_kernel(children, 1);
            }
            let swl = migrate::nodes_to_split(&st.split, view);
            let nodes = swl.nodes().to_vec();
            let (src, eid) = flatten_frontier(sg, &nodes);
            let mut offsets = Vec::with_capacity(nodes.len() + 1);
            offsets.push(0u32);
            let mut acc = 0u32;
            for &nd in &nodes {
                acc += sg.degree(nd);
                offsets.push(acc);
            }
            let work = KernelWork {
                name: "srv_ns_relax",
                src,
                eid,
                assignment: Assignment::Blocked(offsets),
                access: AccessPattern::Scattered,
                extra_cycles_per_edge: 0,
                push: PushTarget::Node,
            };
            let result = ctx.launch(sg, &work, Some(&st.split.map))?;
            result
                .updated
                .iter()
                .map(|&x| st.parent_of[x as usize])
                .collect()
        };
        self.advance(ctx, slot, &parents)
    }

    /// HP style: sub-iterations of ≤ MDT edges per node with the WD
    /// fallback on small residues (mirrors `ad_hp_relax`).
    fn step_hp(&mut self, ctx: &mut ExecCtx, slot: usize, view: &NodeWorklist) -> Result<()> {
        let g = self.graph.clone();
        let mdt = self.mdt.mdt.max(1);
        let block = ctx.dev.block_size as usize;
        let frontier_nodes = view.nodes().to_vec();
        let degrees = view.degrees().to_vec();
        let mut all_updates: Vec<NodeId> = Vec::new();

        if frontier_nodes.len() < block {
            let (src, eid) = flatten_frontier(&g, &frontier_nodes);
            if !src.is_empty() {
                let ups = hp_wd_fallback(ctx, &g, src, eid, frontier_nodes.len() as u64)?;
                all_updates.extend(ups);
            }
        } else {
            let mut sub = SubList::from_super(&frontier_nodes, &degrees);
            let sub_bytes = sub.memory_bytes();
            ctx.mem.charge(SRV_HP_SUBLIST, sub_bytes)?;

            while !sub.is_empty() {
                if sub.len() < block {
                    let mut src = Vec::new();
                    let mut eid = Vec::new();
                    for c in sub.cursors() {
                        let first = g.first_edge(c.node) + c.processed;
                        for e in first..first + c.remaining() {
                            src.push(c.node);
                            eid.push(e);
                        }
                    }
                    let wl_len = sub.len() as u64;
                    let ups = hp_wd_fallback(ctx, &g, src, eid, wl_len)?;
                    all_updates.extend(ups);
                    break;
                }

                let mut src = Vec::new();
                let mut eid = Vec::new();
                let mut offsets = Vec::with_capacity(sub.len() + 1);
                offsets.push(0u32);
                let mut acc = 0u32;
                for c in sub.cursors() {
                    let take = c.remaining().min(mdt);
                    let first = g.first_edge(c.node) + c.processed;
                    for e in first..first + take {
                        src.push(c.node);
                        eid.push(e);
                    }
                    acc += take;
                    offsets.push(acc);
                }
                let work = KernelWork {
                    name: "srv_hp_relax",
                    src,
                    eid,
                    assignment: Assignment::Blocked(offsets),
                    access: AccessPattern::Scattered,
                    extra_cycles_per_edge: 2,
                    push: PushTarget::Node,
                };
                let result = ctx.launch(&g, &work, None)?;
                all_updates.extend(result.updated);
                sub.advance(mdt);
                ctx.charge_aux_kernel(sub.len() as u64 + 1, 1);
            }
            ctx.mem.release(SRV_HP_SUBLIST, sub_bytes);
        }
        self.advance(ctx, slot, &all_updates)
    }
}

/// The differential oracle: replay every query of a batched run through the
/// existing single-query engine ([`crate::coordinator::run`]) with the same
/// strategy and parameters, and require distance-array equality. Returns
/// the first mismatch as a [`Error::Config`] describing the query.
pub fn replay_single(
    graph: &Arc<Csr>,
    queries: &[Query],
    strategy: StrategyKind,
    params: &StrategyParams,
    batched: &[Vec<u32>],
) -> Result<()> {
    if queries.len() != batched.len() {
        return Err(Error::Config(format!(
            "replay: {} queries but {} batched results",
            queries.len(),
            batched.len()
        )));
    }
    for (q, got) in queries.iter().zip(batched) {
        let cfg = RunConfig {
            algo: q.algo,
            strategy,
            source: q.source,
            params: params.clone(),
            ..Default::default()
        };
        let single = run(graph, &cfg)?;
        if &single.dist != got {
            let diverged = single
                .dist
                .iter()
                .zip(got)
                .position(|(a, b)| a != b)
                .unwrap_or(0);
            return Err(Error::Config(format!(
                "query {} ({} from {}): batched dist diverges from the single-query \
                 engine at node {diverged} (single {} vs batched {})",
                q.id,
                q.algo.name(),
                q.source,
                single.dist[diverged],
                got[diverged],
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::graph::generators::{erdos_renyi, rmat, RmatParams};
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    fn batch_run(
        g: &Arc<Csr>,
        queries: &[Query],
        strategy: StrategyKind,
    ) -> (Vec<Vec<u32>>, crate::metrics::RunMetrics) {
        let dev = DeviceSpec::k20c();
        let mut ctx = ExecCtx::new(&dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
        let mut batch =
            QueryBatch::new(g.clone(), queries, strategy, StrategyParams::default()).unwrap();
        batch.init(&mut ctx).unwrap();
        batch.run(&mut ctx, 1_000_000).unwrap();
        ctx.finalize_metrics();
        let dists = (0..queries.len()).map(|i| batch.distances(i)).collect();
        (dists, ctx.metrics)
    }

    fn queries(sources: &[NodeId], algo: AlgoKind) -> Vec<Query> {
        sources
            .iter()
            .enumerate()
            .map(|(id, &source)| Query {
                id: id as u32,
                algo,
                source,
            })
            .collect()
    }

    #[test]
    fn batched_ad_matches_oracles() {
        let g = Arc::new(rmat(9, 4096, RmatParams::default(), 5).unwrap());
        let qs = queries(&[0, 7, 19, 101], AlgoKind::Sssp);
        let (dists, metrics) = batch_run(&g, &qs, StrategyKind::AD);
        for (q, d) in qs.iter().zip(&dists) {
            assert_eq!(d, &traversal::dijkstra(&g, q.source), "query {}", q.id);
        }
        assert!(metrics.inspector_passes > 0);
        assert_eq!(metrics.inspector_passes, metrics.policy_decisions);
        assert_eq!(
            metrics.inspector_passes,
            metrics.decisions.len() as u64,
            "one shared decision per batch iteration"
        );
    }

    #[test]
    fn amortization_beats_independent_inspection() {
        let g = Arc::new(rmat(9, 4096, RmatParams::default(), 5).unwrap());
        let qs = queries(&[0, 7, 19, 101, 33, 64, 90, 110], AlgoKind::Sssp);
        let (_, batched) = batch_run(&g, &qs, StrategyKind::AD);
        let mut independent = 0u64;
        for q in &qs {
            let r = run(
                &g,
                &RunConfig {
                    strategy: StrategyKind::AD,
                    source: q.source,
                    ..Default::default()
                },
            )
            .unwrap();
            independent += r.metrics.inspector_passes + r.metrics.policy_decisions;
        }
        assert!(
            batched.inspector_passes + batched.policy_decisions < independent,
            "batched {} + {} must undercut independent {independent}",
            batched.inspector_passes,
            batched.policy_decisions
        );
    }

    #[test]
    fn every_static_mode_matches_oracles() {
        let g = Arc::new(erdos_renyi(200, 900, 12, 3).unwrap());
        let qs = queries(&[0, 5, 50], AlgoKind::Bfs);
        for strategy in StrategyKind::ALL {
            let (dists, _) = batch_run(&g, &qs, strategy);
            for (q, d) in qs.iter().zip(&dists) {
                assert_eq!(
                    d,
                    &traversal::bfs_levels(&g, q.source),
                    "{strategy} query {}",
                    q.id
                );
            }
        }
    }

    #[test]
    fn mixed_algo_batch_keeps_queries_separate() {
        let g = Arc::new(erdos_renyi(150, 600, 9, 11).unwrap());
        let qs = vec![
            Query { id: 0, algo: AlgoKind::Bfs, source: 3 },
            Query { id: 1, algo: AlgoKind::Sssp, source: 3 },
        ];
        let (dists, _) = batch_run(&g, &qs, StrategyKind::AD);
        assert_eq!(dists[0], traversal::bfs_levels(&g, 3));
        assert_eq!(dists[1], traversal::dijkstra(&g, 3));
    }

    #[test]
    fn replay_single_flags_divergence() {
        let g = Arc::new(erdos_renyi(80, 300, 5, 2).unwrap());
        let qs = queries(&[1, 2], AlgoKind::Sssp);
        let (mut dists, _) = batch_run(&g, &qs, StrategyKind::BS);
        replay_single(&g, &qs, StrategyKind::BS, &StrategyParams::default(), &dists)
            .expect("faithful results must verify");
        dists[1][3] ^= 1;
        assert!(
            replay_single(&g, &qs, StrategyKind::BS, &StrategyParams::default(), &dists)
                .is_err(),
            "corrupted results must be rejected"
        );
    }

    #[test]
    fn rejects_oversized_and_out_of_range() {
        let g = Arc::new(erdos_renyi(50, 200, 5, 1).unwrap());
        let many = queries(&vec![0; MAX_QUERIES_PER_SHARD + 1], AlgoKind::Bfs);
        assert!(QueryBatch::new(
            g.clone(),
            &many,
            StrategyKind::BS,
            StrategyParams::default()
        )
        .is_err());
        let bad = queries(&[10_000], AlgoKind::Bfs);
        assert!(QueryBatch::new(g, &bad, StrategyKind::BS, StrategyParams::default()).is_err());
    }
}
