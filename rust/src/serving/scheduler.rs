//! The admission-controlled serving scheduler: a deterministic
//! virtual-clock coordinator that consumes a continuous arrival stream,
//! admits queries through the bounded [`AdmissionQueue`], places them
//! load-aware over heterogeneous device shards, and hands batches to
//! **real worker threads** — one persistent [`QueryBatch`] engine per
//! shard, executing concurrently while the coordinator folds results
//! back in a fixed shard order.
//!
//! This replaces the batch engine's original operating assumptions — a
//! pre-materialized query list, round-robin placement, identical devices —
//! with the serving reality the adaptive-load-balancing line of work
//! argues for (Jatala et al., arXiv:1911.09135): decisions made online
//! against *observed* load. Concretely, per virtual instant:
//!
//! 1. **Completions first.** Shards whose running batch finishes at `now`
//!    retire it (results folded, the engine's buffers kept warm for the
//!    next batch).
//! 2. **Arrivals** due at `now` enter the bounded FIFO queue; a full
//!    queue invokes the [`OverflowPolicy`] — `drop` sheds (counted),
//!    `block` back-pressures until space frees.
//! 3. **Placement.** Queries leave the queue in FIFO order *as capacity
//!    frees*: only idle shards receive work (a busy shard's next batch is
//!    not committed early, so the bounded queue really is the only buffer
//!    under load), each query going to the idle shard minimizing
//!    *outstanding edges weighted by device throughput*
//!    (`edges_a × tp_b < edges_b × tp_a`, exact u128 integer
//!    cross-multiplication — deterministic on every platform, and a K40
//!    legitimately absorbs more work than a GTX 680).
//! 4. **Dispatch.** Every idle shard with placed queries launches them as
//!    one batch: the coordinator sends a `(shard, batch, base_ps)`
//!    [`LaunchMsg`] to the shard's worker thread, the workers run their
//!    engines **in parallel**, and the coordinator collects every
//!    [`BatchReport`] of the round before the clock moves again.
//!
//! # Parallel execution, deterministic output
//!
//! The threading model follows gpucachesim's cluster-of-cores design:
//! execution order across workers is whatever the OS gives, but *fold*
//! order is a fixed `core_sim_order` analog — ascending shard id. Only
//! batches launched at the same virtual instant ever run wall-clock
//! concurrently (the next event on the clock needs every launched batch's
//! duration, so each dispatch round is a natural barrier), and per round
//! the coordinator:
//!
//! * records each shard's `BatchLaunch` event and replays that shard's
//!   engine events from its private per-shard trace ring into the main
//!   ring via [`TraceSink::absorb`], ascending shard id — reproducing the
//!   exact byte order the sequential loop used to write;
//! * applies cycle counts, outcomes and admission bookkeeping in the same
//!   ascending order.
//!
//! The arrival stream stays authoritative on the coordinator, so
//! `ScheduleReport`, `--trace-out` and `--profile-out` bytes are
//! identical for any worker count — `workers = 1` runs the very same
//! message machinery on a single thread (pinned by
//! `tests/parallel_determinism.rs`).
//!
//! Worker lifecycle: threads spawn in [`Scheduler::new`], drain their
//! mailboxes, and join in [`Scheduler::finish`] (graceful shutdown on
//! drain) or in [`WorkerPool`]'s `Drop` (early exit / error paths). A
//! panic inside an engine is caught on the worker, carried home in the
//! report, and re-raised on the coordinator at the fold, so a crashing
//! strategy fails the run instead of deadlocking it.
//!
//! The steady state still allocates nothing per worker: launch and report
//! messages move pre-allocated buffers (the query slice, the distance
//! container, the per-shard trace ring) back and forth through
//! fixed-capacity [`Mailbox`] slots, and each worker re-assembles its
//! `ExecCtx` from persistent parts (`MemoryTracker`, `RunMetrics`,
//! `ScratchArena`, the distance seam) without touching the heap —
//! enforced by the counting allocator in `tests/alloc_regression.rs`.
//!
//! The virtual clock runs in integer **picoseconds** because
//! heterogeneous shards' cycle counts are incomparable: each device
//! contributes `cycles × ps_per_cycle(device)`. Latency and wait are
//! measured from *arrival* (including any blocked stall), so the
//! latency-vs-arrival-rate curve (`figqueue`) shows the real queueing
//! behavior.
//!
//! # Fault injection & recovery
//!
//! An optional [`FaultPlan`] injects shard faults at exact virtual
//! instants: transient stalls, permanent death, throughput degradation
//! (a ps-per-cycle multiplier) and memory-budget shrinks. Faults are
//! coordinator-side *simulation events*, never races — the recovery
//! paths are:
//!
//! * a down transition quarantines the shard (placement skips it until a
//!   matching up transition re-admits it) and **aborts** its in-flight
//!   batch: the queries go to a pre-allocated retry buffer and re-enter
//!   the queue *at the front* after an exponential virtual-time backoff
//!   (`retry_backoff_ps << attempt`), up to `max_retries` attempts —
//!   beyond that the query lands in the `failed` outcome;
//! * a batch whose engine errors at the fold (e.g. out-of-memory under a
//!   shrunken budget) requeues the same way instead of aborting the run;
//! * a shrink rides the next [`LaunchMsg`] to the worker, which clamps
//!   the shard's persistent [`MemoryTracker`] budget — under the AD
//!   strategy the policy then picks memory-feasible strategies instead
//!   of erroring;
//! * per-query deadlines (`deadline_ps`) shed hopeless work at placement
//!   and retry time with a counted `deadline_expired` outcome;
//! * a no-progress detector fails the remainder cleanly when capacity
//!   can never return (every shard dead with a non-empty queue), instead
//!   of spinning at one instant forever.
//!
//! The retry/quarantine state is pre-allocated, so the zero-alloc steady
//! state holds with an active fault plan, and the conservation identity
//! `arrived == served + dropped + deadline_expired + failed` replaces
//! `arrived == served + dropped` under faults. Determinism is unchanged:
//! same seed + same plan ⇒ byte-identical report/trace/profile for every
//! worker count.

use crate::algorithms::{AlgoKind, NativeRelaxer};
use crate::arena::{GraphCache, ScratchArena};
use crate::coordinator::ExecCtx;
use crate::error::{Error, Result};
use crate::graph::Csr;
use crate::metrics::RunMetrics;
use crate::sim::{DeviceSpec, MemoryTracker};
use crate::strategies::{StrategyKind, StrategyParams};
use crate::telemetry::{Exposition, LogHistogram, TraceEvent, TraceEventKind, TraceSink};
use crate::util::Json;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::batch::QueryBatch;
use super::faults::{FaultEvent, FaultKind, FaultPlan};
use super::query::{Arrival, Query};
use super::queue::{AdmissionQueue, OverflowPolicy, QueueEntry};
use super::shard::{aggregate, AggregateMetrics, ServeConfig, ShardReport};

/// Scheduler configuration: the batch-engine config plus admission
/// control.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Strategy / params / devices / `max_batch` of the per-shard batch
    /// engines.
    pub serve: ServeConfig,
    /// Bound of the admission queue.
    pub queue_cap: usize,
    /// What happens to arrivals at a full queue.
    pub overflow: OverflowPolicy,
    /// Collect per-query distance arrays into the report (needed for
    /// `--verify` / parity; the allocation-regression harness turns it
    /// off because cloning a distance array is inherently an allocation).
    pub collect_distances: bool,
    /// Worker threads executing the per-shard batch engines. `0` (the
    /// default) spawns one worker per shard; values above the shard
    /// count are clamped (an engine never migrates between threads).
    /// Every worker count produces byte-identical reports, traces and
    /// profiles — the coordinator folds batch reports in fixed shard
    /// order regardless of which thread finished first.
    pub workers: usize,
    /// Deterministic shard-fault schedule (`None` = fault-free). See
    /// [`FaultPlan`] for the spec grammar and [`Scheduler`] for the
    /// recovery semantics.
    pub faults: Option<FaultPlan>,
    /// Per-query deadline measured from arrival, ps (`0` disables): a
    /// query not launched by `arrival + deadline_ps` is shed with a
    /// counted `deadline_expired` outcome instead of retried forever.
    pub deadline_ps: u64,
    /// Bound on serving attempts after the first (a query failed by its
    /// batch is retried at most this many times before it lands in the
    /// `failed` outcome).
    pub max_retries: u32,
    /// Base of the exponential virtual-time retry backoff, ps: attempt
    /// `n` becomes eligible `retry_backoff_ps << (n-1)` after its
    /// failure (minimum 1 ps so the clock always advances).
    pub retry_backoff_ps: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            serve: ServeConfig::default(),
            queue_cap: 64,
            overflow: OverflowPolicy::default(),
            collect_distances: true,
            workers: 0,
            faults: None,
            deadline_ps: 0,
            max_retries: 3,
            retry_backoff_ps: 1_000_000_000, // 1 ms
        }
    }
}

/// One served query's timeline on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueryOutcome {
    pub query: Query,
    /// Shard that served it.
    pub shard: usize,
    /// When it arrived (ps) — blocked stalls count from here.
    pub arrival_ps: u64,
    /// When its batch launched (ps).
    pub start_ps: u64,
    /// When its batch completed (ps).
    pub done_ps: u64,
}

impl QueryOutcome {
    /// Arrival → launch (queueing + blocking), ps.
    pub fn wait_ps(&self) -> u64 {
        self.start_ps - self.arrival_ps
    }

    /// Arrival → completion, ps.
    pub fn latency_ps(&self) -> u64 {
        self.done_ps - self.arrival_ps
    }

    /// Arrival → completion, milliseconds.
    pub fn latency_ms(&self) -> f64 {
        self.latency_ps() as f64 / 1e9
    }
}

/// Everything a finished scheduler run reports.
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    /// One report per device shard; `queries`/`dists` accumulate every
    /// batch the shard ran, so the replay oracle applies per shard
    /// exactly as with [`crate::serving::serve`].
    pub shards: Vec<ShardReport>,
    /// Per-served-query timelines, in completion order.
    pub outcomes: Vec<QueryOutcome>,
    /// Queries shed by the drop policy (excluded from results, counted).
    pub dropped: Vec<Query>,
    /// Queries shed past their deadline (admitted, never launched in
    /// time). Part of the faulted conservation identity
    /// `arrived == served + dropped + deadline_expired + failed`.
    pub deadline_expired: Vec<Query>,
    /// Queries that exhausted `max_retries` or were stranded when every
    /// shard died (the no-progress detector fails them cleanly).
    pub failed: Vec<Query>,
    /// Query-attempts returned to the retry buffer after a failed or
    /// aborted batch.
    pub requeued: u64,
    /// Retry re-admissions into the queue (≤ `requeued`; entries still
    /// buffered when the run strands count only as `failed`).
    pub retries: u64,
    /// Query ids in the order they left the admission queue — FIFO
    /// admission order, pinned by `strategy_properties.rs`.
    pub placed_order: Vec<u32>,
    /// Arrivals consumed (`== admitted + dropped.len()` at drain).
    pub arrived: u64,
    /// Queries admitted into the queue.
    pub admitted: u64,
    /// Peak admission-queue depth.
    pub queue_peak: u64,
    /// Arrivals that stalled under [`OverflowPolicy::Block`].
    pub blocked: u64,
    /// Batches launched across all shards.
    pub batches: u64,
    /// Virtual instant the stream drained (ps).
    pub wall_ps: u64,
    /// Queue-wait distribution (arrival → batch launch), ps samples.
    pub wait_hist: LogHistogram,
    /// End-to-end latency distribution (arrival → completion), ps samples.
    pub latency_hist: LogHistogram,
}

impl ScheduleReport {
    /// Queries actually served.
    pub fn served(&self) -> usize {
        self.outcomes.len()
    }

    /// Distance array of the query with `id`, if it was served and
    /// distance collection was on.
    pub fn dist_of(&self, id: u32) -> Option<&[u32]> {
        for s in &self.shards {
            if let Some(i) = s.queries.iter().position(|q| q.id == id) {
                // `dists` is empty when `collect_distances` was off.
                return s.dists.get(i).map(Vec::as_slice);
            }
        }
        None
    }

    /// Wall-clock of the whole stream (arrival of the first query to
    /// completion of the last), ms.
    pub fn wall_ms(&self) -> f64 {
        self.wall_ps as f64 / 1e9
    }

    /// Throughput cost: Σ per-shard simulated ms, each shard on its own
    /// device clock.
    pub fn total_ms(&self) -> f64 {
        self.shards.iter().map(ShardReport::total_ms).sum()
    }

    /// Mean served latency, ms (0 when nothing was served).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(QueryOutcome::latency_ms).sum::<f64>()
            / self.outcomes.len() as f64
    }

    /// Median served latency, ms (histogram-backed, log₂ resolution).
    pub fn p50_latency_ms(&self) -> f64 {
        self.latency_hist.percentile_ms(50)
    }

    /// 95th-percentile served latency, ms.
    ///
    /// Reads the log₂-bucketed histogram — O(buckets), allocation-free —
    /// instead of collecting and sorting every outcome per call. The
    /// reported value is the percentile bucket's upper bound (clamped to
    /// the exact maximum), so it upper-bounds the exact nearest-rank
    /// value within its power-of-two bucket.
    pub fn p95_latency_ms(&self) -> f64 {
        self.latency_hist.percentile_ms(95)
    }

    /// 99th-percentile served latency, ms (histogram-backed).
    pub fn p99_latency_ms(&self) -> f64 {
        self.latency_hist.percentile_ms(99)
    }

    /// Maximum served latency, ms (exact).
    pub fn max_latency_ms(&self) -> f64 {
        self.latency_hist.max_ms()
    }

    /// Median queue wait (arrival → batch launch), ms. Clock-neutral —
    /// measured in virtual ps (the deprecated `wait_cycles` accessor,
    /// which converted on `devices[0]`'s clock, is gone).
    pub fn wait_ms_p50(&self) -> f64 {
        self.wait_hist.percentile_ms(50)
    }

    /// 95th-percentile queue wait, ms (clock-neutral).
    pub fn wait_ms_p95(&self) -> f64 {
        self.wait_hist.percentile_ms(95)
    }

    /// Maximum queue wait, ms (exact, clock-neutral).
    pub fn wait_ms_max(&self) -> f64 {
        self.wait_hist.max_ms()
    }

    /// Fold of the shard metrics plus the scheduler's admission counters.
    pub fn totals(&self) -> AggregateMetrics {
        let mut agg = aggregate(self.shards.iter().map(|s| &s.metrics));
        agg.admitted = self.admitted;
        agg.dropped = self.dropped.len() as u64;
        agg.queue_peak = self.queue_peak;
        agg
    }

    /// JSON rendering: scheduler counters, latency stats (histogram
    /// percentiles), and per-shard summaries — each converted on its own
    /// device clock and carrying `utilization` = busy_ps / wall_ps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arrived", self.arrived.into()),
            ("admitted", self.admitted.into()),
            ("dropped", self.dropped.len().into()),
            ("served", self.served().into()),
            ("deadline_expired", self.deadline_expired.len().into()),
            ("failed", self.failed.len().into()),
            ("requeued", self.requeued.into()),
            ("retries", self.retries.into()),
            ("queue_peak", self.queue_peak.into()),
            ("blocked", self.blocked.into()),
            ("batches", self.batches.into()),
            ("wait_ms_p50", self.wait_ms_p50().into()),
            ("wait_ms_p95", self.wait_ms_p95().into()),
            ("wait_ms_max", self.wait_ms_max().into()),
            ("wall_ms", self.wall_ms().into()),
            ("latency_ms_mean", self.mean_latency_ms().into()),
            ("latency_ms_p50", self.p50_latency_ms().into()),
            ("latency_ms_p95", self.p95_latency_ms().into()),
            ("latency_ms_p99", self.p99_latency_ms().into()),
            ("latency_ms_max", self.max_latency_ms().into()),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| s.to_json_with_span(self.wall_ps))
                        .collect(),
                ),
            ),
            (
                "totals",
                self.totals()
                    .to_json_with_ms(self.total_ms(), self.wall_ms()),
            ),
        ])
    }

    /// Prometheus-style text exposition of the counter registry
    /// (`--metrics-out`). Pass the sink used during the run to include the
    /// per-kind trace-event totals; `None` omits them.
    pub fn prometheus(&self, sink: Option<&TraceSink>) -> String {
        let mut exp = Exposition::new();
        exp.counter("lonestar_arrived_total", "Arrivals consumed by the scheduler", &[], self.arrived as f64);
        exp.counter("lonestar_admitted_total", "Queries admitted into the bounded queue", &[], self.admitted as f64);
        exp.counter("lonestar_dropped_total", "Queries shed by the drop overflow policy", &[], self.dropped.len() as f64);
        exp.counter("lonestar_blocked_total", "Arrivals stalled by the block overflow policy", &[], self.blocked as f64);
        exp.counter("lonestar_served_total", "Queries served to completion", &[], self.served() as f64);
        exp.counter("lonestar_batches_total", "Batches launched across all shards", &[], self.batches as f64);
        exp.counter("lonestar_requeued_total", "Query-attempts returned to the retry buffer by failed/aborted batches", &[], self.requeued as f64);
        exp.counter("lonestar_retries_total", "Retry re-admissions into the queue", &[], self.retries as f64);
        exp.counter("lonestar_deadline_expired_total", "Queries shed past their per-query deadline", &[], self.deadline_expired.len() as f64);
        exp.counter("lonestar_failed_total", "Queries failed after exhausting retries (or stranded by dead shards)", &[], self.failed.len() as f64);
        exp.gauge("lonestar_queue_peak", "Peak admission-queue depth", &[], self.queue_peak as f64);
        exp.gauge("lonestar_wall_ms", "Virtual wall-clock of the drained stream (ms)", &[], self.wall_ms());
        let shard_ids: Vec<String> = (0..self.shards.len()).map(|i| i.to_string()).collect();
        for (s, id) in self.shards.iter().zip(&shard_ids) {
            exp.gauge(
                "lonestar_shard_utilization",
                "Busy fraction of the stream span (busy_ps / wall_ps)",
                &[("shard", id), ("device", s.device.name)],
                s.utilization(self.wall_ps),
            );
        }
        for (s, id) in self.shards.iter().zip(&shard_ids) {
            exp.gauge(
                "lonestar_shard_busy_ms",
                "Total busy time on the shard's own clock (ms)",
                &[("shard", id), ("device", s.device.name)],
                s.busy_ms(),
            );
        }
        for (s, id) in self.shards.iter().zip(&shard_ids) {
            exp.counter(
                "lonestar_shard_queries_total",
                "Queries served per shard",
                &[("shard", id), ("device", s.device.name)],
                s.queries.len() as f64,
            );
        }
        for (s, id) in self.shards.iter().zip(&shard_ids) {
            exp.gauge(
                "lonestar_shard_downtime_ms",
                "Time the shard spent quarantined or dead (ms)",
                &[("shard", id), ("device", s.device.name)],
                s.downtime_ms(),
            );
        }
        for (s, id) in self.shards.iter().zip(&shard_ids) {
            exp.gauge(
                "lonestar_shard_availability",
                "In-service fraction of the stream span (1 - downtime_ps / wall_ps)",
                &[("shard", id), ("device", s.device.name)],
                s.availability(self.wall_ps),
            );
        }
        exp.histogram(
            "lonestar_latency_ms",
            "End-to-end served latency, arrival to completion (ms)",
            &self.latency_hist,
            1e-9,
        );
        exp.histogram(
            "lonestar_wait_ms",
            "Queue wait, arrival to batch launch (ms)",
            &self.wait_hist,
            1e-9,
        );
        let totals = self.totals();
        exp.counter(
            "lonestar_profiled_kernels_total",
            "Processing-kernel launches carrying a per-warp profile",
            &[],
            totals.profiled_kernels as f64,
        );
        exp.counter(
            "lonestar_imbalance_overhead_cycles_total",
            "Cycles spent waiting on straggler warps (per kernel: max-warp minus mean-warp)",
            &[],
            totals.imbalance_overhead_cycles as f64,
        );
        exp.gauge(
            "lonestar_imbalance_peak",
            "Worst single-kernel imbalance factor (max-warp / mean-warp cycles)",
            &[],
            totals.peak_imbalance(),
        );
        exp.histogram(
            "lonestar_warp_cycles",
            "Per-warp busy cycles across all profiled kernels",
            &totals.warp_cycles_hist,
            1.0,
        );
        exp.histogram(
            "lonestar_kernel_imbalance",
            "Per-kernel imbalance factor (recorded as factor x1000, exposed as the factor)",
            &totals.imbalance_hist,
            1e-3,
        );
        if let Some(t) = sink {
            for kind in TraceEventKind::ALL {
                exp.counter(
                    "lonestar_trace_events_total",
                    "Trace events recorded, by kind (survives ring wrap-around)",
                    &[("kind", kind.label())],
                    t.kind_count(kind) as f64,
                );
            }
            exp.counter(
                "lonestar_trace_overwritten_total",
                "Trace events lost to ring wrap-around",
                &[],
                t.overwritten() as f64,
            );
        }
        exp.finish()
    }
}

// ---------------------------------------------------------------------------
// Coordinator ⇄ worker messaging
// ---------------------------------------------------------------------------

/// A fixed-capacity blocking mailbox: `Mutex<VecDeque>` + `Condvar`.
///
/// Why not `std::sync::mpsc`: every mpsc send heap-allocates a queue node,
/// which would break the zero-alloc steady state the scheduler guarantees
/// per iteration. Here the deque is pre-allocated to its worst case (one
/// launch per owned shard plus a shutdown, or one report per shard), so a
/// send is a slot write plus a futex wake.
struct Mailbox<T> {
    slots: Mutex<VecDeque<T>>,
    ready: Condvar,
}

impl<T> Mailbox<T> {
    fn with_capacity(cap: usize) -> Mailbox<T> {
        Mailbox {
            slots: Mutex::new(VecDeque::with_capacity(cap.max(1))),
            ready: Condvar::new(),
        }
    }

    /// Deliver a message. Never blocks and — within the pre-sized
    /// capacity — never allocates.
    fn send(&self, msg: T) {
        let mut q = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert!(q.len() < q.capacity(), "mailbox sized below its worst case");
        q.push_back(msg);
        drop(q);
        self.ready.notify_one();
    }

    /// Block until a message is available.
    fn recv(&self) -> T {
        let mut q = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(msg) = q.pop_front() {
                return msg;
            }
            q = self.ready.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Coordinator → worker. The launch variant is deliberately unboxed:
/// boxing it would put an allocation in every steady-state dispatch,
/// which is exactly what the mailbox design avoids.
#[allow(clippy::large_enum_variant)]
enum WorkerMsg {
    Launch(LaunchMsg),
    Shutdown,
}

/// One batch hand-off: `(shard, batch, base_ps)` plus the recycled
/// buffers that ride along so the worker never allocates.
struct LaunchMsg {
    shard: usize,
    /// Launch instant on the shared virtual clock — the worker's trace
    /// timeline and cycle accounting start here.
    base_ps: u64,
    /// The batch (round-trips home in the report, capacity intact).
    queries: Vec<Query>,
    /// Per-shard trace ring (`None` when tracing is off); the worker's
    /// engine records into it and the coordinator replays it into the
    /// main ring at the fold.
    trace: Option<TraceSink>,
    /// Distance container, filled by the worker when collection is on.
    dists: Vec<Vec<u32>>,
    /// Memory budget override for this batch (bytes): `Some` once a
    /// shrink fault has ever touched the shard (including the restored
    /// value after `factor=1`), `None` while the device default applies.
    /// The worker clamps its persistent tracker before running, so a
    /// shrunken device forces the AD policy onto memory-feasible
    /// strategies — or errors a static strategy into the retry path.
    budget: Option<u64>,
}

/// Worker → coordinator: one per launch, collected before the virtual
/// clock advances.
struct BatchReport {
    shard: usize,
    queries: Vec<Query>,
    trace: Option<TraceSink>,
    dists: Vec<Vec<u32>>,
    /// `Ok(cycles)` — the batch's simulated cost on the shard's device
    /// clock — or the engine's error, surfaced in shard order.
    result: Result<u64>,
    /// Panic payload caught on the worker, re-raised at the fold.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// Everything a worker needs to build its shards' engines locally.
/// Engines are constructed *on* the worker thread because a
/// [`QueryBatch`]'s pluggable policy is not guaranteed `Send`; only
/// plain-data seeds cross the spawn boundary.
struct WorkerSeed {
    shards: Vec<ShardSeed>,
    graph: Arc<Csr>,
    strategy: StrategyKind,
    params: StrategyParams,
    enforce_budget: bool,
    max_iterations: u32,
    collect_distances: bool,
}

struct ShardSeed {
    shard: usize,
    dev: DeviceSpec,
    cache: GraphCache,
}

/// A worker-owned shard: the engine plus the persistent `ExecCtx` parts
/// (the context itself is re-assembled per launch because its borrow of
/// the trace ring lives only as long as one message).
struct ShardExec {
    shard: usize,
    dev: DeviceSpec,
    engine: QueryBatch,
    mem: MemoryTracker,
    metrics: RunMetrics,
    scratch: ScratchArena,
    dist: Vec<u32>,
    /// Cycle watermark for per-batch durations on cumulative metrics.
    prev_cycles: u64,
}

/// A worker's slot for one shard: live, or parked with the engine's
/// construction error (returned with the first launch — unreachable for
/// an empty seed batch, but a clean `Err` beats a worker panic).
struct ExecSlot {
    shard: usize,
    state: std::result::Result<ShardExec, Option<Error>>,
}

/// Run one batch on a worker-owned shard. Mirrors the sequential loop
/// exactly: trace base pinned to the launch instant, reset → run, then
/// (on success) distance extraction, the cycle delta against the
/// watermark, and retirement. On error nothing advances — the same
/// engine/metrics state the sequential path would have left.
fn run_batch(
    ex: &mut ShardExec,
    msg: &mut LaunchMsg,
    max_iterations: u32,
    collect_distances: bool,
) -> Result<u64> {
    if let Some(budget) = msg.budget {
        // A shrink fault (or its later restoration) rides the launch
        // message; the persistent tracker keeps its charges, only the
        // ceiling moves.
        ex.mem.set_budget(budget);
    }
    let mut ctx = ExecCtx::new(&ex.dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
    std::mem::swap(&mut ctx.mem, &mut ex.mem);
    std::mem::swap(&mut ctx.metrics, &mut ex.metrics);
    std::mem::swap(&mut ctx.scratch, &mut ex.scratch);
    std::mem::swap(&mut ctx.dist, &mut ex.dist);
    ctx.trace = msg.trace.as_mut();
    ctx.trace_base_ps = msg.base_ps;
    ctx.trace_base_cycles = ctx.metrics.total_cycles();
    ctx.trace_shard = ex.shard as u32;
    let run = ex
        .engine
        .reset(&mut ctx, &msg.queries)
        .and_then(|()| ex.engine.run(&mut ctx, max_iterations));
    let out = match run {
        Ok(()) => {
            if collect_distances {
                for k in 0..msg.queries.len() {
                    msg.dists.push(ex.engine.distances(k));
                }
            }
            let total = ctx.metrics.total_cycles();
            let cycles = total - ex.prev_cycles;
            ex.prev_cycles = total;
            // Retirement releases the batch's memory charges here; on the
            // virtual clock it is *observed* at the completion instant,
            // and nothing touches this shard's accounting in between, so
            // the fold is indistinguishable from the sequential path.
            ex.engine.retire(&mut ctx);
            Ok(cycles)
        }
        Err(e) => Err(e),
    };
    ctx.trace = None;
    std::mem::swap(&mut ctx.mem, &mut ex.mem);
    std::mem::swap(&mut ctx.metrics, &mut ex.metrics);
    std::mem::swap(&mut ctx.scratch, &mut ex.scratch);
    std::mem::swap(&mut ctx.dist, &mut ex.dist);
    out
}

/// A worker thread's whole life: build the owned shards' engines, answer
/// launch messages until shutdown, then finalize and return each shard's
/// metrics. Panics inside a batch are caught and shipped home in the
/// report so the coordinator can re-raise them instead of deadlocking.
fn worker_main(
    seed: WorkerSeed,
    inbox: &Mailbox<WorkerMsg>,
    reports: &Mailbox<BatchReport>,
) -> Vec<(usize, RunMetrics)> {
    let WorkerSeed {
        shards,
        graph,
        strategy,
        params,
        enforce_budget,
        max_iterations,
        collect_distances,
    } = seed;
    let mut execs: Vec<ExecSlot> = shards
        .into_iter()
        .map(|s| {
            let state = QueryBatch::with_cache(
                graph.clone(),
                &[],
                strategy,
                params.clone(),
                s.cache,
            )
            .map(|engine| ShardExec {
                shard: s.shard,
                mem: if enforce_budget {
                    MemoryTracker::new(s.dev.memory_budget)
                } else {
                    MemoryTracker::unlimited()
                },
                dev: s.dev,
                engine,
                metrics: RunMetrics::default(),
                scratch: ScratchArena::new(),
                dist: Vec::new(),
                prev_cycles: 0,
            })
            .map_err(Some);
            ExecSlot { shard: s.shard, state }
        })
        .collect();

    loop {
        match inbox.recv() {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Launch(mut msg) => {
                let slot = execs.iter_mut().find(|e| e.shard == msg.shard);
                let (result, caught) = match slot {
                    None => (
                        Err(Error::Config(format!(
                            "shard {} is not owned by this worker",
                            msg.shard
                        ))),
                        None,
                    ),
                    Some(slot) => match &mut slot.state {
                        Ok(ex) => match std::panic::catch_unwind(AssertUnwindSafe(|| {
                            run_batch(ex, &mut msg, max_iterations, collect_distances)
                        })) {
                            Ok(r) => (r, None),
                            Err(p) => (
                                Err(Error::Config("shard worker panicked".into())),
                                Some(p),
                            ),
                        },
                        Err(parked) => (
                            Err(parked.take().unwrap_or_else(|| {
                                Error::Config("shard engine construction failed".into())
                            })),
                            None,
                        ),
                    },
                };
                reports.send(BatchReport {
                    shard: msg.shard,
                    queries: msg.queries,
                    trace: msg.trace,
                    dists: msg.dists,
                    result,
                    panic: caught,
                });
            }
        }
    }

    execs
        .into_iter()
        .map(|slot| match slot.state {
            Ok(mut ex) => {
                // The same finalization the sequential path ran through
                // `ExecCtx::finalize_metrics`: fold the memory peak and
                // the arena's pool counters into the metrics.
                let mut ctx = ExecCtx::new(&ex.dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
                std::mem::swap(&mut ctx.mem, &mut ex.mem);
                std::mem::swap(&mut ctx.metrics, &mut ex.metrics);
                std::mem::swap(&mut ctx.scratch, &mut ex.scratch);
                ctx.finalize_metrics();
                (slot.shard, std::mem::take(&mut ctx.metrics))
            }
            Err(_) => (slot.shard, RunMetrics::default()),
        })
        .collect()
}

/// One worker thread: its mailbox plus the join handle.
struct WorkerHandle {
    inbox: Arc<Mailbox<WorkerMsg>>,
    join: Option<JoinHandle<Vec<(usize, RunMetrics)>>>,
}

/// The worker threads plus the shared report mailbox. `Drop` guarantees
/// shutdown + join on every exit path (error returns, panics during the
/// fold, callers that never reach [`Scheduler::finish`]), so a scheduler
/// can never leak a live thread.
struct WorkerPool {
    handles: Vec<WorkerHandle>,
    reports: Arc<Mailbox<BatchReport>>,
}

impl WorkerPool {
    /// Graceful shutdown on drain: tell every worker to exit, join them,
    /// and hand back each shard's finalized metrics. A worker that died
    /// to an uncaught panic surfaces as `Err` with its payload.
    fn shutdown(
        mut self,
    ) -> std::result::Result<Vec<(usize, RunMetrics)>, Box<dyn std::any::Any + Send>> {
        for h in &self.handles {
            h.inbox.send(WorkerMsg::Shutdown);
        }
        let mut all = Vec::new();
        let mut panicked = None;
        for h in &mut self.handles {
            if let Some(join) = h.join.take() {
                match join.join() {
                    Ok(mut metrics) => all.append(&mut metrics),
                    Err(p) => panicked = Some(p),
                }
            }
        }
        match panicked {
            Some(p) => Err(p),
            None => Ok(all),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for h in &self.handles {
            if h.join.is_some() {
                h.inbox.send(WorkerMsg::Shutdown);
            }
        }
        for h in &mut self.handles {
            if let Some(join) = h.join.take() {
                // Already unwinding or discarding: swallow a worker panic
                // rather than aborting the process with a double panic.
                let _ = join.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// One failed query waiting out its retry backoff in virtual time.
#[derive(Debug, Clone, Copy)]
struct RetryEntry {
    /// Instant the entry may re-enter the queue (`failure + backoff`).
    eligible_ps: u64,
    /// Original arrival instant (deadlines and waits measure from here).
    arrived_ps: u64,
    /// Failed serving attempts so far (≥ 1 in this buffer).
    attempts: u32,
    query: Query,
}

/// One device shard's coordinator-side state: admission, placement and
/// clock bookkeeping. The engine itself lives on the shard's worker
/// thread ([`ShardExec`]).
struct ShardSlot {
    /// Owned device spec (the worker holds its own clone).
    dev: DeviceSpec,
    /// Placed, waiting for the shard to go idle.
    pending: Vec<QueueEntry>,
    /// The batch currently executing (on the virtual clock).
    running: Vec<QueueEntry>,
    /// The query buffer that rides the launch message (capacity reused
    /// every batch; empty while a launch is in flight).
    batch_queries: Vec<Query>,
    /// The in-flight batch's distance copies, folded at its virtual
    /// completion; the container itself recycles through the messages.
    batch_dists: Vec<Vec<u32>>,
    start_ps: u64,
    busy_until_ps: u64,
    busy: bool,
    /// Σ busy-interval durations (ps) — feeds the report's per-shard
    /// `utilization` (busy_ps / wall_ps).
    busy_ps_total: u64,
    /// Σ source degrees of pending + running queries — the load signal
    /// placement minimizes (degree 0 counts as 1 so empty-frontier
    /// queries still occupy a slot).
    outstanding_edges: u64,
    /// Integer virtual-clock step of this device.
    ps_per_cycle: u64,
    /// Cached [`DeviceSpec::throughput_index`].
    tp: u64,
    /// Served queries / distances accumulated across every batch.
    served: Vec<Query>,
    dists: Vec<Vec<u32>>,
    /// In service: placement only targets up shards. Starts true; a
    /// down-fault clears it, an up-fault restores it (unless dead).
    up: bool,
    /// Permanently killed — no up-fault revives it.
    dead: bool,
    /// Instant the current outage began (valid while `!up`).
    down_since_ps: u64,
    /// Σ completed outage durations (ps); open outages are closed out at
    /// drain. Feeds the report's per-shard `availability`.
    downtime_ps: u64,
    /// Throughput-degradation multiplier on `ps_per_cycle` (1 = full
    /// speed). Applies to batches launched while degraded; an in-flight
    /// batch keeps the duration computed at its launch.
    slow_factor: u64,
    /// Memory-budget divisor from the latest shrink fault (1 = default).
    budget_divisor: u64,
    /// A shrink has touched this shard at some point: every later launch
    /// carries an explicit budget so a restoration also reaches the
    /// worker's persistent tracker.
    budget_dirty: bool,
}

/// The stepwise scheduler. [`serve_stream`] wraps construct → drain →
/// finish; the allocation-regression harness drives [`Scheduler::step`]
/// directly to measure individual events.
pub struct Scheduler<'a> {
    graph: Arc<Csr>,
    cfg: &'a SchedulerConfig,
    arrivals: Vec<Arrival>,
    next_arrival: usize,
    queue: AdmissionQueue,
    /// Arrivals stalled by [`OverflowPolicy::Block`], in arrival order.
    blocked: VecDeque<(Query, u64)>,
    shards: Vec<ShardSlot>,
    pool: WorkerPool,
    /// Reports parked between the dispatch barrier and the shard-order
    /// fold (slot `i` holds shard `i`'s report for the current round).
    round: Vec<Option<BatchReport>>,
    /// Per-shard worker-side trace rings, created at attach, recycled
    /// through the launch messages (`None` when tracing is off or the
    /// ring is in flight).
    rings: Vec<Option<TraceSink>>,
    now_ps: u64,
    blocked_events: u64,
    batches: u64,
    wait_hist: LogHistogram,
    latency_hist: LogHistogram,
    outcomes: Vec<QueryOutcome>,
    dropped: Vec<Query>,
    placed_order: Vec<u32>,
    /// Compiled fault schedule (empty when `cfg.faults` is `None`) and
    /// the cursor of the next un-fired transition.
    faults: Vec<FaultEvent>,
    next_fault: usize,
    /// Failed queries waiting out their retry backoff, sorted by
    /// `(eligible_ps, arrived_ps, id)`; pre-allocated to the arrival
    /// count so steady-state requeues never touch the heap.
    retry: VecDeque<RetryEntry>,
    /// Queries shed past their deadline / failed terminally.
    deadline_expired: Vec<Query>,
    failed: Vec<Query>,
    /// Query-attempts pushed into the retry buffer.
    requeued: u64,
    /// Optional telemetry sink ([`Scheduler::attach_trace`]): admission /
    /// placement / batch events are recorded here directly; engine events
    /// arrive via the per-shard rings, absorbed in shard order at the
    /// dispatch fold so the byte order matches the sequential loop.
    trace: Option<&'a mut TraceSink>,
}

impl<'a> Scheduler<'a> {
    /// Build the event loop over `arrivals` (sorted by arrival time if
    /// not already) and spawn the worker threads. Every growable buffer
    /// is pre-reserved to its worst-case size here, so steady-state steps
    /// allocate nothing — on the coordinator and on every worker.
    pub fn new(
        graph: Arc<Csr>,
        mut arrivals: Vec<Arrival>,
        cfg: &'a SchedulerConfig,
        cache: &GraphCache,
    ) -> Result<Self> {
        if cfg.serve.devices.is_empty() {
            return Err(Error::Config("devices must list at least one shard".into()));
        }
        if cfg.serve.max_batch == 0 {
            return Err(Error::Config("max_batch must be >= 1".into()));
        }
        arrivals.sort_by_key(|a| a.at_ps);
        let n_arrivals = arrivals.len();
        let n_shards = cfg.serve.devices.len();
        let n_workers = match cfg.workers {
            0 => n_shards,
            w => w.min(n_shards),
        };
        let mut shards = Vec::with_capacity(n_shards);
        for dev in &cfg.serve.devices {
            shards.push(ShardSlot {
                dev: dev.clone(),
                pending: Vec::with_capacity(cfg.serve.max_batch),
                running: Vec::with_capacity(cfg.serve.max_batch),
                batch_queries: Vec::with_capacity(cfg.serve.max_batch),
                batch_dists: Vec::with_capacity(if cfg.collect_distances {
                    cfg.serve.max_batch
                } else {
                    0
                }),
                start_ps: 0,
                busy_until_ps: 0,
                busy: false,
                busy_ps_total: 0,
                outstanding_edges: 0,
                ps_per_cycle: dev.ps_per_cycle(),
                tp: dev.throughput_index(),
                served: Vec::with_capacity(n_arrivals),
                dists: Vec::with_capacity(if cfg.collect_distances { n_arrivals } else { 0 }),
                up: true,
                dead: false,
                down_since_ps: 0,
                downtime_ps: 0,
                slow_factor: 1,
                budget_divisor: 1,
                budget_dirty: false,
            });
        }
        let faults: Vec<FaultEvent> = match &cfg.faults {
            Some(plan) => {
                for f in plan.events() {
                    if f.shard >= n_shards {
                        return Err(Error::Config(format!(
                            "fault plan targets shard {} but the pool has {n_shards}",
                            f.shard
                        )));
                    }
                }
                plan.events().to_vec()
            }
            None => Vec::new(),
        };
        // Shard i lives on worker i % n_workers for its whole life (an
        // engine never migrates between threads). `workers = 1` runs the
        // identical machinery on one thread — same messages, same fold.
        let reports = Arc::new(Mailbox::with_capacity(n_shards));
        let mut pool = WorkerPool {
            handles: Vec::with_capacity(n_workers),
            reports,
        };
        for w in 0..n_workers {
            let shard_seeds: Vec<ShardSeed> = (w..n_shards)
                .step_by(n_workers)
                .map(|id| ShardSeed {
                    shard: id,
                    dev: cfg.serve.devices[id].clone(),
                    cache: cache.scoped(id),
                })
                .collect();
            let inbox = Arc::new(Mailbox::with_capacity(shard_seeds.len() + 1));
            let seed = WorkerSeed {
                shards: shard_seeds,
                graph: graph.clone(),
                strategy: cfg.serve.strategy,
                params: cfg.serve.params.clone(),
                enforce_budget: cfg.serve.enforce_budget,
                max_iterations: cfg.serve.max_iterations,
                collect_distances: cfg.collect_distances,
            };
            let worker_inbox = inbox.clone();
            let worker_reports = pool.reports.clone();
            let join = std::thread::Builder::new()
                .name(format!("lonestar-shard-worker-{w}"))
                .spawn(move || worker_main(seed, &worker_inbox, &worker_reports))
                .map_err(Error::Io)?;
            pool.handles.push(WorkerHandle {
                inbox,
                join: Some(join),
            });
        }
        Ok(Scheduler {
            graph,
            cfg,
            arrivals,
            next_arrival: 0,
            queue: AdmissionQueue::new(cfg.queue_cap),
            blocked: VecDeque::with_capacity(n_arrivals),
            shards,
            pool,
            round: (0..n_shards).map(|_| None).collect(),
            rings: (0..n_shards).map(|_| None).collect(),
            now_ps: 0,
            blocked_events: 0,
            batches: 0,
            wait_hist: LogHistogram::new(),
            latency_hist: LogHistogram::new(),
            outcomes: Vec::with_capacity(n_arrivals),
            dropped: Vec::with_capacity(n_arrivals),
            placed_order: Vec::with_capacity(n_arrivals),
            faults,
            next_fault: 0,
            retry: VecDeque::with_capacity(n_arrivals),
            deadline_expired: Vec::with_capacity(n_arrivals),
            failed: Vec::with_capacity(n_arrivals),
            requeued: 0,
            trace: None,
        })
    }

    /// Attach a pre-allocated telemetry sink: every event from here on is
    /// recorded (ring overwrite on overflow — never an allocation, so the
    /// zero-alloc steady state holds with tracing live). Each shard gets
    /// a private ring of the same capacity for its engine events; with
    /// equal capacities, [`TraceSink::absorb`] reproduces the sequential
    /// ring byte-for-byte in every wrap-around regime.
    pub fn attach_trace(&mut self, sink: &'a mut TraceSink) {
        let cap = sink.capacity();
        for ring in &mut self.rings {
            *ring = Some(TraceSink::with_capacity(cap));
        }
        self.trace = Some(sink);
    }

    /// Batches launched so far — the allocation-regression harness uses
    /// this to find its warm-up horizon (buffers reach their high-water
    /// capacity once a full-size batch has run).
    pub fn batches_launched(&self) -> u64 {
        self.batches
    }

    /// Worker threads actually spawned (`cfg.workers` clamped to the
    /// shard count; `0` means one per shard).
    pub fn worker_threads(&self) -> usize {
        self.pool.handles.len()
    }

    /// Advance the virtual clock to the next event (a batch completion,
    /// an arrival, a retry becoming eligible, or a fault transition) and
    /// process everything due. Returns `false` once the stream has
    /// drained: no future arrivals, every shard idle, nothing queued —
    /// or once the no-progress detector has failed a stranded remainder.
    pub fn step(&mut self) -> Result<bool> {
        let next_arrival = self.arrivals.get(self.next_arrival).map(|a| a.at_ps);
        let next_done = self
            .shards
            .iter()
            .filter(|s| s.busy)
            .map(|s| s.busy_until_ps)
            .min();
        // The buffer is sorted by eligibility, so the front is the min.
        let next_retry = self.retry.front().map(|e| e.eligible_ps);
        let next_fault = self.faults.get(self.next_fault).map(|f| f.at_ps);
        let backlog =
            !self.queue.is_empty() || !self.blocked.is_empty() || !self.retry.is_empty();
        let mut now = [next_arrival, next_done, next_retry]
            .into_iter()
            .flatten()
            .min();
        if let Some(f) = next_fault {
            // A fault instant only matters while the run is live: once
            // nothing is owed (no arrivals, completions, retries, or
            // backlog), the remaining transitions are no-ops and the
            // stream is drained.
            if now.is_some() || backlog {
                now = Some(now.map_or(f, |t| t.min(f)));
            }
        }
        let Some(now) = now else {
            if backlog {
                // Satellite fix: nothing busy, no arrivals, no retries
                // pending, no faults left — yet queries remain (every
                // shard is dead with a full queue under Block). No future
                // event can free capacity, so the old loop would spin
                // here forever. Fail the remainder cleanly instead.
                self.fail_stranded();
            }
            return Ok(false);
        };
        debug_assert!(now >= self.now_ps, "the virtual clock is monotonic");
        self.now_ps = now;

        // 1. Completions first — capacity freed at `now` serves arrivals
        //    and placements of the same instant (and a batch finishing at
        //    the very instant its shard faults still counts as served).
        for i in 0..self.shards.len() {
            if self.shards[i].busy && self.shards[i].busy_until_ps <= now {
                self.complete(i);
            }
        }
        // 1b. Fault transitions due now: quarantine/revive/degrade/shrink
        //     shards, aborting any batch in flight on a shard that goes
        //     down (its queries enter the retry path).
        self.apply_faults(now);
        // 2. Settle the backlog against the freed capacity BEFORE looking
        //    at new arrivals: earlier (blocked) arrivals re-enter first
        //    and queued queries move onto the freed shards, so an arrival
        //    at exactly this instant sees the queue as it is *after* the
        //    completion — capacity freed at `now` really does serve
        //    same-instant arrivals instead of dropping them.
        self.settle();
        // 3. Arrivals due now meet the bounded queue — behind the backlog
        //    (after a full drain, a non-empty backlog implies a full
        //    queue, so `try_admit` fails and the arrival queues behind).
        while let Some(a) = self.arrivals.get(self.next_arrival) {
            if a.at_ps > now {
                break;
            }
            let (query, at_ps) = (a.query, a.at_ps);
            self.next_arrival += 1;
            if let Some(t) = self.trace.as_deref_mut() {
                t.record(TraceEvent {
                    query: query.id,
                    ..TraceEvent::new(TraceEventKind::Arrival, at_ps)
                });
            }
            if self.queue.try_admit(query, at_ps) {
                if let Some(t) = self.trace.as_deref_mut() {
                    let depth = self.queue.len() as u64;
                    t.record(TraceEvent {
                        query: query.id,
                        a: depth,
                        ..TraceEvent::new(TraceEventKind::Admit, now)
                    });
                    t.record(TraceEvent {
                        a: depth,
                        ..TraceEvent::new(TraceEventKind::QueueDepth, now)
                    });
                }
            } else {
                match self.cfg.overflow {
                    OverflowPolicy::Drop => {
                        self.dropped.push(query);
                        if let Some(t) = self.trace.as_deref_mut() {
                            t.record(TraceEvent {
                                query: query.id,
                                ..TraceEvent::new(TraceEventKind::Drop, now)
                            });
                        }
                    }
                    OverflowPolicy::Block => {
                        self.blocked.push_back((query, at_ps));
                        self.blocked_events += 1;
                        if let Some(t) = self.trace.as_deref_mut() {
                            t.record(TraceEvent {
                                query: query.id,
                                ..TraceEvent::new(TraceEventKind::Block, now)
                            });
                        }
                    }
                }
            }
        }
        // 4. Settle again: the new arrivals may themselves be placeable
        //    right now (idle shards), which frees queue slots the blocked
        //    backlog can take at the same instant.
        self.settle();
        // 5. Idle shards with pending work launch a batch.
        self.dispatch()?;
        // 6. No-progress detector, same-instant flavor: queries remain
        //    but every shard is quarantined for good (no up shard, no
        //    arrivals, no fault transitions left — so no event will ever
        //    free capacity, and eligible retries would re-run this very
        //    instant forever). Fail the remainder cleanly.
        let backlog =
            !self.queue.is_empty() || !self.blocked.is_empty() || !self.retry.is_empty();
        if backlog
            && self.arrivals.get(self.next_arrival).is_none()
            && self.next_fault >= self.faults.len()
            && self.shards.iter().all(|s| !s.up)
        {
            self.fail_stranded();
            return Ok(false);
        }
        Ok(true)
    }

    /// Fixpoint of retry drain + placement + backlog drain at one
    /// instant: popping the queue onto idle shards frees slots that
    /// eligible retries (front, with seniority) and the blocked backlog
    /// take right now. All three preserve FIFO-by-arrival, so the
    /// fixpoint does too.
    fn settle(&mut self) {
        loop {
            let moved = self.drain_retries() + self.drain_blocked() + self.place();
            if moved == 0 {
                break;
            }
        }
    }

    /// Fire every fault transition due at `now`, in plan order.
    fn apply_faults(&mut self, now: u64) {
        while let Some(f) = self.faults.get(self.next_fault).copied() {
            if f.at_ps > now {
                break;
            }
            self.next_fault += 1;
            if let Some(t) = self.trace.as_deref_mut() {
                t.record(TraceEvent {
                    shard: f.shard as u32,
                    a: f.kind.code(),
                    b: f.kind.param(),
                    ..TraceEvent::new(TraceEventKind::FaultInject, now)
                });
            }
            match f.kind {
                FaultKind::Down { permanent } => {
                    if self.shards[f.shard].dead {
                        continue; // already gone for good
                    }
                    if self.shards[f.shard].busy {
                        self.abort_running(f.shard);
                    }
                    let s = &mut self.shards[f.shard];
                    debug_assert!(s.pending.is_empty(), "pending is drained between steps");
                    if s.up {
                        s.up = false;
                        s.down_since_ps = now;
                    }
                    s.dead |= permanent;
                    if let Some(t) = self.trace.as_deref_mut() {
                        t.record(TraceEvent {
                            shard: f.shard as u32,
                            a: permanent as u64,
                            ..TraceEvent::new(TraceEventKind::ShardDown, now)
                        });
                    }
                }
                FaultKind::Up => {
                    let s = &mut self.shards[f.shard];
                    if s.dead || s.up {
                        continue; // kills are final; a double-up is a no-op
                    }
                    let outage = now - s.down_since_ps;
                    s.downtime_ps += outage;
                    s.up = true;
                    if let Some(t) = self.trace.as_deref_mut() {
                        t.record(TraceEvent {
                            shard: f.shard as u32,
                            a: outage,
                            ..TraceEvent::new(TraceEventKind::ShardUp, now)
                        });
                    }
                }
                FaultKind::Slow { factor } => {
                    // Takes effect at the next launch; a batch in flight
                    // keeps the duration computed when it launched.
                    self.shards[f.shard].slow_factor = factor.max(1);
                }
                FaultKind::Shrink { divisor } => {
                    let s = &mut self.shards[f.shard];
                    s.budget_divisor = divisor.max(1);
                    s.budget_dirty = true;
                }
            }
        }
    }

    /// Abort shard `i`'s in-flight batch at the current instant (the
    /// shard went down mid-batch): the partial busy interval is real
    /// wasted work (counted and traced), the batch outcome is discarded,
    /// and every running query enters the retry path. The worker-side
    /// engine already ran the batch to completion — identically for
    /// every worker count — so a retry re-derives identical distances.
    fn abort_running(&mut self, i: usize) {
        let now = self.now_ps;
        let s = &mut self.shards[i];
        debug_assert!(s.busy, "abort targets a busy shard");
        s.busy = false;
        let width = s.running.len() as u64;
        let busy = now.saturating_sub(s.start_ps);
        s.busy_ps_total += busy;
        // Discard the extracted distances; a successful retry re-extracts
        // them (bit-identical — the engine is deterministic).
        s.batch_dists.clear();
        if let Some(t) = self.trace.as_deref_mut() {
            t.record(TraceEvent {
                shard: i as u32,
                a: busy,
                b: width,
                ..TraceEvent::new(TraceEventKind::ShardBusy, s.start_ps)
            });
        }
        while let Some(e) = self.shards[i].running.pop() {
            let load = (self.graph.degree(e.query.source) as u64).max(1);
            self.shards[i].outstanding_edges -= load;
            self.requeue_failed(i, e.query, e.arrived_ps, e.attempts);
        }
    }

    /// Route one query whose serving attempt just failed: into the retry
    /// buffer (sorted by eligibility) with exponential virtual-time
    /// backoff, or — once `max_retries` is exhausted — into the `failed`
    /// outcome. The `Requeue` trace event doubles as the span-builder's
    /// cleanup signal (`b = u64::MAX` marks exhaustion).
    fn requeue_failed(&mut self, shard: usize, query: Query, arrived_ps: u64, attempts: u32) {
        let attempts = attempts + 1;
        let exhausted = attempts > self.cfg.max_retries;
        let eligible_ps = if exhausted {
            u64::MAX
        } else {
            // Left-shift backoff with a floor of 1 ps: a failed engine
            // consumes no virtual time, so a zero backoff would retry at
            // the same instant forever. The shift is capped well below
            // overflow (attempts are bounded by max_retries anyway).
            let backoff = self
                .cfg
                .retry_backoff_ps
                .max(1)
                .saturating_mul(1u64 << (attempts - 1).min(20));
            self.now_ps.saturating_add(backoff)
        };
        if let Some(t) = self.trace.as_deref_mut() {
            t.record(TraceEvent {
                shard: shard as u32,
                query: query.id,
                a: attempts as u64,
                b: eligible_ps,
                ..TraceEvent::new(TraceEventKind::Requeue, self.now_ps)
            });
        }
        if exhausted {
            self.failed.push(query);
            return;
        }
        self.requeued += 1;
        let key = (eligible_ps, arrived_ps, query.id);
        let pos = self
            .retry
            .iter()
            .position(|e| (e.eligible_ps, e.arrived_ps, e.query.id) > key)
            .unwrap_or(self.retry.len());
        // VecDeque::insert shifts within capacity — the buffer was
        // pre-reserved to the arrival count, so this never allocates.
        self.retry.insert(
            pos,
            RetryEntry {
                eligible_ps,
                arrived_ps,
                attempts,
                query,
            },
        );
    }

    /// Move eligible retry entries back into the queue (at the *front* —
    /// they predate everything queued) while there is room, shedding
    /// entries whose deadline has passed; returns how many entries left
    /// the buffer.
    fn drain_retries(&mut self) -> usize {
        let now = self.now_ps;
        let deadline = self.cfg.deadline_ps;
        let mut moved = 0;
        // Shed expired entries first — they never take a queue slot.
        if deadline > 0 {
            let mut k = 0;
            while k < self.retry.len() {
                let e = self.retry[k];
                if e.eligible_ps <= now && now > e.arrived_ps.saturating_add(deadline) {
                    self.retry.remove(k);
                    self.expire_deadline(e.query, e.arrived_ps.saturating_add(deadline));
                    moved += 1;
                } else {
                    k += 1;
                }
            }
        }
        // Count the eligible prefix that fits, then requeue it in
        // *reverse* so push_front lands the most senior entry foremost.
        let room = self.queue.cap().saturating_sub(self.queue.len());
        let mut take = 0;
        while take < self.retry.len().min(room) && self.retry[take].eligible_ps <= now {
            take += 1;
        }
        for idx in (0..take).rev() {
            let e = self.retry.remove(idx).expect("index within bounds");
            let entered = self.queue.requeue(e.query, e.arrived_ps, e.attempts);
            debug_assert!(entered, "queue had room");
            if let Some(t) = self.trace.as_deref_mut() {
                let depth = self.queue.len() as u64;
                t.record(TraceEvent {
                    query: e.query.id,
                    a: e.attempts as u64,
                    ..TraceEvent::new(TraceEventKind::Retry, now)
                });
                t.record(TraceEvent {
                    a: depth,
                    ..TraceEvent::new(TraceEventKind::QueueDepth, now)
                });
            }
            moved += 1;
        }
        moved
    }

    /// Count one query out with a `deadline_expired` outcome.
    fn expire_deadline(&mut self, query: Query, deadline_at_ps: u64) {
        self.deadline_expired.push(query);
        if let Some(t) = self.trace.as_deref_mut() {
            t.record(TraceEvent {
                query: query.id,
                a: deadline_at_ps,
                ..TraceEvent::new(TraceEventKind::DeadlineExpired, self.now_ps)
            });
        }
    }

    /// Terminal no-progress path: every query still queued, blocked or
    /// waiting on a retry is failed (capacity can never return). The
    /// conservation identity stays exact — each lands in `failed` once.
    fn fail_stranded(&mut self) {
        while let Some(e) = self.queue.pop() {
            self.failed.push(e.query);
        }
        while let Some((query, _at_ps)) = self.blocked.pop_front() {
            self.failed.push(query);
        }
        while let Some(e) = self.retry.pop_front() {
            self.failed.push(e.query);
        }
    }

    /// Move blocked arrivals (in arrival order) into the queue while it
    /// has room; returns how many entered.
    fn drain_blocked(&mut self) -> usize {
        let mut moved = 0;
        while !self.queue.is_full() {
            let Some((query, at_ps)) = self.blocked.pop_front() else {
                break;
            };
            let entered = self.queue.try_admit(query, at_ps);
            debug_assert!(entered, "queue had room");
            if let Some(t) = self.trace.as_deref_mut() {
                let depth = self.queue.len() as u64;
                t.record(TraceEvent {
                    query: query.id,
                    a: depth,
                    ..TraceEvent::new(TraceEventKind::Admit, self.now_ps)
                });
                t.record(TraceEvent {
                    a: depth,
                    ..TraceEvent::new(TraceEventKind::QueueDepth, self.now_ps)
                });
            }
            moved += 1;
        }
        moved
    }

    /// Retire shard `i`'s finished batch on the virtual clock: record
    /// outcomes, fold the distance copies its worker extracted, update the
    /// load signal. (The engine itself already retired on the worker,
    /// buffers kept warm.)
    fn complete(&mut self, i: usize) {
        let s = &mut self.shards[i];
        s.busy = false;
        let width = s.running.len() as u64;
        s.busy_ps_total += s.busy_until_ps - s.start_ps;
        debug_assert!(
            !self.cfg.collect_distances || s.batch_dists.len() == s.running.len(),
            "one distance array per running query"
        );
        for &e in &s.running {
            self.outcomes.push(QueryOutcome {
                query: e.query,
                shard: i,
                arrival_ps: e.arrived_ps,
                start_ps: s.start_ps,
                done_ps: s.busy_until_ps,
            });
            self.latency_hist.record(s.busy_until_ps - e.arrived_ps);
            s.served.push(e.query);
            s.outstanding_edges -= (self.graph.degree(e.query.source) as u64).max(1);
        }
        // Distance copies were extracted in batch order on the worker, so
        // appending keeps `served[k] ↔ dists[k]` aligned per shard.
        s.dists.append(&mut s.batch_dists);
        s.running.clear();
        if let Some(t) = self.trace.as_deref_mut() {
            // The busy interval is only known complete here, so the slice
            // is recorded at retirement, stamped back at its start.
            t.record(TraceEvent {
                shard: i as u32,
                a: s.busy_until_ps - s.start_ps,
                b: width,
                ..TraceEvent::new(TraceEventKind::ShardBusy, s.start_ps)
            });
            t.record(TraceEvent {
                shard: i as u32,
                a: width,
                ..TraceEvent::new(TraceEventKind::BatchComplete, s.busy_until_ps)
            });
        }
    }

    /// Pop admitted queries FIFO and place each on the **idle, in-service**
    /// shard minimizing outstanding edges per unit *effective* throughput
    /// (exact integer cross-multiplication with the degradation factor
    /// folded in; ties go to the lower shard id). Busy shards take
    /// nothing — their next batch forms from whatever the queue holds
    /// when they free, so the admission queue is the only buffer under
    /// load and its cap is a real bound; quarantined/dead shards take
    /// nothing until a fault lifts. Queries past their deadline are shed
    /// at the head with a counted outcome — hopeless work frees its
    /// queue slot even when no shard can take anything. Stops when the
    /// queue empties or every eligible shard is at `max_batch`; returns
    /// how many queries were placed or shed.
    fn place(&mut self) -> usize {
        let max_batch = self.cfg.serve.max_batch;
        let deadline = self.cfg.deadline_ps;
        let mut placed = 0;
        loop {
            // Deadline shedding first: the queue is FIFO-by-arrival, so
            // expired queries surface at the head.
            if deadline > 0 {
                while let Some(e) = self.queue.peek().copied() {
                    if self.now_ps <= e.arrived_ps.saturating_add(deadline) {
                        break;
                    }
                    self.queue.pop();
                    self.expire_deadline(e.query, e.arrived_ps.saturating_add(deadline));
                    if let Some(t) = self.trace.as_deref_mut() {
                        t.record(TraceEvent {
                            a: self.queue.len() as u64,
                            ..TraceEvent::new(TraceEventKind::QueueDepth, self.now_ps)
                        });
                    }
                    placed += 1;
                }
            }
            if self.queue.is_empty() {
                break;
            }
            let mut best: Option<usize> = None;
            for i in 0..self.shards.len() {
                if self.shards[i].busy
                    || !self.shards[i].up
                    || self.shards[i].pending.len() >= max_batch
                {
                    continue;
                }
                best = Some(match best {
                    None => i,
                    Some(j) => {
                        let (a, b) = (&self.shards[i], &self.shards[j]);
                        // A shard slowed k× serves like a device with
                        // tp/k: compare edges × slow per unit tp.
                        let lhs = a.outstanding_edges as u128
                            * a.slow_factor as u128
                            * b.tp as u128;
                        let rhs = b.outstanding_edges as u128
                            * b.slow_factor as u128
                            * a.tp as u128;
                        if lhs < rhs {
                            i
                        } else {
                            j
                        }
                    }
                });
            }
            let Some(i) = best else { break };
            let entry = self.queue.pop().expect("non-empty");
            let load = (self.graph.degree(entry.query.source) as u64).max(1);
            self.placed_order.push(entry.query.id);
            if let Some(t) = self.trace.as_deref_mut() {
                t.record(TraceEvent {
                    shard: i as u32,
                    query: entry.query.id,
                    a: load,
                    ..TraceEvent::new(TraceEventKind::Place, self.now_ps)
                });
                t.record(TraceEvent {
                    a: self.queue.len() as u64,
                    ..TraceEvent::new(TraceEventKind::QueueDepth, self.now_ps)
                });
            }
            let s = &mut self.shards[i];
            s.pending.push(entry);
            s.outstanding_edges += load;
            placed += 1;
        }
        placed
    }

    /// Launch every idle shard's pending queries as one batch each, run
    /// the batches **concurrently** on the worker threads, and fold the
    /// reports in ascending shard order.
    ///
    /// The collect-everything barrier is not a simplification but the
    /// semantics: the virtual clock's next event depends on every
    /// launched batch's duration, so the round must complete before the
    /// coordinator can move time forward. It also leaves workers
    /// provably idle whenever the coordinator runs — which is what lets
    /// the allocation harness snapshot counters at quiescent instants.
    fn dispatch(&mut self) -> Result<()> {
        let now = self.now_ps;
        let n_workers = self.pool.handles.len();
        // Phase 1: hand every idle shard with pending work to its worker,
        // ascending shard id.
        let mut launched = 0usize;
        for i in 0..self.shards.len() {
            let s = &mut self.shards[i];
            if s.busy || s.pending.is_empty() {
                continue;
            }
            let mut queries = std::mem::take(&mut s.batch_queries);
            queries.clear();
            for &e in &s.pending {
                queries.push(e.query);
                self.wait_hist.record(now - e.arrived_ps);
            }
            let trace = if self.trace.is_some() {
                self.rings[i].take()
            } else {
                None
            };
            let dists = std::mem::take(&mut s.batch_dists);
            // Once a shrink fault has ever touched this shard, every
            // launch carries the effective ceiling (restores included) so
            // the worker-side tracker follows the coordinator's view.
            let budget = if s.budget_dirty {
                Some(if s.budget_divisor > 1 {
                    (s.dev.memory_budget / s.budget_divisor).max(1)
                } else if self.cfg.serve.enforce_budget {
                    s.dev.memory_budget
                } else {
                    u64::MAX
                })
            } else {
                None
            };
            self.pool.handles[i % n_workers].inbox.send(WorkerMsg::Launch(LaunchMsg {
                shard: i,
                base_ps: now,
                queries,
                trace,
                dists,
                budget,
            }));
            launched += 1;
        }
        // Phase 2: barrier — collect the whole round (arrival order is
        // whatever the OS scheduled; the slots re-impose shard order).
        for _ in 0..launched {
            let report = self.pool.reports.recv();
            debug_assert!(
                self.round[report.shard].is_none(),
                "one report per shard per round"
            );
            self.round[report.shard] = Some(report);
        }
        // Phase 3: fold in fixed shard order — gpucachesim's
        // `core_sim_order`. Counters and trace bytes depend only on this
        // order, never on which worker finished first. An engine error is
        // a *recoverable* fault here: the batch's queries re-enter the
        // retry path instead of aborting the run (panics still re-raise).
        for i in 0..self.shards.len() {
            let Some(mut report) = self.round[i].take() else {
                continue;
            };
            if let Some(payload) = report.panic.take() {
                // Re-raise the engine's panic on the coordinator; the
                // pool's Drop shuts the (healthy, idle) workers down.
                std::panic::resume_unwind(payload);
            }
            let width = report.queries.len() as u64;
            let ok = report.result.is_ok();
            if ok {
                if let Some(t) = self.trace.as_deref_mut() {
                    t.record(TraceEvent {
                        shard: i as u32,
                        a: width,
                        b: self.batches,
                        ..TraceEvent::new(TraceEventKind::BatchLaunch, now)
                    });
                    if let Some(ring) = report.trace.as_ref() {
                        t.absorb(ring);
                    }
                }
            }
            // A failed batch's ring is discarded *without* absorbing: its
            // partial kernel events belong to work that never happened on
            // the virtual clock, and orphan spans would corrupt the
            // profiler's imbalance attribution.
            if let Some(mut ring) = report.trace.take() {
                ring.clear();
                self.rings[i] = Some(ring);
            }
            let s = &mut self.shards[i];
            s.batch_queries = report.queries;
            s.batch_dists = report.dists;
            match report.result {
                Ok(cycles) => {
                    s.start_ps = now;
                    s.busy_until_ps =
                        now + cycles.max(1) * s.ps_per_cycle * s.slow_factor;
                    s.busy = true;
                    std::mem::swap(&mut s.running, &mut s.pending);
                    self.batches += 1;
                }
                Err(_e) => {
                    // The attempt consumed no virtual time (the engine
                    // refused before running); every query goes back
                    // through the bounded retry path with backoff.
                    s.batch_dists.clear();
                    while let Some(e) = self.shards[i].pending.pop() {
                        let load =
                            (self.graph.degree(e.query.source) as u64).max(1);
                        self.shards[i].outstanding_edges -= load;
                        self.requeue_failed(i, e.query, e.arrived_ps, e.attempts);
                    }
                }
            }
        }
        Ok(())
    }

    /// Drain the stream, shut the workers down (graceful join), and
    /// assemble the report. Shards still down at drain get their open
    /// outage closed against the final clock so reported downtime and
    /// availability cover the whole run.
    pub fn finish(self) -> ScheduleReport {
        let Scheduler {
            shards,
            pool,
            outcomes,
            dropped,
            deadline_expired,
            failed,
            placed_order,
            next_arrival,
            queue,
            blocked_events,
            batches,
            requeued,
            now_ps,
            wait_hist,
            latency_hist,
            ..
        } = self;
        let mut metrics_by_shard: Vec<Option<RunMetrics>> =
            (0..shards.len()).map(|_| None).collect();
        match pool.shutdown() {
            Ok(all) => {
                for (shard, metrics) in all {
                    metrics_by_shard[shard] = Some(metrics);
                }
            }
            Err(payload) => std::panic::resume_unwind(payload),
        }
        let mut shard_reports = Vec::with_capacity(shards.len());
        for (i, s) in shards.into_iter().enumerate() {
            debug_assert!(!s.busy && s.pending.is_empty(), "finish before drain");
            let mut downtime_ps = s.downtime_ps;
            if !s.up {
                downtime_ps += now_ps - s.down_since_ps;
            }
            shard_reports.push(ShardReport {
                shard: i,
                device: s.dev,
                queries: s.served,
                metrics: metrics_by_shard[i].take().unwrap_or_default(),
                dists: s.dists,
                busy_ps: s.busy_ps_total,
                downtime_ps,
            });
        }
        ScheduleReport {
            shards: shard_reports,
            outcomes,
            dropped,
            deadline_expired,
            failed,
            placed_order,
            arrived: next_arrival as u64,
            admitted: queue.admitted,
            queue_peak: queue.peak,
            blocked: blocked_events,
            batches,
            requeued,
            retries: queue.requeued,
            wall_ps: now_ps,
            wait_hist,
            latency_hist,
        }
    }
}

/// Run an arrival stream through the admission-controlled scheduler to
/// drain: construct, step until idle, report.
pub fn serve_stream(
    graph: &Arc<Csr>,
    arrivals: Vec<Arrival>,
    cfg: &SchedulerConfig,
    cache: &GraphCache,
) -> Result<ScheduleReport> {
    serve_stream_traced(graph, arrivals, cfg, cache, None)
}

/// [`serve_stream`] with an optional telemetry sink: pass a pre-allocated
/// [`TraceSink`] to capture the full event timeline (admissions, drops,
/// placements, per-shard busy intervals, engine kernels and decisions) for
/// export via [`crate::telemetry::chrome_trace`]. The sink borrows for the
/// scheduler's lifetime, so declare it before the call's other borrows.
pub fn serve_stream_traced<'a>(
    graph: &Arc<Csr>,
    arrivals: Vec<Arrival>,
    cfg: &'a SchedulerConfig,
    cache: &GraphCache,
    trace: Option<&'a mut TraceSink>,
) -> Result<ScheduleReport> {
    let mut sched = Scheduler::new(graph.clone(), arrivals, cfg, cache)?;
    if let Some(sink) = trace {
        sched.attach_trace(sink);
    }
    while sched.step()? {}
    Ok(sched.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, rmat, RmatParams};
    use crate::graph::traversal;
    use crate::serving::query::synthetic_arrivals;
    use crate::strategies::StrategyKind;

    fn stream(g: &Csr, count: usize, mean_gap_ps: u64, seed: u64) -> Vec<Arrival> {
        synthetic_arrivals(g, count, 0.0, mean_gap_ps, seed)
    }

    #[test]
    fn drains_and_conserves_queries() {
        let g = Arc::new(erdos_renyi(200, 800, 11, 3).unwrap());
        let arrivals = stream(&g, 40, 500_000, 7);
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 8,
                ..Default::default()
            },
            queue_cap: 4,
            ..Default::default()
        };
        let report = serve_stream(&g, arrivals, &cfg, &GraphCache::new()).unwrap();
        assert_eq!(report.arrived, 40);
        assert_eq!(
            report.arrived,
            report.admitted + report.dropped.len() as u64,
            "arrived == admitted + dropped"
        );
        assert_eq!(
            report.admitted,
            report.served() as u64,
            "admitted == served at drain"
        );
        assert!(report.batches > 0);
        assert!(report.queue_peak >= 1);
        // Every served distance matches the oracle.
        for o in &report.outcomes {
            assert_eq!(
                report.dist_of(o.query.id).unwrap(),
                traversal::dijkstra(&g, o.query.source).as_slice(),
                "query {}",
                o.query.id
            );
        }
    }

    #[test]
    fn tight_queue_drops_and_block_does_not() {
        let g = Arc::new(erdos_renyi(150, 600, 9, 5).unwrap());
        // Near-simultaneous arrivals against a 2-deep queue force overflow.
        let arrivals = stream(&g, 30, 10, 11);
        let mut cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 4,
                ..Default::default()
            },
            queue_cap: 2,
            ..Default::default()
        };
        let dropping = serve_stream(&g, arrivals.clone(), &cfg, &GraphCache::new()).unwrap();
        assert!(!dropping.dropped.is_empty(), "a 2-deep queue must shed");
        assert_eq!(
            dropping.arrived,
            dropping.admitted + dropping.dropped.len() as u64
        );
        // Dropped queries are excluded from results.
        for q in &dropping.dropped {
            assert!(dropping.dist_of(q.id).is_none(), "dropped query {} served", q.id);
        }

        cfg.overflow = OverflowPolicy::Block;
        let blocking = serve_stream(&g, arrivals, &cfg, &GraphCache::new()).unwrap();
        assert!(blocking.dropped.is_empty(), "block never sheds");
        assert_eq!(blocking.served() as u64, blocking.arrived);
        assert!(blocking.blocked > 0, "the stall counter must trip");
        assert!(
            blocking.wait_hist.sum() > dropping.wait_hist.sum(),
            "lossless admission pays with wait"
        );
    }

    #[test]
    fn heterogeneous_pool_is_deterministic_and_uses_every_shard() {
        let g = Arc::new(rmat(8, 2048, RmatParams::default(), 13).unwrap());
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                devices: vec![DeviceSpec::k40(), DeviceSpec::gtx680()],
                max_batch: 8,
                ..Default::default()
            },
            queue_cap: 16,
            ..Default::default()
        };
        let a = serve_stream(&g, stream(&g, 32, 100_000, 21), &cfg, &GraphCache::new()).unwrap();
        let b = serve_stream(&g, stream(&g, 32, 100_000, 21), &cfg, &GraphCache::new()).unwrap();
        assert_eq!(a.outcomes, b.outcomes, "replays must be exact");
        assert_eq!(a.placed_order, b.placed_order);
        for s in &a.shards {
            assert!(
                !s.queries.is_empty(),
                "under sustained load every device serves (shard {})",
                s.shard
            );
        }
        assert_eq!(a.shards[0].device.name, "k40");
        assert_eq!(a.shards[1].device.name, "gtx680");
        assert!(a.total_ms() > 0.0 && a.wall_ms() > 0.0);
        assert!(a.mean_latency_ms() <= a.p95_latency_ms());
    }

    #[test]
    fn worker_counts_do_not_change_the_schedule() {
        // The fold-order contract in miniature (the full byte-level pin
        // lives in tests/parallel_determinism.rs): 1, 2 and
        // one-per-shard workers produce the identical report.
        let g = Arc::new(rmat(8, 2048, RmatParams::default(), 17).unwrap());
        let mut reports = Vec::new();
        for workers in [1usize, 2, 3] {
            let cfg = SchedulerConfig {
                serve: ServeConfig {
                    devices: vec![
                        DeviceSpec::k20c(),
                        DeviceSpec::k40(),
                        DeviceSpec::gtx680(),
                    ],
                    max_batch: 8,
                    ..Default::default()
                },
                queue_cap: 16,
                workers,
                ..Default::default()
            };
            let arrivals = stream(&g, 48, 50_000, 29);
            let sched = {
                let mut s = Scheduler::new(g.clone(), arrivals, &cfg, &GraphCache::new()).unwrap();
                assert_eq!(s.worker_threads(), workers.min(3));
                while s.step().unwrap() {}
                s.finish()
            };
            reports.push(sched);
        }
        let first = &reports[0];
        for other in &reports[1..] {
            assert_eq!(first.outcomes, other.outcomes);
            assert_eq!(first.placed_order, other.placed_order);
            assert_eq!(first.batches, other.batches);
            assert_eq!(first.to_json().to_string(), other.to_json().to_string());
        }
    }

    #[test]
    fn scheduler_forms_batches_past_64_queries() {
        // queue_cap > 64 + max_batch 80: a burst behind one busy shard
        // must coalesce into a batch wider than the old 64-query limit
        // (multi-word tags on the scheduler path), results still exact.
        let g = Arc::new(erdos_renyi(150, 600, 9, 5).unwrap());
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 80,
                ..Default::default()
            },
            queue_cap: 128,
            ..Default::default()
        };
        let arrivals = stream(&g, 100, 10, 9);
        let report = serve_stream(&g, arrivals, &cfg, &GraphCache::new()).unwrap();
        assert_eq!(report.served(), 100, "128-deep queue loses nothing here");
        // Outcomes of one batch share (shard, start_ps).
        let mut widest = 0usize;
        for o in &report.outcomes {
            let width = report
                .outcomes
                .iter()
                .filter(|p| p.shard == o.shard && p.start_ps == o.start_ps)
                .count();
            widest = widest.max(width);
        }
        assert!(
            widest > 64,
            "expected a multi-word batch, widest was {widest}"
        );
        for o in &report.outcomes {
            assert_eq!(
                report.dist_of(o.query.id).unwrap(),
                traversal::dijkstra(&g, o.query.source).as_slice(),
                "query {}",
                o.query.id
            );
        }
    }

    #[test]
    fn batches_grow_under_pressure() {
        let g = Arc::new(erdos_renyi(150, 600, 9, 5).unwrap());
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 16,
                ..Default::default()
            },
            queue_cap: 64,
            ..Default::default()
        };
        let cache = GraphCache::new();
        // Sparse arrivals: every query tends to get its own batch.
        let relaxed = serve_stream(&g, stream(&g, 24, 2_000_000_000, 3), &cfg, &cache).unwrap();
        // A burst: batches must coalesce, so strictly fewer launches.
        let bursty = serve_stream(&g, stream(&g, 24, 10, 3), &cfg, &cache).unwrap();
        assert!(
            bursty.batches < relaxed.batches,
            "burst arrivals must batch ({} vs {})",
            bursty.batches,
            relaxed.batches
        );
        assert!(
            bursty.mean_latency_ms() > 0.0 && relaxed.mean_latency_ms() > 0.0
        );
    }
}
