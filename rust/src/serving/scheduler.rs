//! The admission-controlled serving scheduler: a deterministic
//! virtual-clock event loop that consumes a continuous arrival stream,
//! admits queries through the bounded [`AdmissionQueue`], places them
//! load-aware over heterogeneous device shards, and forms batches per
//! shard as capacity frees.
//!
//! This replaces the batch engine's original operating assumptions — a
//! pre-materialized query list, round-robin placement, identical devices —
//! with the serving reality the adaptive-load-balancing line of work
//! argues for (Jatala et al., arXiv:1911.09135): decisions made online
//! against *observed* load. Concretely, per virtual instant:
//!
//! 1. **Completions first.** Shards whose running batch finishes at `now`
//!    retire it (results extracted, memory accounting released, the
//!    engine's buffers kept warm for the next batch).
//! 2. **Arrivals** due at `now` enter the bounded FIFO queue; a full
//!    queue invokes the [`OverflowPolicy`] — `drop` sheds (counted),
//!    `block` back-pressures until space frees.
//! 3. **Placement.** Queries leave the queue in FIFO order *as capacity
//!    frees*: only idle shards receive work (a busy shard's next batch is
//!    not committed early, so the bounded queue really is the only buffer
//!    under load), each query going to the idle shard minimizing
//!    *outstanding edges weighted by device throughput*
//!    (`edges_a × tp_b < edges_b × tp_a`, exact u128 integer
//!    cross-multiplication — deterministic on every platform, and a K40
//!    legitimately absorbs more work than a GTX 680).
//! 4. **Dispatch.** Every idle shard with placed queries launches them
//!    as one batch on its own [`QueryBatch`] engine (reused via
//!    [`QueryBatch::reset`], so the steady state allocates nothing) and
//!    becomes busy for the batch's simulated duration, converted to the
//!    shared picosecond timeline via its own clock.
//!
//! The virtual clock runs in integer **picoseconds** because
//! heterogeneous shards' cycle counts are incomparable: each device
//! contributes `cycles × ps_per_cycle(device)`. Latency and wait are
//! measured from *arrival* (including any blocked stall), so the
//! latency-vs-arrival-rate curve (`figqueue`) shows the real queueing
//! behavior.

use crate::algorithms::{AlgoKind, NativeRelaxer};
use crate::arena::GraphCache;
use crate::coordinator::ExecCtx;
use crate::error::{Error, Result};
use crate::graph::Csr;
use crate::sim::DeviceSpec;
use crate::telemetry::{Exposition, LogHistogram, TraceEvent, TraceEventKind, TraceSink};
use crate::util::Json;
use std::collections::VecDeque;
use std::sync::Arc;

use super::batch::QueryBatch;
use super::query::{Arrival, Query};
use super::queue::{AdmissionQueue, OverflowPolicy};
use super::shard::{aggregate, AggregateMetrics, ServeConfig, ShardReport};

/// Scheduler configuration: the batch-engine config plus admission
/// control.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Strategy / params / devices / `max_batch` of the per-shard batch
    /// engines.
    pub serve: ServeConfig,
    /// Bound of the admission queue.
    pub queue_cap: usize,
    /// What happens to arrivals at a full queue.
    pub overflow: OverflowPolicy,
    /// Collect per-query distance arrays into the report (needed for
    /// `--verify` / parity; the allocation-regression harness turns it
    /// off because cloning a distance array is inherently an allocation).
    pub collect_distances: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            serve: ServeConfig::default(),
            queue_cap: 64,
            overflow: OverflowPolicy::default(),
            collect_distances: true,
        }
    }
}

/// One served query's timeline on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueryOutcome {
    pub query: Query,
    /// Shard that served it.
    pub shard: usize,
    /// When it arrived (ps) — blocked stalls count from here.
    pub arrival_ps: u64,
    /// When its batch launched (ps).
    pub start_ps: u64,
    /// When its batch completed (ps).
    pub done_ps: u64,
}

impl QueryOutcome {
    /// Arrival → launch (queueing + blocking), ps.
    pub fn wait_ps(&self) -> u64 {
        self.start_ps - self.arrival_ps
    }

    /// Arrival → completion, ps.
    pub fn latency_ps(&self) -> u64 {
        self.done_ps - self.arrival_ps
    }

    /// Arrival → completion, milliseconds.
    pub fn latency_ms(&self) -> f64 {
        self.latency_ps() as f64 / 1e9
    }
}

/// Everything a finished scheduler run reports.
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    /// One report per device shard; `queries`/`dists` accumulate every
    /// batch the shard ran, so the replay oracle applies per shard
    /// exactly as with [`crate::serving::serve`].
    pub shards: Vec<ShardReport>,
    /// Per-served-query timelines, in completion order.
    pub outcomes: Vec<QueryOutcome>,
    /// Queries shed by the drop policy (excluded from results, counted).
    pub dropped: Vec<Query>,
    /// Query ids in the order they left the admission queue — FIFO
    /// admission order, pinned by `strategy_properties.rs`.
    pub placed_order: Vec<u32>,
    /// Arrivals consumed (`== admitted + dropped.len()` at drain).
    pub arrived: u64,
    /// Queries admitted into the queue.
    pub admitted: u64,
    /// Peak admission-queue depth.
    pub queue_peak: u64,
    /// Arrivals that stalled under [`OverflowPolicy::Block`].
    pub blocked: u64,
    /// Batches launched across all shards.
    pub batches: u64,
    /// Σ wait (arrival → launch) over served queries, converted to
    /// reference-device cycles (`devices[0]`). Only the deprecated
    /// [`ScheduleReport::wait_cycles`] accessor reads this; the JSON
    /// report dropped the key in favor of the clock-neutral `wait_ms_*`
    /// figures.
    wait_cycles: u64,
    /// Virtual instant the stream drained (ps).
    pub wall_ps: u64,
    /// Queue-wait distribution (arrival → batch launch), ps samples.
    pub wait_hist: LogHistogram,
    /// End-to-end latency distribution (arrival → completion), ps samples.
    pub latency_hist: LogHistogram,
}

impl ScheduleReport {
    /// Queries actually served.
    pub fn served(&self) -> usize {
        self.outcomes.len()
    }

    /// Distance array of the query with `id`, if it was served and
    /// distance collection was on.
    pub fn dist_of(&self, id: u32) -> Option<&[u32]> {
        for s in &self.shards {
            if let Some(i) = s.queries.iter().position(|q| q.id == id) {
                // `dists` is empty when `collect_distances` was off.
                return s.dists.get(i).map(Vec::as_slice);
            }
        }
        None
    }

    /// Wall-clock of the whole stream (arrival of the first query to
    /// completion of the last), ms.
    pub fn wall_ms(&self) -> f64 {
        self.wall_ps as f64 / 1e9
    }

    /// Throughput cost: Σ per-shard simulated ms, each shard on its own
    /// device clock.
    pub fn total_ms(&self) -> f64 {
        self.shards.iter().map(ShardReport::total_ms).sum()
    }

    /// Mean served latency, ms (0 when nothing was served).
    pub fn mean_latency_ms(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(QueryOutcome::latency_ms).sum::<f64>()
            / self.outcomes.len() as f64
    }

    /// Median served latency, ms (histogram-backed, log₂ resolution).
    pub fn p50_latency_ms(&self) -> f64 {
        self.latency_hist.percentile_ms(50)
    }

    /// 95th-percentile served latency, ms.
    ///
    /// Reads the log₂-bucketed histogram — O(buckets), allocation-free —
    /// instead of collecting and sorting every outcome per call. The
    /// reported value is the percentile bucket's upper bound (clamped to
    /// the exact maximum), so it upper-bounds the exact nearest-rank
    /// value within its power-of-two bucket.
    pub fn p95_latency_ms(&self) -> f64 {
        self.latency_hist.percentile_ms(95)
    }

    /// 99th-percentile served latency, ms (histogram-backed).
    pub fn p99_latency_ms(&self) -> f64 {
        self.latency_hist.percentile_ms(99)
    }

    /// Maximum served latency, ms (exact).
    pub fn max_latency_ms(&self) -> f64 {
        self.latency_hist.max_ms()
    }

    /// Σ wait over served queries in *reference-device cycles*
    /// (`devices[0]`'s clock).
    #[deprecated(
        note = "cycle counts on devices[0]'s clock mislead heterogeneous \
                pools; read the clock-neutral wait_ms_p50/p95/max instead"
    )]
    pub fn wait_cycles(&self) -> u64 {
        self.wait_cycles
    }

    /// Median queue wait (arrival → batch launch), ms. Clock-neutral —
    /// measured in virtual ps, unlike the deprecated `wait_cycles()`.
    pub fn wait_ms_p50(&self) -> f64 {
        self.wait_hist.percentile_ms(50)
    }

    /// 95th-percentile queue wait, ms (clock-neutral).
    pub fn wait_ms_p95(&self) -> f64 {
        self.wait_hist.percentile_ms(95)
    }

    /// Maximum queue wait, ms (exact, clock-neutral).
    pub fn wait_ms_max(&self) -> f64 {
        self.wait_hist.max_ms()
    }

    /// Fold of the shard metrics plus the scheduler's admission counters.
    pub fn totals(&self) -> AggregateMetrics {
        let mut agg = aggregate(self.shards.iter().map(|s| &s.metrics));
        agg.admitted = self.admitted;
        agg.dropped = self.dropped.len() as u64;
        agg.queue_peak = self.queue_peak;
        agg.wait_cycles = self.wait_cycles;
        agg
    }

    /// JSON rendering: scheduler counters, latency stats (histogram
    /// percentiles), and per-shard summaries — each converted on its own
    /// device clock and carrying `utilization` = busy_ps / wall_ps.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arrived", self.arrived.into()),
            ("admitted", self.admitted.into()),
            ("dropped", self.dropped.len().into()),
            ("served", self.served().into()),
            ("queue_peak", self.queue_peak.into()),
            ("blocked", self.blocked.into()),
            ("batches", self.batches.into()),
            ("wait_ms_p50", self.wait_ms_p50().into()),
            ("wait_ms_p95", self.wait_ms_p95().into()),
            ("wait_ms_max", self.wait_ms_max().into()),
            ("wall_ms", self.wall_ms().into()),
            ("latency_ms_mean", self.mean_latency_ms().into()),
            ("latency_ms_p50", self.p50_latency_ms().into()),
            ("latency_ms_p95", self.p95_latency_ms().into()),
            ("latency_ms_p99", self.p99_latency_ms().into()),
            ("latency_ms_max", self.max_latency_ms().into()),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| s.to_json_with_span(self.wall_ps))
                        .collect(),
                ),
            ),
            (
                "totals",
                self.totals()
                    .to_json_with_ms(self.total_ms(), self.wall_ms()),
            ),
        ])
    }

    /// Prometheus-style text exposition of the counter registry
    /// (`--metrics-out`). Pass the sink used during the run to include the
    /// per-kind trace-event totals; `None` omits them.
    pub fn prometheus(&self, sink: Option<&TraceSink>) -> String {
        let mut exp = Exposition::new();
        exp.counter("lonestar_arrived_total", "Arrivals consumed by the scheduler", &[], self.arrived as f64);
        exp.counter("lonestar_admitted_total", "Queries admitted into the bounded queue", &[], self.admitted as f64);
        exp.counter("lonestar_dropped_total", "Queries shed by the drop overflow policy", &[], self.dropped.len() as f64);
        exp.counter("lonestar_blocked_total", "Arrivals stalled by the block overflow policy", &[], self.blocked as f64);
        exp.counter("lonestar_served_total", "Queries served to completion", &[], self.served() as f64);
        exp.counter("lonestar_batches_total", "Batches launched across all shards", &[], self.batches as f64);
        exp.gauge("lonestar_queue_peak", "Peak admission-queue depth", &[], self.queue_peak as f64);
        exp.gauge("lonestar_wall_ms", "Virtual wall-clock of the drained stream (ms)", &[], self.wall_ms());
        let shard_ids: Vec<String> = (0..self.shards.len()).map(|i| i.to_string()).collect();
        for (s, id) in self.shards.iter().zip(&shard_ids) {
            exp.gauge(
                "lonestar_shard_utilization",
                "Busy fraction of the stream span (busy_ps / wall_ps)",
                &[("shard", id), ("device", s.device.name)],
                s.utilization(self.wall_ps),
            );
        }
        for (s, id) in self.shards.iter().zip(&shard_ids) {
            exp.gauge(
                "lonestar_shard_busy_ms",
                "Total busy time on the shard's own clock (ms)",
                &[("shard", id), ("device", s.device.name)],
                s.busy_ms(),
            );
        }
        for (s, id) in self.shards.iter().zip(&shard_ids) {
            exp.counter(
                "lonestar_shard_queries_total",
                "Queries served per shard",
                &[("shard", id), ("device", s.device.name)],
                s.queries.len() as f64,
            );
        }
        exp.histogram(
            "lonestar_latency_ms",
            "End-to-end served latency, arrival to completion (ms)",
            &self.latency_hist,
            1e-9,
        );
        exp.histogram(
            "lonestar_wait_ms",
            "Queue wait, arrival to batch launch (ms)",
            &self.wait_hist,
            1e-9,
        );
        let totals = self.totals();
        exp.counter(
            "lonestar_profiled_kernels_total",
            "Processing-kernel launches carrying a per-warp profile",
            &[],
            totals.profiled_kernels as f64,
        );
        exp.counter(
            "lonestar_imbalance_overhead_cycles_total",
            "Cycles spent waiting on straggler warps (per kernel: max-warp minus mean-warp)",
            &[],
            totals.imbalance_overhead_cycles as f64,
        );
        exp.gauge(
            "lonestar_imbalance_peak",
            "Worst single-kernel imbalance factor (max-warp / mean-warp cycles)",
            &[],
            totals.peak_imbalance(),
        );
        exp.histogram(
            "lonestar_warp_cycles",
            "Per-warp busy cycles across all profiled kernels",
            &totals.warp_cycles_hist,
            1.0,
        );
        exp.histogram(
            "lonestar_kernel_imbalance",
            "Per-kernel imbalance factor (recorded as factor x1000, exposed as the factor)",
            &totals.imbalance_hist,
            1e-3,
        );
        if let Some(t) = sink {
            for kind in TraceEventKind::ALL {
                exp.counter(
                    "lonestar_trace_events_total",
                    "Trace events recorded, by kind (survives ring wrap-around)",
                    &[("kind", kind.label())],
                    t.kind_count(kind) as f64,
                );
            }
            exp.counter(
                "lonestar_trace_overwritten_total",
                "Trace events lost to ring wrap-around",
                &[],
                t.overwritten() as f64,
            );
        }
        exp.finish()
    }
}

/// One device shard's live state inside the event loop.
struct ShardState<'a> {
    dev: &'a DeviceSpec,
    ctx: ExecCtx<'a>,
    /// Persistent batch engine, [`QueryBatch::reset`] per batch.
    engine: QueryBatch,
    /// Placed, waiting for the shard to go idle: `(query, arrival_ps)`.
    pending: Vec<(Query, u64)>,
    /// The batch currently executing.
    running: Vec<(Query, u64)>,
    /// Reset scratch: the query slice handed to the engine.
    batch_queries: Vec<Query>,
    start_ps: u64,
    busy_until_ps: u64,
    busy: bool,
    /// Σ busy-interval durations (ps) — feeds the report's per-shard
    /// `utilization` (busy_ps / wall_ps).
    busy_ps_total: u64,
    /// Σ source degrees of pending + running queries — the load signal
    /// placement minimizes (degree 0 counts as 1 so empty-frontier
    /// queries still occupy a slot).
    outstanding_edges: u64,
    /// Cycle watermark for per-batch durations on a cumulative context.
    prev_cycles: u64,
    /// Integer virtual-clock step of this device.
    ps_per_cycle: u64,
    /// Cached [`DeviceSpec::throughput_index`].
    tp: u64,
    /// Served queries / distances accumulated across every batch.
    served: Vec<Query>,
    dists: Vec<Vec<u32>>,
}

/// The stepwise scheduler. [`serve_stream`] wraps construct → drain →
/// finish; the allocation-regression harness drives [`Scheduler::step`]
/// directly to measure individual events.
pub struct Scheduler<'a> {
    graph: Arc<Csr>,
    cfg: &'a SchedulerConfig,
    arrivals: Vec<Arrival>,
    next_arrival: usize,
    queue: AdmissionQueue,
    /// Arrivals stalled by [`OverflowPolicy::Block`], in arrival order.
    blocked: VecDeque<(Query, u64)>,
    shards: Vec<ShardState<'a>>,
    now_ps: u64,
    blocked_events: u64,
    batches: u64,
    wait_ps_total: u64,
    wait_hist: LogHistogram,
    latency_hist: LogHistogram,
    outcomes: Vec<QueryOutcome>,
    dropped: Vec<Query>,
    placed_order: Vec<u32>,
    /// Optional telemetry sink ([`Scheduler::attach_trace`]): admission /
    /// placement / batch events are recorded here, and the sink travels
    /// into the dispatching shard's `ExecCtx` for the duration of each
    /// batch so engine events share the timeline.
    trace: Option<&'a mut TraceSink>,
}

impl<'a> Scheduler<'a> {
    /// Build the event loop over `arrivals` (sorted by arrival time if
    /// not already). Every growable buffer is pre-reserved to its
    /// worst-case size here, so steady-state steps allocate nothing.
    pub fn new(
        graph: Arc<Csr>,
        mut arrivals: Vec<Arrival>,
        cfg: &'a SchedulerConfig,
        cache: &GraphCache,
    ) -> Result<Self> {
        if cfg.serve.devices.is_empty() {
            return Err(Error::Config("devices must list at least one shard".into()));
        }
        if cfg.serve.max_batch == 0 {
            return Err(Error::Config("max_batch must be >= 1".into()));
        }
        arrivals.sort_by_key(|a| a.at_ps);
        let n_arrivals = arrivals.len();
        let mut shards = Vec::with_capacity(cfg.serve.devices.len());
        for (id, dev) in cfg.serve.devices.iter().enumerate() {
            let mut ctx = ExecCtx::new(dev, AlgoKind::Sssp, Box::new(NativeRelaxer));
            if cfg.serve.enforce_budget {
                ctx = ctx.with_budget(dev.memory_budget);
            }
            let engine = QueryBatch::with_cache(
                graph.clone(),
                &[],
                cfg.serve.strategy,
                cfg.serve.params.clone(),
                cache.scoped(id),
            )?;
            shards.push(ShardState {
                dev,
                ctx,
                engine,
                pending: Vec::with_capacity(cfg.serve.max_batch),
                running: Vec::with_capacity(cfg.serve.max_batch),
                batch_queries: Vec::with_capacity(cfg.serve.max_batch),
                start_ps: 0,
                busy_until_ps: 0,
                busy: false,
                busy_ps_total: 0,
                outstanding_edges: 0,
                prev_cycles: 0,
                ps_per_cycle: dev.ps_per_cycle(),
                tp: dev.throughput_index(),
                served: Vec::with_capacity(n_arrivals),
                dists: Vec::with_capacity(if cfg.collect_distances { n_arrivals } else { 0 }),
            });
        }
        Ok(Scheduler {
            graph,
            cfg,
            arrivals,
            next_arrival: 0,
            queue: AdmissionQueue::new(cfg.queue_cap),
            blocked: VecDeque::with_capacity(n_arrivals),
            shards,
            now_ps: 0,
            blocked_events: 0,
            batches: 0,
            wait_ps_total: 0,
            wait_hist: LogHistogram::new(),
            latency_hist: LogHistogram::new(),
            outcomes: Vec::with_capacity(n_arrivals),
            dropped: Vec::with_capacity(n_arrivals),
            placed_order: Vec::with_capacity(n_arrivals),
            trace: None,
        })
    }

    /// Attach a pre-allocated telemetry sink: every event from here on is
    /// recorded (ring overwrite on overflow — never an allocation, so the
    /// zero-alloc steady state holds with tracing live).
    pub fn attach_trace(&mut self, sink: &'a mut TraceSink) {
        self.trace = Some(sink);
    }

    /// Batches launched so far — the allocation-regression harness uses
    /// this to find its warm-up horizon (buffers reach their high-water
    /// capacity once a full-size batch has run).
    pub fn batches_launched(&self) -> u64 {
        self.batches
    }

    /// Advance the virtual clock to the next event (a batch completion or
    /// an arrival) and process everything due. Returns `false` once the
    /// stream has drained: no future arrivals, every shard idle, nothing
    /// queued.
    pub fn step(&mut self) -> Result<bool> {
        let next_arrival = self.arrivals.get(self.next_arrival).map(|a| a.at_ps);
        let next_done = self
            .shards
            .iter()
            .filter(|s| s.busy)
            .map(|s| s.busy_until_ps)
            .min();
        let now = match (next_arrival, next_done) {
            (Some(a), Some(d)) => a.min(d),
            (Some(a), None) => a,
            (None, Some(d)) => d,
            // No future event: dispatch runs at the end of every step, so
            // anything queued or pending would have made a shard busy.
            (None, None) => return Ok(false),
        };
        debug_assert!(now >= self.now_ps, "the virtual clock is monotonic");
        self.now_ps = now;

        // 1. Completions first — capacity freed at `now` serves arrivals
        //    and placements of the same instant.
        for i in 0..self.shards.len() {
            if self.shards[i].busy && self.shards[i].busy_until_ps <= now {
                self.complete(i);
            }
        }
        // 2. Settle the backlog against the freed capacity BEFORE looking
        //    at new arrivals: earlier (blocked) arrivals re-enter first
        //    and queued queries move onto the freed shards, so an arrival
        //    at exactly this instant sees the queue as it is *after* the
        //    completion — capacity freed at `now` really does serve
        //    same-instant arrivals instead of dropping them.
        self.settle();
        // 3. Arrivals due now meet the bounded queue — behind the backlog
        //    (after a full drain, a non-empty backlog implies a full
        //    queue, so `try_admit` fails and the arrival queues behind).
        while let Some(a) = self.arrivals.get(self.next_arrival) {
            if a.at_ps > now {
                break;
            }
            let (query, at_ps) = (a.query, a.at_ps);
            self.next_arrival += 1;
            if let Some(t) = self.trace.as_deref_mut() {
                t.record(TraceEvent {
                    query: query.id,
                    ..TraceEvent::new(TraceEventKind::Arrival, at_ps)
                });
            }
            if self.queue.try_admit(query, at_ps) {
                if let Some(t) = self.trace.as_deref_mut() {
                    let depth = self.queue.len() as u64;
                    t.record(TraceEvent {
                        query: query.id,
                        a: depth,
                        ..TraceEvent::new(TraceEventKind::Admit, now)
                    });
                    t.record(TraceEvent {
                        a: depth,
                        ..TraceEvent::new(TraceEventKind::QueueDepth, now)
                    });
                }
            } else {
                match self.cfg.overflow {
                    OverflowPolicy::Drop => {
                        self.dropped.push(query);
                        if let Some(t) = self.trace.as_deref_mut() {
                            t.record(TraceEvent {
                                query: query.id,
                                ..TraceEvent::new(TraceEventKind::Drop, now)
                            });
                        }
                    }
                    OverflowPolicy::Block => {
                        self.blocked.push_back((query, at_ps));
                        self.blocked_events += 1;
                        if let Some(t) = self.trace.as_deref_mut() {
                            t.record(TraceEvent {
                                query: query.id,
                                ..TraceEvent::new(TraceEventKind::Block, now)
                            });
                        }
                    }
                }
            }
        }
        // 4. Settle again: the new arrivals may themselves be placeable
        //    right now (idle shards), which frees queue slots the blocked
        //    backlog can take at the same instant.
        self.settle();
        // 5. Idle shards with pending work launch a batch.
        self.dispatch()?;
        Ok(true)
    }

    /// Fixpoint of placement + backlog drain at one instant: popping the
    /// queue onto idle shards frees slots the blocked backlog can take
    /// right now. Both preserve FIFO, so the fixpoint does too.
    fn settle(&mut self) {
        loop {
            let moved = self.drain_blocked() + self.place();
            if moved == 0 {
                break;
            }
        }
    }

    /// Move blocked arrivals (in arrival order) into the queue while it
    /// has room; returns how many entered.
    fn drain_blocked(&mut self) -> usize {
        let mut moved = 0;
        while !self.queue.is_full() {
            let Some((query, at_ps)) = self.blocked.pop_front() else {
                break;
            };
            let entered = self.queue.try_admit(query, at_ps);
            debug_assert!(entered, "queue had room");
            if let Some(t) = self.trace.as_deref_mut() {
                let depth = self.queue.len() as u64;
                t.record(TraceEvent {
                    query: query.id,
                    a: depth,
                    ..TraceEvent::new(TraceEventKind::Admit, self.now_ps)
                });
                t.record(TraceEvent {
                    a: depth,
                    ..TraceEvent::new(TraceEventKind::QueueDepth, self.now_ps)
                });
            }
            moved += 1;
        }
        moved
    }

    /// Retire shard `i`'s finished batch: record outcomes, extract
    /// distances, release its memory accounting, keep the engine warm.
    fn complete(&mut self, i: usize) {
        let s = &mut self.shards[i];
        s.busy = false;
        let width = s.running.len() as u64;
        s.busy_ps_total += s.busy_until_ps - s.start_ps;
        for (k, &(query, arrival_ps)) in s.running.iter().enumerate() {
            self.outcomes.push(QueryOutcome {
                query,
                shard: i,
                arrival_ps,
                start_ps: s.start_ps,
                done_ps: s.busy_until_ps,
            });
            self.latency_hist.record(s.busy_until_ps - arrival_ps);
            s.served.push(query);
            if self.cfg.collect_distances {
                s.dists.push(s.engine.distances(k));
            }
            s.outstanding_edges -= (self.graph.degree(query.source) as u64).max(1);
        }
        s.running.clear();
        s.engine.retire(&mut s.ctx);
        if let Some(t) = self.trace.as_deref_mut() {
            // The busy interval is only known complete here, so the slice
            // is recorded at retirement, stamped back at its start.
            t.record(TraceEvent {
                shard: i as u32,
                a: s.busy_until_ps - s.start_ps,
                b: width,
                ..TraceEvent::new(TraceEventKind::ShardBusy, s.start_ps)
            });
            t.record(TraceEvent {
                shard: i as u32,
                a: width,
                ..TraceEvent::new(TraceEventKind::BatchComplete, s.busy_until_ps)
            });
        }
    }

    /// Pop admitted queries FIFO and place each on the **idle** shard
    /// minimizing outstanding edges per unit throughput (exact integer
    /// cross-multiplication; ties go to the lower shard id). Busy shards
    /// take nothing — their next batch forms from whatever the queue
    /// holds when they free, so the admission queue is the only buffer
    /// under load and its cap is a real bound. Stops when the queue
    /// empties or every idle shard is at `max_batch`; returns how many
    /// queries were placed.
    fn place(&mut self) -> usize {
        let max_batch = self.cfg.serve.max_batch;
        let mut placed = 0;
        while !self.queue.is_empty() {
            let mut best: Option<usize> = None;
            for i in 0..self.shards.len() {
                if self.shards[i].busy || self.shards[i].pending.len() >= max_batch {
                    continue;
                }
                best = Some(match best {
                    None => i,
                    Some(j) => {
                        let (a, b) = (&self.shards[i], &self.shards[j]);
                        let lhs = a.outstanding_edges as u128 * b.tp as u128;
                        let rhs = b.outstanding_edges as u128 * a.tp as u128;
                        if lhs < rhs {
                            i
                        } else {
                            j
                        }
                    }
                });
            }
            let Some(i) = best else { break };
            let (query, at_ps) = self.queue.pop().expect("non-empty");
            let load = (self.graph.degree(query.source) as u64).max(1);
            self.placed_order.push(query.id);
            if let Some(t) = self.trace.as_deref_mut() {
                t.record(TraceEvent {
                    shard: i as u32,
                    query: query.id,
                    a: load,
                    ..TraceEvent::new(TraceEventKind::Place, self.now_ps)
                });
                t.record(TraceEvent {
                    a: self.queue.len() as u64,
                    ..TraceEvent::new(TraceEventKind::QueueDepth, self.now_ps)
                });
            }
            let s = &mut self.shards[i];
            s.pending.push((query, at_ps));
            s.outstanding_edges += load;
            placed += 1;
        }
        placed
    }

    /// Launch every idle shard's pending queries as one batch and stamp
    /// its completion on the shared timeline via the shard's own clock.
    fn dispatch(&mut self) -> Result<()> {
        let now = self.now_ps;
        let max_iterations = self.cfg.serve.max_iterations;
        // The sink moves: scheduler → dispatching shard's ExecCtx (so the
        // engine's kernel/decision events land on the shared timeline) →
        // back. A move of an Option<&mut _>, not a reborrow — the loop
        // below must restore it on every path, error included.
        let mut trace = self.trace.take();
        let mut failed: Option<Error> = None;
        for i in 0..self.shards.len() {
            let s = &mut self.shards[i];
            if s.busy || s.pending.is_empty() {
                continue;
            }
            s.batch_queries.clear();
            for &(query, at_ps) in &s.pending {
                s.batch_queries.push(query);
                self.wait_ps_total += now - at_ps;
                self.wait_hist.record(now - at_ps);
            }
            if let Some(t) = trace.as_deref_mut() {
                t.record(TraceEvent {
                    shard: i as u32,
                    a: s.batch_queries.len() as u64,
                    b: self.batches,
                    ..TraceEvent::new(TraceEventKind::BatchLaunch, now)
                });
            }
            s.ctx.trace = trace.take();
            s.ctx.trace_base_ps = now;
            s.ctx.trace_base_cycles = s.ctx.metrics.total_cycles();
            s.ctx.trace_shard = i as u32;
            let launched = s
                .engine
                .reset(&mut s.ctx, &s.batch_queries)
                .and_then(|()| s.engine.run(&mut s.ctx, max_iterations));
            trace = s.ctx.trace.take();
            if let Err(e) = launched {
                failed = Some(e);
                break;
            }
            let total = s.ctx.metrics.total_cycles();
            let cycles = total - s.prev_cycles;
            s.prev_cycles = total;
            s.start_ps = now;
            s.busy_until_ps = now + cycles.max(1) * s.ps_per_cycle;
            s.busy = true;
            std::mem::swap(&mut s.running, &mut s.pending);
            self.batches += 1;
        }
        self.trace = trace;
        match failed {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Drain the stream and assemble the report.
    pub fn finish(self) -> ScheduleReport {
        let ref_ppc = self.cfg.serve.devices[0].ps_per_cycle().max(1);
        let mut shards = Vec::with_capacity(self.shards.len());
        for (i, mut s) in self.shards.into_iter().enumerate() {
            debug_assert!(!s.busy && s.pending.is_empty(), "finish before drain");
            s.ctx.finalize_metrics();
            let metrics = std::mem::take(&mut s.ctx.metrics);
            drop(s.ctx);
            shards.push(ShardReport {
                shard: i,
                device: s.dev.clone(),
                queries: s.served,
                metrics,
                dists: s.dists,
                busy_ps: s.busy_ps_total,
            });
        }
        ScheduleReport {
            shards,
            outcomes: self.outcomes,
            dropped: self.dropped,
            placed_order: self.placed_order,
            arrived: self.next_arrival as u64,
            admitted: self.queue.admitted,
            queue_peak: self.queue.peak,
            blocked: self.blocked_events,
            batches: self.batches,
            wait_cycles: self.wait_ps_total / ref_ppc,
            wall_ps: self.now_ps,
            wait_hist: self.wait_hist,
            latency_hist: self.latency_hist,
        }
    }
}

/// Run an arrival stream through the admission-controlled scheduler to
/// drain: construct, step until idle, report.
pub fn serve_stream(
    graph: &Arc<Csr>,
    arrivals: Vec<Arrival>,
    cfg: &SchedulerConfig,
    cache: &GraphCache,
) -> Result<ScheduleReport> {
    serve_stream_traced(graph, arrivals, cfg, cache, None)
}

/// [`serve_stream`] with an optional telemetry sink: pass a pre-allocated
/// [`TraceSink`] to capture the full event timeline (admissions, drops,
/// placements, per-shard busy intervals, engine kernels and decisions) for
/// export via [`crate::telemetry::chrome_trace`]. The sink borrows for the
/// scheduler's lifetime, so declare it before the call's other borrows.
pub fn serve_stream_traced<'a>(
    graph: &Arc<Csr>,
    arrivals: Vec<Arrival>,
    cfg: &'a SchedulerConfig,
    cache: &GraphCache,
    trace: Option<&'a mut TraceSink>,
) -> Result<ScheduleReport> {
    let mut sched = Scheduler::new(graph.clone(), arrivals, cfg, cache)?;
    if let Some(sink) = trace {
        sched.attach_trace(sink);
    }
    while sched.step()? {}
    Ok(sched.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, rmat, RmatParams};
    use crate::graph::traversal;
    use crate::serving::query::synthetic_arrivals;
    use crate::strategies::StrategyKind;

    fn stream(g: &Csr, count: usize, mean_gap_ps: u64, seed: u64) -> Vec<Arrival> {
        synthetic_arrivals(g, count, 0.0, mean_gap_ps, seed)
    }

    #[test]
    fn drains_and_conserves_queries() {
        let g = Arc::new(erdos_renyi(200, 800, 11, 3).unwrap());
        let arrivals = stream(&g, 40, 500_000, 7);
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 8,
                ..Default::default()
            },
            queue_cap: 4,
            ..Default::default()
        };
        let report = serve_stream(&g, arrivals, &cfg, &GraphCache::new()).unwrap();
        assert_eq!(report.arrived, 40);
        assert_eq!(
            report.arrived,
            report.admitted + report.dropped.len() as u64,
            "arrived == admitted + dropped"
        );
        assert_eq!(
            report.admitted,
            report.served() as u64,
            "admitted == served at drain"
        );
        assert!(report.batches > 0);
        assert!(report.queue_peak >= 1);
        // Every served distance matches the oracle.
        for o in &report.outcomes {
            assert_eq!(
                report.dist_of(o.query.id).unwrap(),
                traversal::dijkstra(&g, o.query.source).as_slice(),
                "query {}",
                o.query.id
            );
        }
    }

    #[test]
    fn tight_queue_drops_and_block_does_not() {
        let g = Arc::new(erdos_renyi(150, 600, 9, 5).unwrap());
        // Near-simultaneous arrivals against a 2-deep queue force overflow.
        let arrivals = stream(&g, 30, 10, 11);
        let mut cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 4,
                ..Default::default()
            },
            queue_cap: 2,
            ..Default::default()
        };
        let dropping = serve_stream(&g, arrivals.clone(), &cfg, &GraphCache::new()).unwrap();
        assert!(!dropping.dropped.is_empty(), "a 2-deep queue must shed");
        assert_eq!(
            dropping.arrived,
            dropping.admitted + dropping.dropped.len() as u64
        );
        // Dropped queries are excluded from results.
        for q in &dropping.dropped {
            assert!(dropping.dist_of(q.id).is_none(), "dropped query {} served", q.id);
        }

        cfg.overflow = OverflowPolicy::Block;
        let blocking = serve_stream(&g, arrivals, &cfg, &GraphCache::new()).unwrap();
        assert!(blocking.dropped.is_empty(), "block never sheds");
        assert_eq!(blocking.served() as u64, blocking.arrived);
        assert!(blocking.blocked > 0, "the stall counter must trip");
        assert!(
            blocking.wait_hist.sum() > dropping.wait_hist.sum(),
            "lossless admission pays with wait"
        );
    }

    #[test]
    fn heterogeneous_pool_is_deterministic_and_uses_every_shard() {
        let g = Arc::new(rmat(8, 2048, RmatParams::default(), 13).unwrap());
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                devices: vec![DeviceSpec::k40(), DeviceSpec::gtx680()],
                max_batch: 8,
                ..Default::default()
            },
            queue_cap: 16,
            ..Default::default()
        };
        let a = serve_stream(&g, stream(&g, 32, 100_000, 21), &cfg, &GraphCache::new()).unwrap();
        let b = serve_stream(&g, stream(&g, 32, 100_000, 21), &cfg, &GraphCache::new()).unwrap();
        assert_eq!(a.outcomes, b.outcomes, "replays must be exact");
        assert_eq!(a.placed_order, b.placed_order);
        for s in &a.shards {
            assert!(
                !s.queries.is_empty(),
                "under sustained load every device serves (shard {})",
                s.shard
            );
        }
        assert_eq!(a.shards[0].device.name, "k40");
        assert_eq!(a.shards[1].device.name, "gtx680");
        assert!(a.total_ms() > 0.0 && a.wall_ms() > 0.0);
        assert!(a.mean_latency_ms() <= a.p95_latency_ms());
    }

    #[test]
    fn scheduler_forms_batches_past_64_queries() {
        // queue_cap > 64 + max_batch 80: a burst behind one busy shard
        // must coalesce into a batch wider than the old 64-query limit
        // (multi-word tags on the scheduler path), results still exact.
        let g = Arc::new(erdos_renyi(150, 600, 9, 5).unwrap());
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 80,
                ..Default::default()
            },
            queue_cap: 128,
            ..Default::default()
        };
        let arrivals = stream(&g, 100, 10, 9);
        let report = serve_stream(&g, arrivals, &cfg, &GraphCache::new()).unwrap();
        assert_eq!(report.served(), 100, "128-deep queue loses nothing here");
        // Outcomes of one batch share (shard, start_ps).
        let mut widest = 0usize;
        for o in &report.outcomes {
            let width = report
                .outcomes
                .iter()
                .filter(|p| p.shard == o.shard && p.start_ps == o.start_ps)
                .count();
            widest = widest.max(width);
        }
        assert!(
            widest > 64,
            "expected a multi-word batch, widest was {widest}"
        );
        for o in &report.outcomes {
            assert_eq!(
                report.dist_of(o.query.id).unwrap(),
                traversal::dijkstra(&g, o.query.source).as_slice(),
                "query {}",
                o.query.id
            );
        }
    }

    #[test]
    fn batches_grow_under_pressure() {
        let g = Arc::new(erdos_renyi(150, 600, 9, 5).unwrap());
        let cfg = SchedulerConfig {
            serve: ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 16,
                ..Default::default()
            },
            queue_cap: 64,
            ..Default::default()
        };
        let cache = GraphCache::new();
        // Sparse arrivals: every query tends to get its own batch.
        let relaxed = serve_stream(&g, stream(&g, 24, 2_000_000_000, 3), &cfg, &cache).unwrap();
        // A burst: batches must coalesce, so strictly fewer launches.
        let bursty = serve_stream(&g, stream(&g, 24, 10, 3), &cfg, &cache).unwrap();
        assert!(
            bursty.batches < relaxed.batches,
            "burst arrivals must batch ({} vs {})",
            bursty.batches,
            relaxed.batches
        );
        assert!(
            bursty.mean_latency_ms() > 0.0 && relaxed.mean_latency_ms() > 0.0
        );
    }
}
