//! The device-shard layer: partition a batch of queries across simulated
//! devices, run one [`QueryBatch`] per shard, and aggregate the per-shard
//! [`RunMetrics`] into a batch report.
//!
//! Shards are independent simulated devices (each gets its own
//! [`crate::coordinator::ExecCtx`] over a clone of the
//! [`crate::sim::DeviceSpec`]), so the batch's wall-clock is the *maximum*
//! shard time while its throughput cost is the *sum* — [`AggregateMetrics`]
//! carries both. Aggregation is a commutative fold (sums and maxes), so it
//! is invariant under query and shard permutation — a property pinned down
//! in `rust/tests/strategy_properties.rs`.

use crate::algorithms::{AlgoKind, NativeRelaxer};
use crate::arena::GraphCache;
use crate::coordinator::ExecCtx;
use crate::error::{Error, Result};
use crate::graph::Csr;
use crate::metrics::RunMetrics;
use crate::sim::DeviceSpec;
use crate::strategies::{StrategyKind, StrategyParams};
use crate::telemetry::LogHistogram;
use crate::util::Json;
use std::sync::Arc;

use super::batch::QueryBatch;
use super::merged::MAX_QUERIES_PER_SHARD;
use super::query::Query;

/// Everything needed to serve one batch of queries.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Strategy of the batch engine: a static kind, or [`StrategyKind::AD`]
    /// for per-batch adaptive decisions (the default).
    pub strategy: StrategyKind,
    pub params: StrategyParams,
    /// One simulated device per shard — heterogeneous pools list different
    /// presets (replaces the former single `device` + `shards` pair; the
    /// `devices` config key / `--devices` flag feed it).
    pub devices: Vec<DeviceSpec>,
    /// Enforce each device's own memory budget on its shard.
    pub enforce_budget: bool,
    /// Safety valve on batch iterations.
    pub max_iterations: u32,
    /// Per-shard batch capacity: how many concurrent queries one device
    /// carries (the merged worklist grows one tag word per 64 — see
    /// [`crate::serving::merged`]). Defaults to [`MAX_QUERIES_PER_SHARD`].
    pub max_batch: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            strategy: StrategyKind::AD,
            params: StrategyParams::default(),
            devices: vec![DeviceSpec::k20c()],
            enforce_budget: false,
            max_iterations: 1_000_000,
            max_batch: MAX_QUERIES_PER_SHARD,
        }
    }
}

impl ServeConfig {
    /// Homogeneous pool of `n` default (K20c) devices.
    pub fn with_shards(n: usize) -> Self {
        ServeConfig {
            devices: vec![DeviceSpec::k20c(); n.max(1)],
            ..Default::default()
        }
    }

    /// Shard count (one per device).
    pub fn shards(&self) -> usize {
        self.devices.len()
    }
}

/// One simulated device's share of the batch.
#[derive(Debug, Clone)]
pub struct DeviceShard {
    pub id: usize,
    pub queries: Vec<Query>,
}

/// Round-robin partition of `queries` over `shards` devices (deterministic;
/// empty shards are kept so shard ids are stable).
pub fn partition(queries: &[Query], shards: usize) -> Vec<DeviceShard> {
    let shards = shards.max(1);
    let mut out: Vec<DeviceShard> = (0..shards)
        .map(|id| DeviceShard {
            id,
            queries: Vec::new(),
        })
        .collect();
    for (i, &q) in queries.iter().enumerate() {
        out[i % shards].queries.push(q);
    }
    out
}

/// One shard's outcome: its queries, its metrics, and the per-query
/// distance arrays (truncated to the original node ids).
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub shard: usize,
    /// The simulated device this shard ran on — cycle→ms conversions for
    /// this shard MUST use it (shards of a heterogeneous pool run at
    /// different clocks, so one shared `DeviceSpec` mis-times them).
    pub device: DeviceSpec,
    pub queries: Vec<Query>,
    pub metrics: RunMetrics,
    pub dists: Vec<Vec<u32>>,
    /// Virtual time this shard spent busy (ps). On the scheduler path this
    /// sums the actual busy intervals on the shared timeline; on the plain
    /// batch path it is the shard's cycles converted on its own clock.
    pub busy_ps: u64,
    /// Virtual time this shard spent quarantined or dead (ps). Only the
    /// fault-injecting scheduler path ever makes it non-zero; the plain
    /// batch path has no fault model.
    pub downtime_ps: u64,
}

impl ShardReport {
    /// This shard's simulated milliseconds, on its **own** device clock.
    pub fn total_ms(&self) -> f64 {
        self.device.cycles_to_ms(self.metrics.total_cycles())
    }

    /// Busy time in ms (virtual clock).
    pub fn busy_ms(&self) -> f64 {
        self.busy_ps as f64 / 1e9
    }

    /// Busy fraction of `span_ps` — the per-shard utilization figure the
    /// load-balancing analysis reads (0.0 when the span is empty).
    pub fn utilization(&self, span_ps: u64) -> f64 {
        if span_ps == 0 {
            0.0
        } else {
            self.busy_ps as f64 / span_ps as f64
        }
    }

    /// Downtime in ms (virtual clock).
    pub fn downtime_ms(&self) -> f64 {
        self.downtime_ps as f64 / 1e9
    }

    /// In-service fraction of `span_ps`: `1 − downtime_ps / span_ps`
    /// (1.0 when the span is empty — a shard that never saw traffic was
    /// never observed down).
    pub fn availability(&self, span_ps: u64) -> f64 {
        if span_ps == 0 {
            1.0
        } else {
            1.0 - (self.downtime_ps.min(span_ps) as f64 / span_ps as f64)
        }
    }

    /// JSON rendering (all ms figures converted with this shard's device).
    pub fn to_json(&self) -> Json {
        Json::Obj(self.json_fields(None))
    }

    /// [`ShardReport::to_json`] plus `utilization` against `span_ps` (the
    /// stream wall-clock on the scheduler path, the slowest shard's busy
    /// time on the batch path).
    pub fn to_json_with_span(&self, span_ps: u64) -> Json {
        Json::Obj(self.json_fields(Some(span_ps)))
    }

    fn json_fields(&self, span_ps: Option<u64>) -> std::collections::BTreeMap<String, Json> {
        let mut fields = vec![
            ("shard", self.shard.into()),
            ("device", self.device.name.into()),
            ("queries", self.queries.len().into()),
            ("busy_ms", self.busy_ms().into()),
            ("downtime_ms", self.downtime_ms().into()),
            (
                "metrics",
                aggregate(std::iter::once(&self.metrics)).to_json(&self.device),
            ),
        ];
        if let Some(span) = span_ps {
            fields.push(("utilization", self.utilization(span).into()));
            fields.push(("availability", self.availability(span).into()));
        }
        fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
    }
}

/// Commutative aggregate of per-shard metrics: sums for throughput-style
/// counters, max for per-device quantities (peak memory, wall-clock
/// cycles — shards run in parallel).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AggregateMetrics {
    /// Σ over shards of total simulated cycles (throughput cost).
    pub total_cycles: u64,
    /// Max over shards of total simulated cycles (wall-clock: shards run
    /// concurrently on separate devices).
    pub wall_cycles: u64,
    pub kernel_cycles: u64,
    pub overhead_cycles: u64,
    pub inspector_passes: u64,
    pub policy_decisions: u64,
    pub iterations: u64,
    pub kernel_launches: u64,
    pub edge_relaxations: u64,
    pub strategy_switches: u64,
    /// Max over shards (each device holds its own allocations).
    pub peak_memory_bytes: u64,
    /// Σ scratch-arena checkouts that allocated a fresh buffer (warm-up
    /// traffic; see [`crate::arena::PerfCounters`]).
    pub scratch_created: u64,
    /// Σ scratch-arena checkouts served from the pool — the serving
    /// layer's zero-allocation steady state.
    pub scratch_reused: u64,
    /// Max over shards of the arena's peak pooled bytes.
    pub scratch_peak_bytes: u64,
    /// Queries admitted into the scheduler's bounded queue (0 outside the
    /// admission-controlled path — plain [`serve`] admits implicitly).
    pub admitted: u64,
    /// Queries the overflow policy dropped at a full queue.
    pub dropped: u64,
    /// Peak depth the admission queue reached.
    pub queue_peak: u64,
    /// Σ processing-kernel launches that committed at least one warp.
    pub profiled_kernels: u64,
    /// Σ straggler cycles: per kernel, (max-warp − mean-warp) busy cycles.
    pub imbalance_overhead_cycles: u64,
    /// Max over shards of the worst single-kernel imbalance factor, ×1000.
    pub peak_imbalance_x1000: u64,
    /// Merged per-warp busy-cycle distribution across all shards.
    pub warp_cycles_hist: LogHistogram,
    /// Merged per-kernel imbalance-factor distribution (×1000 samples).
    pub imbalance_hist: LogHistogram,
}

/// Fold per-shard (or per-run) metrics into an [`AggregateMetrics`]. Every
/// component is a sum or a max, so any permutation of the input yields the
/// same aggregate.
pub fn aggregate<'a>(metrics: impl IntoIterator<Item = &'a RunMetrics>) -> AggregateMetrics {
    let mut agg = AggregateMetrics::default();
    for m in metrics {
        agg.total_cycles += m.total_cycles();
        agg.wall_cycles = agg.wall_cycles.max(m.total_cycles());
        agg.kernel_cycles += m.kernel_cycles;
        agg.overhead_cycles += m.overhead_cycles;
        agg.inspector_passes += m.inspector_passes;
        agg.policy_decisions += m.policy_decisions;
        agg.iterations += m.iterations as u64;
        agg.kernel_launches += m.kernel_launches as u64;
        agg.edge_relaxations += m.edge_relaxations;
        agg.strategy_switches += m.strategy_switches;
        agg.peak_memory_bytes = agg.peak_memory_bytes.max(m.peak_memory_bytes);
        agg.scratch_created += m.scratch_created;
        agg.scratch_reused += m.scratch_reused;
        agg.scratch_peak_bytes = agg.scratch_peak_bytes.max(m.scratch_peak_bytes);
        agg.profiled_kernels += m.profiled_kernels;
        agg.imbalance_overhead_cycles += m.imbalance_overhead_cycles;
        agg.peak_imbalance_x1000 = agg.peak_imbalance_x1000.max(m.peak_imbalance_x1000);
        agg.warp_cycles_hist.merge(&m.warp_cycles_hist);
        agg.imbalance_hist.merge(&m.imbalance_hist);
    }
    agg
}

impl AggregateMetrics {
    /// Throughput cost in simulated milliseconds on `dev`.
    pub fn total_ms(&self, dev: &DeviceSpec) -> f64 {
        dev.cycles_to_ms(self.total_cycles)
    }

    /// Wall-clock in simulated milliseconds on `dev` (slowest shard).
    pub fn wall_ms(&self, dev: &DeviceSpec) -> f64 {
        dev.cycles_to_ms(self.wall_cycles)
    }

    /// JSON rendering. `dev` converts the cycle totals to ms, so this is
    /// only meaningful for a homogeneous aggregate (a single shard, or a
    /// pool of identical devices); [`BatchReport::to_json`] converts
    /// per-shard before folding when devices differ.
    pub fn to_json(&self, dev: &DeviceSpec) -> Json {
        self.to_json_with_ms(self.total_ms(dev), self.wall_ms(dev))
    }

    /// JSON rendering with externally converted ms figures — the
    /// heterogeneous path, where cycles from different clocks must be
    /// converted per shard *before* summing/maxing.
    pub fn to_json_with_ms(&self, total_ms: f64, wall_ms: f64) -> Json {
        Json::obj(vec![
            ("total_ms", total_ms.into()),
            ("wall_ms", wall_ms.into()),
            ("kernel_cycles", self.kernel_cycles.into()),
            ("overhead_cycles", self.overhead_cycles.into()),
            ("inspector_passes", self.inspector_passes.into()),
            ("policy_decisions", self.policy_decisions.into()),
            ("iterations", self.iterations.into()),
            ("kernel_launches", self.kernel_launches.into()),
            ("edge_relaxations", self.edge_relaxations.into()),
            ("strategy_switches", self.strategy_switches.into()),
            ("peak_memory", self.peak_memory_bytes.into()),
            ("scratch_created", self.scratch_created.into()),
            ("scratch_reused", self.scratch_reused.into()),
            ("scratch_peak_bytes", self.scratch_peak_bytes.into()),
            ("admitted", self.admitted.into()),
            ("dropped", self.dropped.into()),
            ("queue_peak", self.queue_peak.into()),
            ("profiled_kernels", self.profiled_kernels.into()),
            ("imbalance_overhead_cycles", self.imbalance_overhead_cycles.into()),
            ("mean_imbalance", self.mean_imbalance().into()),
            ("peak_imbalance", self.peak_imbalance().into()),
        ])
    }

    /// Mean per-kernel imbalance factor across every profiled kernel
    /// (1.0 when nothing was profiled).
    pub fn mean_imbalance(&self) -> f64 {
        if self.imbalance_hist.is_empty() {
            1.0
        } else {
            self.imbalance_hist.mean() / 1000.0
        }
    }

    /// Worst single-kernel imbalance factor (1.0 when nothing was
    /// profiled).
    pub fn peak_imbalance(&self) -> f64 {
        if self.profiled_kernels == 0 {
            1.0
        } else {
            self.peak_imbalance_x1000 as f64 / 1000.0
        }
    }
}

/// Outcome of serving one batch across its shards.
#[derive(Debug, Clone)]
pub struct BatchReport {
    pub shards: Vec<ShardReport>,
}

impl BatchReport {
    /// Queries served.
    pub fn query_count(&self) -> usize {
        self.shards.iter().map(|s| s.queries.len()).sum()
    }

    /// Aggregate of the shard metrics.
    pub fn totals(&self) -> AggregateMetrics {
        aggregate(self.shards.iter().map(|s| &s.metrics))
    }

    /// Throughput cost in simulated ms: Σ over shards of that shard's
    /// cycles converted on that shard's **own** device clock. (Folding
    /// cycles first and converting once would mis-time every shard of a
    /// heterogeneous pool.)
    pub fn total_ms(&self) -> f64 {
        self.shards.iter().map(ShardReport::total_ms).sum()
    }

    /// Wall-clock in simulated ms: the slowest shard, each on its own
    /// device clock (shards run concurrently).
    pub fn wall_ms(&self) -> f64 {
        self.shards
            .iter()
            .map(ShardReport::total_ms)
            .fold(0.0, f64::max)
    }

    /// Distance array of the query with `id`, if it was in the batch.
    pub fn dist_of(&self, id: u32) -> Option<&[u32]> {
        for s in &self.shards {
            if let Some(i) = s.queries.iter().position(|q| q.id == id) {
                return Some(&s.dists[i]);
            }
        }
        None
    }

    /// JSON rendering (per-shard summaries + totals). Every ms figure is
    /// converted with the owning shard's device before folding, so
    /// heterogeneous pools report honest times.
    pub fn to_json(&self) -> Json {
        // Batch span = the slowest shard's busy time: utilization compares
        // each shard against the shard that bounded the batch.
        let span_ps = self.shards.iter().map(|s| s.busy_ps).max().unwrap_or(0);
        Json::obj(vec![
            ("queries", self.query_count().into()),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| s.to_json_with_span(span_ps))
                        .collect(),
                ),
            ),
            (
                "totals",
                self.totals()
                    .to_json_with_ms(self.total_ms(), self.wall_ms()),
            ),
        ])
    }
}

/// Serve one batch of queries over `graph`: partition across
/// `cfg.shards` simulated devices, run a [`QueryBatch`] per shard, collect
/// per-shard metrics and per-query distances. Uses a fresh [`GraphCache`]
/// — call [`serve_with_cache`] to share graph-keyed artifacts (MDT
/// decision, NS split graph, COO flag) across repeated batches.
pub fn serve(graph: &Arc<Csr>, queries: &[Query], cfg: &ServeConfig) -> Result<BatchReport> {
    serve_with_cache(graph, queries, cfg, &GraphCache::new())
}

/// [`serve`] with a caller-held [`GraphCache`]: batches served repeatedly
/// on the same long-lived graph skip rebuilding the graph-keyed artifacts
/// (the cross-batch reuse seam of the ROADMAP's serving section).
/// Distances are bit-identical with or without a warm cache — only the
/// one-time build kernels are skipped on a hit.
pub fn serve_with_cache(
    graph: &Arc<Csr>,
    queries: &[Query],
    cfg: &ServeConfig,
    cache: &GraphCache,
) -> Result<BatchReport> {
    serve_traced(graph, queries, cfg, cache, None, 0)
}

/// [`serve_with_cache`] with an optional telemetry sink: each shard's
/// engine records kernel slices / decisions / frontier counters stamped
/// from `base_ps` on its own device clock (shards of one batch run
/// concurrently, so they share the base; the CLI advances it per batch so
/// one trace file lays consecutive batches end to end).
pub fn serve_traced(
    graph: &Arc<Csr>,
    queries: &[Query],
    cfg: &ServeConfig,
    cache: &GraphCache,
    mut trace: Option<&mut crate::telemetry::TraceSink>,
    base_ps: u64,
) -> Result<BatchReport> {
    if cfg.devices.is_empty() {
        return Err(Error::Config("devices must list at least one shard".into()));
    }
    if cfg.max_batch == 0 {
        return Err(Error::Config("max_batch must be >= 1".into()));
    }
    let per_shard = queries.len().div_ceil(cfg.devices.len());
    if per_shard > cfg.max_batch {
        return Err(Error::Config(format!(
            "{} queries over {} shards puts {per_shard} on one device \
             (max_batch {}); raise shards/max_batch or lower batch_size",
            queries.len(),
            cfg.devices.len(),
            cfg.max_batch
        )));
    }
    let mut shards = Vec::new();
    for shard in partition(queries, cfg.devices.len()) {
        let device = cfg.devices[shard.id].clone();
        if shard.queries.is_empty() {
            shards.push(ShardReport {
                shard: shard.id,
                device,
                queries: Vec::new(),
                metrics: RunMetrics::default(),
                dists: Vec::new(),
                busy_ps: 0,
                downtime_ps: 0,
            });
            continue;
        }
        let mut ctx = ExecCtx::new(&device, AlgoKind::Sssp, Box::new(NativeRelaxer));
        ctx.trace = trace.as_deref_mut();
        ctx.trace_base_ps = base_ps;
        ctx.trace_shard = shard.id as u32;
        if cfg.enforce_budget {
            ctx = ctx.with_budget(device.memory_budget);
        }
        // Each shard is its own simulated device: it shares the cache's
        // host-side artifacts but pays its own build kernels (scope =
        // shard id), so multi-shard totals stay honest.
        let mut batch = QueryBatch::with_cache(
            graph.clone(),
            &shard.queries,
            cfg.strategy,
            cfg.params.clone(),
            cache.scoped(shard.id),
        )?;
        batch.init(&mut ctx)?;
        batch.run(&mut ctx, cfg.max_iterations)?;
        let dists = (0..shard.queries.len()).map(|i| batch.distances(i)).collect();
        batch.recycle(&mut ctx);
        ctx.finalize_metrics();
        let metrics = std::mem::take(&mut ctx.metrics);
        drop(ctx); // ends the borrows of `device` and the trace sink
        let busy_ps = metrics.total_cycles() * device.ps_per_cycle();
        shards.push(ShardReport {
            shard: shard.id,
            device,
            queries: shard.queries,
            metrics,
            dists,
            busy_ps,
            downtime_ps: 0,
        });
    }
    Ok(BatchReport { shards })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, rmat, RmatParams};
    use crate::graph::traversal;
    use crate::serving::query::synthetic_queries;

    #[test]
    fn partition_is_round_robin_and_stable() {
        let g = erdos_renyi(64, 256, 5, 1).unwrap();
        let qs = synthetic_queries(&g, 7, 0.5, 4);
        let shards = partition(&qs, 3);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].queries.len(), 3);
        assert_eq!(shards[1].queries.len(), 2);
        assert_eq!(shards[2].queries.len(), 2);
        assert_eq!(shards[0].queries[0].id, 0);
        assert_eq!(shards[1].queries[0].id, 1);
        assert_eq!(shards[2].queries[1].id, 5);
    }

    #[test]
    fn sharded_serving_matches_oracles() {
        let g = Arc::new(rmat(8, 2048, RmatParams::default(), 9).unwrap());
        let qs = synthetic_queries(&g, 6, 0.0, 17);
        for shards in [1, 2, 4] {
            let report = serve(&g, &qs, &ServeConfig::with_shards(shards)).unwrap();
            assert_eq!(report.query_count(), 6);
            for q in &qs {
                assert_eq!(
                    report.dist_of(q.id).unwrap(),
                    traversal::dijkstra(&g, q.source).as_slice(),
                    "query {} with {shards} shards",
                    q.id
                );
            }
        }
    }

    #[test]
    fn serve_rejects_overfull_shards() {
        let g = Arc::new(erdos_renyi(32, 64, 3, 2).unwrap());
        let qs = synthetic_queries(&g, MAX_QUERIES_PER_SHARD + 1, 1.0, 3);
        assert!(serve(&g, &qs, &ServeConfig::default()).is_err());
        // Two shards bring the per-device load back under the limit.
        let report = serve(
            &g,
            &qs,
            &ServeConfig {
                strategy: StrategyKind::BS,
                ..ServeConfig::with_shards(2)
            },
        )
        .unwrap();
        assert_eq!(report.query_count(), MAX_QUERIES_PER_SHARD + 1);
        // ...and so does raising max_batch (multi-word tags on one shard).
        let report = serve(
            &g,
            &qs,
            &ServeConfig {
                strategy: StrategyKind::BS,
                max_batch: 2 * MAX_QUERIES_PER_SHARD,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.query_count(), MAX_QUERIES_PER_SHARD + 1);
    }

    #[test]
    fn heterogeneous_shards_convert_ms_per_device() {
        let g = Arc::new(rmat(8, 2048, RmatParams::default(), 6).unwrap());
        let qs = synthetic_queries(&g, 8, 0.0, 9);
        let cfg = ServeConfig {
            devices: vec![DeviceSpec::k20c(), DeviceSpec::gtx680()],
            ..Default::default()
        };
        let report = serve(&g, &qs, &cfg).unwrap();
        // Distances still match the oracle on a mixed pool.
        for q in &qs {
            assert_eq!(
                report.dist_of(q.id).unwrap(),
                crate::graph::traversal::dijkstra(&g, q.source).as_slice(),
                "query {}",
                q.id
            );
        }
        // Per-shard ms must come from each shard's own clock: the folded
        // report equals the by-hand per-device conversion, not a single
        // shared-device conversion.
        let by_hand: f64 = report
            .shards
            .iter()
            .map(|s| s.device.cycles_to_ms(s.metrics.total_cycles()))
            .sum();
        assert!((report.total_ms() - by_hand).abs() < 1e-9);
        let shared_dev: f64 = report
            .shards
            .iter()
            .map(|s| cfg.devices[0].cycles_to_ms(s.metrics.total_cycles()))
            .sum();
        assert!(
            (by_hand - shared_dev).abs() > 1e-9,
            "distinct clocks must actually change the conversion"
        );
        assert_eq!(report.shards[1].device.name, "gtx680");
        assert!(report.wall_ms() <= report.total_ms());
    }

    #[test]
    fn warm_cache_skips_rebuilds_without_changing_distances() {
        // NS forces the split-graph build — the most expensive graph-keyed
        // artifact. A second batch sharing the cache must produce
        // bit-identical distances while paying strictly less overhead
        // (the split transform and MDT histogram kernels are skipped).
        let g = Arc::new(rmat(8, 2048, RmatParams::default(), 4).unwrap());
        let qs = synthetic_queries(&g, 4, 0.0, 5);
        let cfg = ServeConfig {
            strategy: StrategyKind::NS,
            ..Default::default()
        };
        let cache = GraphCache::new();
        let cold = serve_with_cache(&g, &qs, &cfg, &cache).unwrap();
        let warm = serve_with_cache(&g, &qs, &cfg, &cache).unwrap();
        for q in &qs {
            assert_eq!(
                cold.dist_of(q.id).unwrap(),
                warm.dist_of(q.id).unwrap(),
                "cache reuse changed query {}'s distances",
                q.id
            );
        }
        assert!(
            warm.totals().overhead_cycles < cold.totals().overhead_cycles,
            "warm batch overhead {} must undercut cold {}",
            warm.totals().overhead_cycles,
            cold.totals().overhead_cycles
        );
    }

    #[test]
    fn totals_fold_shard_metrics() {
        let g = Arc::new(erdos_renyi(128, 512, 8, 6).unwrap());
        let qs = synthetic_queries(&g, 8, 0.5, 21);
        let report = serve(&g, &qs, &ServeConfig::with_shards(2)).unwrap();
        let totals = report.totals();
        let by_hand: u64 = report
            .shards
            .iter()
            .map(|s| s.metrics.total_cycles())
            .sum();
        assert_eq!(totals.total_cycles, by_hand);
        assert!(totals.wall_cycles <= totals.total_cycles);
        assert!(totals.wall_cycles > 0);
        assert!(totals.inspector_passes > 0, "AD batches inspect");
    }
}
