//! Query descriptions and the deterministic synthetic arrival driver.
//!
//! A serving deployment answers many point queries (BFS levels / SSSP
//! distances from some source) against one long-lived graph. [`Query`] is
//! that unit of work; [`synthetic_queries`] is the load generator the
//! `serve` CLI subcommand and the benches drive the batch engine with —
//! sources drawn from the populated part of the graph, algorithms drawn
//! from a BFS/SSSP mix, everything seeded through [`crate::util::Rng`] so
//! runs reproduce exactly.

use crate::algorithms::AlgoKind;
use crate::graph::{Csr, Graph, NodeId};
use crate::util::Rng;

/// One BFS/SSSP query against the shared graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Query {
    /// Stable id assigned by the driver (reporting / result lookup).
    pub id: u32,
    /// Which propagation the query runs.
    pub algo: AlgoKind,
    /// Source node.
    pub source: NodeId,
}

/// Deterministic synthetic arrival stream: `count` queries whose sources
/// are drawn uniformly from the non-isolated nodes (real traffic starts
/// inside the populated part of the graph) and whose algorithm is BFS with
/// probability `bfs_fraction` (0.0 ⇒ all SSSP, 1.0 ⇒ all BFS).
pub fn synthetic_queries(g: &Csr, count: usize, bfs_fraction: f64, seed: u64) -> Vec<Query> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x5e21_1a6e_0b5e_55e5);
    let candidates: Vec<NodeId> = (0..g.num_nodes() as u32)
        .filter(|&u| g.degree(u) > 0)
        .collect();
    let mut out = Vec::with_capacity(count);
    for id in 0..count as u32 {
        let source = if candidates.is_empty() {
            rng.gen_range_u32(0, g.num_nodes().max(1) as u32)
        } else {
            candidates[rng.gen_index(candidates.len())]
        };
        let algo = if rng.gen_f64() < bfs_fraction {
            AlgoKind::Bfs
        } else {
            AlgoKind::Sssp
        };
        out.push(Query { id, algo, source });
    }
    out
}

/// One timed arrival of the continuous driver: a query plus the virtual
/// instant it reaches the admission queue, in **picoseconds** — the
/// integer unit the scheduler's virtual clock runs in, chosen because
/// heterogeneous shards' cycle counts are incomparable but their
/// [`crate::sim::DeviceSpec::ps_per_cycle`] steps meet on one timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    pub query: Query,
    /// Arrival instant on the scheduler's virtual clock (ps; 1 ms = 1e9).
    pub at_ps: u64,
}

/// Deterministic continuous arrival stream: the same source/algorithm mix
/// as [`synthetic_queries`] (identical seed ⇒ identical queries), plus
/// seeded exponential inter-arrival gaps with mean `mean_gap_ps` — the
/// memoryless arrival process queueing analyses assume, discretized to
/// integer picoseconds (min 1) so replays are exact on every platform.
pub fn synthetic_arrivals(
    g: &Csr,
    count: usize,
    bfs_fraction: f64,
    mean_gap_ps: u64,
    seed: u64,
) -> Vec<Arrival> {
    let queries = synthetic_queries(g, count, bfs_fraction, seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0xa221_7a1e_57a6_e000);
    let mut at_ps = 0u64;
    queries
        .into_iter()
        .map(|query| {
            // Inverse-CDF exponential draw; 1 - u keeps ln's argument > 0.
            let u = rng.gen_f64();
            let gap = (-(1.0 - u).ln() * mean_gap_ps.max(1) as f64).round() as u64;
            at_ps += gap.max(1);
            Arrival { query, at_ps }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn graph() -> Csr {
        // node 3 is isolated; sources must avoid it.
        Csr::from_edges(
            4,
            &[Edge::new(0, 1, 1), Edge::new(1, 2, 1), Edge::new(2, 0, 1)],
        )
        .unwrap()
    }

    #[test]
    fn arrivals_are_deterministic() {
        let g = graph();
        let a = synthetic_queries(&g, 16, 0.5, 42);
        let b = synthetic_queries(&g, 16, 0.5, 42);
        assert_eq!(a, b);
        let c = synthetic_queries(&g, 16, 0.5, 43);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn sources_avoid_isolated_nodes() {
        let g = graph();
        for q in synthetic_queries(&g, 64, 0.5, 7) {
            assert_ne!(q.source, 3, "query {} sourced at an isolated node", q.id);
        }
    }

    #[test]
    fn bfs_fraction_extremes() {
        let g = graph();
        assert!(synthetic_queries(&g, 32, 0.0, 1)
            .iter()
            .all(|q| q.algo == AlgoKind::Sssp));
        assert!(synthetic_queries(&g, 32, 1.0, 1)
            .iter()
            .all(|q| q.algo == AlgoKind::Bfs));
    }

    #[test]
    fn ids_are_sequential() {
        let g = graph();
        let qs = synthetic_queries(&g, 5, 0.5, 9);
        assert_eq!(qs.iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn arrivals_are_deterministic_monotonic_and_share_the_query_stream() {
        let g = graph();
        let a = synthetic_arrivals(&g, 32, 0.5, 1_000_000, 42);
        let b = synthetic_arrivals(&g, 32, 0.5, 1_000_000, 42);
        assert_eq!(a, b, "same seed must replay exactly");
        let queries = synthetic_queries(&g, 32, 0.5, 42);
        assert_eq!(
            a.iter().map(|x| x.query).collect::<Vec<_>>(),
            queries,
            "the timed stream carries the same queries as the untimed driver"
        );
        for w in a.windows(2) {
            assert!(w[0].at_ps < w[1].at_ps, "gaps are at least 1 ps");
        }
        let c = synthetic_arrivals(&g, 32, 0.5, 2_000_000, 42);
        let mean_a = a.last().unwrap().at_ps / 32;
        let mean_c = c.last().unwrap().at_ps / 32;
        assert!(mean_c > mean_a, "a larger mean gap must stretch the stream");
    }
}
