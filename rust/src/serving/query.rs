//! Query descriptions and the deterministic synthetic arrival driver.
//!
//! A serving deployment answers many point queries (BFS levels / SSSP
//! distances from some source) against one long-lived graph. [`Query`] is
//! that unit of work; [`synthetic_queries`] is the load generator the
//! `serve` CLI subcommand and the benches drive the batch engine with —
//! sources drawn from the populated part of the graph, algorithms drawn
//! from a BFS/SSSP mix, everything seeded through [`crate::util::Rng`] so
//! runs reproduce exactly.

use crate::algorithms::AlgoKind;
use crate::graph::{Csr, Graph, NodeId};
use crate::util::Rng;

/// One BFS/SSSP query against the shared graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Query {
    /// Stable id assigned by the driver (reporting / result lookup).
    pub id: u32,
    /// Which propagation the query runs.
    pub algo: AlgoKind,
    /// Source node.
    pub source: NodeId,
}

/// Deterministic synthetic arrival stream: `count` queries whose sources
/// are drawn uniformly from the non-isolated nodes (real traffic starts
/// inside the populated part of the graph) and whose algorithm is BFS with
/// probability `bfs_fraction` (0.0 ⇒ all SSSP, 1.0 ⇒ all BFS).
pub fn synthetic_queries(g: &Csr, count: usize, bfs_fraction: f64, seed: u64) -> Vec<Query> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x5e21_1a6e_0b5e_55e5);
    let candidates: Vec<NodeId> = (0..g.num_nodes() as u32)
        .filter(|&u| g.degree(u) > 0)
        .collect();
    let mut out = Vec::with_capacity(count);
    for id in 0..count as u32 {
        let source = if candidates.is_empty() {
            rng.gen_range_u32(0, g.num_nodes().max(1) as u32)
        } else {
            candidates[rng.gen_index(candidates.len())]
        };
        let algo = if rng.gen_f64() < bfs_fraction {
            AlgoKind::Bfs
        } else {
            AlgoKind::Sssp
        };
        out.push(Query { id, algo, source });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn graph() -> Csr {
        // node 3 is isolated; sources must avoid it.
        Csr::from_edges(
            4,
            &[Edge::new(0, 1, 1), Edge::new(1, 2, 1), Edge::new(2, 0, 1)],
        )
        .unwrap()
    }

    #[test]
    fn arrivals_are_deterministic() {
        let g = graph();
        let a = synthetic_queries(&g, 16, 0.5, 42);
        let b = synthetic_queries(&g, 16, 0.5, 42);
        assert_eq!(a, b);
        let c = synthetic_queries(&g, 16, 0.5, 43);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn sources_avoid_isolated_nodes() {
        let g = graph();
        for q in synthetic_queries(&g, 64, 0.5, 7) {
            assert_ne!(q.source, 3, "query {} sourced at an isolated node", q.id);
        }
    }

    #[test]
    fn bfs_fraction_extremes() {
        let g = graph();
        assert!(synthetic_queries(&g, 32, 0.0, 1)
            .iter()
            .all(|q| q.algo == AlgoKind::Sssp));
        assert!(synthetic_queries(&g, 32, 1.0, 1)
            .iter()
            .all(|q| q.algo == AlgoKind::Bfs));
    }

    #[test]
    fn ids_are_sequential() {
        let g = graph();
        let qs = synthetic_queries(&g, 5, 0.5, 9);
        assert_eq!(qs.iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }
}
