//! The bitmask-tagged merged worklist shared by every query of a batch.
//!
//! A batch of up to [`MAX_QUERIES_PER_SHARD`] concurrent queries keeps one
//! *merged* frontier: the union of the per-query node frontiers, each entry
//! tagged with a `u64` bitmask saying which queries hold that node active.
//! The point is amortization — the [`crate::adaptive::FrontierInspector`]
//! pass and the AD policy decision read the merged degree array once per
//! batch iteration instead of once per query per iteration.
//!
//! Like the single-query representations ([`crate::adaptive::migrate`]),
//! the merged list converts losslessly to an exploded per-edge form and
//! back: tags ride along unchanged, and the only drop on a round-trip is
//! zero-out-degree nodes (which the edge form cannot carry and whose
//! processing is a no-op) — the same documented exception as the
//! single-query `nodes → edges → nodes` path.

use crate::graph::{Csr, NodeId};
use crate::worklist::NodeWorklist;
use std::collections::BTreeMap;

/// Maximum queries one shard's batch can carry: the tag is a `u64` bitmask,
/// one bit per query slot.
pub const MAX_QUERIES_PER_SHARD: usize = 64;

/// Union of per-query node frontiers with a per-node query bitmask, sorted
/// by node id (deterministic regardless of per-query discovery order).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MergedWorklist {
    nodes: Vec<NodeId>,
    degrees: Vec<u32>,
    masks: Vec<u64>,
    /// Running Σ degrees, maintained while the list is built so the
    /// per-batch-iteration inspection pass gets its edge total in O(1)
    /// (mirrors [`NodeWorklist::total_edges`]).
    edge_sum: u64,
}

/// Reusable build scratch for [`MergedWorklist`]: `(node, tag)` pairs
/// accumulated per iteration, sorted in place and OR-folded into the
/// output. Once warm, rebuilding the merged list allocates nothing — the
/// serving engine's per-iteration path ([`crate::serving::batch`]) keeps
/// one builder for the life of the batch.
#[derive(Debug, Default)]
pub struct MergedBuilder {
    pairs: Vec<(NodeId, u64)>,
}

impl MergedBuilder {
    /// Empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a new merge (clears the pair scratch, keeps its capacity).
    pub fn begin(&mut self) {
        self.pairs.clear();
    }

    /// Add one query's frontier under `slot`'s tag bit. Slots must be
    /// below [`MAX_QUERIES_PER_SHARD`].
    pub fn add(&mut self, slot: usize, wl: &NodeWorklist) {
        assert!(
            slot < MAX_QUERIES_PER_SHARD,
            "query slot {slot} exceeds the {MAX_QUERIES_PER_SHARD}-wide tag mask"
        );
        let bit = 1u64 << slot;
        for &n in wl.nodes() {
            self.pairs.push((n, bit));
        }
    }

    /// Sort, OR-fold and write the merged list into `out` (cleared first,
    /// capacity retained). Degrees are re-read from `g` so stale cached
    /// degrees cannot diverge between queries. The in-place unstable sort
    /// on `Copy` pairs allocates nothing, and a sorted fold produces
    /// exactly the node-id order the `BTreeMap`-based builder used to.
    pub fn finish_into(&mut self, g: &Csr, out: &mut MergedWorklist) {
        self.pairs.sort_unstable_by_key(|p| p.0);
        out.nodes.clear();
        out.degrees.clear();
        out.masks.clear();
        out.edge_sum = 0;
        for &(n, bit) in &self.pairs {
            if out.nodes.last() == Some(&n) {
                *out.masks.last_mut().expect("parallel to nodes") |= bit;
            } else {
                let d = g.degree(n);
                out.nodes.push(n);
                out.degrees.push(d);
                out.masks.push(bit);
                out.edge_sum += d as u64;
            }
        }
    }
}

impl MergedWorklist {
    /// Build from `(query slot, frontier)` pairs — the allocating
    /// convenience wrapper around [`MergedBuilder`].
    pub fn from_frontiers(g: &Csr, frontiers: &[(usize, &NodeWorklist)]) -> Self {
        let mut b = MergedBuilder::new();
        b.begin();
        for &(slot, wl) in frontiers {
            b.add(slot, wl);
        }
        let mut out = MergedWorklist::default();
        b.finish_into(g, &mut out);
        out
    }

    /// The pre-arena reference implementation: a fresh `BTreeMap` per
    /// merge (one heap node per distinct frontier node). Kept in-tree as
    /// the baseline `benches/hotpath.rs` measures [`MergedBuilder`]
    /// against and as a differential oracle for it (the builder must
    /// reproduce this output bit for bit).
    pub fn from_frontiers_btree(g: &Csr, frontiers: &[(usize, &NodeWorklist)]) -> Self {
        let mut by_node: BTreeMap<NodeId, u64> = BTreeMap::new();
        for &(slot, wl) in frontiers {
            assert!(
                slot < MAX_QUERIES_PER_SHARD,
                "query slot {slot} exceeds the {MAX_QUERIES_PER_SHARD}-wide tag mask"
            );
            let bit = 1u64 << slot;
            for &n in wl.nodes() {
                *by_node.entry(n).or_insert(0) |= bit;
            }
        }
        let mut out = MergedWorklist::default();
        for (n, mask) in by_node {
            let d = g.degree(n);
            out.nodes.push(n);
            out.degrees.push(d);
            out.masks.push(mask);
            out.edge_sum += d as u64;
        }
        out
    }

    /// Distinct active nodes (union over queries).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when every query's frontier is empty.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Active node ids (sorted).
    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    /// Out-degrees parallel to [`nodes`] — the array one inspector pass
    /// reads for the whole batch.
    ///
    /// [`nodes`]: MergedWorklist::nodes
    pub fn degrees(&self) -> &[u32] {
        &self.degrees
    }

    /// Query bitmasks parallel to [`nodes`].
    ///
    /// [`nodes`]: MergedWorklist::nodes
    pub fn masks(&self) -> &[u64] {
        &self.masks
    }

    /// Total edges across the merged frontier (cached Σ degrees — O(1),
    /// consumed by the batch engine's shared inspection pass).
    pub fn total_edges(&self) -> u64 {
        self.edge_sum
    }

    /// Simulated device bytes: node id (4 B) + degree (4 B) + tag (8 B).
    pub fn memory_bytes(&self) -> u64 {
        16 * self.nodes.len() as u64
    }

    /// Extract one query's frontier (nodes whose tag carries `slot`'s bit),
    /// in merged (node-id) order, into caller-provided scratch (cleared
    /// first, capacity retained).
    pub fn query_frontier_into(&self, slot: usize, out: &mut NodeWorklist) {
        let bit = 1u64 << slot;
        out.clear();
        for i in 0..self.nodes.len() {
            if self.masks[i] & bit != 0 {
                out.push(self.nodes[i], self.degrees[i]);
            }
        }
    }

    /// Allocating convenience wrapper around
    /// [`MergedWorklist::query_frontier_into`].
    pub fn query_frontier(&self, slot: usize) -> NodeWorklist {
        let mut wl = NodeWorklist::new();
        self.query_frontier_into(slot, &mut wl);
        wl
    }

    /// Explode into the per-edge form (EP space): every outgoing edge of
    /// every merged node, tag duplicated per edge.
    pub fn to_edges(&self, g: &Csr) -> MergedEdgeFrontier {
        let mut out = MergedEdgeFrontier::default();
        for i in 0..self.nodes.len() {
            let n = self.nodes[i];
            let first = g.first_edge(n);
            for e in first..first + g.degree(n) {
                out.srcs.push(n);
                out.eids.push(e);
                out.masks.push(self.masks[i]);
            }
        }
        out
    }
}

/// The merged frontier exploded to edge granularity, tags preserved.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MergedEdgeFrontier {
    srcs: Vec<NodeId>,
    eids: Vec<u32>,
    masks: Vec<u64>,
}

impl MergedEdgeFrontier {
    /// Pending edges (duplicated per query only through the tag, never as
    /// separate entries).
    pub fn len(&self) -> usize {
        self.eids.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.eids.is_empty()
    }

    /// Source endpoints.
    pub fn srcs(&self) -> &[NodeId] {
        &self.srcs
    }

    /// Global CSR edge ids.
    pub fn eids(&self) -> &[u32] {
        &self.eids
    }

    /// Query bitmasks parallel to the edges.
    pub fn masks(&self) -> &[u64] {
        &self.masks
    }

    /// Collapse back to the merged node form: distinct sources with their
    /// tags OR-folded. Exact inverse of [`MergedWorklist::to_edges`] up to
    /// zero-out-degree nodes (which contribute no edges).
    pub fn to_nodes(&self, g: &Csr) -> MergedWorklist {
        let mut by_node: BTreeMap<NodeId, u64> = BTreeMap::new();
        for i in 0..self.srcs.len() {
            *by_node.entry(self.srcs[i]).or_insert(0) |= self.masks[i];
        }
        let mut out = MergedWorklist::default();
        for (n, mask) in by_node {
            let d = g.degree(n);
            out.nodes.push(n);
            out.degrees.push(d);
            out.masks.push(mask);
            out.edge_sum += d as u64;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn hub() -> Csr {
        // 0 fans out to 1..=3; 4 is isolated (degree 0); 1 -> 2.
        Csr::from_edges(
            5,
            &[
                Edge::new(0, 1, 1),
                Edge::new(0, 2, 1),
                Edge::new(0, 3, 1),
                Edge::new(1, 2, 1),
            ],
        )
        .unwrap()
    }

    fn wl(g: &Csr, nodes: &[NodeId]) -> NodeWorklist {
        let mut w = NodeWorklist::new();
        for &n in nodes {
            w.push(n, g.degree(n));
        }
        w
    }

    #[test]
    fn union_with_or_folded_tags() {
        let g = hub();
        let a = wl(&g, &[0, 1]);
        let b = wl(&g, &[1, 4]);
        let m = MergedWorklist::from_frontiers(&g, &[(0, &a), (3, &b)]);
        assert_eq!(m.nodes(), &[0, 1, 4]);
        assert_eq!(m.masks(), &[1, 1 | (1 << 3), 1 << 3]);
        assert_eq!(m.degrees(), &[3, 1, 0]);
        assert_eq!(m.memory_bytes(), 48);
    }

    #[test]
    fn query_frontier_recovers_each_query() {
        let g = hub();
        let a = wl(&g, &[0, 1]);
        let b = wl(&g, &[1, 4]);
        let m = MergedWorklist::from_frontiers(&g, &[(0, &a), (3, &b)]);
        assert_eq!(m.query_frontier(0).nodes(), &[0, 1]);
        assert_eq!(m.query_frontier(3).nodes(), &[1, 4]);
        assert!(m.query_frontier(5).is_empty());
    }

    #[test]
    fn edge_roundtrip_preserves_tags_modulo_zero_degree() {
        let g = hub();
        let a = wl(&g, &[0, 4]);
        let b = wl(&g, &[1]);
        let m = MergedWorklist::from_frontiers(&g, &[(1, &a), (2, &b)]);
        let e = m.to_edges(&g);
        assert_eq!(e.len(), 4, "3 hub edges + 1 from node 1");
        assert_eq!(e.masks()[0], 1 << 1);
        let back = e.to_nodes(&g);
        // node 4 (degree 0) vanishes; tags of the survivors are intact.
        assert_eq!(back.nodes(), &[0, 1]);
        assert_eq!(back.masks(), &[1 << 1, 1 << 2]);
    }

    #[test]
    fn builder_reuse_matches_from_frontiers() {
        let g = hub();
        let a = wl(&g, &[1, 0]); // deliberately unsorted input order
        let b = wl(&g, &[1, 4]);
        let oracle = MergedWorklist::from_frontiers_btree(&g, &[(0, &a), (3, &b)]);
        assert_eq!(
            oracle,
            MergedWorklist::from_frontiers(&g, &[(0, &a), (3, &b)]),
            "sort-based builder must reproduce the BTreeMap reference"
        );
        let mut builder = MergedBuilder::new();
        let mut out = MergedWorklist::default();
        let mut view = NodeWorklist::new();
        for _ in 0..3 {
            builder.begin();
            builder.add(0, &a);
            builder.add(3, &b);
            builder.finish_into(&g, &mut out);
            assert_eq!(out, oracle, "warm rebuilds must be bit-identical");
            out.query_frontier_into(3, &mut view);
            assert_eq!(view.nodes(), &[1, 4]);
        }
    }

    #[test]
    #[should_panic(expected = "tag mask")]
    fn slot_out_of_range_panics() {
        let g = hub();
        let a = wl(&g, &[0]);
        MergedWorklist::from_frontiers(&g, &[(64, &a)]);
    }
}
