//! The bitmask-tagged merged worklist shared by every query of a batch.
//!
//! A batch of concurrent queries keeps one *merged* frontier: the union of
//! the per-query node frontiers, each entry tagged with a bitmask saying
//! which queries hold that node active. The point is amortization — the
//! [`crate::adaptive::FrontierInspector`] pass and the AD policy decision
//! read the merged degree array once per batch iteration instead of once
//! per query per iteration.
//!
//! The tag is **multi-word**: node `i`'s mask occupies `stride` consecutive
//! `u64` words of one flat array (`words[i*stride .. (i+1)*stride]`), where
//! `stride = ceil(capacity / 64)`. A batch of ≤ 64 queries keeps the
//! original single-word layout (`stride == 1`); larger batches grow one
//! word per 64 slots, so [`MAX_QUERIES_PER_SHARD`] is a *default* capacity
//! (the `max_batch` config knob raises it), not a structural limit — the
//! hard ceiling is [`MAX_SUPPORTED_QUERIES_PER_SHARD`].
//!
//! Like the single-query representations ([`crate::adaptive::migrate`]),
//! the merged list converts losslessly to an exploded per-edge form and
//! back: tags ride along unchanged, and the only drop on a round-trip is
//! zero-out-degree nodes (which the edge form cannot carry and whose
//! processing is a no-op) — the same documented exception as the
//! single-query `nodes → edges → nodes` path.

use crate::graph::{Csr, NodeId};
use crate::worklist::NodeWorklist;
use std::collections::BTreeMap;

/// Default queries per shard batch: one `u64` tag word. The serving
/// scheduler's `max_batch` knob raises it (one extra mask word per 64
/// slots) up to [`MAX_SUPPORTED_QUERIES_PER_SHARD`].
pub const MAX_QUERIES_PER_SHARD: usize = 64;

/// Hard ceiling on per-shard batch capacity — 64 mask words. A backstop
/// against pathological configs, far above any simulated device's worth of
/// concurrent traversals.
pub const MAX_SUPPORTED_QUERIES_PER_SHARD: usize = 4096;

/// Mask words needed to tag `capacity` query slots.
pub fn mask_words_for(capacity: usize) -> usize {
    capacity.div_ceil(64).max(1)
}

#[inline]
fn word_bit(slot: usize) -> (usize, u64) {
    (slot / 64, 1u64 << (slot % 64))
}

/// Union of per-query node frontiers with a per-node query bitmask, sorted
/// by node id (deterministic regardless of per-query discovery order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MergedWorklist {
    nodes: Vec<NodeId>,
    degrees: Vec<u32>,
    /// Stride-`stride` flat tag words: node `i`'s mask is
    /// `words[i*stride .. (i+1)*stride]`.
    words: Vec<u64>,
    stride: usize,
    /// Running Σ degrees, maintained while the list is built so the
    /// per-batch-iteration inspection pass gets its edge total in O(1)
    /// (mirrors [`NodeWorklist::total_edges`]).
    edge_sum: u64,
}

impl Default for MergedWorklist {
    fn default() -> Self {
        MergedWorklist {
            nodes: Vec::new(),
            degrees: Vec::new(),
            words: Vec::new(),
            stride: 1,
            edge_sum: 0,
        }
    }
}

/// Reusable build scratch for [`MergedWorklist`]: `(node, slot)` pairs
/// accumulated per iteration, sorted in place and OR-folded into the
/// output. Once warm, rebuilding the merged list allocates nothing — the
/// serving engine's per-iteration path ([`crate::serving::batch`]) keeps
/// one builder for the life of the batch.
#[derive(Debug)]
pub struct MergedBuilder {
    pairs: Vec<(NodeId, u32)>,
    capacity: usize,
}

impl Default for MergedBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl MergedBuilder {
    /// Empty builder at the default 64-slot capacity.
    pub fn new() -> Self {
        MergedBuilder {
            pairs: Vec::new(),
            capacity: MAX_QUERIES_PER_SHARD,
        }
    }

    /// Start a new merge (clears the pair scratch, keeps its capacity and
    /// the current slot capacity).
    pub fn begin(&mut self) {
        self.pairs.clear();
    }

    /// Start a new merge that may carry up to `capacity` query slots —
    /// the tag stride becomes `ceil(capacity / 64)` words.
    pub fn begin_with_capacity(&mut self, capacity: usize) {
        assert!(
            capacity <= MAX_SUPPORTED_QUERIES_PER_SHARD,
            "batch capacity {capacity} exceeds the supported \
             {MAX_SUPPORTED_QUERIES_PER_SHARD}-query ceiling"
        );
        self.capacity = capacity.max(1);
        self.pairs.clear();
    }

    /// Add one query's frontier under `slot`'s tag bit. Slots must be
    /// below the capacity set by [`MergedBuilder::begin_with_capacity`]
    /// (default [`MAX_QUERIES_PER_SHARD`]).
    pub fn add(&mut self, slot: usize, wl: &NodeWorklist) {
        assert!(
            slot < self.capacity,
            "query slot {slot} exceeds the {}-wide tag mask",
            self.capacity
        );
        let slot = slot as u32;
        for &n in wl.nodes() {
            self.pairs.push((n, slot));
        }
    }

    /// Sort, OR-fold and write the merged list into `out` (cleared first,
    /// capacity retained). Degrees are re-read from `g` so stale cached
    /// degrees cannot diverge between queries. The in-place unstable sort
    /// on `Copy` pairs allocates nothing, and a sorted fold produces
    /// exactly the node-id order the `BTreeMap`-based builder used to.
    pub fn finish_into(&mut self, g: &Csr, out: &mut MergedWorklist) {
        self.pairs.sort_unstable();
        let stride = mask_words_for(self.capacity);
        out.nodes.clear();
        out.degrees.clear();
        out.words.clear();
        out.stride = stride;
        out.edge_sum = 0;
        for &(n, slot) in &self.pairs {
            if out.nodes.last() != Some(&n) {
                let d = g.degree(n);
                out.nodes.push(n);
                out.degrees.push(d);
                out.words.resize(out.words.len() + stride, 0);
                out.edge_sum += d as u64;
            }
            let (w, b) = word_bit(slot as usize);
            let base = out.words.len() - stride;
            out.words[base + w] |= b;
        }
    }
}

impl MergedWorklist {
    /// Build from `(query slot, frontier)` pairs at the default 64-slot
    /// capacity — the allocating convenience wrapper around
    /// [`MergedBuilder`].
    pub fn from_frontiers(g: &Csr, frontiers: &[(usize, &NodeWorklist)]) -> Self {
        Self::from_frontiers_with_capacity(g, frontiers, MAX_QUERIES_PER_SHARD)
    }

    /// [`MergedWorklist::from_frontiers`] with an explicit slot capacity
    /// (multi-word tags when `capacity > 64`).
    pub fn from_frontiers_with_capacity(
        g: &Csr,
        frontiers: &[(usize, &NodeWorklist)],
        capacity: usize,
    ) -> Self {
        let mut b = MergedBuilder::new();
        b.begin_with_capacity(capacity);
        for &(slot, wl) in frontiers {
            b.add(slot, wl);
        }
        let mut out = MergedWorklist::default();
        b.finish_into(g, &mut out);
        out
    }

    /// The pre-arena reference implementation: a fresh `BTreeMap` per
    /// merge (one heap node per distinct frontier node). Kept in-tree as
    /// the baseline `benches/hotpath.rs` measures [`MergedBuilder`]
    /// against and as a differential oracle for it (the builder must
    /// reproduce this output bit for bit).
    pub fn from_frontiers_btree(g: &Csr, frontiers: &[(usize, &NodeWorklist)]) -> Self {
        Self::from_frontiers_btree_with_capacity(g, frontiers, MAX_QUERIES_PER_SHARD)
    }

    /// [`MergedWorklist::from_frontiers_btree`] with an explicit slot
    /// capacity — the multi-word differential oracle.
    pub fn from_frontiers_btree_with_capacity(
        g: &Csr,
        frontiers: &[(usize, &NodeWorklist)],
        capacity: usize,
    ) -> Self {
        let stride = mask_words_for(capacity);
        let mut by_node: BTreeMap<NodeId, Vec<u64>> = BTreeMap::new();
        for &(slot, wl) in frontiers {
            assert!(
                slot < capacity,
                "query slot {slot} exceeds the {capacity}-wide tag mask"
            );
            let (w, b) = word_bit(slot);
            for &n in wl.nodes() {
                by_node.entry(n).or_insert_with(|| vec![0; stride])[w] |= b;
            }
        }
        let mut out = MergedWorklist {
            stride,
            ..Default::default()
        };
        for (n, mask) in by_node {
            let d = g.degree(n);
            out.nodes.push(n);
            out.degrees.push(d);
            out.words.extend_from_slice(&mask);
            out.edge_sum += d as u64;
        }
        out
    }

    /// Distinct active nodes (union over queries).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when every query's frontier is empty.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Active node ids (sorted).
    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    /// Out-degrees parallel to [`nodes`] — the array one inspector pass
    /// reads for the whole batch.
    ///
    /// [`nodes`]: MergedWorklist::nodes
    pub fn degrees(&self) -> &[u32] {
        &self.degrees
    }

    /// Tag words per node (`ceil(capacity / 64)`).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Node `i`'s tag mask (`stride` words, bit `s % 64` of word `s / 64`
    /// set ⇔ query slot `s` holds the node active).
    pub fn mask_words(&self, i: usize) -> &[u64] {
        &self.words[i * self.stride..(i + 1) * self.stride]
    }

    /// True when node `i`'s tag carries query `slot`'s bit.
    #[inline]
    pub fn has_slot(&self, i: usize, slot: usize) -> bool {
        let (w, b) = word_bit(slot);
        w < self.stride && self.words[i * self.stride + w] & b != 0
    }

    /// Total edges across the merged frontier (cached Σ degrees — O(1),
    /// consumed by the batch engine's shared inspection pass).
    pub fn total_edges(&self) -> u64 {
        self.edge_sum
    }

    /// Simulated device bytes: node id (4 B) + degree (4 B) + tag words
    /// (8 B × stride).
    pub fn memory_bytes(&self) -> u64 {
        (8 + 8 * self.stride as u64) * self.nodes.len() as u64
    }

    /// Extract one query's frontier (nodes whose tag carries `slot`'s bit),
    /// in merged (node-id) order, into caller-provided scratch (cleared
    /// first, capacity retained).
    pub fn query_frontier_into(&self, slot: usize, out: &mut NodeWorklist) {
        let (w, b) = word_bit(slot);
        out.clear();
        if w >= self.stride {
            return;
        }
        for i in 0..self.nodes.len() {
            if self.words[i * self.stride + w] & b != 0 {
                out.push(self.nodes[i], self.degrees[i]);
            }
        }
    }

    /// Allocating convenience wrapper around
    /// [`MergedWorklist::query_frontier_into`].
    pub fn query_frontier(&self, slot: usize) -> NodeWorklist {
        let mut wl = NodeWorklist::new();
        self.query_frontier_into(slot, &mut wl);
        wl
    }

    /// Explode into the per-edge form (EP space): every outgoing edge of
    /// every merged node, tag duplicated per edge.
    pub fn to_edges(&self, g: &Csr) -> MergedEdgeFrontier {
        let mut out = MergedEdgeFrontier {
            stride: self.stride,
            ..Default::default()
        };
        for i in 0..self.nodes.len() {
            let n = self.nodes[i];
            let first = g.first_edge(n);
            for e in first..first + g.degree(n) {
                out.srcs.push(n);
                out.eids.push(e);
                out.words.extend_from_slice(self.mask_words(i));
            }
        }
        out
    }
}

/// The merged frontier exploded to edge granularity, tags preserved
/// (stride-`stride` words per edge, same layout as [`MergedWorklist`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MergedEdgeFrontier {
    srcs: Vec<NodeId>,
    eids: Vec<u32>,
    words: Vec<u64>,
    stride: usize,
}

impl Default for MergedEdgeFrontier {
    fn default() -> Self {
        MergedEdgeFrontier {
            srcs: Vec::new(),
            eids: Vec::new(),
            words: Vec::new(),
            stride: 1,
        }
    }
}

impl MergedEdgeFrontier {
    /// Pending edges (duplicated per query only through the tag, never as
    /// separate entries).
    pub fn len(&self) -> usize {
        self.eids.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.eids.is_empty()
    }

    /// Source endpoints.
    pub fn srcs(&self) -> &[NodeId] {
        &self.srcs
    }

    /// Global CSR edge ids.
    pub fn eids(&self) -> &[u32] {
        &self.eids
    }

    /// Tag words per edge.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Edge `i`'s tag mask (`stride` words).
    pub fn mask_words(&self, i: usize) -> &[u64] {
        &self.words[i * self.stride..(i + 1) * self.stride]
    }

    /// Collapse back to the merged node form: distinct sources with their
    /// tags OR-folded. Exact inverse of [`MergedWorklist::to_edges`] up to
    /// zero-out-degree nodes (which contribute no edges).
    pub fn to_nodes(&self, g: &Csr) -> MergedWorklist {
        let stride = self.stride;
        let mut by_node: BTreeMap<NodeId, Vec<u64>> = BTreeMap::new();
        for i in 0..self.srcs.len() {
            let mask = by_node
                .entry(self.srcs[i])
                .or_insert_with(|| vec![0; stride]);
            for (w, &word) in mask.iter_mut().zip(self.mask_words(i)) {
                *w |= word;
            }
        }
        let mut out = MergedWorklist {
            stride,
            ..Default::default()
        };
        for (n, mask) in by_node {
            let d = g.degree(n);
            out.nodes.push(n);
            out.degrees.push(d);
            out.words.extend_from_slice(&mask);
            out.edge_sum += d as u64;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn hub() -> Csr {
        // 0 fans out to 1..=3; 4 is isolated (degree 0); 1 -> 2.
        Csr::from_edges(
            5,
            &[
                Edge::new(0, 1, 1),
                Edge::new(0, 2, 1),
                Edge::new(0, 3, 1),
                Edge::new(1, 2, 1),
            ],
        )
        .unwrap()
    }

    fn wl(g: &Csr, nodes: &[NodeId]) -> NodeWorklist {
        let mut w = NodeWorklist::new();
        for &n in nodes {
            w.push(n, g.degree(n));
        }
        w
    }

    #[test]
    fn union_with_or_folded_tags() {
        let g = hub();
        let a = wl(&g, &[0, 1]);
        let b = wl(&g, &[1, 4]);
        let m = MergedWorklist::from_frontiers(&g, &[(0, &a), (3, &b)]);
        assert_eq!(m.nodes(), &[0, 1, 4]);
        assert_eq!(m.stride(), 1);
        assert_eq!(m.mask_words(0), &[1]);
        assert_eq!(m.mask_words(1), &[1 | (1 << 3)]);
        assert_eq!(m.mask_words(2), &[1 << 3]);
        assert_eq!(m.degrees(), &[3, 1, 0]);
        assert_eq!(m.memory_bytes(), 48);
    }

    #[test]
    fn query_frontier_recovers_each_query() {
        let g = hub();
        let a = wl(&g, &[0, 1]);
        let b = wl(&g, &[1, 4]);
        let m = MergedWorklist::from_frontiers(&g, &[(0, &a), (3, &b)]);
        assert_eq!(m.query_frontier(0).nodes(), &[0, 1]);
        assert_eq!(m.query_frontier(3).nodes(), &[1, 4]);
        assert!(m.query_frontier(5).is_empty());
    }

    #[test]
    fn edge_roundtrip_preserves_tags_modulo_zero_degree() {
        let g = hub();
        let a = wl(&g, &[0, 4]);
        let b = wl(&g, &[1]);
        let m = MergedWorklist::from_frontiers(&g, &[(1, &a), (2, &b)]);
        let e = m.to_edges(&g);
        assert_eq!(e.len(), 4, "3 hub edges + 1 from node 1");
        assert_eq!(e.mask_words(0), &[1 << 1]);
        let back = e.to_nodes(&g);
        // node 4 (degree 0) vanishes; tags of the survivors are intact.
        assert_eq!(back.nodes(), &[0, 1]);
        assert_eq!(back.mask_words(0), &[1 << 1]);
        assert_eq!(back.mask_words(1), &[1 << 2]);
    }

    #[test]
    fn builder_reuse_matches_from_frontiers() {
        let g = hub();
        let a = wl(&g, &[1, 0]); // deliberately unsorted input order
        let b = wl(&g, &[1, 4]);
        let oracle = MergedWorklist::from_frontiers_btree(&g, &[(0, &a), (3, &b)]);
        assert_eq!(
            oracle,
            MergedWorklist::from_frontiers(&g, &[(0, &a), (3, &b)]),
            "sort-based builder must reproduce the BTreeMap reference"
        );
        let mut builder = MergedBuilder::new();
        let mut out = MergedWorklist::default();
        let mut view = NodeWorklist::new();
        for _ in 0..3 {
            builder.begin();
            builder.add(0, &a);
            builder.add(3, &b);
            builder.finish_into(&g, &mut out);
            assert_eq!(out, oracle, "warm rebuilds must be bit-identical");
            out.query_frontier_into(3, &mut view);
            assert_eq!(view.nodes(), &[1, 4]);
        }
    }

    #[test]
    fn multiword_slots_set_the_right_word() {
        let g = hub();
        let a = wl(&g, &[0]);
        let b = wl(&g, &[0, 1]);
        // Slots 3, 64 and 150 force a 3-word stride (capacity 150 → 192).
        let m =
            MergedWorklist::from_frontiers_with_capacity(&g, &[(3, &a), (64, &b), (150, &b)], 151);
        assert_eq!(m.stride(), 3);
        assert_eq!(m.nodes(), &[0, 1]);
        assert_eq!(m.mask_words(0), &[1 << 3, 1, 1 << (150 - 128)]);
        assert_eq!(m.mask_words(1), &[0, 1, 1 << (150 - 128)]);
        assert!(m.has_slot(0, 3) && m.has_slot(0, 64) && m.has_slot(0, 150));
        assert!(!m.has_slot(1, 3) && m.has_slot(1, 64));
        assert_eq!(m.memory_bytes(), 2 * (8 + 24));
        assert_eq!(m.query_frontier(64).nodes(), &[0, 1]);
        assert_eq!(m.query_frontier(3).nodes(), &[0]);
        // Out-of-stride probes are simply absent, never a panic.
        assert!(m.query_frontier(200).is_empty());
    }

    #[test]
    fn multiword_builder_matches_btree_oracle() {
        let g = hub();
        let a = wl(&g, &[1, 0]);
        let b = wl(&g, &[1, 4]);
        let pairs: [(usize, &NodeWorklist); 3] = [(0, &a), (70, &b), (129, &a)];
        let oracle = MergedWorklist::from_frontiers_btree_with_capacity(&g, &pairs, 130);
        let mut builder = MergedBuilder::new();
        let mut out = MergedWorklist::default();
        for _ in 0..3 {
            builder.begin_with_capacity(130);
            for &(slot, f) in &pairs {
                builder.add(slot, f);
            }
            builder.finish_into(&g, &mut out);
            assert_eq!(out, oracle, "multi-word warm rebuilds must match the oracle");
        }
        // The multi-word edge round-trip keeps every word.
        let back = oracle.to_edges(&g).to_nodes(&g);
        for i in 0..back.len() {
            let n = back.nodes()[i];
            let j = oracle.nodes().iter().position(|&x| x == n).unwrap();
            assert_eq!(back.mask_words(i), oracle.mask_words(j), "node {n}");
        }
    }

    #[test]
    #[should_panic(expected = "tag mask")]
    fn slot_out_of_range_panics() {
        let g = hub();
        let a = wl(&g, &[0]);
        MergedWorklist::from_frontiers(&g, &[(64, &a)]);
    }

    #[test]
    #[should_panic(expected = "ceiling")]
    fn capacity_over_hard_ceiling_panics() {
        let mut b = MergedBuilder::new();
        b.begin_with_capacity(MAX_SUPPORTED_QUERIES_PER_SHARD + 1);
    }
}
