//! Batched multi-query serving on a shared CSR.
//!
//! The paper evaluates its load-balancing strategies one traversal at a
//! time, but a serving system answers *many* concurrent BFS/SSSP queries
//! against the same long-lived graph — exactly where per-query frontier
//! inspection becomes redundant overhead. Jatala et al. (arXiv:1911.09135)
//! show adaptive strategy selection pays off when its inspection cost is
//! amortized; Osama et al. (arXiv:2301.04792) show load-balancing schedules
//! compose cleanly once decoupled from the per-query work definition. This
//! module batches queries behind one shared inspection/policy step:
//!
//! * [`query`] — the [`Query`] unit of work plus the deterministic
//!   synthetic arrival driver behind the `serve` CLI subcommand.
//! * [`merged`] — the bitmask-tagged [`MergedWorklist`]: the union of the
//!   per-query frontiers, a multi-word bitmask per node saying which
//!   queries hold it active (one `u64` word per 64 query slots); converts
//!   to/from edge granularity with tags preserved.
//! * [`batch`] — the [`QueryBatch`] engine: per batch iteration, **one**
//!   [`crate::adaptive::FrontierInspector`] pass and **one** AD policy
//!   decision cover every query; per-query execution then runs in the
//!   chosen strategy's kernel style against per-query `dist` arrays, with
//!   the graph-shaped structures (MDT histogram, EP's COO, NS's split
//!   graph) built once and shared. The differential oracle
//!   [`batch::replay_single`] is baked in: any batched run can replay its
//!   queries one-by-one through the single-query engine and assert
//!   distance-array equality (`rust/tests/serving_parity.rs` does, across
//!   all strategies and shard counts).
//! * [`shard`] — the [`DeviceShard`] layer: round-robin partitioning of
//!   queries across simulated devices (heterogeneous `DeviceSpec`s
//!   allowed, one per shard), one [`QueryBatch`] per shard, and the
//!   permutation-invariant [`AggregateMetrics`] fold into a
//!   [`BatchReport`] whose ms figures are converted on each shard's own
//!   device clock.
//! * [`queue`] + [`scheduler`] — the admission-controlled serving path:
//!   a bounded FIFO [`AdmissionQueue`] with an explicit
//!   [`OverflowPolicy`] (`drop` / `block`), fed by the continuous
//!   [`synthetic_arrivals`] driver, drained by the deterministic
//!   virtual-clock [`Scheduler`] that places queries least-loaded-first
//!   over the device pool and forms batches as capacity frees.
//!
//! Both serving paths accept an optional [`crate::telemetry::TraceSink`]
//! (`serve_traced` / `serve_stream_traced`): when attached, every
//! admission, placement, batch launch, shard-busy interval, and AD
//! strategy decision is recorded on the virtual ps clock without
//! allocating in steady state.
//!
//! The `figserve` figure ([`crate::figures::fig_serving`]) and
//! `benches/serving.rs` compare batched-AD against N independent
//! single-query AD runs: same distances, a fraction of the inspector
//! passes and policy decisions.

pub mod batch;
pub mod faults;
pub mod merged;
pub mod query;
pub mod queue;
pub mod scheduler;
pub mod shard;

pub use batch::{replay_single, QueryBatch};
pub use faults::{FaultEvent, FaultKind, FaultPlan};
pub use merged::{
    mask_words_for, MergedBuilder, MergedEdgeFrontier, MergedWorklist, MAX_QUERIES_PER_SHARD,
    MAX_SUPPORTED_QUERIES_PER_SHARD,
};
pub use query::{synthetic_arrivals, synthetic_queries, Arrival, Query};
pub use queue::{AdmissionQueue, OverflowPolicy, QueueEntry};
pub use scheduler::{
    serve_stream, serve_stream_traced, QueryOutcome, ScheduleReport, Scheduler, SchedulerConfig,
};
pub use shard::{
    aggregate, partition, serve, serve_traced, serve_with_cache, AggregateMetrics, BatchReport,
    DeviceShard, ServeConfig, ShardReport,
};
