//! The bounded FIFO admission queue in front of the serving scheduler.
//!
//! Arrivals that outpace capacity have to go *somewhere*: either the queue
//! absorbs them (up to `cap`), or the [`OverflowPolicy`] decides — `drop`
//! sheds the query (counted, excluded from results), `block` back-pressures
//! the arrival until space frees. Every admission-control decision is
//! counted here so the scheduler's conservation law
//! (`arrived == admitted + dropped`) is checkable from the outside —
//! `rust/tests/strategy_properties.rs` pins it across seeds.

use std::collections::VecDeque;

use super::query::Query;

/// What happens to an arrival when the admission queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverflowPolicy {
    /// Shed the query: it is counted in `dropped`, never served, and
    /// excluded from result comparison (the default — serving systems
    /// prefer bounded latency over lossless admission).
    #[default]
    Drop,
    /// Back-pressure the client: the arrival stalls until the queue has
    /// room, then enters in arrival order. Nothing is lost; the stall is
    /// part of the query's measured wait.
    Block,
}

impl OverflowPolicy {
    /// Parse the `queue_policy` config key / `--queue-policy` flag.
    pub fn parse(s: &str) -> crate::error::Result<Self> {
        match s {
            "drop" => Ok(OverflowPolicy::Drop),
            "block" => Ok(OverflowPolicy::Block),
            other => Err(crate::error::Error::Config(format!(
                "unknown queue policy {other:?} (expected drop | block)"
            ))),
        }
    }

    /// Label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            OverflowPolicy::Drop => "drop",
            OverflowPolicy::Block => "block",
        }
    }
}

/// Bounded FIFO of admitted-but-unplaced queries, with the admission
/// counters the scheduler reports. Each entry remembers its arrival
/// instant (virtual-clock ps) so wait time is measured from arrival, not
/// from admission. Shed queries are NOT counted here — the scheduler
/// keeps the dropped queries themselves (its `dropped` vec is the single
/// source of truth), so there is no second counter to drift out of sync.
#[derive(Debug)]
pub struct AdmissionQueue {
    items: VecDeque<(Query, u64)>,
    cap: usize,
    /// Queries that entered the queue (admission events).
    pub admitted: u64,
    /// Deepest the queue ever got.
    pub peak: u64,
}

impl AdmissionQueue {
    /// Empty queue holding at most `cap` queries (`cap ≥ 1`); backing
    /// storage is pre-allocated so steady-state admission never grows it.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        AdmissionQueue {
            items: VecDeque::with_capacity(cap),
            cap,
            admitted: 0,
            peak: 0,
        }
    }

    /// Capacity bound.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Queries currently queued.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// True when another admission would overflow.
    pub fn is_full(&self) -> bool {
        self.items.len() >= self.cap
    }

    /// Admit `query` (arrived at `at_ps`) if there is room; returns
    /// whether it entered. A `false` means the caller's overflow policy
    /// decides — shed the query (the scheduler records it) or hold the
    /// arrival back for a blocked retry.
    pub fn try_admit(&mut self, query: Query, at_ps: u64) -> bool {
        if self.is_full() {
            return false;
        }
        self.items.push_back((query, at_ps));
        self.admitted += 1;
        self.peak = self.peak.max(self.items.len() as u64);
        true
    }

    /// Pop the oldest admitted query (FIFO — admission order is placement
    /// order, a property `strategy_properties.rs` pins).
    pub fn pop(&mut self) -> Option<(Query, u64)> {
        self.items.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgoKind;

    fn q(id: u32) -> Query {
        Query {
            id,
            algo: AlgoKind::Bfs,
            source: 0,
        }
    }

    #[test]
    fn fifo_order_and_counters() {
        let mut aq = AdmissionQueue::new(2);
        assert!(aq.try_admit(q(0), 10));
        assert!(aq.try_admit(q(1), 20));
        assert!(aq.is_full());
        assert!(!aq.try_admit(q(2), 30), "over-cap admission must fail");
        assert_eq!((aq.admitted, aq.peak), (2, 2));
        assert_eq!(aq.pop().unwrap().0.id, 0, "FIFO");
        assert!(aq.try_admit(q(3), 40), "space frees after a pop");
        assert_eq!(aq.pop().unwrap().0.id, 1);
        assert_eq!(aq.pop().unwrap().0.id, 3);
        assert!(aq.pop().is_none());
        assert_eq!(aq.peak, 2, "peak is sticky");
    }

    #[test]
    fn zero_cap_is_clamped_to_one() {
        let mut aq = AdmissionQueue::new(0);
        assert_eq!(aq.cap(), 1);
        assert!(aq.try_admit(q(0), 0));
        assert!(!aq.try_admit(q(1), 0));
    }

    #[test]
    fn policy_parses() {
        assert_eq!(OverflowPolicy::parse("drop").unwrap(), OverflowPolicy::Drop);
        assert_eq!(
            OverflowPolicy::parse("block").unwrap(),
            OverflowPolicy::Block
        );
        assert!(OverflowPolicy::parse("spill").is_err());
        assert_eq!(OverflowPolicy::default().label(), "drop");
    }
}
