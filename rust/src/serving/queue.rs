//! The bounded FIFO admission queue in front of the serving scheduler.
//!
//! Arrivals that outpace capacity have to go *somewhere*: either the queue
//! absorbs them (up to `cap`), or the [`OverflowPolicy`] decides — `drop`
//! sheds the query (counted, excluded from results), `block` back-pressures
//! the arrival until space frees. Every admission-control decision is
//! counted here so the scheduler's conservation law
//! (`arrived == admitted + dropped`) is checkable from the outside —
//! `rust/tests/strategy_properties.rs` pins it across seeds.

use std::collections::VecDeque;

use super::query::Query;

/// What happens to an arrival when the admission queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverflowPolicy {
    /// Shed the query: it is counted in `dropped`, never served, and
    /// excluded from result comparison (the default — serving systems
    /// prefer bounded latency over lossless admission).
    #[default]
    Drop,
    /// Back-pressure the client: the arrival stalls until the queue has
    /// room, then enters in arrival order. Nothing is lost; the stall is
    /// part of the query's measured wait.
    Block,
}

impl OverflowPolicy {
    /// Parse the `queue_policy` config key / `--queue-policy` flag.
    pub fn parse(s: &str) -> crate::error::Result<Self> {
        match s {
            "drop" => Ok(OverflowPolicy::Drop),
            "block" => Ok(OverflowPolicy::Block),
            other => Err(crate::error::Error::Config(format!(
                "unknown queue policy {other:?} (expected drop | block)"
            ))),
        }
    }

    /// Label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            OverflowPolicy::Drop => "drop",
            OverflowPolicy::Block => "block",
        }
    }
}

/// One queued query: the query itself, its original arrival instant
/// (virtual-clock ps — wait time is measured from arrival, not admission
/// or requeue), and how many serving attempts have already failed (0 for
/// a fresh arrival; recovery requeues carry their retry count through).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueEntry {
    /// The queued query.
    pub query: Query,
    /// Original arrival instant (ps).
    pub arrived_ps: u64,
    /// Failed serving attempts so far (0 = never launched).
    pub attempts: u32,
}

/// Bounded FIFO of admitted-but-unplaced queries, with the admission
/// counters the scheduler reports. Shed queries are NOT counted here —
/// the scheduler keeps the dropped queries themselves (its `dropped` vec
/// is the single source of truth), so there is no second counter to drift
/// out of sync.
///
/// Recovery requeues ([`AdmissionQueue::requeue`]) enter at the *front*:
/// a retried query arrived before anything currently queued, so it keeps
/// its FIFO seniority over fresh arrivals. They bump `requeued`, never
/// `admitted` — `admitted` stays first-admissions-only so the fault-free
/// conservation law `arrived == admitted + dropped` is undisturbed.
#[derive(Debug)]
pub struct AdmissionQueue {
    items: VecDeque<QueueEntry>,
    cap: usize,
    /// Queries that entered the queue for the first time (admission
    /// events).
    pub admitted: u64,
    /// Re-entries of previously admitted queries after a failed attempt.
    pub requeued: u64,
    /// Deepest the queue ever got (requeues count toward depth too).
    pub peak: u64,
}

impl AdmissionQueue {
    /// Empty queue holding at most `cap` queries (`cap ≥ 1`); backing
    /// storage is pre-allocated so steady-state admission never grows it.
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        AdmissionQueue {
            items: VecDeque::with_capacity(cap),
            cap,
            admitted: 0,
            requeued: 0,
            peak: 0,
        }
    }

    /// Capacity bound.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Queries currently queued.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// True when another admission would overflow.
    pub fn is_full(&self) -> bool {
        self.items.len() >= self.cap
    }

    /// Admit `query` (arrived at `at_ps`) if there is room; returns
    /// whether it entered. A `false` means the caller's overflow policy
    /// decides — shed the query (the scheduler records it) or hold the
    /// arrival back for a blocked retry.
    pub fn try_admit(&mut self, query: Query, at_ps: u64) -> bool {
        if self.is_full() {
            return false;
        }
        self.items.push_back(QueueEntry {
            query,
            arrived_ps: at_ps,
            attempts: 0,
        });
        self.admitted += 1;
        self.peak = self.peak.max(self.items.len() as u64);
        true
    }

    /// Return a previously admitted query to the *front* of the queue for
    /// another serving attempt (it predates everything queued, so it keeps
    /// FIFO seniority). Counted in `requeued`, not `admitted`; still
    /// bounded by `cap`. Returns whether it entered.
    pub fn requeue(&mut self, query: Query, arrived_ps: u64, attempts: u32) -> bool {
        if self.is_full() {
            return false;
        }
        self.items.push_front(QueueEntry {
            query,
            arrived_ps,
            attempts,
        });
        self.requeued += 1;
        self.peak = self.peak.max(self.items.len() as u64);
        true
    }

    /// Look at the oldest queued entry without removing it (the scheduler
    /// uses this to shed deadline-expired queries before placement).
    pub fn peek(&self) -> Option<&QueueEntry> {
        self.items.front()
    }

    /// Pop the oldest admitted query (FIFO — admission order is placement
    /// order, a property `strategy_properties.rs` pins).
    pub fn pop(&mut self) -> Option<QueueEntry> {
        self.items.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgoKind;

    fn q(id: u32) -> Query {
        Query {
            id,
            algo: AlgoKind::Bfs,
            source: 0,
        }
    }

    #[test]
    fn fifo_order_and_counters() {
        let mut aq = AdmissionQueue::new(2);
        assert!(aq.try_admit(q(0), 10));
        assert!(aq.try_admit(q(1), 20));
        assert!(aq.is_full());
        assert!(!aq.try_admit(q(2), 30), "over-cap admission must fail");
        assert_eq!((aq.admitted, aq.peak), (2, 2));
        assert_eq!(aq.pop().unwrap().query.id, 0, "FIFO");
        assert!(aq.try_admit(q(3), 40), "space frees after a pop");
        assert_eq!(aq.pop().unwrap().query.id, 1);
        assert_eq!(aq.pop().unwrap().query.id, 3);
        assert!(aq.pop().is_none());
        assert_eq!(aq.peak, 2, "peak is sticky");
    }

    #[test]
    fn zero_cap_is_clamped_to_one() {
        let mut aq = AdmissionQueue::new(0);
        assert_eq!(aq.cap(), 1);
        assert!(aq.try_admit(q(0), 0));
        assert!(!aq.try_admit(q(1), 0));
        assert!(!aq.requeue(q(1), 0, 1), "requeue respects the cap too");
        assert_eq!(aq.pop().unwrap().query.id, 0);
        assert!(aq.requeue(q(0), 0, 1), "requeue fits once space frees");
        assert_eq!((aq.admitted, aq.requeued), (1, 1));
    }

    /// Cap 1 is the degenerate Block regime: exactly one query fits, so
    /// every further arrival must be refused for the caller's overflow
    /// policy to hold back — admission strictly alternates with pops.
    #[test]
    fn cap_one_alternates_admit_and_pop() {
        let mut aq = AdmissionQueue::new(1);
        for round in 0u32..3 {
            assert!(aq.try_admit(q(round), u64::from(round)));
            assert!(aq.is_full());
            assert!(!aq.try_admit(q(100 + round), u64::from(round)));
            assert_eq!(aq.pop().unwrap().query.id, round);
            assert!(aq.is_empty());
        }
        assert_eq!((aq.admitted, aq.peak), (3, 1));
    }

    /// A requeued query re-enters at the *front*: it arrived before
    /// anything currently queued, so it beats fresh arrivals admitted at
    /// the same instant — and its original arrival stamp and attempt
    /// count ride along.
    #[test]
    fn requeue_enters_at_front_ahead_of_same_instant_arrivals() {
        let mut aq = AdmissionQueue::new(4);
        assert!(aq.try_admit(q(7), 50));
        assert!(aq.requeue(q(3), 10, 2), "old query back after a failure");
        assert!(aq.try_admit(q(8), 50), "fresh arrival at the same instant");
        let first = aq.pop().unwrap();
        assert_eq!(
            (first.query.id, first.arrived_ps, first.attempts),
            (3, 10, 2),
            "requeued query keeps seniority, stamp and attempt count"
        );
        assert_eq!(aq.pop().unwrap().query.id, 7);
        assert_eq!(aq.pop().unwrap().query.id, 8);
        assert_eq!((aq.admitted, aq.requeued), (2, 1));
    }

    /// `peak` tracks true depth: requeues deepen the queue exactly like
    /// admissions do.
    #[test]
    fn queue_peak_counts_requeued_depth() {
        let mut aq = AdmissionQueue::new(4);
        assert!(aq.try_admit(q(0), 0));
        assert!(aq.try_admit(q(1), 1));
        assert_eq!(aq.peak, 2);
        assert!(aq.requeue(q(9), 0, 1));
        assert_eq!(aq.peak, 3, "requeue pushed depth past the admit-only peak");
        aq.pop();
        aq.pop();
        assert!(aq.requeue(q(10), 0, 1));
        assert_eq!(aq.peak, 3, "peak is sticky across drains");
        assert_eq!(aq.len(), 2);
    }

    #[test]
    fn policy_parses() {
        assert_eq!(OverflowPolicy::parse("drop").unwrap(), OverflowPolicy::Drop);
        assert_eq!(
            OverflowPolicy::parse("block").unwrap(),
            OverflowPolicy::Block
        );
        assert!(OverflowPolicy::parse("spill").is_err());
        assert_eq!(OverflowPolicy::default().label(), "drop");
    }
}
