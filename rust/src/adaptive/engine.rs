//! The adaptive engine: a [`Strategy`] that re-selects the load-balancing
//! scheme every outer iteration.
//!
//! Per iteration the engine (1) builds a canonical original-graph node view
//! of the pending worklist, (2) inspects it ([`FrontierInspector`]),
//! (3) asks its [`Policy`] which static strategy should run — restricted to
//! the memory-feasible candidates — (4) migrates the worklist to that
//! strategy's representation when it changed ([`super::migrate`]), and
//! (5) executes one iteration in that strategy's exact kernel style
//! (assignments, access patterns, auxiliary kernels and memory charges all
//! mirror the static implementations). Every decision is recorded into
//! [`crate::metrics::RunMetrics::decisions`].
//!
//! Memory accounting differs from running a static strategy in one honest
//! way: the engine keeps the CSR resident at all times (every mode may need
//! it next iteration), charges EP's COO only while the edge representation
//! is live, and keeps NS's split graph resident once built (rebuilding per
//! switch would be slower on a real device, and the policy only chooses NS
//! when the headroom allows it).

use crate::coordinator::exec::flatten_frontier_into;
use crate::coordinator::{Assignment, ExecCtx, KernelWork, PushTarget};
use crate::error::Result;
use crate::graph::{Csr, Graph, NodeId};
use crate::metrics::DecisionRecord;
use crate::sim::AccessPattern;
use crate::strategies::common::{charge_graph_and_dist, init_dist, NodeFrontier};
use crate::strategies::mdt::{auto_mdt, MdtDecision};
use crate::strategies::node_split::{split_graph, SplitGraph};
use crate::strategies::schedule::{composed_step, step_scratch_bytes, Realm};
use crate::strategies::workload_decomp::block_offsets_into;
use crate::strategies::{Schedule, Strategy, StrategyKind, StrategyParams};
use crate::telemetry::TraceEventKind;
use crate::worklist::hierarchy::SubList;
use crate::worklist::{EdgeWorklist, NodeWorklist};
use std::sync::Arc;

use super::inspect::{FrontierInspector, FrontierSnapshot};
use super::migrate::{self, Space};
use super::policy::{build_policy, requires_migration, Feasibility, Policy, PolicyInput};

// Device-memory labels of the adaptive engine's allocations.
const AD_WL: &str = "ad-wl";
const AD_NS_WL: &str = "ad-ns-wl";
const AD_EP_WL: &str = "ad-ep-wl";
const AD_COO: &str = "ad-coo";
const AD_NS_CSR: &str = "ad-ns-csr";
const AD_NS_MAP: &str = "ad-ns-map";
const AD_WD_PREFIX: &str = "ad-wd-prefix";
const AD_WD_OFFSETS: &str = "ad-wd-offsets";
const AD_HP_PREFIX: &str = "ad-hp-prefix";
const AD_HP_SUBLIST: &str = "ad-hp-sublist";

/// Flat host-side cycles charged per decision (the frontier statistics ride
/// along with the worklist's cached degree array and are folded into the
/// previous kernel's epilogue, so inspection needs no extra device kernel —
/// cf. arXiv:1911.09135).
pub(crate) const INSPECT_BASE_CYCLES: u64 = 100;

/// The worklist representation currently held by the engine.
enum Repr {
    /// Original-graph node frontier (BS / WD / HP modes).
    Nodes(NodeFrontier),
    /// EP's exploded edge frontier plus its charged bytes.
    Edges { wl: EdgeWorklist, charged: u64 },
    /// Split-graph node frontier (NS mode).
    Split(NodeFrontier),
}

/// Lazily-built node-splitting state.
struct SplitState {
    split: SplitGraph,
    parent_of: Vec<NodeId>,
}

/// Worklist entry bytes per node-space mode: WD carries (node, degree)
/// pairs (§III-A), BS/HP carry bare node ids.
fn node_entry_bytes(kind: StrategyKind) -> u64 {
    if kind == StrategyKind::WD {
        8
    } else {
        4
    }
}

/// The adaptive per-iteration strategy selector (`StrategyKind::AD`).
pub struct Adaptive {
    graph: Arc<Csr>,
    params: StrategyParams,
    policy: Box<dyn Policy>,
    /// The static strategy the engine is currently shaped as.
    mode: StrategyKind,
    repr: Option<Repr>,
    split: Option<SplitState>,
    mdt: Option<MdtDecision>,
    coo_charged: bool,
    /// Persistent canonical-view scratch (original node space), rebuilt in
    /// place every iteration so the inspection path allocates nothing once
    /// warm.
    view: NodeWorklist,
    /// Dedup bitmap scratch for the edge→node / split→node view rebuilds.
    view_seen: Vec<u64>,
    /// EP's double-buffer spare (the raw output worklist is built here and
    /// swapped in, retaining capacity across iterations).
    ep_spare: EdgeWorklist,
    /// HP's persistent sub-list, rebuilt in place each outer iteration.
    sub: SubList,
    /// HP-mode sub-iteration kernels launched.
    pub hp_sub_iterations: u64,
    /// HP-mode switches to the WD fallback.
    pub hp_wd_switches: u64,
}

impl Adaptive {
    /// New adaptive engine over `graph`, with the policy selected by
    /// `params.adaptive_policy`.
    pub fn new(graph: Arc<Csr>, params: StrategyParams) -> Self {
        let policy = build_policy(params.adaptive_policy);
        Adaptive {
            graph,
            params,
            policy,
            mode: StrategyKind::BS,
            repr: None,
            split: None,
            mdt: None,
            coo_charged: false,
            view: NodeWorklist::new(),
            view_seen: Vec::new(),
            ep_spare: EdgeWorklist::new(),
            sub: SubList::default(),
            hp_sub_iterations: 0,
            hp_wd_switches: 0,
        }
    }

    /// The static strategy the engine is currently executing as.
    pub fn current_mode(&self) -> StrategyKind {
        self.mode
    }

    /// Rebuild the canonical original-space node view of the pending
    /// worklist into the persistent `view` scratch (capacity retained, so
    /// a warm iteration's inspection path performs no heap allocation).
    fn refresh_view(&mut self, g: &Csr) {
        match self.repr.as_ref().expect("init first") {
            Repr::Nodes(f) => self.view.copy_from(f.worklist()),
            Repr::Edges { wl, .. } => {
                migrate::edges_to_nodes_into(g, wl, &mut self.view_seen, &mut self.view)
            }
            Repr::Split(f) => {
                let st = self.split.as_ref().expect("split state exists in NS mode");
                migrate::split_to_nodes_into(
                    g,
                    &st.parent_of,
                    f.worklist(),
                    &mut self.view_seen,
                    &mut self.view,
                );
            }
        }
    }

    /// Memory feasibility of each candidate under the remaining budget,
    /// using worst-case per-iteration allocation bounds.
    fn feasibility(&self, ctx: &ExecCtx, snap: &FrontierSnapshot) -> Feasibility {
        let headroom = ctx.mem.budget().saturating_sub(ctx.mem.current());
        let e = self.graph.num_edges() as u64;
        let n = self.graph.num_nodes() as u64;
        let w = snap.edges;
        let t = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads) as u64;
        let coo_resident = self.coo_charged;
        let split_built = self.split.is_some();
        // EP: COO (unless resident) + input edge worklist + worst-case raw
        // output (bounded by E after condensing).
        let coo_extra = if coo_resident { 0 } else { 12 * e };
        let ep = coo_extra + 8 * w + 8 * e <= headroom;
        // WD: 8 B worklist entries (input + raw output double buffer) +
        // prefix sums + the per-thread offsets array.
        let wd = 12 * snap.nodes + 8 * w + 8 * t <= headroom;
        // NS: the split CSR + parent map + extended dist (once), plus the
        // frontier duplicated into split space.
        let mdt = self.mdt.map(|d| d.mdt.max(1)).unwrap_or(1) as u64;
        let ns_extra = if split_built {
            4 * w
        } else {
            self.graph.memory_bytes() + 8 * n + 4 * (e / mdt + 1) + 4 * w
        };
        let ns = ns_extra <= headroom;
        // Composed schedules keep the 4 B node frontier BS already holds;
        // their extra cost is the per-step transient scratch, bounded by
        // the merge-path orders (prefix sums + dense candidate slots).
        let composed =
            step_scratch_bytes(Schedule::WARP_MERGE_PATH, snap.nodes, w) <= headroom;
        Feasibility {
            ep,
            wd,
            ns,
            coo_resident,
            split_built,
            composed,
        }
    }

    /// Build the split graph (once) for NS mode.
    fn ensure_split(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        if self.split.is_some() {
            return Ok(());
        }
        let decision = self.mdt.expect("init first");
        let n = self.graph.num_nodes();
        let split = split_graph(&self.graph, decision);
        // Unlike standalone NS, the original CSR stays resident: the other
        // modes read it. Only the split CSR and the parent map are added.
        ctx.mem.charge(AD_NS_CSR, split.graph.memory_bytes())?;
        ctx.mem.charge(AD_NS_MAP, 8 * n as u64)?;
        ctx.charge_aux_kernel(self.graph.num_edges() as u64 + n as u64, 2);
        let n_split = split.graph.num_nodes();
        if n_split > n {
            ctx.mem.charge("dist", 4 * (n_split - n) as u64)?;
            ctx.dist.resize(n_split, crate::INF);
        }
        let parent_of = migrate::parent_of_table(&split, n);
        self.split = Some(SplitState { split, parent_of });
        Ok(())
    }

    /// Switch to `to`, converting the worklist representation when the two
    /// strategies disagree on it.
    fn migrate_to(
        &mut self,
        ctx: &mut ExecCtx,
        to: StrategyKind,
        view: &NodeWorklist,
    ) -> Result<()> {
        if !requires_migration(self.mode, to) {
            self.mode = to;
            return Ok(());
        }
        // One conversion kernel over the frontier.
        ctx.charge_aux_kernel(view.len() as u64 + 1, 2);

        // Tear down the old representation's storage.
        match self.repr.take().expect("init first") {
            Repr::Nodes(mut f) | Repr::Split(mut f) => f.release(ctx),
            Repr::Edges { charged, .. } => {
                ctx.mem.release(AD_EP_WL, charged);
                if self.coo_charged {
                    ctx.mem.release(AD_COO, 12 * self.graph.num_edges() as u64);
                    self.coo_charged = false;
                }
            }
        }

        // Build the new one from the canonical node view.
        let repr = match migrate::space_of(to) {
            Space::Node => Repr::Nodes(NodeFrontier::from_worklist(
                ctx,
                &self.graph,
                view.clone(),
                AD_WL,
                node_entry_bytes(to),
            )?),
            Space::Edge => {
                if !self.coo_charged {
                    // Materialize the COO form (the allocation that makes
                    // EP infeasible on Graph500-class graphs, §II-B).
                    ctx.mem.charge(AD_COO, 12 * self.graph.num_edges() as u64)?;
                    ctx.charge_aux_kernel(self.graph.num_edges() as u64, 1);
                    self.coo_charged = true;
                }
                let wl = migrate::nodes_to_edges(&self.graph, view);
                let charged = wl.memory_bytes();
                ctx.mem.charge(AD_EP_WL, charged)?;
                Repr::Edges { wl, charged }
            }
            Space::Split => {
                self.ensure_split(ctx)?;
                let st = self.split.as_ref().expect("just built");
                // Refresh the clones' attributes from their parents so the
                // mirror invariant holds when entering split space.
                let mut children = 0u64;
                for u in 0..self.graph.num_nodes() as u32 {
                    let du = ctx.dist[u as usize];
                    for c in st.split.map.children(u) {
                        ctx.dist[c as usize] = du;
                        children += 1;
                    }
                }
                if children > 0 {
                    ctx.charge_aux_kernel(children, 1);
                }
                let wl = migrate::nodes_to_split(&st.split, view);
                Repr::Split(NodeFrontier::from_worklist(
                    ctx,
                    &st.split.graph,
                    wl,
                    AD_NS_WL,
                    4,
                )?)
            }
        };
        self.repr = Some(repr);
        self.mode = to;
        Ok(())
    }

    /// One BS-style iteration (mirrors [`crate::strategies::NodeBaseline`]).
    fn step_bs(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let mut offsets = ctx.scratch.take_u32();
        {
            let frontier = match self.repr.as_ref() {
                Some(Repr::Nodes(f)) => f,
                _ => unreachable!("BS mode runs on the node representation"),
            };
            let wl = frontier.worklist();
            flatten_frontier_into(&g, wl.nodes(), &mut src, &mut eid);
            offsets.push(0u32);
            let mut acc = 0u32;
            for &d in wl.degrees() {
                acc += d;
                offsets.push(acc);
            }
        }
        let work = KernelWork {
            name: "ad_bs_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&g, &work, None)?;
        let frontier = match self.repr.as_mut() {
            Some(Repr::Nodes(f)) => f,
            _ => unreachable!("BS mode runs on the node representation"),
        };
        frontier.advance(ctx, &g, &result.updated)?;
        ctx.recycle(result);
        ctx.recycle_work(work);
        Ok(())
    }

    /// One WD-style iteration (mirrors
    /// [`crate::strategies::WorkloadDecomposition`]).
    fn step_wd(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let max_threads = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads);
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let wl_len = {
            let frontier = match self.repr.as_ref() {
                Some(Repr::Nodes(f)) => f,
                _ => unreachable!("WD mode runs on the node representation"),
            };
            let wl = frontier.worklist();
            flatten_frontier_into(&g, wl.nodes(), &mut src, &mut eid);
            wl.len() as u64
        };
        let total = src.len();

        // Scan of the worklist's degree array (transient prefix sums).
        ctx.mem.charge(AD_WD_PREFIX, 4 * wl_len)?;
        ctx.charge_aux_kernel(wl_len, 1);
        // find_offsets: per-thread binary search over the prefix sums.
        let threads = (max_threads as usize).min(total.max(1)) as u64;
        let log_wl = (64 - wl_len.leading_zeros() as u64).max(1);
        ctx.charge_aux_kernel(threads, 4 * log_wl);
        // Transient per-thread offsets array.
        let offsets_bytes = 8 * max_threads as u64;
        ctx.mem.charge(AD_WD_OFFSETS, offsets_bytes)?;

        let mut offsets = ctx.scratch.take_u32();
        block_offsets_into(total, max_threads, &mut offsets);
        let work = KernelWork {
            name: "ad_wd_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 4,
            push: PushTarget::Node,
        };
        let result = ctx.launch(&g, &work, None)?;
        ctx.mem.release(AD_WD_OFFSETS, offsets_bytes);
        ctx.mem.release(AD_WD_PREFIX, 4 * wl_len);
        let frontier = match self.repr.as_mut() {
            Some(Repr::Nodes(f)) => f,
            _ => unreachable!("WD mode runs on the node representation"),
        };
        frontier.advance(ctx, &g, &result.updated)?;
        ctx.recycle(result);
        ctx.recycle_work(work);
        Ok(())
    }

    /// One EP-style iteration (mirrors [`crate::strategies::EdgeParallel`]).
    fn step_ep(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let max_threads = self
            .params
            .max_threads
            .unwrap_or(ctx.dev.max_resident_threads);
        // Stage the input worklist into pooled kernel buffers.
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let total = {
            let wl = match self.repr.as_ref() {
                Some(Repr::Edges { wl, .. }) => wl,
                _ => unreachable!("EP mode runs on the edge representation"),
            };
            src.extend_from_slice(wl.srcs());
            eid.extend_from_slice(wl.edges());
            wl.len()
        };
        let threads = (max_threads as usize).min(total).max(1) as u32;
        let work = KernelWork {
            name: "ad_ep_relax",
            src,
            eid,
            assignment: Assignment::Strided {
                num_threads: threads,
            },
            access: AccessPattern::Coalesced,
            extra_cycles_per_edge: 0,
            push: PushTarget::Edges,
        };
        let result = ctx.launch(&g, &work, None)?;
        ctx.recycle_work(work);

        // Build the next edge worklist into the spare half of the double
        // buffer (capacity retained across iterations).
        self.ep_spare.clear();
        for &n in &result.updated {
            self.ep_spare.push_node_edges(&g, n);
        }
        ctx.recycle(result);
        let raw_entries = self.ep_spare.len() as u64;
        ctx.metrics.peak_worklist_entries =
            ctx.metrics.peak_worklist_entries.max(raw_entries);
        let raw_bytes = self.ep_spare.memory_bytes();
        let headroom = ctx.mem.budget().saturating_sub(ctx.mem.current());
        let charged = match self.repr.as_ref() {
            Some(Repr::Edges { charged, .. }) => *charged,
            _ => unreachable!("EP mode runs on the edge representation"),
        };
        if raw_bytes > headroom {
            // Memory pressure: condense in place (streaming, chunk-wise)
            // before materializing the raw buffer — the feasibility check
            // that admitted EP only guarantees the *condensed* worklist
            // (≤ E entries) fits, so the duplicate-laden raw form must
            // never be charged whole. Static EP would OOM here; the
            // adaptive engine's contract is to stay inside the budget.
            let removed = self.ep_spare.condense();
            ctx.metrics.condensed_away += removed as u64;
            ctx.charge_aux_kernel(raw_entries, 2);
            ctx.mem.charge(AD_EP_WL, self.ep_spare.memory_bytes())?;
            ctx.mem.release(AD_EP_WL, charged);
        } else {
            // Plenty of room: mirror static EP exactly (double buffer the
            // raw output, condense only on the size-explosion rule).
            ctx.mem.charge(AD_EP_WL, raw_bytes)?;
            if self.ep_spare.len() > g.num_edges() {
                let removed = self.ep_spare.condense();
                ctx.metrics.condensed_away += removed as u64;
                ctx.charge_aux_kernel(raw_entries, 2);
            }
            let keep = self.ep_spare.memory_bytes();
            ctx.mem.release(AD_EP_WL, charged + raw_bytes - keep);
        }
        match self.repr.as_mut() {
            Some(Repr::Edges { wl, charged }) => {
                *charged = self.ep_spare.memory_bytes();
                std::mem::swap(wl, &mut self.ep_spare);
            }
            _ => unreachable!("EP mode runs on the edge representation"),
        }
        Ok(())
    }

    /// One NS-style iteration (mirrors [`crate::strategies::NodeSplitting`]).
    fn step_ns(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let mut src = ctx.scratch.take_u32();
        let mut eid = ctx.scratch.take_u32();
        let mut offsets = ctx.scratch.take_u32();
        let (st, frontier) = match (&self.split, &mut self.repr) {
            (Some(st), Some(Repr::Split(f))) => (st, f),
            _ => unreachable!("NS mode runs on the split representation"),
        };
        let g = &st.split.graph;
        {
            let wl = frontier.worklist();
            flatten_frontier_into(g, wl.nodes(), &mut src, &mut eid);
            offsets.push(0u32);
            let mut acc = 0u32;
            for &d in wl.degrees() {
                acc += d;
                offsets.push(acc);
            }
        }
        let work = KernelWork {
            name: "ad_ns_relax",
            src,
            eid,
            assignment: Assignment::Blocked(offsets),
            access: AccessPattern::Scattered,
            extra_cycles_per_edge: 0,
            push: PushTarget::Node,
        };
        let result = ctx.launch(g, &work, Some(&st.split.map))?;
        frontier.advance(ctx, g, &result.updated)?;
        ctx.recycle(result);
        ctx.recycle_work(work);
        Ok(())
    }

    /// One composed-schedule iteration (mirrors
    /// [`crate::strategies::ComposedStrategy`]): the shared
    /// [`composed_step`] lowering over the node frontier, with adaptive
    /// kernel labels.
    fn step_composed(&mut self, ctx: &mut ExecCtx, schedule: Schedule) -> Result<()> {
        let g = self.graph.clone();
        let result = {
            let frontier = match self.repr.as_ref() {
                Some(Repr::Nodes(f)) => f,
                _ => unreachable!("composed modes run on the node representation"),
            };
            composed_step(ctx, &g, frontier.worklist(), schedule, Realm::Adaptive)?
        };
        let frontier = match self.repr.as_mut() {
            Some(Repr::Nodes(f)) => f,
            _ => unreachable!("composed modes run on the node representation"),
        };
        frontier.advance(ctx, &g, &result.updated)?;
        ctx.recycle(result);
        Ok(())
    }

    /// One HP-style iteration (mirrors [`crate::strategies::Hierarchical`]).
    fn step_hp(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        let mdt = self.mdt.expect("init first").mdt.max(1);
        let block = ctx.dev.block_size as usize;
        let frontier_len = match self.repr.as_ref() {
            Some(Repr::Nodes(f)) => f.len(),
            _ => unreachable!("HP mode runs on the node representation"),
        };
        let mut all_updates: Vec<NodeId> = ctx.scratch.take_u32();

        if frontier_len < block {
            // Small super list → straight to workload decomposition.
            let mut src = ctx.scratch.take_u32();
            let mut eid = ctx.scratch.take_u32();
            {
                let f = match self.repr.as_ref() {
                    Some(Repr::Nodes(f)) => f,
                    _ => unreachable!("HP mode runs on the node representation"),
                };
                flatten_frontier_into(&g, f.worklist().nodes(), &mut src, &mut eid);
            }
            if src.is_empty() {
                ctx.scratch.put_u32(src);
                ctx.scratch.put_u32(eid);
            } else {
                self.hp_wd_switches += 1;
                let ups = hp_wd_fallback(ctx, &g, src, eid, frontier_len as u64)?;
                all_updates.extend_from_slice(&ups);
                ctx.scratch.put_u32(ups);
            }
        } else {
            // Sub-iterations over the shrinking sub-list (persistent
            // cursor storage, rebuilt in place).
            {
                let f = match self.repr.as_ref() {
                    Some(Repr::Nodes(f)) => f,
                    _ => unreachable!("HP mode runs on the node representation"),
                };
                let wl = f.worklist();
                self.sub.reset(wl.nodes(), wl.degrees());
            }
            let sub_bytes = self.sub.memory_bytes();
            ctx.mem.charge(AD_HP_SUBLIST, sub_bytes)?;

            while !self.sub.is_empty() {
                if self.sub.len() < block {
                    // Residual tail → WD fallback over the remaining edges.
                    let mut src = ctx.scratch.take_u32();
                    let mut eid = ctx.scratch.take_u32();
                    for c in self.sub.cursors() {
                        let first = g.first_edge(c.node) + c.processed;
                        for e in first..first + c.remaining() {
                            src.push(c.node);
                            eid.push(e);
                        }
                    }
                    let wl_len = self.sub.len() as u64;
                    self.hp_wd_switches += 1;
                    let ups = hp_wd_fallback(ctx, &g, src, eid, wl_len)?;
                    all_updates.extend_from_slice(&ups);
                    ctx.scratch.put_u32(ups);
                    break;
                }

                // One sub-iteration: lane per node, ≤ MDT edges each.
                self.hp_sub_iterations += 1;
                let mut src = ctx.scratch.take_u32();
                let mut eid = ctx.scratch.take_u32();
                let mut offsets = ctx.scratch.take_u32();
                offsets.push(0u32);
                let mut acc = 0u32;
                for c in self.sub.cursors() {
                    let take = c.remaining().min(mdt);
                    let first = g.first_edge(c.node) + c.processed;
                    for e in first..first + take {
                        src.push(c.node);
                        eid.push(e);
                    }
                    acc += take;
                    offsets.push(acc);
                }
                let work = KernelWork {
                    name: "ad_hp_relax",
                    src,
                    eid,
                    assignment: Assignment::Blocked(offsets),
                    access: AccessPattern::Scattered,
                    extra_cycles_per_edge: 2,
                    push: PushTarget::Node,
                };
                let result = ctx.launch(&g, &work, None)?;
                all_updates.extend_from_slice(&result.updated);
                ctx.recycle(result);
                ctx.recycle_work(work);
                self.sub.advance(mdt);
                ctx.charge_aux_kernel(self.sub.len() as u64 + 1, 1);
            }
            ctx.mem.release(AD_HP_SUBLIST, sub_bytes);
        }

        let frontier = match self.repr.as_mut() {
            Some(Repr::Nodes(f)) => f,
            _ => unreachable!("HP mode runs on the node representation"),
        };
        frontier.advance(ctx, &g, &all_updates)?;
        ctx.scratch.put_u32(all_updates);
        Ok(())
    }
}

/// HP's WD-style fallback kernel over an explicit edge batch (shared with
/// the batched serving engine, whose HP mode mirrors this one). `src`/`eid`
/// are consumed and returned to the scratch pool; the returned update list
/// is a pooled buffer too — callers give it back with
/// `ctx.scratch.put_u32` once folded into their update stream.
pub(crate) fn hp_wd_fallback(
    ctx: &mut ExecCtx,
    g: &Csr,
    src: Vec<NodeId>,
    eid: Vec<u32>,
    wl_len: u64,
) -> Result<Vec<NodeId>> {
    let total = src.len();
    ctx.mem.charge(AD_HP_PREFIX, 4 * wl_len)?;
    ctx.charge_aux_kernel(wl_len, 1);
    let threads = ctx.dev.max_resident_threads;
    let log_wl = (64 - wl_len.leading_zeros() as u64).max(1);
    ctx.charge_aux_kernel((threads as u64).min(total as u64), 4 * log_wl);
    let mut offsets = ctx.scratch.take_u32();
    block_offsets_into(total, threads, &mut offsets);
    let work = KernelWork {
        name: "ad_hp_wd_relax",
        src,
        eid,
        assignment: Assignment::Blocked(offsets),
        access: AccessPattern::Scattered,
        extra_cycles_per_edge: 4,
        push: PushTarget::Node,
    };
    let result = ctx.launch(g, &work, None)?;
    ctx.recycle_work(work);
    ctx.mem.release(AD_HP_PREFIX, 4 * wl_len);
    Ok(result.updated)
}

impl Strategy for Adaptive {
    fn kind(&self) -> StrategyKind {
        StrategyKind::AD
    }

    fn init(&mut self, ctx: &mut ExecCtx, source: NodeId) -> Result<()> {
        charge_graph_and_dist(ctx, &self.graph, "csr")?;
        init_dist(ctx, self.graph.num_nodes(), source);
        // Degree histogram + MDT once, up front: NS/HP executions and the
        // cost model's predictions all consult it.
        let decision = match self.params.mdt_override {
            Some(mdt) => MdtDecision {
                mdt,
                peak_bin: 0,
                bins: self.params.histogram_bins,
                max_degree: self.graph.max_degree(),
            },
            None => auto_mdt(&self.graph, self.params.histogram_bins),
        };
        ctx.charge_aux_kernel(self.graph.num_nodes() as u64, 2);
        self.mdt = Some(decision);
        self.mode = StrategyKind::BS;
        self.repr = Some(Repr::Nodes(NodeFrontier::seeded(
            ctx,
            &self.graph,
            source,
            AD_WL,
            4,
        )?));
        Ok(())
    }

    fn pending(&self) -> usize {
        match self.repr.as_ref() {
            Some(Repr::Nodes(f)) | Some(Repr::Split(f)) => f.len(),
            Some(Repr::Edges { wl, .. }) => wl.len(),
            None => 0,
        }
    }

    fn run_iteration(&mut self, ctx: &mut ExecCtx) -> Result<()> {
        let g = self.graph.clone();
        // 1. Canonical view + online inspection (host-side, cheap). The
        // view is rebuilt into a persistent scratch worklist and borrowed
        // out of `self` for the iteration (take/restore keeps the capacity
        // across iterations without cloning).
        self.refresh_view(&g);
        let view = std::mem::take(&mut self.view);
        let snap =
            FrontierInspector::inspect_with_edges(view.degrees(), view.total_edges(), ctx.dev);
        ctx.metrics.inspector_passes += 1;
        ctx.charge_overhead(INSPECT_BASE_CYCLES + snap.nodes / 32);

        // 2. Decide, restricted to what fits in the remaining budget.
        let feas = self.feasibility(ctx, &snap);
        let mdt = self.mdt.expect("init first").mdt;
        let decision = {
            let input = PolicyInput {
                snapshot: &snap,
                degrees: view.degrees(),
                current: self.mode,
                feasibility: feas,
                dev: ctx.dev,
                params: &self.params,
                mdt,
                graph_edges: g.num_edges() as u64,
                graph_nodes: g.num_nodes() as u64,
            };
            self.policy.decide(&input)
        };
        ctx.metrics.policy_decisions += 1;
        let choice = if feas.allows(decision.choice) {
            decision.choice
        } else {
            StrategyKind::BS
        };
        // Alias compositions execute (and report) as the monolithic
        // strategy they name — migration entry-byte bookkeeping included.
        let choice = match choice {
            StrategyKind::Composed(s) => s.alias().unwrap_or(choice),
            _ => choice,
        };

        // 3. Migrate if the mode changed. The telemetry instants land
        // here — before the iteration's kernels — so in a trace the
        // decision precedes the slices it caused.
        ctx.record_trace(TraceEventKind::FrontierSize, "", snap.nodes, snap.edges);
        ctx.record_trace(TraceEventKind::StrategyDecision, choice.label(), snap.nodes, snap.edges);
        let migrated = choice != self.mode;
        if migrated {
            ctx.record_trace(TraceEventKind::Migration, choice.label(), snap.nodes, snap.edges);
            self.migrate_to(ctx, choice, &view)?;
        }
        self.view = view; // restore the scratch capacity for next iteration

        // 4. Execute one iteration in the chosen style.
        match self.mode {
            StrategyKind::BS => self.step_bs(ctx)?,
            StrategyKind::EP => self.step_ep(ctx)?,
            StrategyKind::WD => self.step_wd(ctx)?,
            StrategyKind::NS => self.step_ns(ctx)?,
            StrategyKind::HP => self.step_hp(ctx)?,
            StrategyKind::AD => unreachable!("AD never selects itself"),
            StrategyKind::Composed(s) => self.step_composed(ctx, s)?,
        }

        // 5. Record the decision.
        ctx.metrics.record_decision(DecisionRecord {
            iteration: ctx.metrics.iterations,
            strategy: choice.label(),
            migrated,
            frontier_nodes: snap.nodes,
            frontier_edges: snap.edges,
            degree_skew: snap.skew,
            predicted_cycles: decision.predicted_cycles,
        });
        ctx.metrics.iterations += 1;
        Ok(())
    }

    fn finalize(&self, ctx: &ExecCtx) -> Vec<u32> {
        // If the run ever entered split space, dist is sized to the split
        // graph; the original ids hold the answer either way.
        ctx.dist[..self.graph.num_nodes()].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adaptive::AdaptivePolicyKind;
    use crate::algorithms::{AlgoKind, NativeRelaxer};
    use crate::coordinator::{run, RunConfig};
    use crate::graph::generators::{erdos_renyi, rmat, road_grid, RmatParams};
    use crate::graph::traversal;
    use crate::sim::DeviceSpec;

    fn params(policy: AdaptivePolicyKind) -> StrategyParams {
        StrategyParams {
            adaptive_policy: policy,
            ..Default::default()
        }
    }

    fn run_ad(
        g: &Arc<Csr>,
        algo: AlgoKind,
        policy: AdaptivePolicyKind,
    ) -> crate::coordinator::RunResult {
        run(
            g,
            &RunConfig {
                algo,
                strategy: StrategyKind::AD,
                params: params(policy),
                ..Default::default()
            },
        )
        .expect("adaptive run")
    }

    #[test]
    fn adaptive_sssp_matches_dijkstra_all_policies() {
        let g = Arc::new(rmat(9, 4096, RmatParams::default(), 31).unwrap());
        let oracle = traversal::dijkstra(&g, 0);
        for policy in [
            AdaptivePolicyKind::CostModel,
            AdaptivePolicyKind::Heuristic,
            AdaptivePolicyKind::RoundRobin,
        ] {
            let r = run_ad(&g, AlgoKind::Sssp, policy);
            assert_eq!(r.dist, oracle, "{policy:?} diverged from Dijkstra");
            assert!(
                !r.metrics.decisions.is_empty(),
                "{policy:?} recorded no decisions"
            );
            assert_eq!(
                r.metrics.decisions.len() as u32,
                r.metrics.iterations,
                "{policy:?}: one decision per outer iteration"
            );
        }
    }

    #[test]
    fn adaptive_bfs_matches_reference_on_road() {
        let g = Arc::new(road_grid(16, 16, 9, 7).unwrap());
        let oracle = traversal::bfs_levels(&g, 0);
        for policy in [AdaptivePolicyKind::CostModel, AdaptivePolicyKind::Heuristic] {
            let r = run_ad(&g, AlgoKind::Bfs, policy);
            assert_eq!(r.dist, oracle, "{policy:?} diverged from BFS");
        }
    }

    #[test]
    fn round_robin_migrates_and_stays_correct() {
        let g = Arc::new(erdos_renyi(300, 1500, 15, 4).unwrap());
        let oracle = traversal::dijkstra(&g, 0);
        let r = run_ad(&g, AlgoKind::Sssp, AdaptivePolicyKind::RoundRobin);
        assert_eq!(r.dist, oracle);
        assert!(
            r.metrics.strategy_switches > 0,
            "round-robin must switch strategies"
        );
        // At least three distinct modes must have actually executed.
        let mut modes: Vec<&str> = r.metrics.decisions.iter().map(|d| d.strategy).collect();
        modes.sort_unstable();
        modes.dedup();
        assert!(modes.len() >= 3, "only modes {modes:?} were exercised");
    }

    #[test]
    fn budget_keeps_adaptive_off_infeasible_strategies() {
        // Budget large enough for CSR + dist + node worklists, far too
        // small for EP's COO (plus its exploded worklists) or NS's second
        // CSR: headroom after CSR+dist is 8E bytes, while EP needs 12E for
        // the COO alone before any worklist.
        let g = Arc::new(rmat(10, 8 << 10, RmatParams::default(), 9).unwrap());
        let budget =
            g.memory_bytes() + 4 * g.num_nodes() as u64 + 8 * g.num_edges() as u64;
        let dev = DeviceSpec::k20c();
        let mut ctx =
            ExecCtx::new(&dev, AlgoKind::Sssp, Box::new(NativeRelaxer)).with_budget(budget);
        let mut s = Adaptive::new(g.clone(), params(AdaptivePolicyKind::CostModel));
        s.init(&mut ctx, 0).unwrap();
        while s.pending() > 0 {
            s.run_iteration(&mut ctx).unwrap();
        }
        assert_eq!(s.finalize(&ctx), traversal::dijkstra(&g, 0));
        for d in &ctx.metrics.decisions {
            assert!(
                d.strategy != "EP" && d.strategy != "NS",
                "chose {} despite the budget",
                d.strategy
            );
        }
        assert!(ctx.mem.peak() <= budget, "exceeded the device budget");
    }

    #[test]
    fn composed_candidates_stay_correct_and_feasible() {
        // The cost model with the three new composed balancers in its
        // candidate set must still match Dijkstra exactly, keep one
        // decision per iteration, and respect the memory budget.
        let g = Arc::new(rmat(9, 4096, RmatParams::default(), 31).unwrap());
        let oracle = traversal::dijkstra(&g, 0);
        let mut p = params(AdaptivePolicyKind::CostModel);
        p.composed_candidates = Schedule::NEW.to_vec();
        let r = run(
            &g,
            &RunConfig {
                algo: AlgoKind::Sssp,
                strategy: StrategyKind::AD,
                params: p,
                ..Default::default()
            },
        )
        .expect("adaptive run with composed candidates");
        assert_eq!(r.dist, oracle);
        assert_eq!(r.metrics.decisions.len() as u32, r.metrics.iterations);
    }

    #[test]
    fn alias_candidates_normalize_to_the_monolithic_strategy() {
        // An alias composition in the candidate set must never appear in
        // the decision trace under its composed spelling — the engine
        // executes (and labels) it as the strategy it names.
        let g = Arc::new(erdos_renyi(300, 1500, 15, 4).unwrap());
        let mut p = params(AdaptivePolicyKind::CostModel);
        p.composed_candidates = vec!["thread/merge-path".parse().unwrap()];
        let r = run(
            &g,
            &RunConfig {
                algo: AlgoKind::Sssp,
                strategy: StrategyKind::AD,
                params: p,
                ..Default::default()
            },
        )
        .expect("adaptive run with an alias candidate");
        assert_eq!(r.dist, traversal::dijkstra(&g, 0));
        for d in &r.metrics.decisions {
            assert!(
                !d.strategy.contains('/'),
                "alias leaked into the trace as {}",
                d.strategy
            );
        }
    }

    #[test]
    fn unreachable_nodes_stay_inf_through_migration() {
        use crate::graph::Edge;
        let g = Arc::new(Csr::from_edges(5, &[Edge::new(0, 1, 2), Edge::new(1, 2, 3)]).unwrap());
        let r = run_ad(&g, AlgoKind::Sssp, AdaptivePolicyKind::RoundRobin);
        assert_eq!(r.dist, vec![0, 2, 5, crate::INF, crate::INF]);
    }
}
