//! Online frontier inspection: the cheap per-iteration statistics every
//! adaptive decision is made from.
//!
//! The node worklists already cache out-degrees (the paper's "two
//! associative arrays", §III-A), so inspection is a single host-side pass
//! over the degree array — no extra device kernel. The simulated cost the
//! engine charges for it is a small flat overhead
//! ([`crate::adaptive::engine`]), mirroring Jatala et al.'s observation
//! that frontier statistics can be collected almost for free alongside the
//! previous kernel.

use crate::graph::stats::DegreeStats;
use crate::sim::DeviceSpec;

/// Statistics of the current frontier, in original-graph node space.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierSnapshot {
    /// Active nodes in the frontier.
    pub nodes: u64,
    /// Total outgoing edges of the frontier (the iteration's work).
    pub edges: u64,
    /// Maximum out-degree in the frontier.
    pub max_degree: u32,
    /// Mean out-degree in the frontier.
    pub mean_degree: f64,
    /// Degree skew `max / mean` — the first-order predictor of node-based
    /// (BS) warp imbalance. 0 when the frontier carries no edges.
    pub skew: f64,
    /// Fraction of the device's resident threads one-edge-per-thread work
    /// would occupy (`edges / max_resident_threads`; may exceed 1).
    pub occupancy: f64,
}

impl FrontierSnapshot {
    /// True when the frontier is too small to fill even one block.
    pub fn is_small(&self, dev: &DeviceSpec) -> bool {
        self.edges < dev.block_size as u64
    }
}

/// Computes [`FrontierSnapshot`]s from worklist degree arrays.
#[derive(Debug, Default, Clone, Copy)]
pub struct FrontierInspector;

impl FrontierInspector {
    /// Inspect a frontier given the active nodes' out-degrees.
    pub fn inspect(degrees: &[u32], dev: &DeviceSpec) -> FrontierSnapshot {
        let edges: u64 = degrees.iter().map(|&d| d as u64).sum();
        Self::inspect_with_edges(degrees, edges, dev)
    }

    /// [`FrontierInspector::inspect`] with the edge total already known —
    /// worklists cache a running Σ degrees
    /// ([`crate::worklist::NodeWorklist::total_edges`] is O(1)), so the
    /// per-iteration callers (the adaptive engine, the batched serving
    /// engine) skip this function's second pass over the degree array.
    pub fn inspect_with_edges(
        degrees: &[u32],
        edges: u64,
        dev: &DeviceSpec,
    ) -> FrontierSnapshot {
        debug_assert_eq!(
            edges,
            degrees.iter().map(|&d| d as u64).sum::<u64>(),
            "cached edge sum diverged from the degree array"
        );
        let st = DegreeStats::of_degrees(degrees);
        let skew = st.imbalance();
        FrontierSnapshot {
            nodes: degrees.len() as u64,
            edges,
            max_degree: st.max,
            mean_degree: st.avg,
            skew,
            occupancy: edges as f64 / dev.max_resident_threads.max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_of_skewed_frontier() {
        let dev = DeviceSpec::k20c();
        let degs = [1u32, 1, 1, 1, 96];
        let s = FrontierInspector::inspect(&degs, &dev);
        assert_eq!(s.nodes, 5);
        assert_eq!(s.edges, 100);
        assert_eq!(s.max_degree, 96);
        assert!((s.mean_degree - 20.0).abs() < 1e-9);
        assert!((s.skew - 96.0 / 20.0).abs() < 1e-9);
        assert!(s.is_small(&dev));
    }

    #[test]
    fn empty_frontier_is_degenerate() {
        let dev = DeviceSpec::k20c();
        let s = FrontierInspector::inspect(&[], &dev);
        assert_eq!(s.nodes, 0);
        assert_eq!(s.edges, 0);
        assert_eq!(s.skew, 0.0);
    }

    #[test]
    fn occupancy_scales_with_edges() {
        let dev = DeviceSpec::k20c();
        let degs = vec![2u32; dev.max_resident_threads as usize];
        let s = FrontierInspector::inspect(&degs, &dev);
        assert!((s.occupancy - 2.0).abs() < 1e-9);
        assert!(!s.is_small(&dev));
    }
}
