//! Adaptive per-iteration strategy selection (`StrategyKind::AD`).
//!
//! The source paper's own conclusion is that no static scheme wins
//! everywhere: EP dominates where its COO fits, WD wins among node-based
//! schemes on skewed inputs, HP is the only proposed scheme that scales to
//! the Graph500 graphs, and BS's zero overhead wins on tiny frontiers.
//! Later work closes that gap at runtime — Jatala et al. (arXiv:1911.09135)
//! switch load-balancing schemes per kernel invocation from frontier
//! properties, and Osama et al. (arXiv:2301.04792) decouple the schedule
//! from the algorithm entirely. This module is that adaptive layer for the
//! five reproduced strategies:
//!
//! * [`inspect`] — cheap online statistics of the current frontier
//!   (size, total outgoing degree, skew, occupancy), reusing the worklists'
//!   cached degrees and [`crate::graph::stats::DegreeStats`].
//! * [`policy`] — pluggable decision policies: a heuristic with
//!   paper-derived thresholds, a cost model that queries the
//!   [`crate::sim::KernelSim`] predictor per candidate strategy (respecting
//!   the device memory budget so EP/WD are never chosen when their COO /
//!   exploded worklists would OOM), and a round-robin stress policy for
//!   migration testing.
//! * [`migrate`] — lossless worklist conversion between the strategies'
//!   representations (node worklist ↔ exploded edge frontier ↔ split-graph
//!   ids), so switching mid-run preserves the pending set and therefore
//!   correctness.
//! * [`engine`] — the [`Adaptive`] strategy: per outer iteration it
//!   inspects, decides, migrates if needed, and executes that iteration in
//!   the chosen strategy's kernel style, recording the decision trace into
//!   [`crate::metrics::RunMetrics::decisions`].

pub mod cost;
pub mod engine;
pub mod inspect;
pub mod migrate;
pub mod policy;

pub use engine::Adaptive;
pub use inspect::{FrontierInspector, FrontierSnapshot};
pub use policy::{AdaptivePolicyKind, Decision, Feasibility, Policy};
