//! Decision policies for the adaptive engine.
//!
//! A [`Policy`] sees the per-iteration [`FrontierSnapshot`], the frontier's
//! degree list, the memory [`Feasibility`] mask, and the current strategy;
//! it returns the strategy to run the next iteration with. Two production
//! policies are provided plus one for testing:
//!
//! * [`HeuristicPolicy`] — paper-derived thresholds: memory-pressured runs
//!   fall back to HP (the only proposed scheme that scales to Graph500,
//!   §IV-A), small frontiers run BS (zero strategy overhead), skewed
//!   frontiers run EP where its COO fits (60–80% reductions, §IV-A) and WD
//!   otherwise (best node-based scheme on skewed inputs), large uniform
//!   frontiers run WD.
//! * [`CostModelPolicy`] — queries the [`crate::sim::KernelSim`]-backed
//!   predictor ([`super::cost`]) for every memory-feasible candidate and
//!   picks the cheapest, with 5% hysteresis so ties do not cause churn.
//! * [`RoundRobinPolicy`] — cycles through the feasible strategies every
//!   iteration; a stress policy exercising every migration path
//!   (`rust/tests/strategy_properties.rs`).

use crate::sim::DeviceSpec;
use crate::strategies::{StrategyKind, StrategyParams};

use super::cost;
use super::inspect::FrontierSnapshot;
use super::migrate::{space_of, Space};

/// Which decision policy the adaptive engine uses (configured through
/// [`StrategyParams::adaptive_policy`] and the `adaptive_policy` config
/// key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdaptivePolicyKind {
    /// Threshold rules derived from the paper's findings.
    Heuristic,
    /// KernelSim-backed cost model (default).
    #[default]
    CostModel,
    /// Cycle through feasible strategies (migration stress-testing).
    RoundRobin,
}

/// Memory feasibility of the candidate strategies under the device budget,
/// computed by the engine before each decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Feasibility {
    /// EP's COO + exploded worklist fit in the remaining budget.
    pub ep: bool,
    /// WD's degree-carrying worklist + scan scratch fit.
    pub wd: bool,
    /// NS's split graph (+ transient rebuild) fits.
    pub ns: bool,
    /// The COO arrays are already resident (EP was used before).
    pub coo_resident: bool,
    /// The split graph has already been built (NS was used before).
    pub split_built: bool,
    /// A composed schedule's transient step scratch (dense frontier +
    /// prefix/bin arrays, [`crate::strategies::schedule::step_scratch_bytes`])
    /// fits in the remaining budget.
    pub composed: bool,
}

impl Feasibility {
    /// Whether `kind` may be chosen at all. BS and HP are always available:
    /// they add no storage beyond what the engine already holds.
    pub fn allows(&self, kind: StrategyKind) -> bool {
        match kind {
            StrategyKind::EP => self.ep,
            StrategyKind::WD => self.wd,
            StrategyKind::NS => self.ns,
            StrategyKind::BS | StrategyKind::HP => true,
            StrategyKind::AD => false,
            StrategyKind::Composed(s) => match s.alias() {
                // Aliases cost exactly what the monolithic strategy costs.
                Some(k) => self.allows(k),
                None => self.composed,
            },
        }
    }
}

/// Everything a policy may consult for one decision.
pub struct PolicyInput<'a> {
    pub snapshot: &'a FrontierSnapshot,
    /// Out-degrees of the frontier nodes (original-graph space).
    pub degrees: &'a [u32],
    pub current: StrategyKind,
    pub feasibility: Feasibility,
    pub dev: &'a DeviceSpec,
    pub params: &'a StrategyParams,
    /// The MDT threshold NS/HP would use.
    pub mdt: u32,
    /// Edges of the whole graph (COO sizing).
    pub graph_edges: u64,
    /// Nodes of the whole graph.
    pub graph_nodes: u64,
}

/// A policy's verdict for one iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    /// The strategy to run this iteration with (one of the five static
    /// kinds, or a composed schedule when the candidate set includes one).
    pub choice: StrategyKind,
    /// Predicted cycles for the choice (0 when the policy does not
    /// predict).
    pub predicted_cycles: u64,
}

/// Per-iteration strategy selection.
pub trait Policy {
    /// Short name for reporting.
    fn name(&self) -> &'static str;

    /// Pick the strategy for the next iteration.
    fn decide(&mut self, input: &PolicyInput<'_>) -> Decision;
}

/// Build the policy selected by `kind`.
pub fn build_policy(kind: AdaptivePolicyKind) -> Box<dyn Policy> {
    match kind {
        AdaptivePolicyKind::Heuristic => Box::new(HeuristicPolicy),
        AdaptivePolicyKind::CostModel => Box::new(CostModelPolicy::default()),
        AdaptivePolicyKind::RoundRobin => Box::new(RoundRobinPolicy::default()),
    }
}

/// Frontier skew above which the frontier counts as "skewed" (a warp
/// containing the max-degree node stalls ≥ 4× the average lane).
const SKEW_THRESHOLD: f64 = 4.0;

/// Paper-derived threshold rules.
#[derive(Debug, Default, Clone, Copy)]
pub struct HeuristicPolicy;

impl Policy for HeuristicPolicy {
    fn name(&self) -> &'static str {
        "heuristic"
    }

    fn decide(&mut self, input: &PolicyInput<'_>) -> Decision {
        let snap = input.snapshot;
        let feas = &input.feasibility;
        let choice = if !feas.ep && !feas.wd {
            // Memory-pressured: HP is the scheme the paper could still run
            // on the large graphs (§IV-A).
            StrategyKind::HP
        } else if snap.is_small(input.dev) {
            // Tiny frontier: any decomposition overhead dwarfs the kernel;
            // the plain baseline wins (the paper's road-BFS finding).
            StrategyKind::BS
        } else if snap.skew >= SKEW_THRESHOLD {
            // Skewed frontier: EP where the COO fits, else the best
            // node-based scheme for skewed inputs (WD), else HP.
            if feas.ep {
                StrategyKind::EP
            } else if feas.wd {
                StrategyKind::WD
            } else {
                StrategyKind::HP
            }
        } else if feas.wd {
            // Large uniform frontier: workload decomposition.
            StrategyKind::WD
        } else if feas.ep {
            StrategyKind::EP
        } else {
            StrategyKind::HP
        };
        Decision {
            choice,
            predicted_cycles: 0,
        }
    }
}

/// KernelSim-backed cost model with hysteresis. Owns a
/// [`cost::CostScratch`] so its per-iteration predictions allocate nothing
/// once warm.
#[derive(Debug, Default, Clone)]
pub struct CostModelPolicy {
    scratch: cost::CostScratch,
}

impl Policy for CostModelPolicy {
    fn name(&self) -> &'static str {
        "cost-model"
    }

    fn decide(&mut self, input: &PolicyInput<'_>) -> Decision {
        let mut best: Option<(StrategyKind, u64)> = None;
        let mut current_cost: Option<u64> = None;
        // The five monolithic strategies plus any composed schedules the
        // run was configured with (`--adaptive-schedules`); with an empty
        // candidate list the loop — and hence every decision trace — is
        // identical to the pre-algebra model.
        let composed = input
            .params
            .composed_candidates
            .iter()
            .map(|&s| StrategyKind::Composed(s));
        for kind in StrategyKind::ALL.into_iter().chain(composed) {
            if !input.feasibility.allows(kind) {
                continue;
            }
            let mut cycles = cost::predict_with(kind, input, &mut self.scratch);
            if kind != input.current {
                cycles = cycles.saturating_add(cost::migration_cycles(input, kind));
            } else {
                current_cost = Some(cycles);
            }
            if best.map_or(true, |(_, c)| cycles < c) {
                best = Some((kind, cycles));
            }
        }
        let (choice, cycles) = best.unwrap_or((StrategyKind::BS, 0));
        // Hysteresis: stay with the current strategy unless the winner is
        // more than 5% cheaper — repeated migration would eat the gain.
        if let Some(cur) = current_cost {
            if choice != input.current && cur <= cycles.saturating_add(cycles / 20) {
                return Decision {
                    choice: input.current,
                    predicted_cycles: cur,
                };
            }
        }
        Decision {
            choice,
            predicted_cycles: cycles,
        }
    }
}

/// Cycles through the feasible strategies — every call moves on, so every
/// migration path gets exercised.
#[derive(Debug, Default, Clone, Copy)]
pub struct RoundRobinPolicy {
    at: usize,
}

impl Policy for RoundRobinPolicy {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn decide(&mut self, input: &PolicyInput<'_>) -> Decision {
        let order = StrategyKind::ALL;
        for step in 1..=order.len() {
            let kind = order[(self.at + step) % order.len()];
            if input.feasibility.allows(kind) {
                self.at = (self.at + step) % order.len();
                return Decision {
                    choice: kind,
                    predicted_cycles: 0,
                };
            }
        }
        Decision {
            choice: StrategyKind::BS,
            predicted_cycles: 0,
        }
    }
}

/// Whether switching `from → to` requires converting the worklist between
/// spaces (used by the cost model's migration penalty and the engine).
pub fn requires_migration(from: StrategyKind, to: StrategyKind) -> bool {
    space_of(from) != space_of(to) || wd_entry_resize(from, to)
}

/// BS/HP carry 4 B worklist entries, WD carries 8 B (node + degree arrays,
/// §III-A); switching between them re-shapes the buffer even though both
/// live in node space.
fn wd_entry_resize(from: StrategyKind, to: StrategyKind) -> bool {
    space_of(from) == Space::Node
        && space_of(to) == Space::Node
        && (from == StrategyKind::WD) != (to == StrategyKind::WD)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adaptive::inspect::FrontierInspector;

    fn input<'a>(
        snap: &'a FrontierSnapshot,
        degrees: &'a [u32],
        dev: &'a DeviceSpec,
        params: &'a StrategyParams,
        feas: Feasibility,
    ) -> PolicyInput<'a> {
        PolicyInput {
            snapshot: snap,
            degrees,
            current: StrategyKind::BS,
            feasibility: feas,
            dev,
            params,
            mdt: 4,
            graph_edges: 10_000,
            graph_nodes: 1_000,
        }
    }

    fn all_feasible() -> Feasibility {
        Feasibility {
            ep: true,
            wd: true,
            ns: true,
            coo_resident: false,
            split_built: false,
            composed: true,
        }
    }

    #[test]
    fn heuristic_prefers_bs_on_tiny_frontiers() {
        let dev = DeviceSpec::k20c();
        let params = StrategyParams::default();
        let degs = [2u32, 3, 1];
        let snap = FrontierInspector::inspect(&degs, &dev);
        let mut p = HeuristicPolicy;
        let d = p.decide(&input(&snap, &degs, &dev, &params, all_feasible()));
        assert_eq!(d.choice, StrategyKind::BS);
    }

    #[test]
    fn heuristic_prefers_ep_on_large_skewed_frontiers() {
        let dev = DeviceSpec::k20c();
        let params = StrategyParams::default();
        let mut degs = vec![2u32; 4096];
        degs.push(5_000); // hub
        let snap = FrontierInspector::inspect(&degs, &dev);
        let mut p = HeuristicPolicy;
        let d = p.decide(&input(&snap, &degs, &dev, &params, all_feasible()));
        assert_eq!(d.choice, StrategyKind::EP);
    }

    #[test]
    fn heuristic_falls_back_to_hp_under_memory_pressure() {
        let dev = DeviceSpec::k20c();
        let params = StrategyParams::default();
        let degs = vec![8u32; 8192];
        let snap = FrontierInspector::inspect(&degs, &dev);
        let feas = Feasibility {
            ep: false,
            wd: false,
            ns: false,
            coo_resident: false,
            split_built: false,
            composed: false,
        };
        let mut p = HeuristicPolicy;
        let d = p.decide(&input(&snap, &degs, &dev, &params, feas));
        assert_eq!(d.choice, StrategyKind::HP);
    }

    #[test]
    fn cost_model_never_picks_infeasible_strategies() {
        let dev = DeviceSpec::k20c();
        let params = StrategyParams::default();
        let degs = vec![16u32; 8192];
        let snap = FrontierInspector::inspect(&degs, &dev);
        let feas = Feasibility {
            ep: false,
            wd: false,
            ns: false,
            coo_resident: false,
            split_built: false,
            composed: false,
        };
        let mut p = CostModelPolicy::default();
        let d = p.decide(&input(&snap, &degs, &dev, &params, feas));
        assert!(
            matches!(d.choice, StrategyKind::BS | StrategyKind::HP),
            "picked {}",
            d.choice
        );
    }

    #[test]
    fn cost_model_beats_bs_on_heavy_skew() {
        // A single huge hub: BS serializes one lane; every alternative
        // must predict cheaper, so the model must not choose BS.
        let dev = DeviceSpec::k20c();
        let params = StrategyParams::default();
        let mut degs = vec![1u32; 2048];
        degs.push(100_000);
        let snap = FrontierInspector::inspect(&degs, &dev);
        let mut p = CostModelPolicy::default();
        let d = p.decide(&input(&snap, &degs, &dev, &params, all_feasible()));
        assert_ne!(d.choice, StrategyKind::BS);
        assert!(d.predicted_cycles > 0);
    }

    #[test]
    fn round_robin_cycles_and_respects_feasibility() {
        let dev = DeviceSpec::k20c();
        let params = StrategyParams::default();
        let degs = [4u32; 64];
        let snap = FrontierInspector::inspect(&degs, &dev);
        let feas = Feasibility {
            ep: true,
            wd: true,
            ns: false,
            coo_resident: false,
            split_built: false,
            composed: false,
        };
        let mut p = RoundRobinPolicy::default();
        let mut seen = Vec::new();
        for _ in 0..8 {
            let d = p.decide(&input(&snap, &degs, &dev, &params, feas));
            assert_ne!(d.choice, StrategyKind::NS, "NS is infeasible");
            seen.push(d.choice);
        }
        assert!(seen.contains(&StrategyKind::BS));
        assert!(seen.contains(&StrategyKind::EP));
        assert!(seen.contains(&StrategyKind::WD));
        assert!(seen.contains(&StrategyKind::HP));
    }

    #[test]
    fn migration_required_between_spaces_and_wd_reshape() {
        assert!(requires_migration(StrategyKind::BS, StrategyKind::EP));
        assert!(requires_migration(StrategyKind::EP, StrategyKind::NS));
        assert!(requires_migration(StrategyKind::BS, StrategyKind::WD));
        assert!(!requires_migration(StrategyKind::BS, StrategyKind::HP));
        assert!(!requires_migration(StrategyKind::WD, StrategyKind::WD));
        // Lowered compositions consume a plain 4 B node frontier, so BS/HP
        // switch over for free while WD reshapes and EP/NS change spaces.
        let wmp = StrategyKind::Composed(crate::strategies::Schedule::WARP_MERGE_PATH);
        assert!(!requires_migration(StrategyKind::BS, wmp));
        assert!(!requires_migration(wmp, StrategyKind::HP));
        assert!(requires_migration(StrategyKind::WD, wmp));
        assert!(requires_migration(wmp, StrategyKind::EP));
    }

    #[test]
    fn cost_model_considers_feasible_composed_candidates_only() {
        use crate::strategies::Schedule;
        let dev = DeviceSpec::k20c();
        let params = StrategyParams {
            composed_candidates: Schedule::NEW.to_vec(),
            ..Default::default()
        };
        let mut degs = vec![1u32; 2048];
        degs.push(100_000); // heavy hub: composed merge-path should shine
        let snap = FrontierInspector::inspect(&degs, &dev);

        // Scratch-infeasible: the model must never emit a composed choice.
        let mut feas = all_feasible();
        feas.composed = false;
        let mut p = CostModelPolicy::default();
        let d = p.decide(&PolicyInput {
            snapshot: &snap,
            degrees: &degs,
            current: StrategyKind::BS,
            feasibility: feas,
            dev: &dev,
            params: &params,
            mdt: 4,
            graph_edges: 110_000,
            graph_nodes: 4_096,
        });
        assert!(!d.choice.is_composed(), "picked {}", d.choice);

        // Feasible: decisions stay deterministic and predict real cycles.
        let mut p = CostModelPolicy::default();
        let d1 = p.decide(&PolicyInput {
            snapshot: &snap,
            degrees: &degs,
            current: StrategyKind::BS,
            feasibility: all_feasible(),
            dev: &dev,
            params: &params,
            mdt: 4,
            graph_edges: 110_000,
            graph_nodes: 4_096,
        });
        let d2 = p.decide(&PolicyInput {
            snapshot: &snap,
            degrees: &degs,
            current: StrategyKind::BS,
            feasibility: all_feasible(),
            dev: &dev,
            params: &params,
            mdt: 4,
            graph_edges: 110_000,
            graph_nodes: 4_096,
        });
        assert_eq!(d1, d2);
        assert!(d1.predicted_cycles > 0);
    }
}
