//! Lossless worklist migration between strategy representations.
//!
//! The five static strategies disagree on what a worklist holds:
//!
//! * BS / WD / HP — active **node** ids (+ cached out-degrees) of the
//!   original graph.
//! * EP — the exploded **edge** frontier: every outgoing edge of every
//!   active node, with duplicated source endpoints (§II-B).
//! * NS — node ids of the **split graph**, where a high-degree parent's
//!   work is shared with its child clones (§III-B).
//!
//! Switching strategies mid-run therefore converts the pending set between
//! these spaces. All conversions round-trip: the set of pending nodes (and
//! hence the final BFS/SSSP answer) is preserved, with one documented
//! exception — the edge representation cannot carry zero-out-degree nodes,
//! whose processing is a no-op, so `nodes → edges → nodes` drops exactly
//! those. `rust/tests/strategy_properties.rs` asserts both properties.

use crate::graph::{Csr, Graph, NodeId};
use crate::strategies::node_split::SplitGraph;
use crate::strategies::StrategyKind;
use crate::worklist::{EdgeWorklist, NodeWorklist};

/// The worklist space a strategy's kernels consume.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Space {
    /// Original-graph node worklist (BS, WD, HP).
    Node,
    /// Exploded edge frontier over the COO form (EP).
    Edge,
    /// Split-graph node worklist (NS).
    Split,
}

/// Which space a strategy's worklist lives in.
pub fn space_of(kind: StrategyKind) -> Space {
    match kind {
        StrategyKind::EP => Space::Edge,
        StrategyKind::NS => Space::Split,
        // AD is the selector itself; its canonical view is node space.
        StrategyKind::BS | StrategyKind::WD | StrategyKind::HP | StrategyKind::AD => Space::Node,
        // Every lowered composition consumes a plain node frontier — the
        // merge-path / histogram reordering happens inside the kernel step,
        // not in the worklist representation ([`crate::strategies::schedule`]).
        StrategyKind::Composed(_) => Space::Node,
    }
}

/// Node frontier → exploded edge frontier, into caller-provided scratch:
/// all outgoing edges of every active node (`outputWl.push(n.edges)` in
/// the paper's pseudocode). Zero-degree nodes contribute nothing.
pub fn nodes_to_edges_into(g: &Csr, wl: &NodeWorklist, out: &mut EdgeWorklist) {
    out.clear();
    for &n in wl.nodes() {
        out.push_node_edges(g, n);
    }
}

/// Allocating convenience wrapper around [`nodes_to_edges_into`].
pub fn nodes_to_edges(g: &Csr, wl: &NodeWorklist) -> EdgeWorklist {
    let mut out = EdgeWorklist::new();
    nodes_to_edges_into(g, wl, &mut out);
    out
}

/// Exploded edge frontier → node frontier, into caller-provided scratch
/// (including the dedup bitmap): the distinct source endpoints in
/// first-seen order. Exact inverse of [`nodes_to_edges`] because EP's
/// worklists always carry whole adjacencies per source.
pub fn edges_to_nodes_into(
    g: &Csr,
    wl: &EdgeWorklist,
    seen: &mut Vec<u64>,
    out: &mut NodeWorklist,
) {
    seen.clear();
    seen.resize(g.num_nodes().div_ceil(64), 0);
    out.clear();
    for &s in wl.srcs() {
        let (w, b) = (s as usize / 64, s as usize % 64);
        if seen[w] & (1 << b) == 0 {
            seen[w] |= 1 << b;
            out.push(s, g.degree(s));
        }
    }
}

/// Allocating convenience wrapper around [`edges_to_nodes_into`].
pub fn edges_to_nodes(g: &Csr, wl: &EdgeWorklist) -> NodeWorklist {
    let mut seen = Vec::new();
    let mut out = NodeWorklist::new();
    edges_to_nodes_into(g, wl, &mut seen, &mut out);
    out
}

/// Original node frontier → split-graph frontier, into caller-provided
/// scratch: each node plus all of its child clones (the clones own slices
/// of the parent's adjacency, so the parent's pending work is exactly the
/// union).
pub fn nodes_to_split_into(split: &SplitGraph, wl: &NodeWorklist, out: &mut NodeWorklist) {
    let g = &split.graph;
    out.clear();
    for &n in wl.nodes() {
        out.push(n, g.degree(n));
        for c in split.map.children(n) {
            out.push(c, g.degree(c));
        }
    }
}

/// Allocating convenience wrapper around [`nodes_to_split_into`].
pub fn nodes_to_split(split: &SplitGraph, wl: &NodeWorklist) -> NodeWorklist {
    let mut out = NodeWorklist::new();
    nodes_to_split_into(split, wl, &mut out);
    out
}

/// `parent_of[x]` for every split-graph id: identity for original ids,
/// the owning parent for child clones.
pub fn parent_of_table(split: &SplitGraph, original_nodes: usize) -> Vec<NodeId> {
    let n_split = split.graph.num_nodes();
    let mut parent: Vec<NodeId> = (0..n_split as u32).collect();
    for u in 0..original_nodes as u32 {
        for c in split.map.children(u) {
            parent[c as usize] = u;
        }
    }
    parent
}

/// Split-graph frontier → original node frontier, into caller-provided
/// scratch: map every id to its parent and deduplicate (a parent and its
/// clones collapse to one entry).
pub fn split_to_nodes_into(
    original: &Csr,
    parent_of: &[NodeId],
    wl: &NodeWorklist,
    seen: &mut Vec<u64>,
    out: &mut NodeWorklist,
) {
    seen.clear();
    seen.resize(original.num_nodes().div_ceil(64), 0);
    out.clear();
    for &x in wl.nodes() {
        let p = parent_of[x as usize];
        let (w, b) = (p as usize / 64, p as usize % 64);
        if seen[w] & (1 << b) == 0 {
            seen[w] |= 1 << b;
            out.push(p, original.degree(p));
        }
    }
}

/// Allocating convenience wrapper around [`split_to_nodes_into`].
pub fn split_to_nodes(
    original: &Csr,
    parent_of: &[NodeId],
    wl: &NodeWorklist,
) -> NodeWorklist {
    let mut seen = Vec::new();
    let mut out = NodeWorklist::new();
    split_to_nodes_into(original, parent_of, wl, &mut seen, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;
    use crate::strategies::mdt::MdtDecision;
    use crate::strategies::node_split::split_graph;

    fn hub_graph() -> Csr {
        // node 0 fans out to 1..=7; node 8 is isolated (degree 0).
        let edges: Vec<Edge> = (1..8u32).map(|v| Edge::new(0, v, 1)).collect();
        Csr::from_edges(9, &edges).unwrap()
    }

    fn sorted_nodes(wl: &NodeWorklist) -> Vec<u32> {
        let mut v = wl.nodes().to_vec();
        v.sort_unstable();
        v
    }

    #[test]
    fn node_edge_roundtrip_drops_only_zero_degree() {
        let g = hub_graph();
        let mut wl = NodeWorklist::new();
        wl.push(0, g.degree(0));
        wl.push(8, g.degree(8)); // zero-degree: vanishes in edge space
        wl.push(1, g.degree(1)); // zero-degree too (leaf)
        let edges = nodes_to_edges(&g, &wl);
        assert_eq!(edges.len(), 7);
        let back = edges_to_nodes(&g, &edges);
        assert_eq!(sorted_nodes(&back), vec![0]);
    }

    #[test]
    fn split_roundtrip_is_exact() {
        let g = hub_graph();
        let decision = MdtDecision {
            mdt: 3,
            peak_bin: 0,
            bins: 10,
            max_degree: 7,
        };
        let split = split_graph(&g, decision);
        assert!(split.split_nodes > 0, "hub must split at MDT 3");
        let parent_of = parent_of_table(&split, g.num_nodes());

        let mut wl = NodeWorklist::new();
        wl.push(0, g.degree(0));
        wl.push(5, g.degree(5));
        let split_wl = nodes_to_split(&split, &wl);
        // parent 0 plus its clones, plus node 5
        assert_eq!(
            split_wl.len(),
            2 + split.map.children(0).len()
        );
        let back = split_to_nodes(&g, &parent_of, &split_wl);
        assert_eq!(sorted_nodes(&back), vec![0, 5]);
    }

    #[test]
    fn split_frontier_degrees_are_bounded_by_mdt() {
        let g = hub_graph();
        let decision = MdtDecision {
            mdt: 3,
            peak_bin: 0,
            bins: 10,
            max_degree: 7,
        };
        let split = split_graph(&g, decision);
        let mut wl = NodeWorklist::new();
        wl.push(0, g.degree(0));
        let split_wl = nodes_to_split(&split, &wl);
        assert!(split_wl.degrees().iter().all(|&d| d <= 3));
        assert_eq!(split_wl.total_edges(), 7, "no pending edge lost");
    }

    #[test]
    fn spaces_cover_every_kind() {
        assert_eq!(space_of(StrategyKind::EP), Space::Edge);
        assert_eq!(space_of(StrategyKind::NS), Space::Split);
        for k in [StrategyKind::BS, StrategyKind::WD, StrategyKind::HP] {
            assert_eq!(space_of(k), Space::Node);
        }
        for s in crate::strategies::Schedule::NEW {
            assert_eq!(space_of(StrategyKind::Composed(s)), Space::Node);
        }
    }
}
