//! KernelSim-backed per-iteration cost prediction.
//!
//! For each candidate strategy the predictor synthesizes the thread
//! assignment that strategy would launch over the *actual* frontier degree
//! list and accounts it with the same [`KernelSim`] warp model the
//! execution path uses — so relative predictions track the simulator by
//! construction. Deliberately unmodelled (identical or second-order across
//! candidates): update atomics and worklist-append reservations. NS gets a
//! flat surcharge for its child-mirroring atomics, which the kernel shape
//! alone cannot see.

use crate::sim::{AccessPattern, DeviceSpec, KernelSim};
use crate::strategies::partition;
use crate::strategies::schedule::{Granularity, Order};
use crate::strategies::StrategyKind;

use super::policy::{requires_migration, PolicyInput};

/// Auxiliary-kernel cost for prediction without charging — delegates to
/// the shared formula on [`DeviceSpec::aux_kernel_cycles`], the same one
/// [`crate::coordinator::ExecCtx::charge_aux_kernel`] charges with.
pub fn aux_kernel_cycles(dev: &DeviceSpec, items: u64, per_item: u64) -> u64 {
    dev.aux_kernel_cycles(items, per_item)
}

/// Reusable predictor scratch: synthesized lane-step vectors plus the
/// [`KernelSim`] per-SM accumulators, so a warm policy predicts with zero
/// heap allocation — the arena discipline of the execution path, applied
/// to the decision path (a cost-model decision runs every iteration).
#[derive(Debug, Default, Clone)]
pub struct CostScratch {
    /// Synthesized per-lane step counts for the candidate kernel.
    lanes: Vec<u32>,
    /// HP's shrinking residual-degree list (distinct from `lanes`, which
    /// its inner WD fallback clobbers).
    residual: Vec<u32>,
    /// Histogram-binned prediction: the 33-entry bin histogram and the
    /// binned permutation (the same pair the execution path takes from the
    /// arena).
    bins: Vec<u32>,
    order: Vec<u32>,
    sm_a: Vec<u64>,
    sm_b: Vec<u64>,
}

/// Account one kernel whose lane `l` performs `lane_steps[l]` edge steps,
/// warp by warp in launch order (exactly how [`KernelSim`] sees the real
/// launch, minus atomics).
fn sim_lanes(
    dev: &DeviceSpec,
    lane_steps: &[u32],
    access: AccessPattern,
    extra_per_edge: u64,
    sm_a: &mut Vec<u64>,
    sm_b: &mut Vec<u64>,
) -> u64 {
    let warp = dev.warp_size as usize;
    let mut ks = KernelSim::new_with(dev, std::mem::take(sm_a), std::mem::take(sm_b));
    for chunk in lane_steps.chunks(warp) {
        let max_steps = chunk.iter().copied().max().unwrap_or(0);
        if max_steps == 0 {
            continue;
        }
        let mut w = ks.warp();
        for step in 0..max_steps {
            let active = chunk.iter().filter(|&&c| c > step).count() as u32;
            w.step(active, access);
            if extra_per_edge > 0 {
                w.extra(extra_per_edge * active as u64);
            }
        }
        ks.commit(w);
    }
    let (t, a, b) = ks.finish_into();
    *sm_a = a;
    *sm_b = b;
    t.cycles
}

/// BS: one lane per node walking its whole adjacency (scattered).
fn bs_cycles(dev: &DeviceSpec, degrees: &[u32], s: &mut CostScratch) -> u64 {
    sim_lanes(
        dev,
        degrees,
        AccessPattern::Scattered,
        0,
        &mut s.sm_a,
        &mut s.sm_b,
    )
}

/// EP: `min(T, W)` lanes, round-robin edges, coalesced, plus the one-time
/// CSR→COO conversion if the COO is not yet resident.
fn ep_cycles(dev: &DeviceSpec, total_edges: u64, max_threads: u32, s: &mut CostScratch) -> u64 {
    if total_edges == 0 {
        return dev.launch_overhead;
    }
    let t = (max_threads as u64).min(total_edges).max(1) as usize;
    let total = total_edges as usize;
    s.lanes.clear();
    for l in 0..t {
        s.lanes.push(((total - l - 1) / t + 1) as u32);
    }
    sim_lanes(
        dev,
        &s.lanes,
        AccessPattern::Coalesced,
        0,
        &mut s.sm_a,
        &mut s.sm_b,
    )
}

/// WD: blocked chunks of `⌈W/T⌉` edges, scattered, node-boundary
/// bookkeeping, plus the scan and `find_offsets` auxiliary kernels.
fn wd_cycles(
    dev: &DeviceSpec,
    total_edges: u64,
    wl_len: u64,
    max_threads: u32,
    s: &mut CostScratch,
) -> u64 {
    if total_edges == 0 {
        return dev.launch_overhead;
    }
    let t = (max_threads as u64).min(total_edges).max(1);
    let per = (total_edges + t - 1) / t;
    let lanes = ((total_edges + per - 1) / per) as usize;
    s.lanes.clear();
    s.lanes.resize(lanes, per as u32);
    let rem = total_edges - per * (lanes as u64 - 1);
    s.lanes[lanes - 1] = rem as u32;
    let kernel = sim_lanes(
        dev,
        &s.lanes,
        AccessPattern::Scattered,
        4,
        &mut s.sm_a,
        &mut s.sm_b,
    );
    let log_wl = (64 - wl_len.leading_zeros() as u64).max(1);
    kernel + aux_kernel_cycles(dev, wl_len, 1) + aux_kernel_cycles(dev, t, 4 * log_wl)
}

/// NS: one lane per (parent or clone) node, every lane ≤ MDT edges.
fn ns_cycles(dev: &DeviceSpec, degrees: &[u32], mdt: u32, s: &mut CostScratch) -> u64 {
    let mdt = mdt.max(1);
    s.lanes.clear();
    for &d in degrees {
        if d <= mdt {
            s.lanes.push(d);
            continue;
        }
        let pieces = ((d + mdt - 1) / mdt) as usize;
        let base = d / pieces as u32;
        let extra = (d as usize) % pieces;
        for p in 0..pieces {
            s.lanes.push(base + u32::from(p < extra));
        }
    }
    sim_lanes(
        dev,
        &s.lanes,
        AccessPattern::Scattered,
        0,
        &mut s.sm_a,
        &mut s.sm_b,
    )
}

/// HP: sub-iterations of ≤ MDT edges per remaining node, switching to a
/// WD-style kernel once the sub-list drops below one block (§III-C).
fn hp_cycles(
    dev: &DeviceSpec,
    degrees: &[u32],
    mdt: u32,
    max_threads: u32,
    s: &mut CostScratch,
) -> u64 {
    let mdt = mdt.max(1);
    let block = dev.block_size as usize;
    let total: u64 = degrees.iter().map(|&d| d as u64).sum();
    if degrees.len() < block {
        return wd_cycles(dev, total, degrees.len() as u64, max_threads, s);
    }
    s.residual.clear();
    s.residual.extend(degrees.iter().copied().filter(|&d| d > 0));
    let mut cycles = 0u64;
    while !s.residual.is_empty() {
        if s.residual.len() < block {
            let rem_edges: u64 = s.residual.iter().map(|&d| d as u64).sum();
            let rem_len = s.residual.len() as u64;
            cycles += wd_cycles(dev, rem_edges, rem_len, max_threads, s);
            break;
        }
        s.lanes.clear();
        for &d in &s.residual {
            s.lanes.push(d.min(mdt));
        }
        cycles += sim_lanes(
            dev,
            &s.lanes,
            AccessPattern::Scattered,
            2,
            &mut s.sm_a,
            &mut s.sm_b,
        );
        s.residual.retain_mut(|d| {
            if *d > mdt {
                *d -= mdt;
                true
            } else {
                false
            }
        });
        cycles += aux_kernel_cycles(dev, s.residual.len() as u64 + 1, 1);
    }
    cycles.max(dev.launch_overhead)
}

/// Composed merge-path (warp or block granularity): equal edge spans per
/// `width`-lane group, coalesced, dense-epilogue — mirrors
/// [`crate::strategies::schedule`]'s `merge_path_step` charge for charge
/// (prefix sum, diagonal searches, the relax kernel, the compaction pass).
fn composed_mp_cycles(
    dev: &DeviceSpec,
    total_edges: u64,
    wl_len: u64,
    width: u32,
    s: &mut CostScratch,
) -> u64 {
    let total = total_edges as usize;
    let mut cycles = aux_kernel_cycles(dev, wl_len, 1);
    if total == 0 {
        return cycles + dev.launch_overhead;
    }
    let chunks = partition::merge_path_chunks(total, width);
    let search_steps = (usize::BITS - total.leading_zeros()) as u64;
    cycles += aux_kernel_cycles(dev, chunks as u64 + 1, search_steps);
    let (base, rem) = (total / chunks as usize, total % chunks as usize);
    let w = width.max(1) as usize;
    s.lanes.clear();
    for c in 0..chunks as usize {
        let span = base + usize::from(c < rem);
        for r in 0..w {
            s.lanes
                .push(if r < span { ((span - r - 1) / w + 1) as u32 } else { 0 });
        }
    }
    cycles += sim_lanes(
        dev,
        &s.lanes,
        AccessPattern::Coalesced,
        0,
        &mut s.sm_a,
        &mut s.sm_b,
    );
    cycles + aux_kernel_cycles(dev, total as u64, 1)
}

/// Composed histogram-binned: two binning passes, then one lane per node
/// in binned order (the exact permutation `histogram_step` launches with).
fn composed_hist_cycles(dev: &DeviceSpec, degrees: &[u32], s: &mut CostScratch) -> u64 {
    let wl_len = degrees.len() as u64;
    let mut cycles = 2 * aux_kernel_cycles(dev, wl_len, 1);
    partition::histogram_bin_order_into(degrees, &mut s.bins, &mut s.order);
    s.lanes.clear();
    for &i in &s.order {
        s.lanes.push(degrees[i as usize]);
    }
    cycles += sim_lanes(
        dev,
        &s.lanes,
        AccessPattern::Scattered,
        0,
        &mut s.sm_a,
        &mut s.sm_b,
    );
    cycles.max(dev.launch_overhead)
}

/// Predicted cycles for one iteration of `kind` over the frontier in
/// `input`, including one-time setup the choice would trigger (COO
/// materialization for EP, the split rebuild for NS). Allocating wrapper
/// around [`predict_with`].
pub fn predict(kind: StrategyKind, input: &PolicyInput<'_>) -> u64 {
    let mut s = CostScratch::default();
    predict_with(kind, input, &mut s)
}

/// [`predict`] with caller-provided scratch — the zero-allocation path the
/// cost-model policy uses every iteration.
pub fn predict_with(kind: StrategyKind, input: &PolicyInput<'_>, s: &mut CostScratch) -> u64 {
    let dev = input.dev;
    let degs = input.degrees;
    let w = input.snapshot.edges;
    let wl_len = degs.len() as u64;
    let max_threads = input
        .params
        .max_threads
        .unwrap_or(dev.max_resident_threads);
    match kind {
        StrategyKind::BS => bs_cycles(dev, degs, s),
        StrategyKind::EP => {
            let mut c = ep_cycles(dev, w, max_threads, s);
            if !input.feasibility.coo_resident {
                c = c.saturating_add(aux_kernel_cycles(dev, input.graph_edges, 1));
            }
            c
        }
        StrategyKind::WD => wd_cycles(dev, w, wl_len, max_threads, s),
        StrategyKind::NS => {
            let mut c = ns_cycles(dev, degs, input.mdt, s);
            // Unmodelled child-mirroring atomics: flat ~15% surcharge.
            c = c.saturating_add(c / 7);
            if !input.feasibility.split_built {
                c = c.saturating_add(aux_kernel_cycles(
                    dev,
                    input.graph_edges + input.graph_nodes,
                    2,
                ));
            }
            c
        }
        StrategyKind::HP => hp_cycles(dev, degs, input.mdt, max_threads, s),
        // AD never predicts itself.
        StrategyKind::AD => u64::MAX,
        StrategyKind::Composed(sch) => {
            if let Some(alias) = sch.alias() {
                // An alias costs exactly what the monolithic strategy
                // costs — the composition *is* that strategy.
                return predict_with(alias, input, s);
            }
            match sch.order {
                Order::MergePath => {
                    let width = match sch.granularity {
                        Granularity::Warp => dev.warp_size,
                        _ => dev.block_size,
                    };
                    composed_mp_cycles(dev, w, wl_len, width, s)
                }
                Order::HistogramBinned => composed_hist_cycles(dev, degs, s),
                // Every sorted point is an alias; nothing reaches here (the
                // parser rejects unlowered compositions), but the model
                // must never *recommend* one either.
                Order::Sorted => u64::MAX,
            }
        }
    }
}

/// Penalty the cost model adds when choosing `to` would migrate the
/// worklist out of the current representation: one conversion kernel over
/// the frontier.
pub fn migration_cycles(input: &PolicyInput<'_>, to: StrategyKind) -> u64 {
    if requires_migration(input.current, to) {
        aux_kernel_cycles(input.dev, input.snapshot.nodes.max(1), 2)
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adaptive::inspect::FrontierInspector;
    use crate::adaptive::policy::Feasibility;
    use crate::strategies::StrategyParams;

    fn dev() -> DeviceSpec {
        DeviceSpec::k20c()
    }

    #[test]
    fn aux_formula_matches_exec_charge() {
        // Same numbers as ExecCtx::charge_aux_kernel for a known input.
        let d = dev();
        let mut ex = crate::coordinator::ExecCtx::new(
            &d,
            crate::algorithms::AlgoKind::Sssp,
            Box::new(crate::algorithms::NativeRelaxer),
        );
        ex.charge_aux_kernel(1000, 2);
        assert_eq!(ex.metrics.overhead_cycles, aux_kernel_cycles(&d, 1000, 2));
    }

    #[test]
    fn bs_pays_for_the_straggler_lane() {
        let d = dev();
        let mut s = CostScratch::default();
        let balanced = bs_cycles(&d, &[8u32; 32], &mut s);
        let mut skewed = vec![1u32; 31];
        skewed.push(8 * 32 - 31); // same total work, one hub lane
        let imbalanced = bs_cycles(&d, &skewed, &mut s);
        assert!(
            imbalanced > 2 * balanced,
            "hub lane {imbalanced} must dwarf balanced {balanced}"
        );
    }

    #[test]
    fn ep_beats_bs_on_skewed_frontiers() {
        let d = dev();
        let mut s = CostScratch::default();
        let mut degs = vec![2u32; 1000];
        degs.push(20_000);
        let total: u64 = degs.iter().map(|&x| x as u64).sum();
        let bs = bs_cycles(&d, &degs, &mut s);
        let ep = ep_cycles(&d, total, d.max_resident_threads, &mut s);
        assert!(ep < bs, "EP {ep} must beat BS {bs} on a hub frontier");
    }

    #[test]
    fn ns_clamps_the_hub() {
        let d = dev();
        let mut s = CostScratch::default();
        let mut degs = vec![2u32; 1000];
        degs.push(20_000);
        let bs = bs_cycles(&d, &degs, &mut s);
        let ns = ns_cycles(&d, &degs, 16, &mut s);
        assert!(ns < bs, "NS {ns} must beat BS {bs} once the hub is split");
    }

    #[test]
    fn empty_frontier_costs_one_launch() {
        let d = dev();
        let mut s = CostScratch::default();
        assert_eq!(ep_cycles(&d, 0, 1024, &mut s), d.launch_overhead);
        assert_eq!(wd_cycles(&d, 0, 0, 1024, &mut s), d.launch_overhead);
        assert_eq!(bs_cycles(&d, &[], &mut s), d.launch_overhead);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        // predict() with a fresh scratch and predict_with() on a warm one
        // must agree exactly — pooling is invisible to the numbers.
        let d = dev();
        let params = StrategyParams::default();
        let mut degs = vec![3u32; 4096];
        degs.push(9_000);
        let snap = FrontierInspector::inspect(&degs, &d);
        let input = PolicyInput {
            snapshot: &snap,
            degrees: &degs,
            current: StrategyKind::BS,
            feasibility: Feasibility {
                ep: true,
                wd: true,
                ns: true,
                coo_resident: false,
                split_built: false,
                composed: true,
            },
            dev: &d,
            params: &params,
            mdt: 8,
            graph_edges: 1 << 16,
            graph_nodes: 1 << 12,
        };
        let mut warm = CostScratch::default();
        let composed = crate::strategies::Schedule::NEW.map(StrategyKind::Composed);
        for kind in StrategyKind::ALL.into_iter().chain(composed) {
            let _ = predict_with(kind, &input, &mut warm); // warm the pool
        }
        for kind in StrategyKind::ALL.into_iter().chain(composed) {
            assert_eq!(
                predict(kind, &input),
                predict_with(kind, &input, &mut warm),
                "{kind}: warm scratch changed the prediction"
            );
        }
    }

    #[test]
    fn predict_covers_every_kind() {
        let d = dev();
        let params = StrategyParams::default();
        let degs = vec![4u32; 2048];
        let snap = FrontierInspector::inspect(&degs, &d);
        let input = PolicyInput {
            snapshot: &snap,
            degrees: &degs,
            current: StrategyKind::BS,
            feasibility: Feasibility {
                ep: true,
                wd: true,
                ns: true,
                coo_resident: false,
                split_built: false,
                composed: true,
            },
            dev: &d,
            params: &params,
            mdt: 4,
            graph_edges: 8192,
            graph_nodes: 2048,
        };
        for kind in StrategyKind::ALL {
            let c = predict(kind, &input);
            assert!(c > 0, "{kind} predicted zero cycles");
            assert!(c < u64::MAX);
        }
        assert_eq!(predict(StrategyKind::AD, &input), u64::MAX);
        for s in crate::strategies::Schedule::NEW {
            let c = predict(StrategyKind::Composed(s), &input);
            assert!(c > 0 && c < u64::MAX, "{s} prediction out of range");
        }
    }

    #[test]
    fn alias_predictions_equal_the_monolithic_strategy() {
        let d = dev();
        let params = StrategyParams::default();
        let mut degs = vec![3u32; 1024];
        degs.push(4_000);
        let snap = FrontierInspector::inspect(&degs, &d);
        let input = PolicyInput {
            snapshot: &snap,
            degrees: &degs,
            current: StrategyKind::BS,
            feasibility: Feasibility {
                ep: true,
                wd: true,
                ns: true,
                coo_resident: false,
                split_built: false,
                composed: true,
            },
            dev: &d,
            params: &params,
            mdt: 8,
            graph_edges: 1 << 14,
            graph_nodes: 1 << 11,
        };
        for (text, kind) in [
            ("thread/sorted", StrategyKind::BS),
            ("cta/sorted", StrategyKind::EP),
            ("thread/merge-path", StrategyKind::WD),
            ("block/sorted", StrategyKind::NS),
            ("warp/sorted", StrategyKind::HP),
        ] {
            let sched: crate::strategies::Schedule = text.parse().unwrap();
            assert_eq!(
                predict(StrategyKind::Composed(sched), &input),
                predict(kind, &input),
                "{text} must predict exactly like {kind}"
            );
        }
    }

    #[test]
    fn composed_merge_path_beats_bs_on_a_hub_frontier() {
        // The whole point of the warp merge-path lowering: equal spans
        // flatten the straggler lane BS serializes on.
        let d = dev();
        let mut s = CostScratch::default();
        let mut degs = vec![1u32; 2048];
        degs.push(100_000);
        let total: u64 = degs.iter().map(|&x| x as u64).sum();
        let bs = bs_cycles(&d, &degs, &mut s);
        let wmp = composed_mp_cycles(&d, total, degs.len() as u64, d.warp_size, &mut s);
        let bmp = composed_mp_cycles(&d, total, degs.len() as u64, d.block_size, &mut s);
        assert!(wmp < bs, "warp merge-path {wmp} must beat BS {bs}");
        assert!(bmp < bs, "block merge-path {bmp} must beat BS {bs}");
    }
}
