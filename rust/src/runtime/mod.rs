//! L3 ⇄ XLA bridge: loads the AOT-compiled artifacts produced by the
//! Python build path (`python/compile/aot.py`) and executes them on the
//! PJRT CPU client from the coordinator's hot loop.
//!
//! Interchange format is **HLO text** — jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md). Python never runs
//! at request time: `make artifacts` is the only compile step.

pub mod artifact;
pub mod relaxer;
#[doc(hidden)]
pub mod xla_stub;

pub use artifact::{ArtifactManifest, ArtifactRegistry};
pub use relaxer::XlaRelaxer;
