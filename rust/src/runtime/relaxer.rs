//! [`XlaRelaxer`] — the production relaxation backend: batched candidate
//! computation on the XLA CPU runtime through the AOT Pallas/JAX artifact.
//!
//! Distances travel as `i32` with `i32::MAX` as the infinity sentinel (the
//! kernel saturates there); the coordinator's `u32::MAX` infinity maps
//! to/from it at the boundary. Batches are padded up to the artifact's
//! static shape with `(INF, 0)` lanes, which are inert (INF stays INF).

use crate::algorithms::Relaxer;
use crate::error::{Error, Result};
use crate::INF;

use super::ArtifactRegistry;

// Offline build: the PJRT bindings are stubbed (see `xla_stub` docs).
use super::xla_stub as xla;

/// i32 infinity sentinel used inside the artifacts.
pub const INF_I32: i32 = i32::MAX;

/// Relaxer executing the `relax` artifact.
pub struct XlaRelaxer {
    registry: ArtifactRegistry,
    /// Scratch buffers reused across calls (hot-path allocation hygiene).
    src_buf: Vec<i32>,
    w_buf: Vec<i32>,
    /// Batches executed (diagnostics).
    pub executions: u64,
}

impl XlaRelaxer {
    /// Load artifacts from `dir` (expects `manifest.json` + HLO text files
    /// produced by `make artifacts`).
    pub fn load(dir: &str) -> Result<Self> {
        Ok(XlaRelaxer {
            registry: ArtifactRegistry::open(dir)?,
            src_buf: Vec::new(),
            w_buf: Vec::new(),
            executions: 0,
        })
    }

    /// PJRT platform (diagnostics).
    pub fn platform(&self) -> String {
        self.registry.platform()
    }

    fn to_i32(v: u32) -> i32 {
        if v == INF {
            INF_I32
        } else {
            v.min(INF_I32 as u32 - 1) as i32
        }
    }

    fn to_u32(v: i32) -> u32 {
        if v >= INF_I32 {
            INF
        } else {
            v.max(0) as u32
        }
    }

    /// Run one padded batch of exactly `batch` lanes; returns `take`
    /// candidates.
    fn run_batch(&mut self, batch: usize, take: usize, out: &mut Vec<u32>) -> Result<()> {
        debug_assert_eq!(self.src_buf.len(), batch);
        let exe = self.registry.executable("relax", batch)?;
        let x = xla::Literal::vec1(&self.src_buf);
        let y = xla::Literal::vec1(&self.w_buf);
        let result = exe
            .execute::<xla::Literal>(&[x, y])
            .map_err(|e| Error::Xla(format!("execute relax@{batch}: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Xla(e.to_string()))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let cand = result
            .to_tuple1()
            .map_err(|e| Error::Xla(e.to_string()))?
            .to_vec::<i32>()
            .map_err(|e| Error::Xla(e.to_string()))?;
        if cand.len() != batch {
            return Err(Error::Xla(format!(
                "relax@{batch} returned {} lanes",
                cand.len()
            )));
        }
        out.extend(cand[..take].iter().map(|&c| Self::to_u32(c)));
        self.executions += 1;
        Ok(())
    }
}

impl Relaxer for XlaRelaxer {
    fn candidates(&mut self, dist_src: &[u32], w: &[u32]) -> Result<Vec<u32>> {
        let mut out = Vec::with_capacity(dist_src.len());
        self.candidates_into(dist_src, w, &mut out)?;
        Ok(out)
    }

    /// Writes into the caller's pooled buffer; the staging (`src_buf` /
    /// `w_buf`) is reused across calls. The PJRT execute itself still owns
    /// its result literal — that allocation lives inside the runtime and
    /// is outside the arena's reach.
    fn candidates_into(&mut self, dist_src: &[u32], w: &[u32], out: &mut Vec<u32>) -> Result<()> {
        debug_assert_eq!(dist_src.len(), w.len());
        let total = dist_src.len();
        out.clear();
        let mut at = 0usize;
        while at < total {
            let remaining = total - at;
            let batch = self.registry.pick_batch("relax", remaining)?;
            let take = remaining.min(batch);
            self.src_buf.clear();
            self.w_buf.clear();
            self.src_buf
                .extend(dist_src[at..at + take].iter().map(|&d| Self::to_i32(d)));
            self.w_buf
                .extend(w[at..at + take].iter().map(|&x| x.min(INF_I32 as u32) as i32));
            // Pad inert lanes.
            self.src_buf.resize(batch, INF_I32);
            self.w_buf.resize(batch, 0);
            self.run_batch(batch, take, out)?;
            at += take;
        }
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentinel_mapping_roundtrips() {
        assert_eq!(XlaRelaxer::to_i32(INF), INF_I32);
        assert_eq!(XlaRelaxer::to_u32(INF_I32), INF);
        assert_eq!(XlaRelaxer::to_i32(5), 5);
        assert_eq!(XlaRelaxer::to_u32(5), 5);
        // negative garbage clamps to 0 rather than wrapping
        assert_eq!(XlaRelaxer::to_u32(-3), 0);
    }

    // End-to-end XLA tests live in rust/tests/backend_parity.rs and are
    // skipped when `make artifacts` hasn't run.
}
