//! Offline stand-in for the `xla` crate (PJRT / XLA bindings).
//!
//! The build environment carries no external crates, so the runtime layer
//! compiles against this shim instead of the real bindings. Every type and
//! method signature mirrors the subset of the `xla` crate the registry and
//! relaxer use, so swapping the real crate back in is a one-line change in
//! [`super::artifact`] / [`super::relaxer`] (replace the `use ... as xla`
//! alias with the external crate).
//!
//! Behaviour: [`PjRtClient::cpu`] fails with a descriptive error, so any
//! attempt to use the XLA backend surfaces as [`crate::Error::Xla`] before
//! reaching the stubbed execution paths. Manifest parsing and batch
//! selection (pure Rust) keep working and stay unit-tested.

use std::fmt;

/// Error type standing in for the binding crate's error.
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

type XlaResult<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "XLA runtime not linked in this build (offline xla_stub); \
         use the native backend"
            .to_string(),
    )
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a slice (stub: drops the data).
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    /// First element of a 1-tuple literal.
    pub fn to_tuple1(&self) -> XlaResult<Literal> {
        Err(unavailable())
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(unavailable())
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Synchronous device → host transfer.
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute over host inputs.
    pub fn execute<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client (stub). `cpu()` always fails, which is the single gate that
/// keeps the rest of the stub unreachable at runtime.
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU client.
    pub fn cpu() -> XlaResult<PjRtClient> {
        Err(unavailable())
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _c: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> XlaResult<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("offline xla_stub"));
    }
}
