//! Artifact registry: `artifacts/manifest.json` + compiled executables.
//!
//! The Python AOT path writes one HLO-text file per (kernel, batch size)
//! and a manifest describing them. The registry compiles each on first use
//! and caches the `PjRtLoadedExecutable`.

use crate::error::{Error, Result};
use crate::util::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

// Offline build: the PJRT bindings are stubbed. Swap in the real `xla`
// crate by replacing this alias (see `xla_stub` docs).
use super::xla_stub as xla;

/// One entry of `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Kernel name (e.g. `"relax"`).
    pub name: String,
    /// Static batch size the HLO was lowered for.
    pub batch: usize,
    /// File name within the artifact directory.
    pub file: String,
}

/// The manifest the AOT pass emits.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Producing jax version (informational).
    pub jax_version: String,
    pub artifacts: Vec<ArtifactEntry>,
}

impl ArtifactManifest {
    /// Read `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let data = std::fs::read_to_string(&path)
            .map_err(|_| Error::MissingArtifact(path.display().to_string()))?;
        let v = Json::parse(&data)
            .map_err(|e| Error::Xla(format!("bad manifest {}: {e}", path.display())))?;
        let bad = |m: &str| Error::Xla(format!("bad manifest {}: {m}", path.display()));
        let artifacts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing artifacts array"))?
            .iter()
            .map(|a| {
                Ok(ArtifactEntry {
                    name: a
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| bad("entry missing name"))?
                        .to_string(),
                    batch: a
                        .get("batch")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| bad("entry missing batch"))?,
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| bad("entry missing file"))?
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactManifest {
            jax_version: v
                .get("jax_version")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            artifacts,
        })
    }

    /// Batch sizes available for `name`, ascending.
    pub fn batches_for(&self, name: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.name == name)
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }
}

/// Lazily-compiled executables over a PJRT CPU client.
pub struct ArtifactRegistry {
    dir: PathBuf,
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    compiled: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
}

impl ArtifactRegistry {
    /// Open `dir`, read the manifest and create the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Xla(e.to_string()))?;
        Ok(ArtifactRegistry {
            dir,
            client,
            manifest,
            compiled: HashMap::new(),
        })
    }

    /// The manifest.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest available batch ≥ `len` for kernel `name`, or the largest
    /// batch if `len` exceeds all (callers chunk).
    pub fn pick_batch(&self, name: &str, len: usize) -> Result<usize> {
        let batches = self.manifest.batches_for(name);
        if batches.is_empty() {
            return Err(Error::MissingArtifact(format!(
                "kernel {name:?} not in manifest"
            )));
        }
        Ok(batches
            .iter()
            .copied()
            .find(|&b| b >= len)
            .unwrap_or(*batches.last().unwrap()))
    }

    /// Get (compiling on first use) the executable for `(name, batch)`.
    pub fn executable(
        &mut self,
        name: &str,
        batch: usize,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        let key = (name.to_string(), batch);
        if !self.compiled.contains_key(&key) {
            let entry = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name && a.batch == batch)
                .ok_or_else(|| {
                    Error::MissingArtifact(format!("{name} @ batch {batch} not in manifest"))
                })?;
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Xla("non-utf8 path".into()))?,
            )
            .map_err(|e| Error::Xla(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Xla(format!("compile {}: {e}", path.display())))?;
            self.compiled.insert(key.clone(), exe);
        }
        Ok(self.compiled.get(&key).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_and_batch_pick() {
        let dir = crate::util::tmp::TempPath::dir();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"{"jax_version":"0.8.2","artifacts":[
                {"name":"relax","batch":1024,"file":"relax_b1024.hlo.txt"},
                {"name":"relax","batch":8192,"file":"relax_b8192.hlo.txt"}
            ]}"#,
        )
        .unwrap();
        let m = ArtifactManifest::load(dir.path()).unwrap();
        assert_eq!(m.batches_for("relax"), vec![1024, 8192]);
        assert!(m.batches_for("nope").is_empty());
    }

    #[test]
    fn missing_manifest_is_missing_artifact_error() {
        let dir = crate::util::tmp::TempPath::dir();
        let err = ArtifactManifest::load(dir.path()).unwrap_err();
        assert!(matches!(err, Error::MissingArtifact(_)));
    }
}
