//! Run metrics: the kernel-time / overhead-time split of Figures 7 and 8,
//! plus counters that feed the trade-off analysis (Figure 9) and
//! EXPERIMENTS.md.

use crate::sim::{DeviceSpec, KernelTime, WarpStats};
use crate::telemetry::LogHistogram;

/// One adaptive-engine decision: which strategy ran a given outer iteration
/// and what the frontier looked like when the choice was made. Recorded by
/// [`crate::adaptive`]; empty for static-strategy runs.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRecord {
    /// Outer iteration index (0-based).
    pub iteration: u32,
    /// Label of the strategy chosen for the iteration ("BS", "EP", ...).
    pub strategy: &'static str,
    /// Whether the engine switched strategies this iteration (migrating the
    /// worklist representation when the two strategies disagree on it).
    pub migrated: bool,
    /// Frontier size in nodes when the decision was made.
    pub frontier_nodes: u64,
    /// Total outgoing edges of the frontier.
    pub frontier_edges: u64,
    /// Frontier degree skew (max / mean outdegree).
    pub degree_skew: f64,
    /// Cost-model estimate for the chosen strategy (0 when the policy does
    /// not predict, e.g. the heuristic policy).
    pub predicted_cycles: u64,
}

/// Accumulated metrics of one strategy × algorithm × graph run.
///
/// The paper splits execution time into "useful kernel time" and "the
/// overhead associated with implementing a strategy … initializations,
/// extra kernel invocations and bookkeeping" (§IV-A). Processing kernels
/// charge their body to `kernel_cycles` and their launch cost to
/// `overhead_cycles` (BS too — "Note that BS also has an overhead
/// component"); auxiliary kernels (scan, `find_offsets`, condensing,
/// splitting) charge wholly to `overhead_cycles`.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Useful processing-kernel cycles.
    pub kernel_cycles: u64,
    /// Strategy-implementation overhead cycles.
    pub overhead_cycles: u64,
    /// Outer worklist iterations.
    pub iterations: u32,
    /// Kernel launches (processing + auxiliary); HP's sub-iterations show
    /// up here.
    pub kernel_launches: u32,
    /// Edge relaxation steps executed (the paper's TEPS numerator).
    pub edge_relaxations: u64,
    /// Successful distance updates.
    pub updates: u64,
    /// Atomic operations issued.
    pub atomics: u64,
    /// Atomics that conflicted within a warp.
    pub atomic_conflicts: u64,
    /// Memory transactions issued.
    pub mem_transactions: u64,
    /// Peak raw worklist entries observed (pre-condensing).
    pub peak_worklist_entries: u64,
    /// Worklist entries removed by condensing.
    pub condensed_away: u64,
    /// Peak simulated device memory (bytes).
    pub peak_memory_bytes: u64,
    /// Host wall-clock spent in the coordinator itself (ns) — the L3 perf
    /// figure tracked in EXPERIMENTS.md §Perf.
    pub host_ns: u64,
    /// Times the adaptive engine switched strategies mid-run (0 for static
    /// strategies).
    pub strategy_switches: u64,
    /// Frontier-inspection passes performed (adaptive runs: one per outer
    /// iteration; batched serving: one per *batch* iteration, amortized
    /// across every query in the batch — the serving layer's headline
    /// saving).
    pub inspector_passes: u64,
    /// Policy decisions made (same amortization as `inspector_passes`).
    pub policy_decisions: u64,
    /// Scratch-arena checkouts that had to allocate a fresh buffer
    /// (warm-up traffic; see [`crate::arena::PerfCounters`]).
    pub scratch_created: u64,
    /// Scratch-arena checkouts served from the pool — the zero-allocation
    /// steady-state path.
    pub scratch_reused: u64,
    /// Peak heap bytes parked in the scratch arena (the price of pooling).
    pub scratch_peak_bytes: u64,
    /// Per-iteration decision trace of the adaptive engine (empty for
    /// static strategies).
    pub decisions: Vec<DecisionRecord>,
    /// Processing-kernel launches that committed at least one warp (the
    /// population behind the imbalance aggregates below).
    pub profiled_kernels: u64,
    /// Per-warp busy-cycle distribution across all profiled kernels
    /// (inline log₂ buckets — collecting this never allocates).
    pub warp_cycles_hist: LogHistogram,
    /// Per-kernel imbalance factor (max-warp ÷ mean-warp cycles),
    /// fixed-point ×1000 so it fits the integer histogram.
    pub imbalance_hist: LogHistogram,
    /// Σ over profiled kernels of (max-warp − mean-warp) cycles: the time
    /// the device spent waiting on stragglers — the paper's imbalance cost.
    pub imbalance_overhead_cycles: u64,
    /// Worst single-kernel imbalance factor seen, ×1000.
    pub peak_imbalance_x1000: u64,
}

impl RunMetrics {
    /// Fold one *processing* kernel: body → kernel, launch → overhead.
    pub fn charge_processing(&mut self, t: KernelTime, launch_overhead: u64) {
        let body = t.cycles.saturating_sub(launch_overhead);
        self.kernel_cycles += body;
        self.overhead_cycles += launch_overhead;
        self.kernel_launches += 1;
        self.absorb_counters(&t);
    }

    /// Fold one *auxiliary* kernel wholly into overhead.
    pub fn charge_aux(&mut self, t: KernelTime) {
        self.overhead_cycles += t.cycles;
        self.kernel_launches += 1;
        self.absorb_counters(&t);
    }

    /// Flat overhead cycles (host-side prep attributed to the device
    /// timeline, e.g. graph splitting, histogramming).
    pub fn charge_overhead(&mut self, cycles: u64) {
        self.overhead_cycles += cycles;
    }

    /// Append one adaptive-engine decision, updating the switch counter.
    pub fn record_decision(&mut self, rec: DecisionRecord) {
        if rec.migrated {
            self.strategy_switches += 1;
        }
        self.decisions.push(rec);
    }

    /// Fold one launch's per-warp distribution into the run-level imbalance
    /// aggregates. Allocation-free (histogram merges are fixed-size array
    /// adds); empty launches are skipped so they cannot dilute the factors.
    pub fn absorb_warp_profile(&mut self, p: &WarpStats) {
        if p.warps == 0 {
            return;
        }
        self.profiled_kernels += 1;
        self.warp_cycles_hist.merge(&p.hist);
        self.imbalance_overhead_cycles += p.tail_excess_cycles();
        let fx = (p.imbalance_factor() * 1000.0).round() as u64;
        self.imbalance_hist.record(fx);
        if fx > self.peak_imbalance_x1000 {
            self.peak_imbalance_x1000 = fx;
        }
    }

    /// Mean per-kernel imbalance factor over the profiled population
    /// (1.0 when nothing was profiled).
    pub fn mean_imbalance(&self) -> f64 {
        if self.imbalance_hist.is_empty() {
            1.0
        } else {
            self.imbalance_hist.mean() / 1000.0
        }
    }

    /// Worst per-kernel imbalance factor (1.0 when nothing was profiled).
    pub fn peak_imbalance(&self) -> f64 {
        if self.profiled_kernels == 0 {
            1.0
        } else {
            self.peak_imbalance_x1000 as f64 / 1000.0
        }
    }

    fn absorb_counters(&mut self, t: &KernelTime) {
        self.edge_relaxations += t.edge_steps;
        self.atomics += t.atomics;
        self.atomic_conflicts += t.atomic_conflicts;
        self.mem_transactions += t.mem_transactions;
    }

    /// Total simulated cycles.
    pub fn total_cycles(&self) -> u64 {
        self.kernel_cycles + self.overhead_cycles
    }

    /// Total simulated milliseconds on `dev`.
    pub fn total_ms(&self, dev: &DeviceSpec) -> f64 {
        dev.cycles_to_ms(self.total_cycles())
    }

    /// Kernel-only milliseconds.
    pub fn kernel_ms(&self, dev: &DeviceSpec) -> f64 {
        dev.cycles_to_ms(self.kernel_cycles)
    }

    /// Overhead-only milliseconds.
    pub fn overhead_ms(&self, dev: &DeviceSpec) -> f64 {
        dev.cycles_to_ms(self.overhead_cycles)
    }

    /// Millions of traversed edges per (simulated) second — the paper's
    /// MTEPS metric (§IV-A quotes 0.17 vs 0.54 MTEPS for rmat20 BFS).
    pub fn mteps(&self, dev: &DeviceSpec) -> f64 {
        let ms = self.total_ms(dev);
        if ms > 0.0 {
            self.edge_relaxations as f64 / (ms * 1e3)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(cycles: u64) -> KernelTime {
        KernelTime {
            cycles,
            warps: 1,
            edge_steps: 10,
            atomics: 2,
            atomic_conflicts: 1,
            mem_transactions: 5,
        }
    }

    #[test]
    fn processing_splits_launch_overhead() {
        let mut m = RunMetrics::default();
        m.charge_processing(t(10_000), 8_000);
        assert_eq!(m.kernel_cycles, 2_000);
        assert_eq!(m.overhead_cycles, 8_000);
        assert_eq!(m.kernel_launches, 1);
        assert_eq!(m.edge_relaxations, 10);
    }

    #[test]
    fn aux_is_all_overhead() {
        let mut m = RunMetrics::default();
        m.charge_aux(t(9_000));
        assert_eq!(m.kernel_cycles, 0);
        assert_eq!(m.overhead_cycles, 9_000);
    }

    #[test]
    fn decision_trace_counts_switches() {
        let mut m = RunMetrics::default();
        let rec = |iteration, strategy, migrated| DecisionRecord {
            iteration,
            strategy,
            migrated,
            frontier_nodes: 1,
            frontier_edges: 2,
            degree_skew: 1.0,
            predicted_cycles: 0,
        };
        m.record_decision(rec(0, "BS", false));
        m.record_decision(rec(1, "WD", true));
        m.record_decision(rec(2, "WD", false));
        assert_eq!(m.strategy_switches, 1);
        assert_eq!(m.decisions.len(), 3);
        assert_eq!(m.decisions[1].strategy, "WD");
    }

    #[test]
    fn warp_profiles_fold_into_imbalance_aggregates() {
        let mut m = RunMetrics::default();
        assert_eq!(m.mean_imbalance(), 1.0, "unprofiled run is neutral");
        assert_eq!(m.peak_imbalance(), 1.0);

        let mut hist = LogHistogram::new();
        for c in [100u64, 100, 100, 400] {
            hist.record(c);
        }
        let skewed = WarpStats {
            warps: 4,
            max_cycles: 400,
            sum_cycles: 700,
            sq_sum_cycles: 3 * 100 * 100 + 400 * 400,
            hist,
        };
        m.absorb_warp_profile(&skewed);
        // Empty launches must not dilute the aggregates.
        m.absorb_warp_profile(&WarpStats {
            warps: 0,
            max_cycles: 0,
            sum_cycles: 0,
            sq_sum_cycles: 0,
            hist: LogHistogram::new(),
        });
        assert_eq!(m.profiled_kernels, 1);
        assert_eq!(m.warp_cycles_hist.count(), 4);
        // factor = 400 / 175 ≈ 2.286 → 2286 fixed-point.
        assert_eq!(m.peak_imbalance_x1000, 2286);
        assert!((m.peak_imbalance() - 2.286).abs() < 1e-9);
        assert_eq!(m.imbalance_overhead_cycles, 400 - 700 / 4);
        assert_eq!(m.imbalance_hist.count(), 1);
    }

    #[test]
    fn mteps_uses_total_time() {
        let dev = DeviceSpec::k20c();
        let mut m = RunMetrics::default();
        m.charge_processing(t(706_000 + 8_000), 8_000); // 1 ms kernel + overhead
        let mteps = m.mteps(&dev);
        assert!(mteps > 0.0);
    }
}
