//! Batched candidate computation — the numeric hot path.
//!
//! A kernel launch relaxes a batch of edges. The candidate values
//! `cand[i] = sat_add(dist_src[i], w[i])` are computed for the whole batch
//! up front from a snapshot of the distance array (GPU threads read
//! possibly-stale values; the worklist re-push makes this safe), then the
//! launcher folds them in with `min` under the simulator's atomic
//! accounting.
//!
//! Two implementations exist:
//! * [`NativeRelaxer`] — pure Rust (simulation and oracle runs).
//! * [`crate::runtime::XlaRelaxer`] — executes the AOT-compiled
//!   Pallas/JAX artifact on the XLA CPU runtime (the production path).
//!
//! Both must agree bit-for-bit; `rust/tests/backend_parity.rs` enforces it.

use crate::error::Result;
use crate::INF;

/// Batched edge-relaxation candidate computation.
pub trait Relaxer {
    /// `cand[i] = dist_src[i] + w[i]`, saturating at [`INF`]; `INF` inputs
    /// stay `INF`.
    fn candidates(&mut self, dist_src: &[u32], w: &[u32]) -> Result<Vec<u32>>;

    /// [`Relaxer::candidates`] writing into a caller-provided buffer — the
    /// scratch-arena path of [`crate::coordinator::ExecCtx::launch`]. The
    /// default delegates (and so still allocates); backends on the
    /// per-iteration hot path should override it allocation-free.
    fn candidates_into(&mut self, dist_src: &[u32], w: &[u32], out: &mut Vec<u32>) -> Result<()> {
        let cand = self.candidates(dist_src, w)?;
        out.clear();
        out.extend_from_slice(&cand);
        Ok(())
    }

    /// Backend name for reporting.
    fn backend(&self) -> &'static str;
}

/// Pure-Rust relaxer.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeRelaxer;

impl Relaxer for NativeRelaxer {
    fn candidates(&mut self, dist_src: &[u32], w: &[u32]) -> Result<Vec<u32>> {
        let mut out = Vec::new();
        self.candidates_into(dist_src, w, &mut out)?;
        Ok(out)
    }

    fn candidates_into(&mut self, dist_src: &[u32], w: &[u32], out: &mut Vec<u32>) -> Result<()> {
        debug_assert_eq!(dist_src.len(), w.len());
        out.clear();
        out.extend(
            dist_src
                .iter()
                .zip(w)
                .map(|(&d, &w)| if d == INF { INF } else { d.saturating_add(w) }),
        );
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturates_at_inf() {
        let mut r = NativeRelaxer;
        let c = r
            .candidates(&[0, 5, INF, INF - 1], &[3, 7, 10, 10])
            .unwrap();
        assert_eq!(c, vec![3, 12, INF, INF]);
    }

    #[test]
    fn empty_batch() {
        let mut r = NativeRelaxer;
        assert!(r.candidates(&[], &[]).unwrap().is_empty());
    }

    #[test]
    fn into_variant_reuses_and_matches() {
        let mut r = NativeRelaxer;
        let mut out = vec![99u32; 8]; // stale content must be overwritten
        r.candidates_into(&[0, 5, INF], &[3, 7, 10], &mut out).unwrap();
        assert_eq!(out, vec![3, 12, INF]);
        assert_eq!(out, r.candidates(&[0, 5, INF], &[3, 7, 10]).unwrap());
    }
}
