//! Processing kernels: BFS and SSSP as min-propagation algorithms, plus the
//! batched relaxation abstraction shared by the native and XLA backends.
//!
//! Both algorithms are instances of the same *distributive* propagation
//! (§II-B): a candidate value is computed from the source attribute and the
//! edge (`dist[src] + w` for SSSP, `level[src] + 1` for BFS) and folded into
//! the destination with `min`. The distributivity of `min` over `+` is what
//! legitimizes edge-based task distribution for these kernels.

pub mod relax;

pub use relax::{NativeRelaxer, Relaxer};

/// Which propagation algorithm runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoKind {
    /// Breadth-first search: level computation, unit edge weights. A
    /// memory-bound kernel — "performs only a little computation" (§IV-A),
    /// so strategy overheads dominate on small graphs.
    Bfs,
    /// Single-source shortest paths: weighted relaxation with re-expansion
    /// when a distance improves. Computation-heavy relative to BFS.
    Sssp,
}

impl AlgoKind {
    /// The weight the relaxation actually uses: BFS ignores stored weights.
    #[inline]
    pub fn effective_weight(&self, stored: u32) -> u32 {
        match self {
            AlgoKind::Bfs => 1,
            AlgoKind::Sssp => stored,
        }
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Bfs => "bfs",
            AlgoKind::Sssp => "sssp",
        }
    }

    /// Serial oracle for validation.
    pub fn reference(&self, g: &crate::graph::Csr, source: crate::graph::NodeId) -> Vec<u32> {
        match self {
            AlgoKind::Bfs => crate::graph::traversal::bfs_levels(g, source),
            AlgoKind::Sssp => crate::graph::traversal::dijkstra(g, source),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfs_ignores_weights() {
        assert_eq!(AlgoKind::Bfs.effective_weight(99), 1);
        assert_eq!(AlgoKind::Sssp.effective_weight(99), 99);
    }
}
