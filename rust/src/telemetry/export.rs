//! Trace & metrics exporters: Chrome trace-event JSON (Perfetto /
//! `chrome://tracing`) and Prometheus-style text exposition.
//!
//! Both formats are emitted deterministically: the JSON rides on
//! [`crate::util::Json`] (object keys are `BTreeMap`-sorted, number
//! formatting is stable) and the exposition is appended in a fixed order —
//! so two runs with the same seed and config produce byte-identical files,
//! which is what the CI trace-determinism gate checks.

use super::{LogHistogram, TraceEvent, TraceEventKind, TraceSink, NO_ID};
use crate::util::Json;
use std::fmt::Write as _;

/// Export a sink as Chrome trace-event JSON.
///
/// Layout: one process (pid 1); tid 0 is the admission/scheduler track;
/// tid `i + 1` is shard `i` (named after `shard_devices[i]`). Shard busy
/// intervals and kernel launches are complete slices (`ph:"X"`), queue
/// depth and per-shard frontier size are counter tracks (`ph:"C"`), and
/// the admission/decision events are thread-scoped instants (`ph:"i"`).
/// Timestamps convert ps → µs (the trace-event unit) as `ts = at_ps/1e6`.
///
/// A `Kernel` event immediately followed by its `KernelProfile` companion
/// (same shard and timestamp) is rendered as **one** slice whose args carry
/// the full imbalance profile (`warps`, `imbalance`, `cv`, `occupancy`, …)
/// so Perfetto shows the straggler cost on hover. A profile whose kernel
/// was lost to ring wrap-around renders nothing.
pub fn chrome_trace(sink: &TraceSink, shard_devices: &[&str]) -> String {
    let mut events: Vec<Json> = Vec::with_capacity(sink.len() + shard_devices.len() + 2);
    events.push(meta_event(0, "process_name", "lonestar-lb (virtual ps clock)"));
    events.push(meta_event(0, "thread_name", "admission/scheduler"));
    for (i, name) in shard_devices.iter().enumerate() {
        events.push(meta_event(
            i as u64 + 1,
            "thread_name",
            &format!("shard {i} [{name}]"),
        ));
    }
    let evs: Vec<&TraceEvent> = sink.events().collect();
    let mut i = 0;
    while i < evs.len() {
        let ev = evs[i];
        if ev.kind == TraceEventKind::KernelProfile {
            // Orphaned profile (its kernel slice fell off the ring):
            // nothing to attach it to.
            i += 1;
            continue;
        }
        let profile = if ev.kind == TraceEventKind::Kernel {
            evs.get(i + 1).copied().filter(|p| {
                p.kind == TraceEventKind::KernelProfile
                    && p.shard == ev.shard
                    && p.at_ps == ev.at_ps
            })
        } else {
            None
        };
        if profile.is_some() {
            i += 1;
        }
        events.push(trace_event_json(ev, profile));
        i += 1;
    }
    Json::obj(vec![
        ("displayTimeUnit", "ms".into()),
        ("traceEvents", Json::Arr(events)),
    ])
    .to_string()
}

fn meta_event(tid: u64, name: &str, value: &str) -> Json {
    Json::obj(vec![
        ("ph", "M".into()),
        ("pid", 1u64.into()),
        ("tid", tid.into()),
        ("name", name.into()),
        ("args", Json::obj(vec![("name", value.into())])),
    ])
}

fn trace_event_json(ev: &TraceEvent, profile: Option<&TraceEvent>) -> Json {
    let tid: u64 = if ev.shard == NO_ID { 0 } else { ev.shard as u64 + 1 };
    let mut fields: Vec<(&str, Json)> = vec![
        ("pid", 1u64.into()),
        ("tid", tid.into()),
        ("ts", (ev.at_ps as f64 / 1e6).into()),
        ("cat", ev.kind.label().into()),
    ];
    let mut args: Vec<(&str, Json)> = Vec::new();
    if ev.query != NO_ID {
        args.push(("query", ev.query.into()));
    }
    match ev.kind {
        TraceEventKind::ShardBusy => {
            fields.push(("ph", "X".into()));
            fields.push(("name", "batch".into()));
            fields.push(("dur", (ev.a as f64 / 1e6).into()));
            args.push(("queries", ev.b.into()));
        }
        TraceEventKind::Kernel => {
            fields.push(("ph", "X".into()));
            let name = if ev.label.is_empty() { "kernel" } else { ev.label };
            fields.push(("name", name.into()));
            fields.push(("dur", (ev.a as f64 / 1e6).into()));
            args.push(("items", ev.b.into()));
            if let Some(p) = profile {
                let warps = p.a;
                let mean = if warps > 0 { ev.d as f64 / warps as f64 } else { 0.0 };
                let imbalance = if mean > 0.0 { ev.c as f64 / mean } else { 1.0 };
                let tx_per_item = if ev.b > 0 { p.b as f64 / ev.b as f64 } else { 0.0 };
                args.push(("warps", warps.into()));
                args.push(("mem_transactions", p.b.into()));
                args.push(("max_warp_cycles", ev.c.into()));
                args.push(("mean_warp_cycles", mean.into()));
                args.push(("imbalance", imbalance.into()));
                args.push(("cv", (p.c as f64 / 1e6).into()));
                args.push(("occupancy", (p.d as f64 / 1e6).into()));
                args.push(("mem_tx_per_item", tx_per_item.into()));
            }
        }
        TraceEventKind::KernelProfile => {
            // Paired profiles are folded into their kernel slice by
            // `chrome_trace`; a stray one renders as an instant so the
            // function stays total over every kind.
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("warps", ev.a.into()));
            args.push(("mem_transactions", ev.b.into()));
        }
        TraceEventKind::QueueDepth => {
            fields.push(("ph", "C".into()));
            fields.push(("name", "queue depth".into()));
            args.push(("depth", ev.a.into()));
        }
        TraceEventKind::FrontierSize => {
            fields.push(("ph", "C".into()));
            // Counter tracks are keyed by name: one per shard.
            fields.push(("name", Json::Str(format!("frontier (shard {})", ev.shard))));
            args.push(("nodes", ev.a.into()));
            args.push(("edges", ev.b.into()));
        }
        TraceEventKind::StrategyDecision => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", Json::Str(format!("decide {}", ev.label))));
            args.push(("frontier_nodes", ev.a.into()));
            args.push(("frontier_edges", ev.b.into()));
        }
        TraceEventKind::Migration => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", Json::Str(format!("migrate to {}", ev.label))));
        }
        TraceEventKind::BatchLaunch | TraceEventKind::BatchComplete => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("queries", ev.a.into()));
        }
        TraceEventKind::Admit => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("depth", ev.a.into()));
        }
        TraceEventKind::Place => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("load_edges", ev.a.into()));
        }
        TraceEventKind::Arrival | TraceEventKind::Drop | TraceEventKind::Block => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
        }
        TraceEventKind::FaultInject => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("code", ev.a.into()));
            args.push(("param", ev.b.into()));
        }
        TraceEventKind::ShardDown => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("permanent", ev.a.into()));
        }
        TraceEventKind::ShardUp => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("outage_ms", (ev.a as f64 / 1e9).into()));
        }
        TraceEventKind::Retry => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("attempt", ev.a.into()));
        }
        TraceEventKind::Requeue => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("attempts", ev.a.into()));
            // b = u64::MAX marks retry exhaustion (no eligibility instant).
            if ev.b != u64::MAX {
                args.push(("eligible_ms", (ev.b as f64 / 1e9).into()));
            } else {
                args.push(("exhausted", 1u64.into()));
            }
        }
        TraceEventKind::DeadlineExpired => {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
            fields.push(("name", ev.kind.label().into()));
            args.push(("deadline_ms", (ev.a as f64 / 1e9).into()));
        }
    }
    fields.push(("args", Json::obj(args)));
    Json::obj(fields)
}

/// Prometheus text-exposition builder (`--metrics-out`). Samples are
/// appended in call order; `# HELP`/`# TYPE` headers are emitted once per
/// metric name (group all samples of one name together, as the format
/// requires).
#[derive(Debug, Default)]
pub struct Exposition {
    out: String,
    last_name: String,
}

impl Exposition {
    /// An empty exposition.
    pub fn new() -> Exposition {
        Exposition::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        if self.last_name != name {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {kind}");
            self.last_name = name.to_string();
        }
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                let _ = write!(self.out, "{k}=\"{v}\"");
            }
            self.out.push('}');
        }
        // Whole numbers print as integers (same rule as Json::Num) so
        // counters read naturally and output is deterministic.
        let _ = writeln!(self.out, " {}", Json::Num(value));
    }

    /// Append a counter sample (header emitted on first use of `name`).
    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.header(name, help, "counter");
        self.sample(name, labels, value);
    }

    /// Append a gauge sample (header emitted on first use of `name`).
    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.header(name, help, "gauge");
        self.sample(name, labels, value);
    }

    /// Append a [`LogHistogram`] in Prometheus histogram form.
    /// `unit_scale` converts the recorded integer unit into the exposed
    /// unit (ps samples exposed as ms ⇒ `1e-9`). Buckets use cumulative
    /// counts with `le` at each occupied bucket's upper bound plus the
    /// mandatory `+Inf`.
    pub fn histogram(&mut self, name: &str, help: &str, hist: &LogHistogram, unit_scale: f64) {
        self.header(name, help, "histogram");
        let bucket = format!("{name}_bucket");
        let mut cum = 0u64;
        for (i, &c) in hist.buckets().iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            let le = Json::Num(LogHistogram::bucket_upper(i) as f64 * unit_scale).to_string();
            self.sample(&bucket, &[("le", &le)], cum as f64);
        }
        self.sample(&bucket, &[("le", "+Inf")], hist.count() as f64);
        self.sample(&format!("{name}_sum"), &[], hist.sum() as f64 * unit_scale);
        self.sample(&format!("{name}_count"), &[], hist.count() as f64);
        // _bucket/_sum/_count share the one header; reset so a following
        // metric with the same base prefix still gets its own.
        self.last_name = name.to_string();
    }

    /// The finished exposition text.
    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chrome_trace_shape_and_determinism() {
        let mut sink = TraceSink::with_capacity(16);
        sink.record(TraceEvent {
            query: 3,
            ..TraceEvent::new(TraceEventKind::Arrival, 1_000_000)
        });
        sink.record(TraceEvent {
            query: 3,
            a: 1,
            ..TraceEvent::new(TraceEventKind::Admit, 1_000_000)
        });
        sink.record(TraceEvent {
            a: 1,
            ..TraceEvent::new(TraceEventKind::QueueDepth, 1_000_000)
        });
        sink.record(TraceEvent {
            shard: 0,
            a: 5_000_000,
            b: 2,
            ..TraceEvent::new(TraceEventKind::ShardBusy, 2_000_000)
        });
        sink.record(TraceEvent {
            shard: 1,
            a: 2_000_000,
            b: 64,
            label: "relax_bs",
            ..TraceEvent::new(TraceEventKind::Kernel, 2_000_000)
        });

        let a = chrome_trace(&sink, &["k20c", "gtx680"]);
        let b = chrome_trace(&sink, &["k20c", "gtx680"]);
        assert_eq!(a, b, "export must be deterministic");

        let v = Json::parse(&a).expect("valid json");
        assert_eq!(v.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 metadata + 5 events.
        assert_eq!(evs.len(), 8);
        let meta: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .filter_map(|e| e.get("args").unwrap().get("name").unwrap().as_str())
            .collect();
        assert!(meta.contains(&"shard 0 [k20c]"));
        assert!(meta.contains(&"shard 1 [gtx680]"));
        let busy = evs
            .iter()
            .find(|e| e.get("name").map(|n| n.as_str()) == Some(Some("batch")))
            .expect("busy slice");
        assert_eq!(busy.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(busy.get("ts").unwrap().as_f64(), Some(2.0), "ps → µs");
        assert_eq!(busy.get("dur").unwrap().as_f64(), Some(5.0));
        assert_eq!(busy.get("tid").unwrap().as_usize(), Some(1), "shard 0 = tid 1");
        let depth = evs
            .iter()
            .find(|e| e.get("ph").unwrap().as_str() == Some("C"))
            .expect("counter");
        assert_eq!(depth.get("name").unwrap().as_str(), Some("queue depth"));
        assert_eq!(depth.get("tid").unwrap().as_usize(), Some(0), "queue on tid 0");
        let kernel = evs
            .iter()
            .find(|e| e.get("name").map(|n| n.as_str()) == Some(Some("relax_bs")))
            .expect("kernel slice");
        assert_eq!(kernel.get("tid").unwrap().as_usize(), Some(2), "shard 1 = tid 2");
    }

    #[test]
    fn kernel_profile_pairs_into_one_slice_with_imbalance_args() {
        let mut sink = TraceSink::with_capacity(16);
        sink.record(TraceEvent {
            shard: 0,
            a: 2_000_000,
            b: 100,
            c: 400, // max warp cycles
            d: 700, // Σ warp cycles
            label: "relax_bs",
            ..TraceEvent::new(TraceEventKind::Kernel, 5_000_000)
        });
        sink.record(TraceEvent {
            shard: 0,
            a: 4,       // warps
            b: 250,     // mem transactions
            c: 740_000, // CV ×1e6
            d: 62_500,  // occupancy ×1e6
            label: "relax_bs",
            ..TraceEvent::new(TraceEventKind::KernelProfile, 5_000_000)
        });
        // An orphaned profile (kernel lost to wrap-around) renders nothing.
        sink.record(TraceEvent {
            shard: 1,
            a: 8,
            ..TraceEvent::new(TraceEventKind::KernelProfile, 9_000_000)
        });

        let text = chrome_trace(&sink, &["k20c", "k20c"]);
        let v = Json::parse(&text).expect("valid json");
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 metadata + exactly one rendered event: the merged kernel slice.
        assert_eq!(evs.len(), 4);
        let kernel = evs
            .iter()
            .find(|e| e.get("name").map(|n| n.as_str()) == Some(Some("relax_bs")))
            .expect("kernel slice");
        assert_eq!(kernel.get("ph").unwrap().as_str(), Some("X"));
        let args = kernel.get("args").unwrap();
        assert_eq!(args.get("items").unwrap().as_usize(), Some(100));
        assert_eq!(args.get("warps").unwrap().as_usize(), Some(4));
        assert_eq!(args.get("mem_transactions").unwrap().as_usize(), Some(250));
        assert_eq!(args.get("max_warp_cycles").unwrap().as_usize(), Some(400));
        assert_eq!(args.get("mean_warp_cycles").unwrap().as_f64(), Some(175.0));
        let imb = args.get("imbalance").unwrap().as_f64().unwrap();
        assert!((imb - 400.0 / 175.0).abs() < 1e-9);
        assert_eq!(args.get("cv").unwrap().as_f64(), Some(0.74));
        assert_eq!(args.get("occupancy").unwrap().as_f64(), Some(0.0625));
        assert_eq!(args.get("mem_tx_per_item").unwrap().as_f64(), Some(2.5));
    }

    #[test]
    fn exposition_headers_once_labels_and_histogram() {
        let mut h = LogHistogram::new();
        h.record(1_000_000_000); // 1 ms
        h.record(3_000_000_000); // 3 ms
        let mut exp = Exposition::new();
        exp.counter("app_served_total", "Queries served", &[], 96.0);
        exp.gauge("app_util", "Busy fraction", &[("shard", "0"), ("device", "k20c")], 0.5);
        exp.gauge("app_util", "Busy fraction", &[("shard", "1"), ("device", "k40")], 0.25);
        exp.histogram("app_latency_ms", "Latency (ms)", &h, 1e-9);
        let text = exp.finish();

        assert_eq!(text.matches("# TYPE app_util gauge").count(), 1);
        assert!(text.contains("app_served_total 96\n"));
        assert!(text.contains("app_util{shard=\"0\",device=\"k20c\"} 0.5\n"));
        assert!(text.contains("# TYPE app_latency_ms histogram"));
        assert!(text.contains("app_latency_ms_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("app_latency_ms_count 2\n"));
        assert!(text.contains("app_latency_ms_sum 4\n"));
        // Cumulative bucket counts are monotone.
        let mut prev = 0.0;
        for line in text.lines().filter(|l| l.starts_with("app_latency_ms_bucket")) {
            let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "bucket counts must be cumulative: {line}");
            prev = v;
        }
    }
}
