//! Log₂-bucketed histogram: constant-size, allocation-free percentile
//! tracking for latency/wait distributions.
//!
//! The previous `ScheduleReport::p95_latency_ms` collected every outcome
//! into a fresh `Vec<u64>` and sorted it on **every call** — an allocation
//! and an O(n log n) sort to read one number. A [`LogHistogram`] is 65
//! fixed buckets updated with a `leading_zeros` in O(1); any percentile is
//! a single bucket walk. The price is resolution — a percentile is only
//! known to within its power-of-two bucket — which is the right trade for
//! monitoring: the *ratio* between p50 and p99 is what the load-balancing
//! analysis reads, not the fourth significant digit.

/// Power-of-two bucketed histogram over `u64` samples (we record virtual
/// picoseconds). Bucket 0 holds the value 0; bucket `i ≥ 1` holds
/// `[2^(i-1), 2^i)`; bucket 64 holds `[2^63, u64::MAX]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    buckets: [u64; 65],
    count: u64,
    max: u64,
    sum: u128,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram::new()
    }
}

impl LogHistogram {
    /// An empty histogram. No heap allocation — the buckets are inline.
    pub const fn new() -> LogHistogram {
        LogHistogram {
            buckets: [0; 65],
            count: 0,
            max: 0,
            sum: 0,
        }
    }

    /// Record one sample. O(1), allocation-free.
    #[inline]
    pub fn record(&mut self, v: u64) {
        let idx = if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v as u128;
        if v > self.max {
            self.max = v;
        }
    }

    /// Fold `other` into `self`: bucket-wise sums, max of maxes. O(65),
    /// allocation-free — how per-kernel warp histograms aggregate into
    /// per-run and per-pool distributions.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Largest sample seen (exact, not bucketed).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Sum of all samples (exact; u128 so ps sums cannot overflow).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Mean sample, 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Raw bucket counts (index semantics per the type docs).
    pub fn buckets(&self) -> &[u64; 65] {
        &self.buckets
    }

    /// Inclusive upper bound of bucket `i` — the value a percentile
    /// resolving to that bucket reports.
    pub fn bucket_upper(i: usize) -> u64 {
        match i {
            0 => 0,
            _ if i >= 64 => u64::MAX,
            _ => (1u64 << i) - 1,
        }
    }

    /// Nearest-rank percentile (`p` in 0..=100), reported as the bucket's
    /// inclusive upper bound, clamped to the exact tracked maximum (so
    /// `percentile(100) == max()`). Returns 0 when empty.
    pub fn percentile(&self, p: u8) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as u128 * p as u128).div_ceil(100) as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_upper(i).min(self.max);
            }
        }
        self.max
    }

    /// [`LogHistogram::percentile`] over picosecond samples, in ms.
    pub fn percentile_ms(&self, p: u8) -> f64 {
        self.percentile(p) as f64 / 1e9
    }

    /// Exact maximum over picosecond samples, in ms.
    pub fn max_ms(&self) -> f64 {
        self.max as f64 / 1e9
    }

    /// Mean over picosecond samples, in ms.
    pub fn mean_ms(&self) -> f64 {
        self.mean() / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_all_zero() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(50), 0);
        assert_eq!(h.percentile(100), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn bucket_boundaries() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1 << 62, u64::MAX] {
            h.record(v);
        }
        let b = h.buckets();
        assert_eq!(b[0], 1, "value 0");
        assert_eq!(b[1], 1, "value 1 = [1,2)");
        assert_eq!(b[2], 2, "values 2,3 = [2,4)");
        assert_eq!(b[3], 3, "values 4..8");
        assert_eq!(b[4], 1, "value 8");
        assert_eq!(b[63], 1, "1<<62");
        assert_eq!(b[64], 1, "u64::MAX");
        assert_eq!(h.count(), 9);
        assert_eq!(h.max(), u64::MAX);
    }

    #[test]
    fn percentiles_return_bucket_upper_clamped_to_max() {
        let mut h = LogHistogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        // rank 50 → value 50 → bucket [32,64) → upper 63.
        assert_eq!(h.percentile(50), 63);
        // rank 95 → value 95 → bucket [64,128) → upper 127, clamped to 100.
        assert_eq!(h.percentile(95), 100);
        assert_eq!(h.percentile(100), 100, "p100 is the exact max");
        assert_eq!(h.mean(), 50.5);
        assert_eq!(h.sum(), 5050);
    }

    #[test]
    fn percentile_is_monotone_and_bounds_exact_rank() {
        let mut h = LogHistogram::new();
        let samples = [3u64, 17, 17, 90, 1000, 1000, 1000, 40_000];
        for &v in &samples {
            h.record(v);
        }
        let mut prev = 0;
        for p in [1u8, 25, 50, 75, 90, 99, 100] {
            let got = h.percentile(p);
            assert!(got >= prev, "p{p} dropped below p of smaller rank");
            prev = got;
            // Nearest-rank exact value for comparison.
            let mut sorted = samples.to_vec();
            sorted.sort_unstable();
            let rank = ((sorted.len() * p as usize).div_ceil(100)).max(1);
            let exact = sorted[rank - 1];
            assert!(
                got >= exact,
                "p{p}: bucket upper {got} must bound exact {exact}"
            );
        }
    }

    #[test]
    fn empty_percentiles_are_zero_at_every_rank() {
        let h = LogHistogram::new();
        for p in [0u8, 1, 50, 95, 99, 100] {
            assert_eq!(h.percentile(p), 0, "empty histogram must report 0 at p{p}");
        }
        assert_eq!(h.sum(), 0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn single_sample_dominates_every_percentile() {
        let mut h = LogHistogram::new();
        h.record(12_345);
        for p in [1u8, 50, 95, 99, 100] {
            assert_eq!(h.percentile(p), 12_345, "one sample is every percentile");
        }
        assert_eq!(h.max(), 12_345);
        assert_eq!(h.mean(), 12_345.0);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn top_bucket_holds_values_at_and_above_its_bound() {
        // Bucket 64 holds [2^63, u64::MAX]: the bound itself, one past it,
        // and the largest representable value all land there, and the
        // percentile reports the exact tracked max (not the 2^64-1 upper).
        let mut h = LogHistogram::new();
        for v in [1u64 << 63, (1u64 << 63) + 1, u64::MAX] {
            h.record(v);
        }
        assert_eq!(h.buckets()[64], 3);
        assert_eq!(h.percentile(100), u64::MAX);
        assert_eq!(h.percentile(1), u64::MAX, "all mass in one bucket → max clamp");
        // Just below the bound lands in bucket 63.
        h.record((1u64 << 63) - 1);
        assert_eq!(h.buckets()[63], 1);
    }

    #[test]
    fn percentiles_stay_ordered_under_random_fills() {
        // Deterministic xorshift fill; p50 ≤ p95 ≤ p99 ≤ max must hold for
        // any sample population.
        let mut state = 0x9e37_79b9_7f4a_7c15u64;
        let mut h = LogHistogram::new();
        for _ in 0..10_000 {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            h.record(state >> (state % 50));
        }
        let (p50, p95, p99) = (h.percentile(50), h.percentile(95), h.percentile(99));
        assert!(p50 <= p95, "p50 {p50} > p95 {p95}");
        assert!(p95 <= p99, "p95 {p95} > p99 {p99}");
        assert!(p99 <= h.max(), "p99 {p99} > max {}", h.max());
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn merge_matches_recording_everything_into_one() {
        let samples_a = [0u64, 3, 17, 40_000, 1 << 40];
        let samples_b = [1u64, 17, 90, u64::MAX];
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut whole = LogHistogram::new();
        for &v in &samples_a {
            a.record(v);
            whole.record(v);
        }
        for &v in &samples_b {
            b.record(v);
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a, whole, "merge must equal recording the union");
        a.merge(&LogHistogram::new());
        assert_eq!(a, whole, "merging an empty histogram is a no-op");
    }

    #[test]
    fn ms_views_scale_by_1e9() {
        let mut h = LogHistogram::new();
        h.record(2_000_000_000); // 2 ms in ps → bucket upper 2^31-1
        assert_eq!(h.max_ms(), 2.0);
        assert_eq!(h.mean_ms(), 2.0);
        assert!(h.percentile_ms(50) <= 2.0 + 1e-9);
        assert!(h.percentile_ms(50) > 1.9);
    }
}
