//! Virtual-clock tracing & telemetry: a fixed-size, pre-allocated event
//! ring stamped on the simulator's picosecond clock.
//!
//! The paper's argument rests on *seeing* per-iteration imbalance — the
//! kernel/overhead split of Figures 7–8 — yet aggregates alone cannot say
//! *when* a shard sat idle or *which* iteration the adaptive policy
//! mis-chose. This module turns the deterministic virtual clock into a
//! first-class timeline:
//!
//! - [`TraceEvent`] is a fixed-width, `Copy` record (kind + ps timestamp +
//!   shard/query ids + two kind-specific payload words). No strings are
//!   built at record time; labels are `&'static str`.
//! - [`TraceSink`] is a ring buffer whose storage is allocated **once** at
//!   construction. Recording is an index write — zero allocations, so a
//!   sink can stay attached through the scheduler's steady state without
//!   violating the PR-3 counting-allocator invariant. When the ring wraps,
//!   the oldest events are overwritten (and counted), never reallocated.
//! - Because every timestamp comes from the virtual clock, a trace is a
//!   pure function of (graph, config, seed): two runs export byte-identical
//!   files. That determinism is what makes traces replayable — the
//!   ROADMAP's learned serving policies train on exactly these streams.
//!
//! Exporters live in [`export`]: Chrome trace-event JSON (open in Perfetto
//! or `chrome://tracing`) and a Prometheus-style text exposition.
//! [`hist::LogHistogram`] provides the log₂-bucketed latency/wait
//! histograms that replaced the allocating sort-based percentiles.

pub mod export;
pub mod hist;
pub mod spans;

pub use export::{chrome_trace, Exposition};
pub use hist::LogHistogram;
pub use spans::{kernel_records, profile_report, query_spans, KernelRecord, QuerySpan};

/// Shard/query id meaning "not applicable" (e.g. a queue-depth counter has
/// no shard; an arrival has no shard yet).
pub const NO_ID: u32 = u32::MAX;

/// Default ring capacity used by the CLI: 64 Ki events ≈ 2.5 MiB, enough
/// for the figure-scale streams without ever wrapping.
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// What happened. The payload words `a`/`b`/`c`/`d` of [`TraceEvent`] are
/// kind-specific (`c`/`d` are zero for every kind that does not list them):
///
/// | kind               | `a`                    | `b`              | `c`               | `d`               |
/// |--------------------|------------------------|------------------|-------------------|-------------------|
/// | `Admit`            | queue depth after      | —                | —                 | —                 |
/// | `Place`            | shard load (edges)     | —                | —                 | —                 |
/// | `BatchLaunch`      | batch width (queries)  | batch index      | —                 | —                 |
/// | `BatchComplete`    | batch width (queries)  | —                | —                 | —                 |
/// | `ShardBusy`        | busy duration (ps)     | batch width      | —                 | —                 |
/// | `StrategyDecision` | frontier nodes         | frontier edges   | —                 | —                 |
/// | `Migration`        | frontier nodes         | frontier edges   | —                 | —                 |
/// | `Kernel`           | kernel duration (ps)   | work items       | max warp cycles   | Σ warp cycles     |
/// | `QueueDepth`       | queue depth            | —                | —                 | —                 |
/// | `FrontierSize`     | frontier nodes         | frontier edges   | —                 | —                 |
/// | `KernelProfile`    | warps launched         | mem transactions | CV ×1e6           | occupancy ×1e6    |
/// | `FaultInject`      | fault code (see below) | fault parameter  | —                 | —                 |
/// | `ShardDown`        | 1 = permanent (kill)   | —                | —                 | —                 |
/// | `ShardUp`          | outage duration (ps)   | —                | —                 | —                 |
/// | `Retry`            | attempt number         | —                | —                 | —                 |
/// | `Requeue`          | attempts so far        | eligible instant (ps); `u64::MAX` = retries exhausted | — | — |
/// | `DeadlineExpired`  | deadline instant (ps)  | —                | —                 | —                 |
///
/// `FaultInject` codes in `a`: 0 = transient stall (down), 1 = permanent
/// death (kill), 2 = recovery (up), 3 = throughput degradation (slow,
/// `b` = ps_per_cycle multiplier), 4 = memory-budget shrink (`b` =
/// divisor of the device budget).
///
/// `KernelProfile` is the load-imbalance companion of a `Kernel` event: it
/// is recorded immediately after its kernel with the same timestamp, shard
/// and label, and carries the distribution facts that do not fit in the
/// kernel slice itself. Exporters pair the two records back up (see
/// [`spans::kernel_records`]); a profile whose kernel was lost to ring
/// wrap-around is skipped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum TraceEventKind {
    /// A query arrived at the admission queue.
    Arrival,
    /// The queue accepted a query (first try or un-blocked later).
    Admit,
    /// The drop overflow policy shed a query.
    Drop,
    /// The block overflow policy stalled a query.
    Block,
    /// The placement loop bound a query to a shard.
    Place,
    /// A shard launched a batch.
    BatchLaunch,
    /// A shard's batch completed (virtual time).
    BatchComplete,
    /// A shard's busy interval — the slice Perfetto renders per shard.
    ShardBusy,
    /// The adaptive engine chose a strategy for an iteration.
    StrategyDecision,
    /// The adaptive engine migrated worklist representations.
    Migration,
    /// One processing-kernel launch on a shard's device.
    Kernel,
    /// Admission-queue depth sample (counter track).
    QueueDepth,
    /// Frontier size sample (counter track, per shard).
    FrontierSize,
    /// Per-warp load-imbalance profile of the preceding `Kernel` event.
    KernelProfile,
    /// A fault-plan event fired on the virtual clock.
    FaultInject,
    /// A shard left service (transient stall or permanent death).
    ShardDown,
    /// A quarantined shard re-entered service (transient fault lifted).
    ShardUp,
    /// A requeued query re-entered the admission queue for another attempt.
    Retry,
    /// A failed/aborted batch returned a query to the retry buffer (or, on
    /// exhausted attempts, to the `failed` outcome).
    Requeue,
    /// A query exceeded its per-query deadline and was shed.
    DeadlineExpired,
}

impl TraceEventKind {
    /// Number of kinds (size of per-kind counter arrays).
    pub const COUNT: usize = 20;

    /// Every kind, in `repr` order.
    pub const ALL: [TraceEventKind; Self::COUNT] = [
        TraceEventKind::Arrival,
        TraceEventKind::Admit,
        TraceEventKind::Drop,
        TraceEventKind::Block,
        TraceEventKind::Place,
        TraceEventKind::BatchLaunch,
        TraceEventKind::BatchComplete,
        TraceEventKind::ShardBusy,
        TraceEventKind::StrategyDecision,
        TraceEventKind::Migration,
        TraceEventKind::Kernel,
        TraceEventKind::QueueDepth,
        TraceEventKind::FrontierSize,
        TraceEventKind::KernelProfile,
        TraceEventKind::FaultInject,
        TraceEventKind::ShardDown,
        TraceEventKind::ShardUp,
        TraceEventKind::Retry,
        TraceEventKind::Requeue,
        TraceEventKind::DeadlineExpired,
    ];

    /// Stable lowercase label (metric label values, trace categories).
    pub fn label(self) -> &'static str {
        match self {
            TraceEventKind::Arrival => "arrival",
            TraceEventKind::Admit => "admit",
            TraceEventKind::Drop => "drop",
            TraceEventKind::Block => "block",
            TraceEventKind::Place => "place",
            TraceEventKind::BatchLaunch => "batch-launch",
            TraceEventKind::BatchComplete => "batch-complete",
            TraceEventKind::ShardBusy => "shard-busy",
            TraceEventKind::StrategyDecision => "decision",
            TraceEventKind::Migration => "migration",
            TraceEventKind::Kernel => "kernel",
            TraceEventKind::QueueDepth => "queue-depth",
            TraceEventKind::FrontierSize => "frontier-size",
            TraceEventKind::KernelProfile => "kernel-profile",
            TraceEventKind::FaultInject => "fault-inject",
            TraceEventKind::ShardDown => "shard-down",
            TraceEventKind::ShardUp => "shard-up",
            TraceEventKind::Retry => "retry",
            TraceEventKind::Requeue => "requeue",
            TraceEventKind::DeadlineExpired => "deadline-expired",
        }
    }
}

/// One fixed-width trace record. Construct with [`TraceEvent::new`] and
/// struct-update syntax for the fields that apply:
///
/// ```
/// use lonestar_lb::telemetry::{TraceEvent, TraceEventKind};
/// let ev = TraceEvent { shard: 1, a: 42, ..TraceEvent::new(TraceEventKind::QueueDepth, 1_000) };
/// assert_eq!(ev.at_ps, 1_000);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Virtual timestamp, integer picoseconds.
    pub at_ps: u64,
    /// What happened.
    pub kind: TraceEventKind,
    /// Shard index, or [`NO_ID`] for scheduler-/queue-level events.
    pub shard: u32,
    /// Query id, or [`NO_ID`] when the event is not per-query.
    pub query: u32,
    /// Kind-specific payload (see [`TraceEventKind`]).
    pub a: u64,
    /// Kind-specific payload (see [`TraceEventKind`]).
    pub b: u64,
    /// Kind-specific payload (see [`TraceEventKind`]); zero for most kinds.
    pub c: u64,
    /// Kind-specific payload (see [`TraceEventKind`]); zero for most kinds.
    pub d: u64,
    /// Optional static label (kernel name, strategy label). Empty when the
    /// kind's label suffices.
    pub label: &'static str,
}

impl TraceEvent {
    /// A `kind` event at `at_ps` with no shard, no query, zero payload.
    pub fn new(kind: TraceEventKind, at_ps: u64) -> TraceEvent {
        TraceEvent {
            at_ps,
            kind,
            shard: NO_ID,
            query: NO_ID,
            a: 0,
            b: 0,
            c: 0,
            d: 0,
            label: "",
        }
    }
}

impl Default for TraceEvent {
    fn default() -> TraceEvent {
        TraceEvent::new(TraceEventKind::Arrival, 0)
    }
}

/// Fixed-capacity event ring. All storage is allocated in
/// [`TraceSink::with_capacity`]; [`TraceSink::record`] is an index write.
/// On overflow the oldest events are overwritten (counted in
/// [`TraceSink::overwritten`]) — tracing never grows the heap mid-run.
#[derive(Debug)]
pub struct TraceSink {
    buf: Vec<TraceEvent>,
    /// Next write slot.
    head: usize,
    /// Live events (≤ capacity).
    len: usize,
    /// Total events ever recorded (including overwritten ones).
    recorded: u64,
    /// Events lost to ring wrap-around.
    overwritten: u64,
    /// Per-kind totals (never lost to wrap-around).
    kind_counts: [u64; TraceEventKind::COUNT],
}

impl TraceSink {
    /// A sink holding up to `capacity` events (min 1). The one and only
    /// allocation this type ever performs happens here.
    pub fn with_capacity(capacity: usize) -> TraceSink {
        let capacity = capacity.max(1);
        TraceSink {
            buf: vec![TraceEvent::default(); capacity],
            head: 0,
            len: 0,
            recorded: 0,
            overwritten: 0,
            kind_counts: [0; TraceEventKind::COUNT],
        }
    }

    /// Record one event: a ring-slot write plus counter bumps. Never
    /// allocates.
    #[inline]
    pub fn record(&mut self, ev: TraceEvent) {
        let cap = self.buf.len();
        self.buf[self.head] = ev;
        self.head = (self.head + 1) % cap;
        if self.len < cap {
            self.len += 1;
        } else {
            self.overwritten += 1;
        }
        self.recorded += 1;
        self.kind_counts[ev.kind as usize] += 1;
    }

    /// Live events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        let cap = self.buf.len();
        let start = (self.head + cap - self.len) % cap;
        (0..self.len).map(move |i| &self.buf[(start + i) % cap])
    }

    /// Live event count (≤ capacity).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing has been recorded (or everything was cleared).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Total events ever recorded, including those lost to wrap-around.
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Events lost to wrap-around (0 means the export is complete).
    pub fn overwritten(&self) -> u64 {
        self.overwritten
    }

    /// Lifetime total for one kind (survives wrap-around).
    pub fn kind_count(&self, kind: TraceEventKind) -> u64 {
        self.kind_counts[kind as usize]
    }

    /// Replay another sink's surviving events into this ring and fold in
    /// the counts of the events it already lost to wrap-around, exactly
    /// as if every event `other` ever saw had been [`record`]ed here
    /// directly, in order. Never allocates.
    ///
    /// This is the parallel scheduler's deterministic trace merge: each
    /// shard's worker records engine events into a private ring of the
    /// **same capacity** as the main sink, and the coordinator absorbs
    /// the rings in fixed shard order. With equal capacities the
    /// reproduction is byte-exact in every wrap-around regime — events
    /// `other` dropped are ones this ring would also have dropped (they
    /// are followed by ≥ capacity others from `other` alone), and the
    /// counter adjustments below account for them:
    ///
    /// * `recorded` grows by everything `other` saw (replayed + lost);
    /// * `overwritten` grows by `other`'s losses plus whatever the
    ///   replay itself evicts here;
    /// * `kind_counts` fold in `other`'s lifetime totals (the replay
    ///   writes ring slots directly, so survivors and lost events alike
    ///   are covered by the one fold).
    pub fn absorb(&mut self, other: &TraceSink) {
        debug_assert_eq!(
            self.capacity(),
            other.capacity(),
            "absorb is byte-exact only for equal ring capacities"
        );
        let cap = self.buf.len();
        let start = (other.head + other.buf.len() - other.len) % other.buf.len();
        for i in 0..other.len {
            self.buf[self.head] = other.buf[(start + i) % other.buf.len()];
            self.head = (self.head + 1) % cap;
            if self.len < cap {
                self.len += 1;
            } else {
                self.overwritten += 1;
            }
        }
        self.recorded += other.recorded;
        self.overwritten += other.recorded - other.len as u64;
        for k in 0..TraceEventKind::COUNT {
            self.kind_counts[k] += other.kind_counts[k];
        }
    }

    /// Forget all events and totals; capacity (and its allocation) stays.
    pub fn clear(&mut self) {
        self.head = 0;
        self.len = 0;
        self.recorded = 0;
        self.overwritten = 0;
        self.kind_counts = [0; TraceEventKind::COUNT];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: TraceEventKind, at_ps: u64) -> TraceEvent {
        TraceEvent::new(kind, at_ps)
    }

    #[test]
    fn ring_records_in_order_and_wraps() {
        let mut sink = TraceSink::with_capacity(4);
        for i in 0..3 {
            sink.record(ev(TraceEventKind::Arrival, i));
        }
        assert_eq!(sink.len(), 3);
        assert_eq!(sink.overwritten(), 0);
        let ts: Vec<u64> = sink.events().map(|e| e.at_ps).collect();
        assert_eq!(ts, vec![0, 1, 2]);

        for i in 3..6 {
            sink.record(ev(TraceEventKind::Admit, i));
        }
        assert_eq!(sink.len(), 4, "ring holds exactly capacity");
        assert_eq!(sink.recorded(), 6);
        assert_eq!(sink.overwritten(), 2);
        let ts: Vec<u64> = sink.events().map(|e| e.at_ps).collect();
        assert_eq!(ts, vec![2, 3, 4, 5], "oldest events overwritten first");
    }

    #[test]
    fn kind_counts_survive_wraparound() {
        let mut sink = TraceSink::with_capacity(2);
        for i in 0..5 {
            sink.record(ev(TraceEventKind::Drop, i));
        }
        sink.record(ev(TraceEventKind::Block, 9));
        assert_eq!(sink.kind_count(TraceEventKind::Drop), 5);
        assert_eq!(sink.kind_count(TraceEventKind::Block), 1);
        assert_eq!(sink.kind_count(TraceEventKind::Admit), 0);
        sink.clear();
        assert!(sink.is_empty());
        assert_eq!(sink.kind_count(TraceEventKind::Drop), 0);
        assert_eq!(sink.capacity(), 2, "clear keeps the allocation");
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let mut sink = TraceSink::with_capacity(0);
        assert_eq!(sink.capacity(), 1);
        sink.record(ev(TraceEventKind::Arrival, 7));
        assert_eq!(sink.events().next().unwrap().at_ps, 7);
    }

    /// `absorb` must be indistinguishable from having recorded the other
    /// sink's events directly — the invariant the parallel scheduler's
    /// trace merge rests on. Exercised in three regimes: no wrap, the
    /// absorbed batch wrapping the target, and a pre-wrapped source.
    #[test]
    fn absorb_matches_direct_recording() {
        // (target capacity, events already in target, events in source)
        for &(cap, pre, n) in &[(8usize, 3u64, 4u64), (4, 3, 6), (3, 2, 9), (5, 7, 11)] {
            let mut direct = TraceSink::with_capacity(cap);
            let mut target = TraceSink::with_capacity(cap);
            for i in 0..pre {
                direct.record(ev(TraceEventKind::Arrival, i));
                target.record(ev(TraceEventKind::Arrival, i));
            }
            let mut source = TraceSink::with_capacity(cap);
            for i in 0..n {
                // Alternate kinds so per-kind counters are exercised too.
                let kind = if i % 2 == 0 {
                    TraceEventKind::Kernel
                } else {
                    TraceEventKind::FrontierSize
                };
                direct.record(ev(kind, 100 + i));
                source.record(ev(kind, 100 + i));
            }
            target.absorb(&source);
            let d: Vec<u64> = direct.events().map(|e| e.at_ps).collect();
            let t: Vec<u64> = target.events().map(|e| e.at_ps).collect();
            assert_eq!(d, t, "cap={cap} pre={pre} n={n}: event order");
            assert_eq!(direct.recorded(), target.recorded(), "cap={cap} pre={pre} n={n}");
            assert_eq!(direct.overwritten(), target.overwritten(), "cap={cap} pre={pre} n={n}");
            for kind in TraceEventKind::ALL {
                assert_eq!(
                    direct.kind_count(kind),
                    target.kind_count(kind),
                    "cap={cap} pre={pre} n={n}: {}",
                    kind.label()
                );
            }
        }
    }

    #[test]
    fn absorb_empty_source_is_a_noop() {
        let mut target = TraceSink::with_capacity(4);
        target.record(ev(TraceEventKind::Admit, 1));
        let source = TraceSink::with_capacity(4);
        target.absorb(&source);
        assert_eq!(target.len(), 1);
        assert_eq!(target.recorded(), 1);
        assert_eq!(target.overwritten(), 0);
    }

    #[test]
    fn kind_repr_matches_all_table() {
        for (i, k) in TraceEventKind::ALL.iter().enumerate() {
            assert_eq!(*k as usize, i, "ALL must follow repr order");
            assert!(!k.label().is_empty());
        }
    }
}
