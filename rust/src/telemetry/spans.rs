//! Span reconstruction: turn the flat [`TraceEvent`] ring back into
//! per-kernel load-imbalance records, per-query lifecycle spans
//! (arrival → admit → place → launch → complete) and per-batch
//! critical-path summaries.
//!
//! The ring records *facts*; this module recovers *attribution*: how much
//! of a query's latency was queue wait, how much was placement stall, how
//! much was compute — and, within compute, how many cycles the device
//! spent waiting on straggler warps (the paper's imbalance overhead).
//! Everything here runs at export time on an immutable sink, so ordinary
//! allocation is fine; the zero-alloc constraint applies only to
//! recording.
//!
//! The latency decomposition is conservative **by construction**:
//! `queue_wait + placement_stall + compute` is a telescoping sum of
//! `(place − arrival) + (launch − place) + (done − launch)`, which equals
//! `done − arrival` — the reported latency — exactly, in integer
//! picoseconds. A telemetry test pins this.

use super::{TraceEventKind, TraceSink};
use crate::util::Json;
use std::collections::BTreeMap;

/// One profiled kernel launch, reconstructed from a `Kernel` event and its
/// immediately-following `KernelProfile` companion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelRecord {
    /// Shard the kernel ran on (0 on the single-device `run` path).
    pub shard: u32,
    /// Slice start on the shared virtual timeline, ps.
    pub start_ps: u64,
    /// Slice duration, ps.
    pub dur_ps: u64,
    /// Work items (batch positions) the kernel processed.
    pub items: u64,
    /// Warps committed.
    pub warps: u64,
    /// Busiest warp, cycles.
    pub max_warp_cycles: u64,
    /// Σ warp cycles.
    pub warp_cycles_sum: u64,
    /// Memory transactions issued.
    pub mem_transactions: u64,
    /// Coefficient of variation of warp cycles (σ / mean).
    pub cv: f64,
    /// Achieved occupancy (resident threads / device capacity).
    pub occupancy: f64,
    /// Kernel name.
    pub label: &'static str,
}

impl KernelRecord {
    /// Mean warp cycles, 0.0 for an empty launch.
    pub fn mean_warp_cycles(&self) -> f64 {
        if self.warps == 0 {
            0.0
        } else {
            self.warp_cycles_sum as f64 / self.warps as f64
        }
    }

    /// Imbalance factor: max-warp ÷ mean-warp cycles (1.0 when empty or
    /// perfectly balanced).
    pub fn imbalance_factor(&self) -> f64 {
        let mean = self.mean_warp_cycles();
        if mean <= 0.0 {
            1.0
        } else {
            self.max_warp_cycles as f64 / mean
        }
    }

    /// Max-warp − mean-warp cycles (integer floor): what the launch paid
    /// for its slowest warp.
    pub fn tail_excess_cycles(&self) -> u64 {
        if self.warps == 0 {
            return 0;
        }
        self.max_warp_cycles
            .saturating_sub(self.warp_cycles_sum / self.warps)
    }

    /// Memory transactions per work item (per edge for edge kernels).
    pub fn mem_tx_per_item(&self) -> f64 {
        if self.items == 0 {
            0.0
        } else {
            self.mem_transactions as f64 / self.items as f64
        }
    }
}

/// Pair every `Kernel` event with its `KernelProfile` companion (recorded
/// adjacently, same timestamp/shard/label) into [`KernelRecord`]s, in ring
/// order. A kernel whose profile was lost to wrap-around yields a record
/// with zeroed distribution fields; an orphaned profile (its kernel was
/// overwritten) is skipped.
pub fn kernel_records(sink: &TraceSink) -> Vec<KernelRecord> {
    let events: Vec<_> = sink.events().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < events.len() {
        let ev = events[i];
        if ev.kind != TraceEventKind::Kernel {
            i += 1;
            continue;
        }
        let mut rec = KernelRecord {
            shard: ev.shard,
            start_ps: ev.at_ps,
            dur_ps: ev.a,
            items: ev.b,
            warps: 0,
            max_warp_cycles: ev.c,
            warp_cycles_sum: ev.d,
            mem_transactions: 0,
            cv: 0.0,
            occupancy: 0.0,
            label: ev.label,
        };
        if let Some(p) = events.get(i + 1) {
            if p.kind == TraceEventKind::KernelProfile
                && p.shard == ev.shard
                && p.at_ps == ev.at_ps
            {
                rec.warps = p.a;
                rec.mem_transactions = p.b;
                rec.cv = p.c as f64 / 1e6;
                rec.occupancy = p.d as f64 / 1e6;
                i += 1;
            }
        }
        out.push(rec);
        i += 1;
    }
    out
}

/// One served query's reconstructed lifecycle on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuerySpan {
    /// Query id.
    pub query: u32,
    /// Shard that served it.
    pub shard: u32,
    /// Arrival at the admission queue, ps.
    pub arrival_ps: u64,
    /// Admission into the bounded queue (later than arrival only under the
    /// block overflow policy), ps.
    pub admit_ps: u64,
    /// Placement onto the shard, ps.
    pub place_ps: u64,
    /// Batch launch, ps.
    pub launch_ps: u64,
    /// Batch completion, ps.
    pub done_ps: u64,
}

impl QuerySpan {
    /// Arrival → completion, ps.
    pub fn latency_ps(&self) -> u64 {
        self.done_ps - self.arrival_ps
    }

    /// Arrival → placement: time spent blocked and in the admission
    /// queue, ps.
    pub fn queue_wait_ps(&self) -> u64 {
        self.place_ps - self.arrival_ps
    }

    /// Placement → batch launch: placed on a shard, waiting for the batch
    /// to form/dispatch, ps.
    pub fn placement_stall_ps(&self) -> u64 {
        self.launch_ps - self.place_ps
    }

    /// Batch launch → completion, ps.
    pub fn compute_ps(&self) -> u64 {
        self.done_ps - self.launch_ps
    }

    /// Σ tail-excess cycles of `records` kernels inside this span's compute
    /// window on its shard, converted to ps at `ps_per_cycle` — the slice
    /// of this query's latency attributable to warp-level load imbalance.
    pub fn imbalance_overhead_ps(&self, records: &[KernelRecord], ps_per_cycle: u64) -> u64 {
        records
            .iter()
            .filter(|r| {
                r.shard == self.shard
                    && r.start_ps >= self.launch_ps
                    && r.start_ps < self.done_ps
            })
            .map(|r| r.tail_excess_cycles() * ps_per_cycle)
            .sum()
    }
}

/// Reconstruct per-query spans from a scheduler-path sink, in completion
/// order (ties broken by query id). Dropped queries never complete and are
/// excluded; a run-path sink (no admission events) yields an empty vec.
pub fn query_spans(sink: &TraceSink) -> Vec<QuerySpan> {
    #[derive(Clone, Copy)]
    struct Partial {
        arrival_ps: u64,
        admit_ps: u64,
        place_ps: u64,
    }
    let mut building: BTreeMap<u32, Partial> = BTreeMap::new();
    // Per shard: (query, launch_ps) placed-not-launched, then running.
    let mut pending: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
    let mut running: BTreeMap<u32, (u64, Vec<u32>)> = BTreeMap::new();
    let mut done: Vec<QuerySpan> = Vec::new();

    for ev in sink.events() {
        match ev.kind {
            TraceEventKind::Arrival => {
                building.insert(
                    ev.query,
                    Partial {
                        arrival_ps: ev.at_ps,
                        admit_ps: ev.at_ps,
                        place_ps: ev.at_ps,
                    },
                );
            }
            TraceEventKind::Admit => {
                if let Some(p) = building.get_mut(&ev.query) {
                    p.admit_ps = ev.at_ps;
                }
            }
            TraceEventKind::Drop => {
                building.remove(&ev.query);
            }
            TraceEventKind::Place => {
                if let Some(p) = building.get_mut(&ev.query) {
                    p.place_ps = ev.at_ps;
                }
                pending.entry(ev.shard).or_default().push(ev.query);
            }
            TraceEventKind::BatchLaunch => {
                let queries = pending.entry(ev.shard).or_default();
                let (launch_ps, run) =
                    running.entry(ev.shard).or_insert_with(|| (0, Vec::new()));
                *launch_ps = ev.at_ps;
                run.append(queries);
            }
            TraceEventKind::BatchComplete => {
                if let Some((launch_ps, run)) = running.get_mut(&ev.shard) {
                    for q in run.drain(..) {
                        let Some(p) = building.remove(&q) else { continue };
                        done.push(QuerySpan {
                            query: q,
                            shard: ev.shard,
                            arrival_ps: p.arrival_ps,
                            admit_ps: p.admit_ps,
                            place_ps: p.place_ps,
                            launch_ps: *launch_ps,
                            done_ps: ev.at_ps,
                        });
                    }
                }
            }
            TraceEventKind::Requeue => {
                // A failed attempt: the query leaves its shard (it was
                // either placed-not-launched — engine error at the fold —
                // or mid-batch when the shard went down). Its partial
                // span survives unless retries are exhausted
                // (`b == u64::MAX`); a later Place re-stamps `place_ps`.
                if let Some(v) = pending.get_mut(&ev.shard) {
                    v.retain(|&q| q != ev.query);
                }
                if let Some((_, v)) = running.get_mut(&ev.shard) {
                    v.retain(|&q| q != ev.query);
                }
                if ev.b == u64::MAX {
                    building.remove(&ev.query);
                }
            }
            TraceEventKind::DeadlineExpired => {
                // Shed from the queue or the retry buffer: never launched,
                // so it only exists in `building`.
                building.remove(&ev.query);
            }
            _ => {}
        }
    }
    done.sort_by_key(|s| (s.done_ps, s.query));
    done
}

/// One batch's critical-path summary: its compute window plus the kernels
/// that filled it.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchSpan {
    /// Shard the batch ran on.
    pub shard: u32,
    /// Launch instant, ps.
    pub launch_ps: u64,
    /// Completion instant, ps.
    pub done_ps: u64,
    /// Queries in the batch.
    pub width: u64,
    /// Kernels launched inside the window.
    pub kernels: u64,
    /// Σ kernel slice durations, ps.
    pub kernel_ps: u64,
    /// Σ tail-excess over the window's kernels, ps.
    pub imbalance_overhead_ps: u64,
    /// Worst single-kernel imbalance factor in the window.
    pub peak_imbalance: f64,
    /// Label of the longest kernel — the critical launch.
    pub critical_kernel: &'static str,
    /// Duration of that longest kernel, ps.
    pub critical_kernel_ps: u64,
}

/// Summarize each batch's compute window from the spans and kernel
/// records, in (launch, shard) order. `ps_per_cycle` maps a shard id to
/// its device clock (see [`profile_report`]).
pub fn batch_spans(
    spans: &[QuerySpan],
    records: &[KernelRecord],
    ps_per_cycle: &dyn Fn(u32) -> u64,
) -> Vec<BatchSpan> {
    let mut widths: BTreeMap<(u64, u32, u64), u64> = BTreeMap::new();
    for s in spans {
        *widths.entry((s.launch_ps, s.shard, s.done_ps)).or_default() += 1;
    }
    let mut out = Vec::with_capacity(widths.len());
    for (&(launch_ps, shard, done_ps), &width) in &widths {
        let mut b = BatchSpan {
            shard,
            launch_ps,
            done_ps,
            width,
            kernels: 0,
            kernel_ps: 0,
            imbalance_overhead_ps: 0,
            peak_imbalance: 1.0,
            critical_kernel: "",
            critical_kernel_ps: 0,
        };
        let ppc = ps_per_cycle(shard);
        for r in records {
            if r.shard != shard || r.start_ps < launch_ps || r.start_ps >= done_ps {
                continue;
            }
            b.kernels += 1;
            b.kernel_ps += r.dur_ps;
            b.imbalance_overhead_ps += r.tail_excess_cycles() * ppc;
            let f = r.imbalance_factor();
            if f > b.peak_imbalance {
                b.peak_imbalance = f;
            }
            if r.dur_ps > b.critical_kernel_ps {
                b.critical_kernel_ps = r.dur_ps;
                b.critical_kernel = r.label;
            }
        }
        out.push(b);
    }
    out
}

/// Assemble the full `--profile-out` JSON report from a sink:
/// per-(shard, kernel) aggregates, per-query latency decompositions and
/// per-batch critical paths. `shard_ppc[i]` is shard `i`'s
/// `ps_per_cycle`; out-of-range shards fall back to the first entry (the
/// single-device `run` path passes one element). Deterministic: BTreeMap
/// key order everywhere, integer fields wherever the source is integral.
pub fn profile_report(sink: &TraceSink, shard_ppc: &[u64]) -> Json {
    let ppc = |shard: u32| -> u64 {
        shard_ppc
            .get(shard as usize)
            .or_else(|| shard_ppc.first())
            .copied()
            .unwrap_or(1)
            .max(1)
    };
    let records = kernel_records(sink);
    let spans = query_spans(sink);
    let batches = batch_spans(&spans, &records, &ppc);

    // Per-(shard, kernel-label) aggregate over every profiled launch.
    #[derive(Default)]
    struct Agg {
        launches: u64,
        total_ps: u64,
        items: u64,
        warps: u64,
        mem_transactions: u64,
        tail_excess_cycles: u64,
        imbalance_sum: f64,
        peak_imbalance: f64,
        cv_sum: f64,
        occupancy_sum: f64,
    }
    let mut aggs: BTreeMap<(u32, &'static str), Agg> = BTreeMap::new();
    for r in &records {
        let a = aggs.entry((r.shard, r.label)).or_default();
        a.launches += 1;
        a.total_ps += r.dur_ps;
        a.items += r.items;
        a.warps += r.warps;
        a.mem_transactions += r.mem_transactions;
        a.tail_excess_cycles += r.tail_excess_cycles();
        let f = r.imbalance_factor();
        a.imbalance_sum += f;
        if f > a.peak_imbalance {
            a.peak_imbalance = f;
        }
        a.cv_sum += r.cv;
        a.occupancy_sum += r.occupancy;
    }

    let kernels: Vec<Json> = aggs
        .iter()
        .map(|(&(shard, label), a)| {
            let n = a.launches as f64;
            Json::obj(vec![
                ("shard", shard.into()),
                ("kernel", label.into()),
                ("launches", a.launches.into()),
                ("total_ps", a.total_ps.into()),
                ("items", a.items.into()),
                ("warps", a.warps.into()),
                ("mem_transactions", a.mem_transactions.into()),
                (
                    "mem_tx_per_item",
                    if a.items == 0 {
                        0.0.into()
                    } else {
                        (a.mem_transactions as f64 / a.items as f64).into()
                    },
                ),
                ("tail_excess_cycles", a.tail_excess_cycles.into()),
                (
                    "imbalance_overhead_ps",
                    (a.tail_excess_cycles * ppc(shard)).into(),
                ),
                ("mean_imbalance", (a.imbalance_sum / n).into()),
                ("peak_imbalance", a.peak_imbalance.into()),
                ("mean_cv", (a.cv_sum / n).into()),
                ("mean_occupancy", (a.occupancy_sum / n).into()),
            ])
        })
        .collect();

    let span_rows: Vec<Json> = spans
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("query", s.query.into()),
                ("shard", s.shard.into()),
                ("arrival_ps", s.arrival_ps.into()),
                ("admit_ps", s.admit_ps.into()),
                ("place_ps", s.place_ps.into()),
                ("launch_ps", s.launch_ps.into()),
                ("done_ps", s.done_ps.into()),
                ("latency_ps", s.latency_ps().into()),
                ("queue_wait_ps", s.queue_wait_ps().into()),
                ("placement_stall_ps", s.placement_stall_ps().into()),
                ("compute_ps", s.compute_ps().into()),
                (
                    "imbalance_overhead_ps",
                    s.imbalance_overhead_ps(&records, ppc(s.shard)).into(),
                ),
            ])
        })
        .collect();

    let batch_rows: Vec<Json> = batches
        .iter()
        .map(|b| {
            Json::obj(vec![
                ("shard", b.shard.into()),
                ("launch_ps", b.launch_ps.into()),
                ("done_ps", b.done_ps.into()),
                ("width", b.width.into()),
                ("kernels", b.kernels.into()),
                ("kernel_ps", b.kernel_ps.into()),
                ("imbalance_overhead_ps", b.imbalance_overhead_ps.into()),
                ("peak_imbalance", b.peak_imbalance.into()),
                ("critical_kernel", b.critical_kernel.into()),
                ("critical_kernel_ps", b.critical_kernel_ps.into()),
            ])
        })
        .collect();

    Json::obj(vec![
        ("schema", "lonestar-profile-v1".into()),
        ("kernel_count", records.len().into()),
        ("span_count", spans.len().into()),
        ("batch_count", batches.len().into()),
        ("kernels", Json::Arr(kernels)),
        ("spans", Json::Arr(span_rows)),
        ("batches", Json::Arr(batch_rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::{TraceEvent, NO_ID};
    use super::*;

    fn kernel_pair(
        sink: &mut TraceSink,
        shard: u32,
        at_ps: u64,
        dur_ps: u64,
        (max_c, sum_c, warps): (u64, u64, u64),
        label: &'static str,
    ) {
        sink.record(TraceEvent {
            shard,
            a: dur_ps,
            b: 100,
            c: max_c,
            d: sum_c,
            label,
            ..TraceEvent::new(TraceEventKind::Kernel, at_ps)
        });
        sink.record(TraceEvent {
            shard,
            a: warps,
            b: 50,
            c: 250_000,  // cv 0.25
            d: 500_000,  // occupancy 0.5
            label,
            ..TraceEvent::new(TraceEventKind::KernelProfile, at_ps)
        });
    }

    #[test]
    fn records_pair_kernel_with_profile() {
        let mut sink = TraceSink::with_capacity(16);
        kernel_pair(&mut sink, 0, 1000, 500, (400, 700, 4), "relax");
        // Unpaired kernel (e.g. profile lost): zeroed distribution.
        sink.record(TraceEvent {
            shard: 0,
            a: 10,
            b: 1,
            ..TraceEvent::new(TraceEventKind::Kernel, 2000)
        });
        // Orphaned profile (its kernel overwritten): skipped.
        sink.record(TraceEvent {
            shard: 1,
            a: 8,
            ..TraceEvent::new(TraceEventKind::KernelProfile, 3000)
        });
        let recs = kernel_records(&sink);
        assert_eq!(recs.len(), 2);
        let r = &recs[0];
        assert_eq!((r.warps, r.mem_transactions), (4, 50));
        assert_eq!(r.max_warp_cycles, 400);
        assert!((r.imbalance_factor() - 400.0 / 175.0).abs() < 1e-9);
        assert_eq!(r.tail_excess_cycles(), 400 - 175);
        assert!((r.cv - 0.25).abs() < 1e-9);
        assert!((r.occupancy - 0.5).abs() < 1e-9);
        assert!((r.mem_tx_per_item() - 0.5).abs() < 1e-9);
        assert_eq!(recs[1].warps, 0, "unpaired kernel keeps zeroed profile");
        assert_eq!(recs[1].imbalance_factor(), 1.0);
    }

    #[test]
    fn spans_rebuild_the_query_lifecycle_and_conserve_latency() {
        let mut sink = TraceSink::with_capacity(64);
        let ev = |kind, at_ps, query, shard| TraceEvent {
            query,
            shard,
            ..TraceEvent::new(kind, at_ps)
        };
        // Query 0: arrives 100, admitted 100, placed 150 on shard 0,
        // launched 200, done 900. Query 1 shares the batch, arriving 120.
        // Query 2 is dropped. Query 3 runs alone on shard 1.
        sink.record(ev(TraceEventKind::Arrival, 100, 0, NO_ID));
        sink.record(ev(TraceEventKind::Admit, 100, 0, NO_ID));
        sink.record(ev(TraceEventKind::Arrival, 120, 1, NO_ID));
        sink.record(ev(TraceEventKind::Admit, 120, 1, NO_ID));
        sink.record(ev(TraceEventKind::Arrival, 130, 2, NO_ID));
        sink.record(ev(TraceEventKind::Drop, 130, 2, NO_ID));
        sink.record(ev(TraceEventKind::Place, 150, 0, 0));
        sink.record(ev(TraceEventKind::Place, 150, 1, 0));
        sink.record(ev(TraceEventKind::BatchLaunch, 200, NO_ID, 0));
        kernel_pair(&mut sink, 0, 300, 400, (400, 700, 4), "relax");
        sink.record(ev(TraceEventKind::Arrival, 400, 3, NO_ID));
        sink.record(ev(TraceEventKind::Admit, 400, 3, NO_ID));
        sink.record(ev(TraceEventKind::Place, 410, 3, 1));
        sink.record(ev(TraceEventKind::BatchLaunch, 420, NO_ID, 1));
        sink.record(ev(TraceEventKind::BatchComplete, 900, NO_ID, 0));
        sink.record(ev(TraceEventKind::BatchComplete, 950, NO_ID, 1));

        let spans = query_spans(&sink);
        assert_eq!(spans.len(), 3, "dropped query must not span");
        assert_eq!(spans[0].query, 0);
        assert_eq!(spans[1].query, 1);
        assert_eq!(spans[2].query, 3);
        for s in &spans {
            assert_eq!(
                s.queue_wait_ps() + s.placement_stall_ps() + s.compute_ps(),
                s.latency_ps(),
                "decomposition must telescope exactly (query {})",
                s.query
            );
        }
        assert_eq!(spans[0].queue_wait_ps(), 50);
        assert_eq!(spans[0].placement_stall_ps(), 50);
        assert_eq!(spans[0].compute_ps(), 700);
        // The kernel at 300 sits inside query 0's window on shard 0:
        // tail excess (400-175) cycles × 2 ps/cycle.
        let records = kernel_records(&sink);
        assert_eq!(spans[0].imbalance_overhead_ps(&records, 2), 225 * 2);
        assert_eq!(spans[2].imbalance_overhead_ps(&records, 2), 0);

        let batches = batch_spans(&spans, &records, &|_| 2);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].width, 2);
        assert_eq!(batches[0].kernels, 1);
        assert_eq!(batches[0].critical_kernel, "relax");
        assert_eq!(batches[0].imbalance_overhead_ps, 450);
        assert_eq!(batches[1].width, 1);
        assert_eq!(batches[1].kernels, 0);
    }

    #[test]
    fn profile_report_shape_is_stable() {
        let mut sink = TraceSink::with_capacity(32);
        kernel_pair(&mut sink, 0, 1000, 500, (400, 700, 4), "relax");
        kernel_pair(&mut sink, 0, 2000, 300, (100, 400, 4), "relax");
        let report = profile_report(&sink, &[1416]);
        assert_eq!(
            report.get("schema").unwrap().as_str(),
            Some("lonestar-profile-v1")
        );
        assert_eq!(report.get("kernel_count").unwrap().as_usize(), Some(2));
        assert_eq!(report.get("span_count").unwrap().as_usize(), Some(0));
        let kernels = report.get("kernels").unwrap().as_arr().unwrap();
        assert_eq!(kernels.len(), 1, "same (shard, label) aggregates");
        let k = &kernels[0];
        assert_eq!(k.get("launches").unwrap().as_usize(), Some(2));
        assert_eq!(k.get("total_ps").unwrap().as_usize(), Some(800));
        // The balanced second launch (100 max vs 100 mean) adds no excess.
        assert_eq!(k.get("tail_excess_cycles").unwrap().as_usize(), Some(400 - 175));
        // Byte determinism: rebuilding the report reproduces the string.
        assert_eq!(report.to_string(), profile_report(&sink, &[1416]).to_string());
    }
}
