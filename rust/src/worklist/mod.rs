//! Worklist machinery for data-driven execution (§III, [9]).
//!
//! All strategies are *data-driven*: only active elements are processed,
//! tracked in worklists that are double-buffered per iteration (`inputWl` /
//! `outputWl` in the paper's pseudocode).
//!
//! * [`NodeWorklist`] — the node-based strategies' worklist: two associative
//!   arrays (node id, out-degree), exactly as WD maintains them (§III-A).
//! * [`EdgeWorklist`] — EP's worklist of edge ids; subject to the size
//!   explosion and condensing overhead described in §II-B.
//! * [`chunking`] — the work-chunking optimization (§IV-D): one append
//!   reservation per node instead of per edge.
//! * [`hierarchy`] — HP's sub-list cursors (§III-C).

pub mod chunking;
pub mod hierarchy;

use crate::graph::{Csr, NodeId};

/// Double-buffered worklist of active nodes with cached out-degrees.
///
/// The degree array is what WD's prefix-sum pass scans; caching it at push
/// time (rather than re-reading CSR offsets) matches the paper's
/// description of the worklist "maintaining the nodes to be processed and
/// each node's outdegree as two associative arrays".
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NodeWorklist {
    nodes: Vec<NodeId>,
    degrees: Vec<u32>,
    /// Running Σ degrees, maintained at push time so
    /// [`NodeWorklist::total_edges`] is O(1) — it is consulted every
    /// iteration by the frontier inspector and the cost model.
    edge_sum: u64,
}

impl NodeWorklist {
    /// Empty worklist.
    pub fn new() -> Self {
        Self::default()
    }

    /// Worklist seeded with one source node.
    pub fn seeded(g: &Csr, source: NodeId) -> Self {
        let mut wl = Self::new();
        wl.push(source, g.degree(source));
        wl
    }

    /// Append an active node.
    #[inline]
    pub fn push(&mut self, node: NodeId, degree: u32) {
        self.nodes.push(node);
        self.degrees.push(degree);
        self.edge_sum += degree as u64;
    }

    /// Overwrite with the contents of `other`, reusing this worklist's
    /// capacity (the arena-friendly alternative to `clone`).
    pub fn copy_from(&mut self, other: &NodeWorklist) {
        self.nodes.clear();
        self.nodes.extend_from_slice(&other.nodes);
        self.degrees.clear();
        self.degrees.extend_from_slice(&other.degrees);
        self.edge_sum = other.edge_sum;
    }

    /// Number of entries (duplicates included).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when no work remains.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Active node ids.
    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    /// Cached out-degrees (parallel to [`nodes`]).
    ///
    /// [`nodes`]: NodeWorklist::nodes
    pub fn degrees(&self) -> &[u32] {
        &self.degrees
    }

    /// Total edges carried by the worklist (cached Σ degrees — O(1)).
    pub fn total_edges(&self) -> u64 {
        self.edge_sum
    }

    /// Simulated device bytes: two 4-byte arrays.
    pub fn memory_bytes(&self) -> u64 {
        2 * 4 * self.nodes.len() as u64
    }

    /// Remove duplicate node entries in place (worklist condensing, §II-B),
    /// keeping first occurrence order-independently (sort + dedup).
    /// Returns the number of entries removed.
    pub fn condense(&mut self) -> usize {
        let before = self.nodes.len();
        let mut pairs: Vec<(NodeId, u32)> = self
            .nodes
            .iter()
            .copied()
            .zip(self.degrees.iter().copied())
            .collect();
        pairs.sort_unstable();
        pairs.dedup_by_key(|p| p.0);
        self.nodes = pairs.iter().map(|p| p.0).collect();
        self.degrees = pairs.iter().map(|p| p.1).collect();
        self.edge_sum = self.degrees.iter().map(|&d| d as u64).sum();
        before - self.nodes.len()
    }

    /// Clear, retaining capacity (double-buffer reuse).
    pub fn clear(&mut self) {
        self.nodes.clear();
        self.degrees.clear();
        self.edge_sum = 0;
    }
}

/// EP's worklist: global edge ids awaiting relaxation.
///
/// A node's successful update pushes *all* its outgoing edges, possibly
/// redundantly from multiple threads — the "size explosion" of §II-B. The
/// engine watches [`EdgeWorklist::len`] against the memory budget.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EdgeWorklist {
    /// Global CSR edge indices.
    edges: Vec<u32>,
    /// Source endpoint of each pending edge — duplicated per edge, the COO
    /// denormalization EP depends on (§II-B).
    srcs: Vec<NodeId>,
}

impl EdgeWorklist {
    /// Empty worklist.
    pub fn new() -> Self {
        Self::default()
    }

    /// Worklist seeded with all outgoing edges of `source`.
    pub fn seeded(g: &Csr, source: NodeId) -> Self {
        let mut wl = Self::new();
        wl.push_node_edges(g, source);
        wl
    }

    /// Append one edge.
    #[inline]
    pub fn push(&mut self, src: NodeId, eid: u32) {
        self.edges.push(eid);
        self.srcs.push(src);
    }

    /// Append every outgoing edge of `node` (`outputWl.push(n.edges)` in
    /// the paper's pseudocode).
    pub fn push_node_edges(&mut self, g: &Csr, node: NodeId) {
        let start = g.first_edge(node);
        let end = start + g.degree(node);
        self.edges.extend(start..end);
        self.srcs.extend(std::iter::repeat(node).take((end - start) as usize));
    }

    /// Number of pending edges (duplicates included).
    pub fn len(&self) -> usize {
        self.edges.len()
    }

    /// True when no work remains.
    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    /// Pending global edge ids.
    pub fn edges(&self) -> &[u32] {
        &self.edges
    }

    /// Source endpoints (parallel to [`edges`]).
    ///
    /// [`edges`]: EdgeWorklist::edges
    pub fn srcs(&self) -> &[NodeId] {
        &self.srcs
    }

    /// Simulated device bytes: two 4-byte arrays (edge id + duplicated
    /// source endpoint).
    pub fn memory_bytes(&self) -> u64 {
        2 * 4 * self.edges.len() as u64
    }

    /// Sort + dedup by edge id (condensing). Returns entries removed.
    pub fn condense(&mut self) -> usize {
        let before = self.edges.len();
        let mut pairs: Vec<(u32, NodeId)> = self
            .edges
            .iter()
            .copied()
            .zip(self.srcs.iter().copied())
            .collect();
        pairs.sort_unstable();
        pairs.dedup_by_key(|p| p.0);
        self.edges = pairs.iter().map(|p| p.0).collect();
        self.srcs = pairs.iter().map(|p| p.1).collect();
        before - self.edges.len()
    }

    /// Clear, retaining capacity.
    pub fn clear(&mut self) {
        self.edges.clear();
        self.srcs.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::graph::Edge;

    fn star() -> Csr {
        Csr::from_edges(
            5,
            &[
                Edge::new(0, 1, 1),
                Edge::new(0, 2, 1),
                Edge::new(0, 3, 1),
                Edge::new(1, 4, 1),
            ],
        )
        .unwrap()
    }

    #[test]
    fn node_worklist_tracks_degrees() {
        let g = star();
        let wl = NodeWorklist::seeded(&g, 0);
        assert_eq!(wl.nodes(), &[0]);
        assert_eq!(wl.degrees(), &[3]);
        assert_eq!(wl.total_edges(), 3);
    }

    #[test]
    fn node_condense_removes_duplicates() {
        let g = star();
        let mut wl = NodeWorklist::new();
        wl.push(1, g.degree(1));
        wl.push(2, g.degree(2));
        wl.push(1, g.degree(1));
        let removed = wl.condense();
        assert_eq!(removed, 1);
        assert_eq!(wl.len(), 2);
    }

    #[test]
    fn edge_worklist_pushes_whole_adjacency() {
        let g = star();
        let wl = EdgeWorklist::seeded(&g, 0);
        assert_eq!(wl.edges(), &[0, 1, 2]);
    }

    #[test]
    fn edge_worklist_can_explode_past_e() {
        // redundant pushes from "multiple threads": size > E is legal
        let g = star();
        let mut wl = EdgeWorklist::new();
        for _ in 0..3 {
            wl.push_node_edges(&g, 0);
        }
        assert!(wl.len() > g.num_edges() as usize - 1);
        let removed = wl.condense();
        assert_eq!(removed, 6);
        assert_eq!(wl.len(), 3);
    }

    #[test]
    fn memory_accounting() {
        let g = star();
        let nwl = NodeWorklist::seeded(&g, 0);
        assert_eq!(nwl.memory_bytes(), 8);
        let ewl = EdgeWorklist::seeded(&g, 0);
        assert_eq!(ewl.memory_bytes(), 24);
    }

    #[test]
    fn total_edges_cache_survives_mutation() {
        let g = star();
        let mut wl = NodeWorklist::seeded(&g, 0);
        wl.push(1, g.degree(1));
        wl.push(1, g.degree(1)); // duplicate
        assert_eq!(wl.total_edges(), 5);
        wl.condense();
        assert_eq!(wl.total_edges(), 4, "condense recomputes the sum");
        let mut copy = NodeWorklist::new();
        copy.push(3, 9); // stale content to be overwritten
        copy.copy_from(&wl);
        assert_eq!(copy, wl);
        assert_eq!(copy.total_edges(), 4);
        wl.clear();
        assert_eq!(wl.total_edges(), 0);
    }

    #[test]
    fn edge_worklist_tracks_srcs() {
        let g = star();
        let ewl = EdgeWorklist::seeded(&g, 0);
        assert_eq!(ewl.srcs(), &[0, 0, 0]);
        let mut ewl2 = ewl.clone();
        ewl2.push_node_edges(&g, 1);
        assert_eq!(ewl2.srcs(), &[0, 0, 0, 1]);
        assert_eq!(ewl2.edges(), &[0, 1, 2, 3]);
    }
}
