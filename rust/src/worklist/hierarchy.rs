//! Sub-list cursors for hierarchical processing (§III-C).
//!
//! HP partitions an iteration over the super-worklist into sub-iterations:
//! each sub-iteration processes at most `MDT` *unprocessed* outgoing edges
//! of every remaining node; nodes whose adjacency is exhausted leave the
//! sub-list. [`SubList`] tracks the per-node progress cursor.

use crate::graph::NodeId;

/// One node's residual work inside an HP iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeCursor {
    pub node: NodeId,
    /// Edges of this node already processed in earlier sub-iterations.
    pub processed: u32,
    /// Total out-degree of the node.
    pub degree: u32,
}

impl NodeCursor {
    /// Edges still unprocessed.
    #[inline]
    pub fn remaining(&self) -> u32 {
        self.degree - self.processed
    }
}

/// The shrinking sub-list of an HP iteration.
#[derive(Debug, Clone, Default)]
pub struct SubList {
    cursors: Vec<NodeCursor>,
}

impl SubList {
    /// Build the initial sub-list from the super-worklist's (node, degree)
    /// pairs, dropping zero-degree nodes.
    pub fn from_super(nodes: &[NodeId], degrees: &[u32]) -> Self {
        let mut sub = SubList::default();
        sub.reset(nodes, degrees);
        sub
    }

    /// Rebuild in place from the super-worklist's (node, degree) pairs,
    /// dropping zero-degree nodes. Capacity is retained, so a persistent
    /// sub-list is allocation-free across iterations (the arena path of
    /// [`crate::strategies::Hierarchical`]).
    pub fn reset(&mut self, nodes: &[NodeId], degrees: &[u32]) {
        self.cursors.clear();
        self.cursors.extend(
            nodes
                .iter()
                .zip(degrees)
                .filter(|(_, &d)| d > 0)
                .map(|(&node, &degree)| NodeCursor {
                    node,
                    processed: 0,
                    degree,
                }),
        );
    }

    /// Nodes still holding unprocessed edges.
    pub fn len(&self) -> usize {
        self.cursors.len()
    }

    /// True when the iteration's work is complete.
    pub fn is_empty(&self) -> bool {
        self.cursors.is_empty()
    }

    /// Current cursors.
    pub fn cursors(&self) -> &[NodeCursor] {
        &self.cursors
    }

    /// Advance every node by up to `mdt` edges and drop the exhausted ones
    /// (one sub-iteration's bookkeeping). Returns the number of edges
    /// consumed.
    pub fn advance(&mut self, mdt: u32) -> u64 {
        debug_assert!(mdt > 0);
        let mut consumed = 0u64;
        self.cursors.retain_mut(|c| {
            let take = c.remaining().min(mdt);
            c.processed += take;
            consumed += take as u64;
            c.remaining() > 0
        });
        consumed
    }

    /// Total unprocessed edges across the sub-list.
    pub fn remaining_edges(&self) -> u64 {
        self.cursors.iter().map(|c| c.remaining() as u64).sum()
    }

    /// Simulated device bytes for the sub-list structures (node id,
    /// processed, degree — 3 × 4 B per entry).
    pub fn memory_bytes(&self) -> u64 {
        3 * 4 * self.cursors.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_figure6_walkthrough() {
        // Fig. 6: nodes 1 (deg 5) and 8 (deg 7), MDT = 3.
        let mut sub = SubList::from_super(&[1, 8], &[5, 7]);
        assert_eq!(sub.len(), 2);
        // sub-iteration 1: both relax 3 edges
        assert_eq!(sub.advance(3), 6);
        assert_eq!(sub.len(), 2); // 1 has 2 left, 8 has 4 left
        // sub-iteration 2: node 1 finishes (2), node 8 relaxes 3
        assert_eq!(sub.advance(3), 5);
        assert_eq!(sub.len(), 1);
        // sub-iteration 3: node 8 finishes its last edge
        assert_eq!(sub.advance(3), 1);
        assert!(sub.is_empty());
    }

    #[test]
    fn zero_degree_nodes_never_enter() {
        let sub = SubList::from_super(&[3, 4], &[0, 2]);
        assert_eq!(sub.len(), 1);
        assert_eq!(sub.cursors()[0].node, 4);
    }

    #[test]
    fn remaining_edges_decreases_monotonically() {
        let mut sub = SubList::from_super(&[0, 1, 2], &[10, 1, 5]);
        let mut prev = sub.remaining_edges();
        while !sub.is_empty() {
            sub.advance(4);
            let now = sub.remaining_edges();
            assert!(now < prev);
            prev = now;
        }
        assert_eq!(prev, 0);
    }

    #[test]
    fn total_consumed_equals_total_degree() {
        let mut sub = SubList::from_super(&[0, 1], &[7, 9]);
        let mut total = 0;
        while !sub.is_empty() {
            total += sub.advance(2);
        }
        assert_eq!(total, 16);
    }
}
