//! Work chunking (§IV-D): reserving worklist space with one atomic per
//! node's edge block instead of one atomic per edge.
//!
//! The paper measures 1.11–3.125× (avg 1.82×) speedups for EP from this
//! optimization (Figure 11). The policy only changes *atomic accounting*,
//! not the resulting worklist contents — captured by [`PushPolicy::append_atomics`].

/// Worklist-append reservation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PushPolicy {
    /// One atomic reservation per appended element (naïve).
    PerEdge,
    /// One atomic reservation per node's block of appended elements
    /// (work chunking, the default — used by all paper results except the
    /// Figure 11 ablation).
    #[default]
    Chunked,
}

impl PushPolicy {
    /// Atomic operations needed to append `elements` entries that belong to
    /// one node's chunk.
    #[inline]
    pub fn append_atomics(&self, elements: u64) -> u64 {
        match self {
            PushPolicy::PerEdge => elements,
            PushPolicy::Chunked => {
                if elements > 0 {
                    1
                } else {
                    0
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunked_is_one_atomic_per_block() {
        assert_eq!(PushPolicy::Chunked.append_atomics(17), 1);
        assert_eq!(PushPolicy::Chunked.append_atomics(0), 0);
    }

    #[test]
    fn per_edge_is_linear() {
        assert_eq!(PushPolicy::PerEdge.append_atomics(17), 17);
    }

    #[test]
    fn chunked_never_exceeds_per_edge() {
        for n in 0..100u64 {
            assert!(PushPolicy::Chunked.append_atomics(n) <= PushPolicy::PerEdge.append_atomics(n));
        }
    }
}
