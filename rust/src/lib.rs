//! `lonestar-lb` — a reproduction of *"Dynamic Load Balancing Strategies for
//! Graph Applications on GPUs"* (Raval, Nasre, Kumar, Vasudevan, Vadhiyar,
//! Pingali; CS.DC 2017) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper's contribution — five task-distribution strategies for
//! data-driven graph algorithms (node-based `BS`, edge-based `EP`, workload
//! decomposition `WD`, node splitting `NS`, hierarchical processing `HP`) —
//! lives in [`strategies`]. Strategies plan per-kernel thread assignments;
//! the [`coordinator`] engine executes those plans against one of three
//! interchangeable backends:
//!
//! * `sim`    — a deterministic SIMT cost model ([`sim`]) reproducing the
//!   paper's Kepler K20c testbed (warps, SMX scheduling, coalescing, atomic
//!   serialization, memory budget). All paper figures are regenerated in
//!   this mode.
//! * `xla`    — the numeric hot loop (batched edge relaxation) executes on
//!   the real XLA CPU runtime through AOT-compiled artifacts produced by
//!   the Python build path (L2 JAX model calling an L1 Pallas kernel). See
//!   [`runtime`].
//! * `native` — a pure-Rust interpreter of the same plans (correctness
//!   oracle and performance baseline).
//!
//! Substrates built for the reproduction: a graph library ([`graph`]) with
//! CSR/COO storage, RMAT / Erdős–Rényi / Kronecker(Graph500) / road-network
//! generators and DIMACS IO; worklist machinery ([`worklist`]) including the
//! paper's work-chunking optimization; and the metrics / reporting layer
//! ([`metrics`], [`figures`]) that regenerates every table and figure of the
//! evaluation section.
//!
//! On top of the five static reproductions sits the [`adaptive`] subsystem
//! (`StrategyKind::AD`): a per-iteration selector that inspects the live
//! frontier, asks a pluggable policy (paper-derived heuristics or a
//! [`sim::KernelSim`]-backed cost model bounded by the device memory
//! budget) which scheme should run the next kernel, and migrates the
//! worklist between representations losslessly — turning the five static
//! strategies into one self-tuning engine (after Jatala et al.,
//! arXiv:1911.09135). The decision trace lands in
//! [`metrics::RunMetrics::decisions`] and the `figad` figure compares AD
//! against the per-graph best static strategy.
//!
//! The [`serving`] layer batches many concurrent queries over one shared
//! CSR: per batch iteration a single frontier inspection and a single AD
//! policy decision cover every query (multi-word bitmask-tagged merged
//! worklist — one tag word per 64 queries, so batches are not capped at
//! 64), and batches shard across simulated devices, heterogeneous
//! `DeviceSpec`s included. In front sits an admission-controlled
//! scheduler ([`serving::Scheduler`]): continuous seeded arrivals, a
//! bounded FIFO queue with a drop/block overflow policy, and load-aware
//! placement on a deterministic virtual clock (`figqueue` figure). Every
//! batched run can replay its queries through the single-query engine as
//! a differential oracle (`serve` CLI subcommand, `figserve` figure,
//! `benches/serving.rs`).
//!
//! Underneath all of it sits the [`arena`] subsystem: a scratch buffer
//! pool threaded through [`coordinator::ExecCtx`] plus a graph-keyed
//! artifact cache, giving the per-iteration hot path a **zero-allocation
//! steady state** (proved by `rust/tests/alloc_regression.rs`) and letting
//! serving reuse the MDT/COO/split-graph artifacts across batches. The
//! perf trajectory is tracked in `BENCH_hotpath.json` (see README
//! "Performance").
//!
//! Observability rides on the same virtual clock: the [`telemetry`]
//! subsystem records fixed-width events into a pre-allocated ring
//! ([`telemetry::TraceSink`], attached through the scheduler and
//! [`coordinator::ExecCtx`] behind an `Option<&mut TraceSink>` seam) and
//! exports Chrome trace-event JSON (Perfetto) plus a Prometheus-style
//! text exposition (`--trace-out` / `--metrics-out`). Latency and queue
//! wait are tracked in log₂-bucketed histograms
//! ([`telemetry::LogHistogram`]) — p50/p95/p99/max without the old
//! sort-per-call, and allocation-free so a live sink preserves the
//! zero-alloc invariant.

pub mod adaptive;
pub mod algorithms;
pub mod arena;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod figures;
pub mod graph;
pub mod metrics;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod strategies;
pub mod telemetry;
pub mod util;
pub mod worklist;

pub use error::{Error, Result};
pub use graph::{Csr, Graph, NodeId};

/// Sentinel "infinite" distance used by BFS / SSSP (`u32::MAX` is reserved
/// so saturating adds cannot wrap).
pub const INF: u32 = u32::MAX;
