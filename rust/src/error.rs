//! Error type shared across the crate.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors surfaced by the library.
///
/// `OutOfMemory` is a first-class citizen: the paper's evaluation hinges on
/// strategies *failing to run* when their storage (COO arrays, exploded
/// worklists) exceeds the device budget, so the simulator reports budget
/// violations through this variant and the figure harness renders them as
/// "OOM" cells, exactly like the paper's missing bars.
#[derive(Debug)]
pub enum Error {
    /// Device memory budget exceeded: `(what, requested_bytes, budget_bytes)`.
    OutOfMemory {
        what: String,
        requested: u64,
        budget: u64,
    },
    /// Malformed graph input (parser or validation failure).
    InvalidGraph(String),
    /// Bad configuration value.
    Config(String),
    /// Underlying IO failure.
    Io(std::io::Error),
    /// XLA / PJRT runtime failure.
    Xla(String),
    /// An AOT artifact is missing (run `make artifacts`).
    MissingArtifact(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::OutOfMemory {
                what,
                requested,
                budget,
            } => write!(
                f,
                "out of device memory: {what} needs {requested} B but budget is {budget} B"
            ),
            Error::InvalidGraph(m) => write!(f, "invalid graph: {m}"),
            Error::Config(m) => write!(f, "bad config: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::MissingArtifact(p) => {
                write!(f, "missing AOT artifact {p}; run `make artifacts`")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// True when the error is a device-memory budget violation.
    pub fn is_oom(&self) -> bool {
        matches!(self, Error::OutOfMemory { .. })
    }
}
