//! Per-kernel cycle accounting: warps in lockstep, blocks scheduled
//! round-robin over SMs, per-SM throughput limits.

use super::DeviceSpec;
use crate::telemetry::LogHistogram;

/// Warp-level memory access pattern of a kernel's edge reads.
///
/// * `Coalesced` — lanes of a warp touch consecutive addresses each step
///   (EP's round-robin assignment; BS/NS reading a node's contiguous
///   adjacency when lanes advance together).
/// * `Scattered` — lanes touch unrelated addresses (WD's block
///   decomposition separates a node's edges across threads, §III-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    Coalesced,
    Scattered,
}

/// Accumulated cycle cost and counters for one simulated kernel launch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelTime {
    /// Simulated wall-clock cycles for the launch (including launch
    /// overhead).
    pub cycles: u64,
    /// Number of warps that executed.
    pub warps: u64,
    /// Total edge-relaxation steps executed (work measure).
    pub edge_steps: u64,
    /// Total atomic operations issued.
    pub atomics: u64,
    /// Atomic operations that conflicted within their warp.
    pub atomic_conflicts: u64,
    /// Memory transactions issued.
    pub mem_transactions: u64,
}

/// Per-warp busy-cycle distribution of one launch — the *realized* load
/// imbalance the paper's argument turns on, as opposed to the frontier-level
/// estimate `FrontierInspector::imbalance` computes before the kernel runs.
/// Everything here lives inline on the stack (the histogram is a fixed
/// 65-bucket array), so collecting it costs no heap allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct WarpStats {
    /// Warps committed to the launch.
    pub warps: u64,
    /// Busiest single warp, cycles.
    pub max_cycles: u64,
    /// Σ warp cycles across the launch.
    pub sum_cycles: u64,
    /// Σ warp cycles², for the coefficient of variation.
    pub sq_sum_cycles: u128,
    /// Log₂ histogram of per-warp busy cycles.
    pub hist: LogHistogram,
}

impl WarpStats {
    /// Mean warp cycles, 0.0 for an empty launch.
    pub fn mean_cycles(&self) -> f64 {
        if self.warps == 0 {
            0.0
        } else {
            self.sum_cycles as f64 / self.warps as f64
        }
    }

    /// Imbalance factor: max-warp ÷ mean-warp cycles. 1.0 for an empty or
    /// perfectly balanced launch — the paper's headline per-kernel metric.
    pub fn imbalance_factor(&self) -> f64 {
        let mean = self.mean_cycles();
        if mean <= 0.0 {
            1.0
        } else {
            self.max_cycles as f64 / mean
        }
    }

    /// Coefficient of variation of warp cycles: σ ÷ mean, 0.0 when empty.
    pub fn cv(&self) -> f64 {
        if self.warps == 0 {
            return 0.0;
        }
        let mean = self.mean_cycles();
        if mean <= 0.0 {
            return 0.0;
        }
        let ex2 = self.sq_sum_cycles as f64 / self.warps as f64;
        let var = (ex2 - mean * mean).max(0.0);
        var.sqrt() / mean
    }

    /// Tail-warp excess: max-warp − mean-warp cycles (integer floor) — the
    /// cycles the whole launch waited on its single slowest warp.
    pub fn tail_excess_cycles(&self) -> u64 {
        if self.warps == 0 {
            return 0;
        }
        self.max_cycles.saturating_sub(self.sum_cycles / self.warps)
    }

    /// Achieved occupancy on `dev`: resident threads ÷ device capacity,
    /// clamped to 1.0.
    pub fn occupancy(&self, dev: &DeviceSpec) -> f64 {
        let cap = dev.max_resident_threads as u64;
        if cap == 0 {
            return 0.0;
        }
        let threads = (self.warps * dev.warp_size as u64).min(cap);
        threads as f64 / cap as f64
    }
}

/// Accounts one kernel launch. Create with [`KernelSim::new`], feed warps
/// via [`KernelSim::warp`] / [`WarpSim::commit`], and finish with
/// [`KernelSim::finish`].
///
/// Scheduling model: warps belong to blocks of `block_size / warp_size`
/// warps; blocks are assigned round-robin to SMs. An SM with `k` resident
/// warps and throughput `t` (warps retired in parallel) takes
/// `max(Σ warp_cycles / t, max warp_cycles)` — the standard
/// "throughput-bound or latency-bound, whichever is worse" approximation.
#[derive(Debug)]
pub struct KernelSim<'d> {
    dev: &'d DeviceSpec,
    warps_per_block: u64,
    sm_total: Vec<u64>,
    sm_max: Vec<u64>,
    warp_count: u64,
    stats: KernelTime,
    warp_max: u64,
    warp_sum: u64,
    warp_sq_sum: u128,
    warp_hist: LogHistogram,
}

impl<'d> KernelSim<'d> {
    /// Start accounting a kernel on `dev`.
    pub fn new(dev: &'d DeviceSpec) -> Self {
        Self::new_with(dev, Vec::new(), Vec::new())
    }

    /// Start accounting a kernel on `dev`, reusing caller-provided per-SM
    /// accumulator buffers (the scratch-arena path: paired with
    /// [`KernelSim::finish_into`], a warm caller launches kernels with zero
    /// heap allocation).
    pub fn new_with(dev: &'d DeviceSpec, mut sm_total: Vec<u64>, mut sm_max: Vec<u64>) -> Self {
        sm_total.clear();
        sm_total.resize(dev.num_sm as usize, 0);
        sm_max.clear();
        sm_max.resize(dev.num_sm as usize, 0);
        KernelSim {
            dev,
            warps_per_block: dev.warps_per_block() as u64,
            sm_total,
            sm_max,
            warp_count: 0,
            stats: KernelTime::default(),
            warp_max: 0,
            warp_sum: 0,
            warp_sq_sum: 0,
            warp_hist: LogHistogram::new(),
        }
    }

    /// Begin accounting the next warp (warps must be committed in launch
    /// order).
    pub fn warp(&mut self) -> WarpSim<'d> {
        WarpSim {
            dev: self.dev,
            cycles: 0,
            edge_steps: 0,
            atomics: 0,
            atomic_conflicts: 0,
            mem_transactions: 0,
        }
    }

    /// Commit a finished warp to its SM.
    pub fn commit(&mut self, w: WarpSim<'_>) {
        let block = self.warp_count / self.warps_per_block;
        let sm = (block % self.dev.num_sm as u64) as usize;
        self.sm_total[sm] += w.cycles;
        self.sm_max[sm] = self.sm_max[sm].max(w.cycles);
        self.warp_max = self.warp_max.max(w.cycles);
        self.warp_sum += w.cycles;
        self.warp_sq_sum += (w.cycles as u128) * (w.cycles as u128);
        self.warp_hist.record(w.cycles);
        self.warp_count += 1;
        self.stats.edge_steps += w.edge_steps;
        self.stats.atomics += w.atomics;
        self.stats.atomic_conflicts += w.atomic_conflicts;
        self.stats.mem_transactions += w.mem_transactions;
    }

    /// Snapshot the per-warp distribution accumulated so far (call just
    /// before [`KernelSim::finish_into`], which consumes the sim). Copies
    /// only inline state — no heap.
    pub fn warp_stats(&self) -> WarpStats {
        WarpStats {
            warps: self.warp_count,
            max_cycles: self.warp_max,
            sum_cycles: self.warp_sum,
            sq_sum_cycles: self.warp_sq_sum,
            hist: self.warp_hist.clone(),
        }
    }

    /// Close the launch and return its cost.
    pub fn finish(self) -> KernelTime {
        self.finish_into().0
    }

    /// Close the launch, returning the cost plus the per-SM buffers so a
    /// pooled caller can reuse them (see [`KernelSim::new_with`]).
    pub fn finish_into(mut self) -> (KernelTime, Vec<u64>, Vec<u64>) {
        let t = self.dev.warp_throughput();
        let busiest = self
            .sm_total
            .iter()
            .zip(&self.sm_max)
            .map(|(&total, &mx)| (total / t).max(mx))
            .max()
            .unwrap_or(0);
        self.stats.cycles = self.dev.launch_overhead + busiest;
        self.stats.warps = self.warp_count;
        (
            self.stats,
            std::mem::take(&mut self.sm_total),
            std::mem::take(&mut self.sm_max),
        )
    }
}

/// Accounts one warp executing in SIMT lockstep.
#[derive(Debug)]
pub struct WarpSim<'d> {
    dev: &'d DeviceSpec,
    cycles: u64,
    edge_steps: u64,
    atomics: u64,
    atomic_conflicts: u64,
    mem_transactions: u64,
}

impl WarpSim<'_> {
    /// One lockstep step where `active` lanes each read one edge and do the
    /// relaxation ALU work. Inactive lanes idle (divergence) but the warp
    /// still pays the step.
    ///
    /// Memory cost is latency + transactions: every step stalls for the
    /// (partially hidden) global-load latency, then pays per transaction —
    /// one for a coalesced warp, one per active lane when scattered. This
    /// is what makes SIMT imbalance expensive: a warp with one straggler
    /// lane re-pays the latency every extra step.
    pub fn step(&mut self, active: u32, access: AccessPattern) {
        debug_assert!(active > 0 && active <= self.dev.warp_size);
        let mem = match access {
            AccessPattern::Coalesced => {
                self.mem_transactions += 1;
                self.dev.mem_latency + self.dev.coalesced_tx
            }
            AccessPattern::Scattered => {
                self.mem_transactions += active as u64;
                self.dev.mem_latency + self.dev.scattered_tx * active as u64
            }
        };
        self.cycles += mem + self.dev.alu_relax;
        self.edge_steps += active as u64;
    }

    /// Successful distance updates this step, identified by destination
    /// node. The warp issues them as one wide atomic instruction:
    /// distinct addresses pipeline behind a single base latency
    /// (~1 address/4 cycles on Kepler's L2 atomic units), while conflicting
    /// destinations serialize (`atomicMin` read-modify-write semantics).
    ///
    /// `dsts` is reordered (sorted) in place.
    pub fn atomics(&mut self, dsts: &mut [u32]) {
        if dsts.is_empty() {
            return;
        }
        dsts.sort_unstable();
        let mut groups = 0u64;
        let mut conflicts = 0u64;
        let mut i = 0;
        while i < dsts.len() {
            let mut j = i + 1;
            while j < dsts.len() && dsts[j] == dsts[i] {
                j += 1;
            }
            groups += 1;
            conflicts += (j - i - 1) as u64;
            i = j;
        }
        self.atomics += dsts.len() as u64;
        self.atomic_conflicts += conflicts;
        self.cycles +=
            self.dev.atomic_base + (groups - 1) * 4 + conflicts * self.dev.atomic_conflict;
    }

    /// `count` worklist-append reservations (atomicAdd on the shared tail
    /// counter). Pipelined fire-and-forget read-modify-writes — much
    /// cheaper than the dependent `atomicMin`s of [`WarpSim::atomics`];
    /// work chunking (§IV-D) reduces `count` from per-edge to per-node.
    pub fn append_atomics(&mut self, count: u64) {
        self.atomics += count;
        self.cycles += count * self.dev.atomic_append;
    }

    /// Flat bookkeeping cycles (offset binary search, child mirroring walk,
    /// etc.).
    pub fn extra(&mut self, cycles: u64) {
        self.cycles += cycles;
    }

    /// Cycles accumulated so far.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> DeviceSpec {
        DeviceSpec::k20c()
    }

    #[test]
    fn empty_kernel_costs_launch_overhead() {
        let d = dev();
        let k = KernelSim::new(&d);
        let t = k.finish();
        assert_eq!(t.cycles, d.launch_overhead);
        assert_eq!(t.warps, 0);
    }

    #[test]
    fn imbalanced_warp_costs_max_lane() {
        // one warp where a single lane does 100 steps vs. a warp where all
        // 32 lanes do 100 steps: same cycle count (lockstep) — the paper's
        // core load-imbalance observation.
        let d = dev();
        let mut k1 = KernelSim::new(&d);
        let mut w = k1.warp();
        for _ in 0..100 {
            w.step(1, AccessPattern::Coalesced);
        }
        k1.commit(w);
        let lone = k1.finish();

        let mut k2 = KernelSim::new(&d);
        let mut w = k2.warp();
        for _ in 0..100 {
            w.step(32, AccessPattern::Coalesced);
        }
        k2.commit(w);
        let full = k2.finish();
        assert_eq!(lone.cycles, full.cycles);
        assert_eq!(full.edge_steps, 3200);
    }

    #[test]
    fn scattered_costs_more_than_coalesced() {
        let d = dev();
        let mut co = d.clone();
        co.launch_overhead = 0;
        let mut k1 = KernelSim::new(&co);
        let mut w = k1.warp();
        w.step(32, AccessPattern::Coalesced);
        k1.commit(w);
        let c = k1.finish().cycles;

        let mut k2 = KernelSim::new(&co);
        let mut w = k2.warp();
        w.step(32, AccessPattern::Scattered);
        k2.commit(w);
        let s = k2.finish().cycles;
        assert!(s > 2 * c, "scattered {s} should dwarf coalesced {c}");
    }

    #[test]
    fn atomic_conflicts_serialize() {
        let d = dev();
        let mut k = KernelSim::new(&d);
        let mut w = k.warp();
        let mut no_conflict = [1u32, 2, 3, 4];
        w.atomics(&mut no_conflict);
        let base = w.cycles();
        let mut w2 = k.warp();
        let mut all_same = [7u32, 7, 7, 7];
        w2.atomics(&mut all_same);
        assert!(w2.cycles() > base, "conflicting atomics must cost more");
        assert_eq!(w2.cycles() - d.atomic_base, 3 * d.atomic_conflict + 0);
        k.commit(w);
        k.commit(w2);
        let t = k.finish();
        assert_eq!(t.atomics, 8);
        assert_eq!(t.atomic_conflicts, 3);
    }

    #[test]
    fn sm_parallelism_speeds_up_many_warps() {
        // 13*6 = 78 warps of equal work should take ~1 warp-time, not 78.
        let d = dev();
        let mut k = KernelSim::new(&d);
        // one warp per block so blocks spread over SMs
        let mut small = d.clone();
        small.block_size = 32;
        let mut k2 = KernelSim::new(&small);
        for _ in 0..78 {
            let mut w = k2.warp();
            for _ in 0..10 {
                w.step(32, AccessPattern::Coalesced);
            }
            k2.commit(w);
        }
        let many = k2.finish();
        let mut w = k.warp();
        for _ in 0..10 {
            w.step(32, AccessPattern::Coalesced);
        }
        k.commit(w);
        let one = k.finish();
        assert_eq!(many.cycles, one.cycles, "78 equal warps fill the device exactly");
    }

    #[test]
    fn warp_stats_measure_realized_imbalance() {
        let d = dev();
        let mut k = KernelSim::new(&d);
        // Three light warps and one 4× straggler: max=40 steps, mean=17.5.
        for steps in [10u64, 10, 10, 40] {
            let mut w = k.warp();
            for _ in 0..steps {
                w.step(32, AccessPattern::Coalesced);
            }
            k.commit(w);
        }
        let ws = k.warp_stats();
        assert_eq!(ws.warps, 4);
        assert_eq!(ws.hist.count(), 4);
        let per_step = ws.max_cycles / 40;
        assert_eq!(ws.max_cycles, 40 * per_step);
        assert_eq!(ws.sum_cycles, 70 * per_step);
        let f = ws.imbalance_factor();
        assert!((f - 40.0 / 17.5).abs() < 1e-9, "imbalance {f}");
        assert!(ws.cv() > 0.0);
        assert_eq!(ws.tail_excess_cycles(), 40 * per_step - 70 * per_step / 4);
        assert!(ws.occupancy(&d) > 0.0 && ws.occupancy(&d) <= 1.0);

        // A balanced launch reports factor 1.0 and CV 0.0 exactly.
        let mut k2 = KernelSim::new(&d);
        for _ in 0..4 {
            let mut w = k2.warp();
            for _ in 0..10 {
                w.step(32, AccessPattern::Coalesced);
            }
            k2.commit(w);
        }
        let even = k2.warp_stats();
        assert_eq!(even.imbalance_factor(), 1.0);
        assert_eq!(even.cv(), 0.0);
        assert_eq!(even.tail_excess_cycles(), 0);

        // Empty launch: well-defined neutral values.
        let none = KernelSim::new(&d).warp_stats();
        assert_eq!(none.imbalance_factor(), 1.0);
        assert_eq!(none.cv(), 0.0);
        assert_eq!(none.tail_excess_cycles(), 0);
    }

    #[test]
    fn blocks_round_robin_over_sms() {
        let d = dev();
        let mut k = KernelSim::new(&d);
        // 2 full blocks = 64 warps; block 0 -> SM0, block 1 -> SM1
        for _ in 0..64 {
            let mut w = k.warp();
            w.step(32, AccessPattern::Coalesced);
            k.commit(w);
        }
        let t = k.finish();
        assert_eq!(t.warps, 64);
        // per-SM: 32 warps, throughput 6 → ceil-ish total/6 ≥ max
        assert!(t.cycles > d.launch_overhead);
    }
}
