//! Device-memory budget tracking.
//!
//! Every strategy declares its allocations (graph storage, worklists,
//! offset arrays, prefix sums) against the tracker; exceeding the budget
//! aborts the run with [`Error::OutOfMemory`] — this is how the simulator
//! reproduces "EP could not be executed for these large graphs" (§IV-A).

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Tracks current and peak simulated device-memory usage by label.
///
/// Labels are `&'static str`: every call site charges a literal, and static
/// keys keep [`MemoryTracker::charge`] allocation-free on the per-iteration
/// hot path (the zero-allocation steady state of
/// `rust/tests/alloc_regression.rs`).
#[derive(Debug, Clone)]
pub struct MemoryTracker {
    budget: u64,
    current: u64,
    peak: u64,
    by_label: BTreeMap<&'static str, u64>,
}

impl MemoryTracker {
    /// Tracker with the given budget in bytes.
    pub fn new(budget: u64) -> Self {
        MemoryTracker {
            budget,
            current: 0,
            peak: 0,
            by_label: BTreeMap::new(),
        }
    }

    /// Unlimited tracker (native/xla correctness runs).
    pub fn unlimited() -> Self {
        MemoryTracker::new(u64::MAX)
    }

    /// Allocate `bytes` under `label`; errors if the budget is exceeded.
    pub fn charge(&mut self, label: &'static str, bytes: u64) -> Result<()> {
        let next = self.current.saturating_add(bytes);
        if next > self.budget {
            return Err(Error::OutOfMemory {
                what: label.to_string(),
                requested: bytes,
                budget: self.budget,
            });
        }
        self.current = next;
        self.peak = self.peak.max(self.current);
        *self.by_label.entry(label).or_insert(0) += bytes;
        Ok(())
    }

    /// Release `bytes` previously charged under `label`.
    pub fn release(&mut self, label: &'static str, bytes: u64) {
        self.current = self.current.saturating_sub(bytes);
        if let Some(v) = self.by_label.get_mut(label) {
            *v = v.saturating_sub(bytes);
        }
    }

    /// Grow/shrink a label to a new size (worklists resize per iteration);
    /// peak accounting sees the high-water mark.
    pub fn resize(&mut self, label: &'static str, old_bytes: u64, new_bytes: u64) -> Result<()> {
        self.release(label, old_bytes);
        self.charge(label, new_bytes)
    }

    /// Current usage in bytes.
    pub fn current(&self) -> u64 {
        self.current
    }

    /// Peak usage in bytes.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Budget in bytes.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Move the budget ceiling without disturbing the charges (the
    /// fault-injecting scheduler shrinks/restores a live device's budget
    /// between batches). Existing usage above a lowered ceiling is kept —
    /// the *next* charge fails, mirroring a device that lost headroom
    /// rather than one that evicted allocations.
    pub fn set_budget(&mut self, budget: u64) {
        self.budget = budget;
    }

    /// Cumulative bytes charged per label (diagnostics / Figure 9 memory
    /// axis).
    pub fn by_label(&self) -> &BTreeMap<&'static str, u64> {
        &self.by_label
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_and_release() {
        let mut t = MemoryTracker::new(100);
        t.charge("a", 60).unwrap();
        assert_eq!(t.current(), 60);
        t.release("a", 60);
        assert_eq!(t.current(), 0);
        assert_eq!(t.peak(), 60);
    }

    #[test]
    fn oom_on_budget_violation() {
        let mut t = MemoryTracker::new(100);
        t.charge("graph", 80).unwrap();
        let err = t.charge("worklist", 30).unwrap_err();
        assert!(err.is_oom());
        // failed charge does not count
        assert_eq!(t.current(), 80);
    }

    #[test]
    fn resize_tracks_peak() {
        let mut t = MemoryTracker::new(1000);
        t.charge("wl", 100).unwrap();
        t.resize("wl", 100, 700).unwrap();
        t.resize("wl", 700, 50).unwrap();
        assert_eq!(t.peak(), 700);
        assert_eq!(t.current(), 50);
    }

    #[test]
    fn set_budget_moves_the_ceiling_only() {
        let mut t = MemoryTracker::new(100);
        t.charge("graph", 80).unwrap();
        t.set_budget(50);
        assert_eq!(t.current(), 80, "charges survive a shrink");
        assert!(t.charge("wl", 1).is_err(), "no headroom under the new cap");
        t.set_budget(200);
        t.charge("wl", 100).unwrap();
        assert_eq!(t.peak(), 180);
    }

    #[test]
    fn unlimited_never_fails() {
        let mut t = MemoryTracker::unlimited();
        t.charge("x", u64::MAX / 2).unwrap();
        assert!(t.charge("y", u64::MAX / 4).is_ok());
    }
}
