//! Device descriptor: SMX topology, cycle costs, memory budget.

/// Static description of the simulated GPU plus calibrated cycle costs.
///
/// Defaults model the paper's Tesla K20c: 13 SMX × 192 cores, warp size 32,
/// 4.66 GB device memory, 0.706 GHz. Cycle costs are calibrated to Kepler
/// latencies (global load ≈ 200–400 cycles uncached; atomics ≈ 100s of
/// cycles under contention) — the *ratios* drive every figure, not the
/// absolute values.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Preset name (`k20c`, `k40`, `gtx680`) — the string accepted by
    /// [`DeviceSpec::by_name`], the `devices` config key and `--devices`.
    pub name: &'static str,
    /// Streaming multiprocessors (SMX on Kepler).
    pub num_sm: u32,
    /// CUDA cores per SM — determines how many warps retire in parallel.
    pub cores_per_sm: u32,
    /// SIMT width.
    pub warp_size: u32,
    /// Threads per block used by kernel launches (the paper uses 1024;
    /// also HP's switch-to-WD threshold).
    pub block_size: u32,
    /// Maximum threads resident across the device — EP's launch size
    /// ("maximum number of active threads possible", §II-B).
    pub max_resident_threads: u32,
    /// Device memory budget in bytes (K20c: 4.66 GB).
    pub memory_budget: u64,
    /// Core clock in GHz, for cycles → milliseconds.
    pub clock_ghz: f64,

    // --- calibrated cycle costs ---
    /// Fixed cost of one kernel launch (host driver + dispatch), in cycles.
    pub launch_overhead: u64,
    /// Stall latency a warp pays per memory step (global-load latency,
    /// partially hidden by the SM's other warps).
    pub mem_latency: u64,
    /// Additional cycles per 128 B transaction: a coalesced warp step
    /// issues one, a scattered step issues one per active lane.
    pub coalesced_tx: u64,
    /// Per-transaction cost of scattered (per-lane) accesses.
    pub scattered_tx: u64,
    /// ALU cost of one edge relaxation step (SSSP: add + compare).
    pub alu_relax: u64,
    /// Base cost of an uncontended read-modify-write atomic (atomicMin on
    /// a distance word).
    pub atomic_base: u64,
    /// Additional serialization cost per conflicting atomic in a warp.
    pub atomic_conflict: u64,
    /// Cost of one worklist-append reservation (atomicAdd on the tail
    /// counter — pipelined in L2, far cheaper than a dependent atomicMin).
    pub atomic_append: u64,
}

impl Default for DeviceSpec {
    fn default() -> Self {
        DeviceSpec::k20c()
    }
}

impl DeviceSpec {
    /// The paper's testbed: Tesla K20c (Kepler GK110).
    pub fn k20c() -> Self {
        DeviceSpec {
            name: "k20c",
            num_sm: 13,
            cores_per_sm: 192,
            warp_size: 32,
            block_size: 1024,
            max_resident_threads: 13 * 2048,
            memory_budget: (4.66 * 1024.0 * 1024.0 * 1024.0) as u64,
            clock_ghz: 0.706,
            // Calibration note: Kepler kernel dispatch is ~5-11 us, but the
            // reduced-size suite (DESIGN.md SS6) shrinks per-iteration kernel
            // work faster than it shrinks iteration counts (road frontiers
            // scale with sqrt(N)). 3000 cycles (~4 us) keeps the
            // overhead:kernel ratio at reduced scale in line with the
            // paper's at full scale; `--scale paper` runs are conservative.
            launch_overhead: 3_000,
            mem_latency: 150,       // global-load stall after warp overlap
            coalesced_tx: 30,       // one 128 B transaction for the warp
            scattered_tx: 20,       // per-lane transaction, pipelined
            alu_relax: 12,
            atomic_base: 40,
            atomic_conflict: 60,
            atomic_append: 10,
        }
    }

    /// Tesla K40 (Kepler GK110B): two more SMX, triple the memory and a
    /// slightly faster clock than the K20c — same per-op Kepler latencies,
    /// so heterogeneous serving pools mix it with the K20c cleanly.
    pub fn k40() -> Self {
        DeviceSpec {
            name: "k40",
            num_sm: 15,
            max_resident_threads: 15 * 2048,
            memory_budget: (12.0 * 1024.0 * 1024.0 * 1024.0) as u64,
            clock_ghz: 0.745,
            ..DeviceSpec::k20c()
        }
    }

    /// GeForce GTX 680 (Kepler GK104): fewer SMX and a quarter of the
    /// K40's memory, but a much higher clock — the "small fast consumer
    /// card" end of a heterogeneous pool.
    pub fn gtx680() -> Self {
        DeviceSpec {
            name: "gtx680",
            num_sm: 8,
            max_resident_threads: 8 * 2048,
            memory_budget: (2.0 * 1024.0 * 1024.0 * 1024.0) as u64,
            clock_ghz: 1.006,
            ..DeviceSpec::k20c()
        }
    }

    /// Preset names accepted by [`DeviceSpec::by_name`].
    pub const PRESETS: &'static [&'static str] = &["k20c", "k40", "gtx680"];

    /// Resolve a preset by name (the `devices` config key / `--devices`).
    pub fn by_name(name: &str) -> crate::error::Result<DeviceSpec> {
        match name {
            "k20c" => Ok(DeviceSpec::k20c()),
            "k40" => Ok(DeviceSpec::k40()),
            "gtx680" => Ok(DeviceSpec::gtx680()),
            other => Err(crate::error::Error::Config(format!(
                "unknown device {other:?}; available: {}",
                DeviceSpec::PRESETS.join(", ")
            ))),
        }
    }

    /// Integer picoseconds per core cycle — the exact unit the serving
    /// scheduler's virtual clock runs in, so heterogeneous shards (whose
    /// cycle counts are incomparable) meet on one deterministic timeline.
    pub fn ps_per_cycle(&self) -> u64 {
        (1000.0 / self.clock_ghz).round() as u64
    }

    /// Dimensionless throughput index (`SMs × cores × clock in MHz`) used
    /// for cross-multiplied load comparisons in the scheduler's
    /// least-outstanding-edges placement — pure integer math, so shard
    /// choice is deterministic on every platform.
    pub fn throughput_index(&self) -> u64 {
        self.num_sm as u64 * self.cores_per_sm as u64 * (self.clock_ghz * 1000.0).round() as u64
    }

    /// Warps an SM retires in parallel (`cores / warp_size`; 6 on K20c).
    pub fn warp_throughput(&self) -> u64 {
        (self.cores_per_sm / self.warp_size).max(1) as u64
    }

    /// Warps per block.
    pub fn warps_per_block(&self) -> u32 {
        (self.block_size + self.warp_size - 1) / self.warp_size
    }

    /// Convert simulated cycles to milliseconds at the device clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_ghz * 1e6)
    }

    /// Cycles of one auxiliary kernel streaming `items` elements coalesced
    /// with `per_item` extra ALU cycles (scan, `find_offsets`, condensing,
    /// split preprocessing). Single source of truth shared by execution
    /// charging ([`crate::coordinator::ExecCtx::charge_aux_kernel`]) and
    /// the adaptive cost model's predictions.
    pub fn aux_kernel_cycles(&self, items: u64, per_item: u64) -> u64 {
        let warps = (items + self.warp_size as u64 - 1) / self.warp_size as u64;
        let per_warp = self.coalesced_tx + self.alu_relax + per_item;
        let parallel = self.num_sm as u64 * self.warp_throughput();
        let busy = (warps * per_warp + parallel - 1) / parallel.max(1);
        self.launch_overhead + busy.max(if warps > 0 { per_warp } else { 0 })
    }

    /// Scale the memory budget for a reduced-size experiment suite.
    ///
    /// The paper's Graph500 graphs (335 M edges) exceed a 4.66 GB budget in
    /// COO form; a scale-16 rerun keeps the same *ratio* of budget to graph
    /// size so the same strategies hit the same wall (DESIGN.md §6).
    pub fn scaled_budget(mut self, paper_edges: u64, actual_edges: u64) -> Self {
        if actual_edges > 0 && paper_edges > 0 {
            self.memory_budget =
                (self.memory_budget as f64 * actual_edges as f64 / paper_edges as f64) as u64;
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k20c_defaults() {
        let d = DeviceSpec::k20c();
        assert_eq!(d.num_sm, 13);
        assert_eq!(d.warp_throughput(), 6);
        assert_eq!(d.warps_per_block(), 32);
        assert!(d.memory_budget > 4 * 1024 * 1024 * 1024);
    }

    #[test]
    fn cycles_to_ms_at_clock() {
        let d = DeviceSpec::k20c();
        let ms = d.cycles_to_ms(706_000);
        assert!((ms - 1.0).abs() < 1e-9);
    }

    #[test]
    fn presets_resolve_by_name_and_differ() {
        for name in DeviceSpec::PRESETS {
            let d = DeviceSpec::by_name(name).unwrap();
            assert_eq!(d.name, *name);
        }
        assert!(DeviceSpec::by_name("h100").is_err());
        let (k20c, k40, gtx680) = (
            DeviceSpec::k20c(),
            DeviceSpec::k40(),
            DeviceSpec::gtx680(),
        );
        assert!(k40.throughput_index() > k20c.throughput_index());
        assert!(k40.memory_budget > k20c.memory_budget);
        assert!(gtx680.memory_budget < k20c.memory_budget);
        // Distinct clocks ⇒ distinct integer virtual-clock steps.
        assert_eq!(k20c.ps_per_cycle(), 1416);
        assert_eq!(k40.ps_per_cycle(), 1342);
        assert_eq!(gtx680.ps_per_cycle(), 994);
    }

    #[test]
    fn scaled_budget_is_proportional() {
        let d = DeviceSpec::k20c().scaled_budget(335_000_000, 33_500_000);
        let full = DeviceSpec::k20c().memory_budget;
        assert!((d.memory_budget as f64 - full as f64 / 10.0).abs() < full as f64 * 0.01);
    }
}
