//! Deterministic SIMT cost-model simulator of the paper's testbed (Tesla
//! K20c, Kepler).
//!
//! The paper's measurements are *relative* comparisons of load-balancing
//! strategies. Four first-order effects determine those comparisons, and the
//! simulator models exactly these (DESIGN.md §2):
//!
//! 1. **Warp-level load imbalance** — a warp retires when its slowest lane
//!    does (SIMT lockstep), so kernel time tracks the *maximum* per-lane
//!    work. This is what makes node-based (BS) slow on skewed graphs.
//! 2. **Memory coalescing** — a warp whose lanes touch consecutive edges
//!    issues one wide transaction per step; scattered lanes pay one
//!    transaction each. This is WD's documented weakness (§III-A) and EP's
//!    round-robin strength (§II-B).
//! 3. **Atomic serialization** — conflicting atomics to the same address
//!    serialize within a warp; work chunking (§IV-D) reduces worklist-append
//!    atomics from per-edge to per-node.
//! 4. **Kernel launch overhead** — HP's sub-iterations and WD's auxiliary
//!    scan / `find_offsets` kernels pay per-launch costs; this is the
//!    "overhead" component of Figures 7 and 8.
//!
//! A fifth modelled constraint is the **device memory budget**: EP's COO
//! arrays and exploded worklists must fit in device memory, or the run
//! aborts with [`crate::Error::OutOfMemory`] — reproducing the paper's
//! missing EP/WD/NS bars on the Graph500 graphs.

pub mod device;
pub mod kernel;
pub mod memory;

pub use device::DeviceSpec;
pub use kernel::{AccessPattern, KernelSim, KernelTime, WarpSim, WarpStats};
pub use memory::MemoryTracker;
