//! Recursive-matrix (RMAT) generator — the small-world, power-law graphs
//! GTgraph produces (Chakrabarti et al.; paper reference [11]).

use super::draw_weight;
use crate::error::Result;
use crate::graph::{Csr, Edge};
use crate::util::Rng;

/// RMAT quadrant probabilities.
///
/// Defaults are `(a, b, c, d) = (0.55, 0.15, 0.15, 0.15)` — calibrated so
/// the *reduced-scale* suite reproduces the degree-skew class of the
/// paper's rmat20 (max ≈ 150× avg, σ ≫ avg, < 5 % of nodes above the
/// auto-MDT; Table II reports max 1181 / avg 8 / σ 177). GTgraph's classic
/// `(0.45, 0.15, 0.15, 0.25)` only reaches that skew at scale 20, which is
/// too large for CI — see DESIGN.md §6.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
    /// Maximum integer edge weight (weights drawn uniformly in `1..=max_wt`).
    pub max_wt: u32,
    /// Per-level probability noise, as in GTgraph, to avoid exact
    /// self-similarity artifacts.
    pub noise: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        RmatParams {
            a: 0.55,
            b: 0.15,
            c: 0.15,
            d: 0.15,
            max_wt: 100,
            noise: 0.05,
        }
    }
}

impl RmatParams {
    /// GTgraph's classic parameters `(0.45, 0.15, 0.15, 0.25)` — what the
    /// paper's generator used at scale 20.
    pub fn gtgraph() -> Self {
        RmatParams {
            a: 0.45,
            b: 0.15,
            c: 0.15,
            d: 0.25,
            max_wt: 100,
            noise: 0.1,
        }
    }
}

impl RmatParams {
    /// Graph500-style parameters `(0.57, 0.19, 0.19, 0.05)` — heavier skew.
    pub fn graph500() -> Self {
        RmatParams {
            a: 0.57,
            b: 0.19,
            c: 0.19,
            d: 0.05,
            max_wt: 100,
            noise: 0.05,
        }
    }
}

/// Generate an RMAT graph with `2^scale` nodes and `num_edges` edges.
///
/// Parallel edges and self loops are kept, matching GTgraph output (the
/// paper's rmat20: scale 20, ≈8.26 M edges, max degree ≈1181, σ ≈ 177).
pub fn rmat(scale: u32, num_edges: usize, params: RmatParams, seed: u64) -> Result<Csr> {
    let n = 1usize << scale;
    let mut rng = Rng::seed_from_u64(seed);
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        let (u, v) = sample_cell(scale, &params, &mut rng);
        let wt = draw_weight(&mut rng, params.max_wt);
        edges.push(Edge::new(u, v, wt));
    }
    Csr::from_edges(n, &edges)
}

/// Recursively descend the adjacency matrix choosing a quadrant per level.
fn sample_cell(scale: u32, p: &RmatParams, rng: &mut Rng) -> (u32, u32) {
    let mut u = 0u32;
    let mut v = 0u32;
    for level in (0..scale).rev() {
        // Jitter the quadrant probabilities a little per level (GTgraph's
        // "noise" knob) then renormalize.
        let jitter = |x: f64, r: &mut Rng| x * (1.0 - p.noise + 2.0 * p.noise * r.gen_f64());
        let (mut a, mut b, mut c, mut d) = (
            jitter(p.a, rng),
            jitter(p.b, rng),
            jitter(p.c, rng),
            jitter(p.d, rng),
        );
        let s = a + b + c + d;
        a /= s;
        b /= s;
        c /= s;
        d /= s;
        let roll: f64 = rng.gen_f64();
        let bit = 1u32 << level;
        if roll < a {
            // top-left: no bits set
        } else if roll < a + b {
            v |= bit;
        } else if roll < a + b + c {
            u |= bit;
        } else {
            u |= bit;
            v |= bit;
        }
        let _ = d;
    }
    (u, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::DegreeStats;
    use crate::graph::Graph;

    #[test]
    fn node_and_edge_counts_match_request() {
        let g = rmat(10, 8 * 1024, RmatParams::default(), 42).unwrap();
        assert_eq!(g.num_nodes(), 1024);
        assert_eq!(g.num_edges(), 8 * 1024);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let a = rmat(8, 2048, RmatParams::default(), 7).unwrap();
        let b = rmat(8, 2048, RmatParams::default(), 7).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = rmat(8, 2048, RmatParams::default(), 7).unwrap();
        let b = rmat(8, 2048, RmatParams::default(), 8).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn rmat_is_skewed_relative_to_er() {
        // The motivating observation of the paper (Fig. 1): RMAT degree
        // distributions have much higher variance than uniform graphs.
        let g = rmat(12, 8 * 4096, RmatParams::default(), 3).unwrap();
        let st = DegreeStats::of(&g);
        assert!(
            st.max as f64 > 10.0 * st.avg,
            "rmat max degree {} should dwarf avg {}",
            st.max,
            st.avg
        );
        assert!(st.stddev > st.avg, "rmat sigma {} <= avg {}", st.stddev, st.avg);
    }

    #[test]
    fn weights_within_range() {
        let g = rmat(6, 512, RmatParams { max_wt: 10, ..Default::default() }, 1).unwrap();
        assert!(g.weights().iter().all(|&w| (1..=10).contains(&w)));
    }
}
