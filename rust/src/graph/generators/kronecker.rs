//! Graph500 Kronecker generator — the paper's three large "Graph500"
//! graphs (16.78 M nodes, 335 M edges, max degree ≈924 k, σ ≈ 20 900).
//!
//! The Graph500 reference generator is an RMAT process with parameters
//! `(A, B, C) = (0.57, 0.19, 0.19)` and edge factor 20, followed by vertex
//! relabeling. Differing seeds yield differing connectivity, exactly as the
//! paper describes ("Depending upon the seed value, the graph connectivity
//! differs").

use crate::error::Result;
use crate::graph::generators::rmat::{rmat, RmatParams};
use crate::graph::{Csr, Edge};
use crate::util::Rng;

/// Graph500 edge factor: edges = 20 × nodes.
pub const EDGE_FACTOR: usize = 20;

/// Generate a Graph500-spec Kronecker graph at `scale` (`2^scale` nodes,
/// `EDGE_FACTOR · 2^scale` edges) with vertex relabeling.
pub fn graph500_kronecker(scale: u32, seed: u64) -> Result<Csr> {
    let n = 1usize << scale;
    let m = EDGE_FACTOR * n;
    let base = rmat(scale, m, RmatParams::graph500(), seed)?;

    // Graph500 permutes vertex labels so locality cannot be exploited by
    // construction order. The permutation is part of the spec.
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::seed_from_u64(seed ^ 0x9e37_79b9_7f4a_7c15);
    rng.shuffle(&mut perm);

    let edges: Vec<Edge> = base
        .edges()
        .map(|e| Edge::new(perm[e.src as usize], perm[e.dst as usize], e.wt))
        .collect();
    Csr::from_edges(n, &edges)
}

/// The three differently-seeded Graph500 instances used in the paper's
/// scalability experiments.
pub fn graph500_triple(scale: u32, base_seed: u64) -> Result<[Csr; 3]> {
    Ok([
        graph500_kronecker(scale, base_seed)?,
        graph500_kronecker(scale, base_seed + 1)?,
        graph500_kronecker(scale, base_seed + 2)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::DegreeStats;
    use crate::graph::Graph;

    #[test]
    fn edge_factor_is_twenty() {
        let g = graph500_kronecker(10, 1).unwrap();
        assert_eq!(g.num_edges(), 20 * g.num_nodes());
    }

    #[test]
    fn extremely_skewed_degrees() {
        // Table II: Graph500 graphs are the most skewed in the suite
        // (avg 20, sigma ~1000x avg at full scale; the ratio grows with
        // scale but is already >5x at scale 12).
        let g = graph500_kronecker(12, 2).unwrap();
        let st = DegreeStats::of(&g);
        assert!(st.stddev > 3.0 * st.avg, "sigma {} vs avg {}", st.stddev, st.avg);
        assert!(st.max > 100, "max degree {}", st.max);
    }

    #[test]
    fn seeds_change_connectivity() {
        let [a, b, c] = graph500_triple(8, 100).unwrap();
        assert_ne!(a, b);
        assert_ne!(b, c);
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(
            graph500_kronecker(8, 5).unwrap(),
            graph500_kronecker(8, 5).unwrap()
        );
    }
}
