//! The paper's experimental graph suite (Table II) with a scale knob.
//!
//! `SuiteScale::Paper` regenerates the Table II sizes exactly (tens to
//! hundreds of millions of edges — minutes of generation, gigabytes of
//! RAM); `SuiteScale::Small` keeps the same generative models, degree-skew
//! classes and edge factors at CI-friendly sizes; `SuiteScale::Tiny` is for
//! unit tests. Relative strategy behaviour (who wins where) is preserved
//! because it depends on skew class and diameter class, not absolute size —
//! the device memory budget scales along with the graphs (see
//! [`crate::sim::DeviceSpec::scaled_budget`]).

use crate::error::Result;
use crate::graph::generators::{erdos_renyi, graph500_kronecker, rmat, road_grid, RmatParams};
use crate::graph::Csr;

/// How large to instantiate the paper suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SuiteScale {
    /// Unit-test sizes (thousands of edges).
    Tiny,
    /// CI-friendly sizes (hundreds of thousands of edges) — default.
    #[default]
    Small,
    /// The paper's Table II sizes.
    Paper,
}

/// A named graph recipe from Table II.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphSpec {
    /// RMAT (GTgraph defaults): `rmat20` in the paper.
    Rmat { scale: u32, edge_factor: usize },
    /// Erdős–Rényi `G(n, m)`: `ER20`, `ER23`.
    ErdosRenyi { scale: u32, edge_factor: usize },
    /// Road grid: `road-FLA`, `road-W`, `road-USA`.
    Road { rows: usize, cols: usize },
    /// Graph500 Kronecker (three seeds in the paper).
    Graph500 { scale: u32, seed_offset: u64 },
}

impl GraphSpec {
    /// Instantiate the recipe deterministically.
    pub fn generate(&self, seed: u64) -> Result<Csr> {
        match *self {
            GraphSpec::Rmat { scale, edge_factor } => rmat(
                scale,
                edge_factor << scale,
                RmatParams::default(),
                seed,
            ),
            GraphSpec::ErdosRenyi { scale, edge_factor } => {
                erdos_renyi(1 << scale, edge_factor << scale, 100, seed)
            }
            GraphSpec::Road { rows, cols } => road_grid(rows, cols, 100, seed),
            GraphSpec::Graph500 { scale, seed_offset } => {
                graph500_kronecker(scale, seed + seed_offset)
            }
        }
    }

    /// Skew class for reporting ("skewed", "uniform", "road").
    pub fn skew_class(&self) -> &'static str {
        match self {
            GraphSpec::Rmat { .. } | GraphSpec::Graph500 { .. } => "skewed",
            GraphSpec::ErdosRenyi { .. } => "uniform",
            GraphSpec::Road { .. } => "road",
        }
    }
}

/// One (name, recipe) entry of the experiment suite.
#[derive(Debug, Clone)]
pub struct SuiteEntry {
    pub name: String,
    pub spec: GraphSpec,
    /// Edge count of the paper's Table II counterpart — used to scale the
    /// simulated device memory budget proportionally when running reduced
    /// sizes (DESIGN.md §6), so EP/WD/NS hit the same memory wall the paper
    /// reports.
    pub paper_edges: u64,
}

/// The Table II suite at the requested scale, in the paper's row order:
/// rmat, road-FLA, road-W, road-USA, ER20, ER23, Graph500 × 3.
pub fn paper_suite(scale: SuiteScale) -> Vec<SuiteEntry> {
    const M: u64 = 1_000_000;
    let e = |name: &str, spec: GraphSpec, paper_edges: u64| SuiteEntry {
        name: name.to_string(),
        spec,
        paper_edges,
    };
    match scale {
        SuiteScale::Paper => vec![
            e("rmat20", GraphSpec::Rmat { scale: 20, edge_factor: 8 }, 8_260_000),
            e("road-FLA", GraphSpec::Road { rows: 1035, cols: 1035 }, 2_710_000),
            e("road-W", GraphSpec::Road { rows: 2502, cols: 2502 }, 15_120_000),
            e("road-USA", GraphSpec::Road { rows: 4895, cols: 4895 }, 57_710_000),
            e("ER20", GraphSpec::ErdosRenyi { scale: 20, edge_factor: 4 }, 4_190_000),
            e("ER23", GraphSpec::ErdosRenyi { scale: 23, edge_factor: 4 }, 33_550_000),
            e("Graph500-a", GraphSpec::Graph500 { scale: 24, seed_offset: 0 }, 335 * M),
            e("Graph500-b", GraphSpec::Graph500 { scale: 24, seed_offset: 1 }, 335 * M),
            e("Graph500-c", GraphSpec::Graph500 { scale: 24, seed_offset: 2 }, 335 * M),
        ],
        SuiteScale::Small => vec![
            e("rmat16", GraphSpec::Rmat { scale: 16, edge_factor: 8 }, 8_260_000),
            e("road-FLA", GraphSpec::Road { rows: 128, cols: 128 }, 2_710_000),
            e("road-W", GraphSpec::Road { rows: 256, cols: 256 }, 15_120_000),
            e("road-USA", GraphSpec::Road { rows: 512, cols: 512 }, 57_710_000),
            e("ER16", GraphSpec::ErdosRenyi { scale: 16, edge_factor: 4 }, 4_190_000),
            e("ER18", GraphSpec::ErdosRenyi { scale: 18, edge_factor: 4 }, 33_550_000),
            e("Graph500-a", GraphSpec::Graph500 { scale: 16, seed_offset: 0 }, 335 * M),
            e("Graph500-b", GraphSpec::Graph500 { scale: 16, seed_offset: 1 }, 335 * M),
            e("Graph500-c", GraphSpec::Graph500 { scale: 16, seed_offset: 2 }, 335 * M),
        ],
        SuiteScale::Tiny => vec![
            e("rmat10", GraphSpec::Rmat { scale: 10, edge_factor: 8 }, 8_260_000),
            e("road-tiny", GraphSpec::Road { rows: 24, cols: 24 }, 2_710_000),
            e("ER10", GraphSpec::ErdosRenyi { scale: 10, edge_factor: 4 }, 4_190_000),
            e("Graph500-t", GraphSpec::Graph500 { scale: 10, seed_offset: 0 }, 335 * M),
        ],
    }
}

/// Default seed used by the CLI and benches.
pub const DEFAULT_SEED: u64 = 20170101;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::DegreeStats;
    use crate::graph::Graph;

    #[test]
    fn tiny_suite_generates() {
        for entry in paper_suite(SuiteScale::Tiny) {
            let g = entry.spec.generate(DEFAULT_SEED).unwrap();
            assert!(g.num_nodes() > 0, "{} empty", entry.name);
            assert!(g.num_edges() > 0, "{} no edges", entry.name);
        }
    }

    #[test]
    fn skew_classes_hold_at_tiny_scale() {
        for entry in paper_suite(SuiteScale::Tiny) {
            let g = entry.spec.generate(DEFAULT_SEED).unwrap();
            let st = DegreeStats::of(&g);
            match entry.spec.skew_class() {
                "skewed" => assert!(
                    st.stddev > st.avg,
                    "{}: sigma {} <= avg {}",
                    entry.name,
                    st.stddev,
                    st.avg
                ),
                "road" => assert!(st.max <= 8, "{}: max {}", entry.name, st.max),
                _ => assert!(st.max < 10 * (st.avg.ceil() as u32 + 1)),
            }
        }
    }

    #[test]
    fn suite_names_are_unique() {
        let suite = paper_suite(SuiteScale::Small);
        let mut names: Vec<&str> = suite.iter().map(|e| e.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), suite.len());
    }
}
