//! Road-network generator — a planar grid with occasional diagonals and
//! deletions, matched to the 9th-DIMACS USA road networks the paper uses
//! (road-FLA / road-W / road-USA: max degree 8–9, avg ≈3, σ ≈ 2.5, very
//! large diameter).
//!
//! Real `.gr` files load through [`crate::graph::io::dimacs`]; this
//! generator provides an in-repo substitute with the same degree profile
//! and diameter class (substitution documented in DESIGN.md §2).

use super::draw_weight;
use crate::error::Result;
use crate::graph::{Csr, GraphBuilder};
use crate::util::Rng;

/// Generate a `rows × cols` road-like network.
///
/// Each intersection connects to its 4-neighborhood; fractions of both
/// diagonals are added (freeway ramps / shortcuts — giving the degree-5..8
/// tail the DIMACS road graphs show) and a fraction of grid edges removed
/// (rivers, dead ends). Yields max degree 8, modal degree 4, average ≈ 3.7
/// like the paper's road networks, while keeping the diameter Θ(rows+cols).
pub fn road_grid(rows: usize, cols: usize, max_wt: u32, seed: u64) -> Result<Csr> {
    assert!(rows >= 2 && cols >= 2, "road grid needs at least 2x2");
    let mut rng = Rng::seed_from_u64(seed);
    let idx = |r: usize, c: usize| (r * cols + c) as u32;
    // Symmetric: road segments are two-way, matching DIMACS .gr files that
    // list both arcs.
    let mut b = GraphBuilder::new(rows * cols).symmetric(true);
    const DROP_P: f64 = 0.06; // removed grid segments
    const DIAG_P: f64 = 0.05; // added ↘ diagonal shortcuts
    const DIAG2_P: f64 = 0.05; // added ↙ diagonal shortcuts

    for r in 0..rows {
        for c in 0..cols {
            // Right and down neighbors (each undirected segment considered
            // once; the builder mirrors it).
            if c + 1 < cols && rng.gen_f64() >= DROP_P {
                let w = draw_weight(&mut rng, max_wt);
                b.add_edge(idx(r, c), idx(r, c + 1), w);
            }
            if r + 1 < rows && rng.gen_f64() >= DROP_P {
                let w = draw_weight(&mut rng, max_wt);
                b.add_edge(idx(r, c), idx(r + 1, c), w);
            }
            if r + 1 < rows && c + 1 < cols && rng.gen_f64() < DIAG_P {
                let w = draw_weight(&mut rng, max_wt);
                b.add_edge(idx(r, c), idx(r + 1, c + 1), w);
            }
            if r + 1 < rows && c >= 1 && rng.gen_f64() < DIAG2_P {
                let w = draw_weight(&mut rng, max_wt);
                b.add_edge(idx(r, c), idx(r + 1, c - 1), w);
            }
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::DegreeStats;
    use crate::graph::traversal;
    use crate::graph::Graph;

    #[test]
    fn degree_profile_matches_road_networks() {
        let g = road_grid(100, 100, 100, 21).unwrap();
        let st = DegreeStats::of(&g);
        assert!(st.max <= 8, "road max degree {} > 8", st.max);
        assert!(
            (2.0..=4.5).contains(&st.avg),
            "road avg degree {} outside Table II band",
            st.avg
        );
        assert!(st.stddev < 3.0, "road sigma {}", st.stddev);
    }

    #[test]
    fn diameter_is_large() {
        // Road networks are the paper's large-diameter class: BFS depth
        // should scale with grid side, unlike RMAT's O(log n).
        let g = road_grid(64, 64, 1, 3).unwrap();
        let ecc = traversal::bfs_eccentricity(&g, 0);
        assert!(ecc > 32, "eccentricity {} too small for a 64x64 grid", ecc);
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            road_grid(16, 16, 10, 5).unwrap(),
            road_grid(16, 16, 10, 5).unwrap()
        );
    }

    #[test]
    fn mostly_connected() {
        let g = road_grid(32, 32, 10, 7).unwrap();
        let reached = traversal::bfs_reachable(&g, 0);
        assert!(
            reached as f64 > 0.9 * g.num_nodes() as f64,
            "only {reached} of {} reachable",
            g.num_nodes()
        );
    }
}
