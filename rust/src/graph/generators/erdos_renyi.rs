//! Erdős–Rényi `G(n, m)` generator — the paper's `ER20` / `ER23` inputs
//! (uniform random edges; moderate max degree, no small-world structure).

use super::draw_weight;
use crate::error::Result;
use crate::graph::{Csr, Edge};
use crate::util::Rng;

/// Generate a `G(n, m)` random directed graph: `num_edges` edges drawn
/// uniformly over all ordered pairs (self loops excluded, parallels kept —
/// GTgraph's random-graph model).
pub fn erdos_renyi(num_nodes: usize, num_edges: usize, max_wt: u32, seed: u64) -> Result<Csr> {
    assert!(num_nodes >= 2, "ER graph needs >= 2 nodes");
    let mut rng = Rng::seed_from_u64(seed);
    let n = num_nodes as u32;
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        let u = rng.gen_range_u32(0, n);
        let mut v = rng.gen_range_u32(0, n - 1);
        if v >= u {
            v += 1; // skip self loop without rejection sampling
        }
        let wt = draw_weight(&mut rng, max_wt);
        edges.push(Edge::new(u, v, wt));
    }
    Csr::from_edges(num_nodes, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::DegreeStats;
    use crate::graph::Graph;

    #[test]
    fn counts_match() {
        let g = erdos_renyi(1000, 4000, 100, 11).unwrap();
        assert_eq!(g.num_nodes(), 1000);
        assert_eq!(g.num_edges(), 4000);
    }

    #[test]
    fn no_self_loops() {
        let g = erdos_renyi(100, 1000, 10, 5).unwrap();
        assert!(g.edges().all(|e| e.src != e.dst));
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            erdos_renyi(64, 256, 10, 3).unwrap(),
            erdos_renyi(64, 256, 10, 3).unwrap()
        );
    }

    #[test]
    fn degree_distribution_is_mild() {
        // Table II: ER graphs have small max degree relative to RMAT —
        // Poisson-ish tails, max ≈ avg + a few sigma.
        let g = erdos_renyi(1 << 14, 4 << 14, 100, 9).unwrap();
        let st = DegreeStats::of(&g);
        assert!(
            (st.max as f64) < 8.0 * st.avg.max(1.0),
            "ER max degree {} too skewed vs avg {}",
            st.max,
            st.avg
        );
    }
}
