//! Deterministic, seeded graph generators covering the paper's input suite
//! (Table II): RMAT, Erdős–Rényi, Graph500 Kronecker and road networks.
//!
//! The paper used GTgraph for RMAT/ER and the Graph500 reference generator
//! for the large graphs; we implement the same generative models in-repo
//! (substitution documented in DESIGN.md §2). All generators take an
//! explicit seed and are reproducible across runs and platforms.

pub mod erdos_renyi;
pub mod kronecker;
pub mod rmat;
pub mod road;
pub mod suite;

pub use erdos_renyi::erdos_renyi;
pub use kronecker::graph500_kronecker;
pub use rmat::{rmat, RmatParams};
pub use road::road_grid;
pub use suite::{paper_suite, GraphSpec, SuiteScale};

use crate::util::Rng;

/// Draw a DIMACS-style integer weight in `1..=max_wt`.
pub(crate) fn draw_weight(rng: &mut Rng, max_wt: u32) -> u32 {
    if max_wt <= 1 {
        1
    } else {
        rng.gen_range_inclusive_u32(1, max_wt)
    }
}
