//! Serial reference traversals — the correctness oracles every strategy is
//! validated against, plus diameter-class probes used by the generators'
//! tests and graph inspection.

use crate::graph::{Csr, Graph, NodeId};
use crate::INF;
use std::collections::{BinaryHeap, VecDeque};

/// Serial BFS levels from `source` (`INF` for unreachable nodes).
pub fn bfs_levels(g: &Csr, source: NodeId) -> Vec<u32> {
    let mut level = vec![INF; g.num_nodes()];
    if g.num_nodes() == 0 {
        return level;
    }
    let mut q = VecDeque::new();
    level[source as usize] = 0;
    q.push_back(source);
    while let Some(u) = q.pop_front() {
        let next = level[u as usize] + 1;
        for &v in g.neighbors(u) {
            if level[v as usize] == INF {
                level[v as usize] = next;
                q.push_back(v);
            }
        }
    }
    level
}

/// Serial Dijkstra distances from `source` (`INF` for unreachable nodes).
pub fn dijkstra(g: &Csr, source: NodeId) -> Vec<u32> {
    use std::cmp::Reverse;
    let mut dist = vec![INF; g.num_nodes()];
    if g.num_nodes() == 0 {
        return dist;
    }
    let mut heap = BinaryHeap::new();
    dist[source as usize] = 0;
    heap.push(Reverse((0u32, source)));
    while let Some(Reverse((d, u))) = heap.pop() {
        if d > dist[u as usize] {
            continue; // stale entry
        }
        for (&v, &w) in g.neighbors(u).iter().zip(g.edge_weights(u)) {
            let nd = d.saturating_add(w);
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(Reverse((nd, v)));
            }
        }
    }
    dist
}

/// Number of nodes reachable from `source` (including itself).
pub fn bfs_reachable(g: &Csr, source: NodeId) -> usize {
    bfs_levels(g, source).iter().filter(|&&l| l != INF).count()
}

/// Eccentricity of `source`: max finite BFS level.
pub fn bfs_eccentricity(g: &Csr, source: NodeId) -> u32 {
    bfs_levels(g, source)
        .iter()
        .filter(|&&l| l != INF)
        .copied()
        .max()
        .unwrap_or(0)
}

/// A deterministic "interesting" source: the maximum out-degree node.
/// Graph500-style generators permute vertex labels, so a fixed id (e.g. 0)
/// can be isolated; BFS/SSSP evaluations conventionally start from a node
/// inside the giant component, which the top hub almost surely is.
pub fn hub_source(g: &Csr) -> NodeId {
    (0..g.num_nodes() as u32)
        .max_by_key(|&u| g.degree(u))
        .unwrap_or(0)
}

/// Double-sweep lower bound on the diameter (exact on trees; a good
/// diameter-class probe for road vs. small-world graphs).
pub fn diameter_lower_bound(g: &Csr, start: NodeId) -> u32 {
    let levels = bfs_levels(g, start);
    let far = levels
        .iter()
        .enumerate()
        .filter(|(_, &l)| l != INF)
        .max_by_key(|(_, &l)| l)
        .map(|(i, _)| i as u32)
        .unwrap_or(start);
    bfs_eccentricity(g, far)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn weighted_diamond() -> Csr {
        // 0 ->1 (1), 0->2 (4), 1->3 (2), 2->3 (1): shortest 0->3 = 3 via 1
        Csr::from_edges(
            4,
            &[
                Edge::new(0, 1, 1),
                Edge::new(0, 2, 4),
                Edge::new(1, 3, 2),
                Edge::new(2, 3, 1),
            ],
        )
        .unwrap()
    }

    #[test]
    fn dijkstra_picks_cheaper_path() {
        let d = dijkstra(&weighted_diamond(), 0);
        assert_eq!(d, vec![0, 1, 4, 3]);
    }

    #[test]
    fn bfs_counts_hops_not_weights() {
        let l = bfs_levels(&weighted_diamond(), 0);
        assert_eq!(l, vec![0, 1, 1, 2]);
    }

    #[test]
    fn unreachable_is_inf() {
        let g = Csr::from_edges(3, &[Edge::new(0, 1, 1)]).unwrap();
        let l = bfs_levels(&g, 0);
        assert_eq!(l[2], INF);
        let d = dijkstra(&g, 0);
        assert_eq!(d[2], INF);
    }

    #[test]
    fn path_graph_diameter() {
        let edges: Vec<Edge> = (0..9u32)
            .flat_map(|u| [Edge::new(u, u + 1, 1), Edge::new(u + 1, u, 1)])
            .collect();
        let g = Csr::from_edges(10, &edges).unwrap();
        assert_eq!(diameter_lower_bound(&g, 5), 9);
    }

    #[test]
    fn saturating_add_never_wraps() {
        let g = Csr::from_edges(2, &[Edge::new(0, 1, u32::MAX - 1)]).unwrap();
        let d = dijkstra(&g, 0);
        assert_eq!(d[1], u32::MAX - 1);
    }
}
