//! Graph file formats: DIMACS `.gr` (the 9th-DIMACS road-network format the
//! paper's real inputs ship in), whitespace edge lists, and a compact
//! binary CSR snapshot for fast reloads.

pub mod binary;
pub mod dimacs;
pub mod edgelist;

use crate::error::{Error, Result};
use crate::graph::Csr;
use std::path::Path;

/// Load a graph, dispatching on extension: `.gr` → DIMACS, `.bin` →
/// binary CSR, anything else → edge list.
pub fn load<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let p = path.as_ref();
    match p.extension().and_then(|e| e.to_str()) {
        Some("gr") => dimacs::read_gr(p),
        Some("bin") => binary::read_csr(p),
        Some(_) | None => edgelist::read_edgelist(p),
    }
}

/// Save a graph, dispatching on extension like [`load`].
pub fn save<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let p = path.as_ref();
    match p.extension().and_then(|e| e.to_str()) {
        Some("gr") => dimacs::write_gr(g, p),
        Some("bin") => binary::write_csr(g, p),
        Some("txt") | Some("el") => edgelist::write_edgelist(g, p),
        other => Err(Error::Config(format!(
            "don't know how to write extension {other:?}"
        ))),
    }
}
