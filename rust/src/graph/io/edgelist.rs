//! Whitespace-separated edge lists: `src dst [weight]` per line, `#`
//! comments, 0-based ids (SNAP-style). Missing weights default to 1.

use crate::error::{Error, Result};
use crate::graph::{Csr, Graph, GraphBuilder};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Read an edge list file.
pub fn read_edgelist<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let f = File::open(path)?;
    let reader = BufReader::new(f);
    let mut b = GraphBuilder::new(0);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let src: u32 = parse(it.next(), lineno)?;
        let dst: u32 = parse(it.next(), lineno)?;
        let wt: u32 = match it.next() {
            Some(s) => s
                .parse()
                .map_err(|_| Error::InvalidGraph(format!("line {}: bad weight", lineno + 1)))?,
            None => 1,
        };
        b.add_edge(src, dst, wt);
    }
    b.build()
}

/// Write an edge list file (always includes weights).
pub fn write_edgelist<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let f = File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# {} nodes, {} edges", g.num_nodes(), g.num_edges())?;
    for e in g.edges() {
        writeln!(w, "{} {} {}", e.src, e.dst, e.wt)?;
    }
    Ok(())
}

fn parse(field: Option<&str>, lineno: usize) -> Result<u32> {
    field
        .ok_or_else(|| Error::InvalidGraph(format!("line {}: missing field", lineno + 1)))?
        .parse()
        .map_err(|_| Error::InvalidGraph(format!("line {}: bad node id", lineno + 1)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tmp::TempPath;

    #[test]
    fn parses_with_and_without_weights() {
        let f = TempPath::file(".el");
        std::fs::write(f.path(), b"# comment\n0 1 9\n1 2\n").unwrap();
        let g = read_edgelist(f.path()).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.edge_weights(0), &[9]);
        assert_eq!(g.edge_weights(1), &[1]);
    }

    #[test]
    fn roundtrip() {
        let g = crate::graph::generators::erdos_renyi(32, 128, 10, 4).unwrap();
        let f = TempPath::file(".el");
        write_edgelist(&g, f.path()).unwrap();
        let g2 = read_edgelist(f.path()).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn rejects_garbage() {
        let f = TempPath::file(".el");
        std::fs::write(f.path(), b"0 not_a_number\n").unwrap();
        assert!(read_edgelist(f.path()).is_err());
    }
}
