//! Compact binary CSR snapshots for fast reload of large generated graphs.
//!
//! Layout (little-endian):
//! `magic "LLBG" | version u32 | num_nodes u64 | num_edges u64 |
//!  row_offsets [u32; n+1] | col_idx [u32; m] | weights [u32; m]`

use crate::error::{Error, Result};
use crate::graph::{Csr, Graph};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LLBG";
const VERSION: u32 = 1;

/// Write a binary CSR snapshot.
pub fn write_csr<P: AsRef<Path>>(g: &Csr, path: P) -> Result<()> {
    let f = File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(g.num_nodes() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    write_u32s(&mut w, g.row_offsets())?;
    write_u32s(&mut w, g.col_indices())?;
    write_u32s(&mut w, g.weights())?;
    Ok(())
}

/// Read a binary CSR snapshot.
pub fn read_csr<P: AsRef<Path>>(path: P) -> Result<Csr> {
    let f = File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::InvalidGraph("bad magic (not a LLBG file)".into()));
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        return Err(Error::InvalidGraph(format!(
            "unsupported snapshot version {version}"
        )));
    }
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let row_offsets = read_u32s(&mut r, n + 1)?;
    let col_idx = read_u32s(&mut r, m)?;
    let weights = read_u32s(&mut r, m)?;
    Csr::from_raw(row_offsets, col_idx, weights)
}

fn write_u32s<W: Write>(w: &mut W, xs: &[u32]) -> Result<()> {
    // bulk little-endian write
    for chunk in xs.chunks(4096) {
        let mut buf = Vec::with_capacity(chunk.len() * 4);
        for x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32s(r: &mut impl Read, count: usize) -> Result<Vec<u32>> {
    let mut buf = vec![0u8; count * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let g = crate::graph::generators::rmat(
            8,
            2048,
            crate::graph::generators::RmatParams::default(),
            9,
        )
        .unwrap();
        let f = crate::util::tmp::TempPath::file(".bin");
        write_csr(&g, f.path()).unwrap();
        let g2 = read_csr(f.path()).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn rejects_bad_magic() {
        let f = crate::util::tmp::TempPath::file(".bin");
        std::fs::write(f.path(), b"NOPE....").unwrap();
        assert!(read_csr(f.path()).is_err());
    }

    #[test]
    fn rejects_truncated_file() {
        let g = crate::graph::generators::erdos_renyi(16, 64, 5, 1).unwrap();
        let f = crate::util::tmp::TempPath::file(".bin");
        write_csr(&g, f.path()).unwrap();
        let bytes = std::fs::read(f.path()).unwrap();
        std::fs::write(f.path(), &bytes[..bytes.len() / 2]).unwrap();
        assert!(read_csr(f.path()).is_err());
    }
}
