//! Coordinate-list storage — the less-normalized format required by
//! edge-based task distribution (EP).

use super::{Csr, Edge, Graph, NodeId};
use crate::error::{Error, Result};

/// COO graph: a sequence of `⟨src, dst, wt⟩` tuples stored as three parallel
/// arrays. Source endpoints are duplicated across the outgoing edges of a
/// node, which is what lets a thread own an edge without consulting row
/// offsets — and what doubles the storage versus CSR (§II-B).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Coo {
    num_nodes: usize,
    src: Vec<NodeId>,
    dst: Vec<NodeId>,
    wt: Vec<u32>,
}

impl Coo {
    /// Build from raw parallel arrays.
    pub fn from_raw(num_nodes: usize, src: Vec<NodeId>, dst: Vec<NodeId>, wt: Vec<u32>) -> Result<Self> {
        if src.len() != dst.len() || src.len() != wt.len() {
            return Err(Error::InvalidGraph("COO arrays must be equal length".into()));
        }
        if let Some(&bad) = src.iter().chain(dst.iter()).find(|&&v| v as usize >= num_nodes) {
            return Err(Error::InvalidGraph(format!(
                "endpoint {bad} out of range (n = {num_nodes})"
            )));
        }
        Ok(Coo {
            num_nodes,
            src,
            dst,
            wt,
        })
    }

    /// Build from an edge list.
    pub fn from_edges(num_nodes: usize, edges: &[Edge]) -> Result<Self> {
        Coo::from_raw(
            num_nodes,
            edges.iter().map(|e| e.src).collect(),
            edges.iter().map(|e| e.dst).collect(),
            edges.iter().map(|e| e.wt).collect(),
        )
    }

    /// The edge stored at index `i`.
    #[inline]
    pub fn edge(&self, i: usize) -> Edge {
        Edge::new(self.src[i], self.dst[i], self.wt[i])
    }

    /// Source endpoints array.
    pub fn srcs(&self) -> &[NodeId] {
        &self.src
    }

    /// Destination endpoints array.
    pub fn dsts(&self) -> &[NodeId] {
        &self.dst
    }

    /// Weight array.
    pub fn wts(&self) -> &[u32] {
        &self.wt
    }

    /// Iterate over edges in storage order.
    pub fn edges(&self) -> impl Iterator<Item = Edge> + '_ {
        (0..self.src.len()).map(move |i| self.edge(i))
    }

    /// Normalize back to CSR (counting sort by source).
    pub fn to_csr(&self) -> Csr {
        let edges: Vec<Edge> = self.edges().collect();
        Csr::from_edges(self.num_nodes, &edges).expect("valid COO converts to CSR")
    }
}

impl Graph for Coo {
    fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    fn num_edges(&self) -> usize {
        self.src.len()
    }

    /// `2E` endpoints + `E` weights, 4 B each — the paper's "2E elements"
    /// accounting plus weights for SSSP (§II-B).
    fn memory_bytes(&self) -> u64 {
        4 * 3 * self.src.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_csr_coo_csr() {
        let edges = vec![
            Edge::new(0, 1, 3),
            Edge::new(1, 2, 1),
            Edge::new(2, 0, 7),
            Edge::new(0, 2, 2),
        ];
        let csr = Csr::from_edges(3, &edges).unwrap();
        let coo = csr.to_coo();
        let back = coo.to_csr();
        assert_eq!(csr, back);
    }

    #[test]
    fn coo_memory_is_about_three_e() {
        let coo = Coo::from_edges(3, &[Edge::new(0, 1, 1), Edge::new(1, 2, 1)]).unwrap();
        assert_eq!(coo.memory_bytes(), 4 * 3 * 2);
    }

    #[test]
    fn coo_uses_more_memory_than_csr_for_dense_graphs() {
        // Average degree > 1 makes COO strictly bigger — the paper's EP
        // memory argument.
        let mut edges = Vec::new();
        for u in 0..16u32 {
            for v in 0..16u32 {
                if u != v {
                    edges.push(Edge::new(u, v, 1));
                }
            }
        }
        let csr = Csr::from_edges(16, &edges).unwrap();
        let coo = Coo::from_edges(16, &edges).unwrap();
        assert!(coo.memory_bytes() > csr.memory_bytes());
    }

    #[test]
    fn rejects_mismatched_arrays() {
        assert!(Coo::from_raw(2, vec![0], vec![1, 0], vec![1]).is_err());
    }

    #[test]
    fn rejects_out_of_range_endpoint() {
        assert!(Coo::from_raw(2, vec![0], vec![9], vec![1]).is_err());
    }
}
