//! Degree statistics and histograms — the inputs to Table II, Figure 1,
//! Figure 10 and the histogram-based MDT heuristic (§III-B).

use crate::graph::Csr;

/// Summary out-degree statistics of a graph, as reported per row of
/// Table II (max / avg / σ).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegreeStats {
    pub min: u32,
    pub max: u32,
    pub avg: f64,
    /// Population standard deviation of the out-degrees.
    pub stddev: f64,
}

impl DegreeStats {
    /// Compute stats over all nodes of `g`.
    pub fn of(g: &Csr) -> Self {
        use crate::graph::Graph;
        let n = g.num_nodes();
        Self::over((0..n as u32).map(|u| g.degree(u)), n)
    }

    /// Compute stats over an explicit degree list — the online frontier
    /// inspection path of the adaptive subsystem ([`crate::adaptive`]),
    /// which reuses the worklists' cached out-degrees.
    pub fn of_degrees(degrees: &[u32]) -> Self {
        Self::over(degrees.iter().copied(), degrees.len())
    }

    fn over(degrees: impl Iterator<Item = u32>, n: usize) -> Self {
        if n == 0 {
            return DegreeStats {
                min: 0,
                max: 0,
                avg: 0.0,
                stddev: 0.0,
            };
        }
        let mut min = u32::MAX;
        let mut max = 0u32;
        let mut sum = 0u64;
        let mut sumsq = 0u128;
        for d in degrees {
            min = min.min(d);
            max = max.max(d);
            sum += d as u64;
            sumsq += (d as u128) * (d as u128);
        }
        let avg = sum as f64 / n as f64;
        let var = (sumsq as f64 / n as f64) - avg * avg;
        DegreeStats {
            min,
            max,
            avg,
            stddev: var.max(0.0).sqrt(),
        }
    }

    /// Imbalance factor `max / avg` — the first-order predictor of
    /// node-based (BS) slowdown.
    pub fn imbalance(&self) -> f64 {
        if self.avg > 0.0 {
            self.max as f64 / self.avg
        } else {
            0.0
        }
    }
}

/// A fixed-bin-count histogram over node out-degrees.
///
/// Bin `i` covers degrees in `[i * bin_width, (i+1) * bin_width)` with
/// `bin_width = ceil((max_degree + 1) / bins)`. This is the structure the
/// MDT heuristic (§III-B) peaks over.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DegreeHistogram {
    pub bin_width: u32,
    pub counts: Vec<u64>,
    pub max_degree: u32,
}

impl DegreeHistogram {
    /// Histogram the out-degrees of `g` into `bins` bins.
    pub fn of(g: &Csr, bins: usize) -> Self {
        use crate::graph::Graph;
        assert!(bins > 0, "need at least one bin");
        let max_degree = g.max_degree();
        let bin_width = (max_degree / bins as u32) + 1;
        let mut counts = vec![0u64; bins];
        for u in 0..g.num_nodes() as u32 {
            let b = (g.degree(u) / bin_width) as usize;
            counts[b.min(bins - 1)] += 1;
        }
        DegreeHistogram {
            bin_width,
            counts,
            max_degree,
        }
    }

    /// Index of the tallest bin (ties broken toward lower degrees — less
    /// splitting, per the heuristic's minimality goal).
    pub fn peak_bin(&self) -> usize {
        let mut best = 0usize;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > self.counts[best] {
                best = i;
            }
        }
        best
    }

    /// Number of nodes with degree in bin `i`.
    pub fn count(&self, i: usize) -> u64 {
        self.counts[i]
    }
}

/// Full degree-frequency table `degree -> node count` (Figures 1 and 10 plot
/// this directly).
pub fn degree_frequency(g: &Csr) -> Vec<(u32, u64)> {
    use crate::graph::Graph;
    use std::collections::BTreeMap;
    let mut freq: BTreeMap<u32, u64> = BTreeMap::new();
    for u in 0..g.num_nodes() as u32 {
        *freq.entry(g.degree(u)).or_insert(0) += 1;
    }
    freq.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Csr, Edge};

    fn star(n: u32) -> Csr {
        // node 0 points at everyone else: max skew
        let edges: Vec<Edge> = (1..n).map(|v| Edge::new(0, v, 1)).collect();
        Csr::from_edges(n as usize, &edges).unwrap()
    }

    #[test]
    fn star_stats() {
        let g = star(11);
        let st = DegreeStats::of(&g);
        assert_eq!(st.max, 10);
        assert_eq!(st.min, 0);
        assert!((st.avg - 10.0 / 11.0).abs() < 1e-9);
        assert!(st.imbalance() > 10.0);
    }

    #[test]
    fn uniform_stats_have_zero_sigma() {
        // ring: every node degree 1
        let edges: Vec<Edge> = (0..8u32).map(|u| Edge::new(u, (u + 1) % 8, 1)).collect();
        let g = Csr::from_edges(8, &edges).unwrap();
        let st = DegreeStats::of(&g);
        assert_eq!(st.min, 1);
        assert_eq!(st.max, 1);
        assert_eq!(st.stddev, 0.0);
    }

    #[test]
    fn histogram_counts_sum_to_n() {
        let g = star(50);
        let h = DegreeHistogram::of(&g, 8);
        assert_eq!(h.counts.iter().sum::<u64>(), 50);
    }

    #[test]
    fn histogram_peak_is_low_degree_for_star() {
        let g = star(50);
        let h = DegreeHistogram::of(&g, 8);
        assert_eq!(h.peak_bin(), 0, "49 zero-degree nodes dominate");
    }

    #[test]
    fn degree_frequency_matches_histogram_total() {
        let g = star(20);
        let freq = degree_frequency(&g);
        let total: u64 = freq.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 20);
        assert_eq!(freq.iter().find(|(d, _)| *d == 19).unwrap().1, 1);
    }

    #[test]
    fn empty_graph_stats() {
        let g = Csr::from_edges(0, &[]).unwrap();
        let st = DegreeStats::of(&g);
        assert_eq!(st.max, 0);
        assert_eq!(st.avg, 0.0);
    }

    #[test]
    fn of_degrees_matches_whole_graph_path() {
        let g = star(20);
        use crate::graph::Graph;
        let degs: Vec<u32> = (0..g.num_nodes() as u32).map(|u| g.degree(u)).collect();
        assert_eq!(DegreeStats::of(&g), DegreeStats::of_degrees(&degs));
        assert_eq!(DegreeStats::of_degrees(&[]).max, 0);
        let sub = DegreeStats::of_degrees(&[3, 3, 3]);
        assert_eq!(sub.max, 3);
        assert_eq!(sub.stddev, 0.0);
    }
}
