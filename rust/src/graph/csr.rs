//! Compressed sparse row storage — the space-efficient format used by all
//! node-based strategies (BS, WD, NS, HP).

use super::{Coo, Edge, Graph, NodeId};
use crate::error::{Error, Result};

/// CSR graph: adjacencies of each node concatenated into one monolithic
/// list, with per-node start offsets (§I of the paper).
///
/// Weights are always materialized; BFS simply ignores them (LonestarGPU
/// style, where BFS is level computation over unit weights).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csr {
    /// `num_nodes + 1` offsets into `col_idx`/`weights`.
    row_offsets: Vec<u32>,
    /// Destination node of each edge, grouped by source.
    col_idx: Vec<NodeId>,
    /// Weight of each edge (parallel to `col_idx`).
    weights: Vec<u32>,
}

impl Csr {
    /// Build from raw arrays, validating the CSR invariants.
    pub fn from_raw(row_offsets: Vec<u32>, col_idx: Vec<NodeId>, weights: Vec<u32>) -> Result<Self> {
        if row_offsets.is_empty() {
            return Err(Error::InvalidGraph("row_offsets must have >= 1 entry".into()));
        }
        if *row_offsets.last().unwrap() as usize != col_idx.len() {
            return Err(Error::InvalidGraph(format!(
                "last row offset {} != edge count {}",
                row_offsets.last().unwrap(),
                col_idx.len()
            )));
        }
        if col_idx.len() != weights.len() {
            return Err(Error::InvalidGraph("weights length != edge count".into()));
        }
        if row_offsets.windows(2).any(|w| w[0] > w[1]) {
            return Err(Error::InvalidGraph("row offsets not monotonic".into()));
        }
        let n = (row_offsets.len() - 1) as u32;
        if let Some(&bad) = col_idx.iter().find(|&&d| d >= n) {
            return Err(Error::InvalidGraph(format!(
                "edge destination {bad} out of range (n = {n})"
            )));
        }
        Ok(Csr {
            row_offsets,
            col_idx,
            weights,
        })
    }

    /// Build from an unsorted edge list using counting sort (O(N + E)).
    pub fn from_edges(num_nodes: usize, edges: &[Edge]) -> Result<Self> {
        for e in edges {
            if e.src as usize >= num_nodes || e.dst as usize >= num_nodes {
                return Err(Error::InvalidGraph(format!(
                    "edge ({}, {}) out of range (n = {num_nodes})",
                    e.src, e.dst
                )));
            }
        }
        let mut counts = vec![0u32; num_nodes + 1];
        for e in edges {
            counts[e.src as usize + 1] += 1;
        }
        for i in 0..num_nodes {
            counts[i + 1] += counts[i];
        }
        let row_offsets = counts.clone();
        let mut col_idx = vec![0u32; edges.len()];
        let mut weights = vec![0u32; edges.len()];
        let mut cursor = row_offsets.clone();
        for e in edges {
            let slot = cursor[e.src as usize] as usize;
            col_idx[slot] = e.dst;
            weights[slot] = e.wt;
            cursor[e.src as usize] += 1;
        }
        Ok(Csr {
            row_offsets,
            col_idx,
            weights,
        })
    }

    /// Out-degree of `node`.
    #[inline]
    pub fn degree(&self, node: NodeId) -> u32 {
        self.row_offsets[node as usize + 1] - self.row_offsets[node as usize]
    }

    /// Index of `node`'s first edge in the monolithic adjacency list.
    #[inline]
    pub fn first_edge(&self, node: NodeId) -> u32 {
        self.row_offsets[node as usize]
    }

    /// Neighbors (destinations) of `node`.
    #[inline]
    pub fn neighbors(&self, node: NodeId) -> &[NodeId] {
        let s = self.row_offsets[node as usize] as usize;
        let e = self.row_offsets[node as usize + 1] as usize;
        &self.col_idx[s..e]
    }

    /// Edge weights of `node`'s outgoing edges (parallel to [`neighbors`]).
    ///
    /// [`neighbors`]: Csr::neighbors
    #[inline]
    pub fn edge_weights(&self, node: NodeId) -> &[u32] {
        let s = self.row_offsets[node as usize] as usize;
        let e = self.row_offsets[node as usize + 1] as usize;
        &self.weights[s..e]
    }

    /// Destination of the edge with monolithic index `eid`.
    #[inline]
    pub fn edge_dst(&self, eid: u32) -> NodeId {
        self.col_idx[eid as usize]
    }

    /// Weight of the edge with monolithic index `eid`.
    #[inline]
    pub fn edge_wt(&self, eid: u32) -> u32 {
        self.weights[eid as usize]
    }

    /// Raw row-offset array (length `num_nodes + 1`).
    pub fn row_offsets(&self) -> &[u32] {
        &self.row_offsets
    }

    /// Raw destination array (length `num_edges`).
    pub fn col_indices(&self) -> &[NodeId] {
        &self.col_idx
    }

    /// Raw weight array (length `num_edges`).
    pub fn weights(&self) -> &[u32] {
        &self.weights
    }

    /// Iterate over all edges in monolithic order.
    pub fn edges(&self) -> impl Iterator<Item = Edge> + '_ {
        (0..self.num_nodes() as u32).flat_map(move |u| {
            self.neighbors(u)
                .iter()
                .zip(self.edge_weights(u))
                .map(move |(&v, &w)| Edge::new(u, v, w))
        })
    }

    /// Convert to COO, duplicating source endpoints (the memory cost the
    /// paper charges EP for — §II-B).
    pub fn to_coo(&self) -> Coo {
        let m = self.num_edges();
        let mut src = Vec::with_capacity(m);
        let mut dst = Vec::with_capacity(m);
        let mut wt = Vec::with_capacity(m);
        for e in self.edges() {
            src.push(e.src);
            dst.push(e.dst);
            wt.push(e.wt);
        }
        Coo::from_raw(self.num_nodes(), src, dst, wt).expect("CSR produces valid COO")
    }

    /// Maximum out-degree (0 for an empty graph).
    pub fn max_degree(&self) -> u32 {
        (0..self.num_nodes() as u32)
            .map(|u| self.degree(u))
            .max()
            .unwrap_or(0)
    }
}

impl Graph for Csr {
    fn num_nodes(&self) -> usize {
        self.row_offsets.len() - 1
    }

    fn num_edges(&self) -> usize {
        self.col_idx.len()
    }

    /// `N+1` offsets + `E` destinations + `E` weights, 4 B each.
    fn memory_bytes(&self) -> u64 {
        4 * (self.row_offsets.len() as u64 + 2 * self.col_idx.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Csr {
        // 0 -> 1 (w1), 0 -> 2 (w4), 1 -> 3 (w2), 2 -> 3 (w1)
        Csr::from_edges(
            4,
            &[
                Edge::new(0, 1, 1),
                Edge::new(0, 2, 4),
                Edge::new(1, 3, 2),
                Edge::new(2, 3, 1),
            ],
        )
        .unwrap()
    }

    #[test]
    fn from_edges_builds_expected_offsets() {
        let g = diamond();
        assert_eq!(g.row_offsets(), &[0, 2, 3, 4, 4]);
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 4);
    }

    #[test]
    fn neighbors_and_weights_align() {
        let g = diamond();
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.edge_weights(0), &[1, 4]);
        assert_eq!(g.neighbors(3), &[] as &[u32]);
    }

    #[test]
    fn degree_matches_offsets() {
        let g = diamond();
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 1);
        assert_eq!(g.degree(3), 0);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn edges_iterator_roundtrips() {
        let g = diamond();
        let edges: Vec<Edge> = g.edges().collect();
        let g2 = Csr::from_edges(4, &edges).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn coo_conversion_preserves_edges() {
        let g = diamond();
        let coo = g.to_coo();
        assert_eq!(coo.num_edges(), 4);
        assert_eq!(coo.edge(0), Edge::new(0, 1, 1));
        assert_eq!(coo.edge(3), Edge::new(2, 3, 1));
    }

    #[test]
    fn memory_accounting_matches_paper_formula() {
        let g = diamond();
        // (N+1 + 2E) * 4 bytes
        assert_eq!(g.memory_bytes(), 4 * (5 + 8));
    }

    #[test]
    fn rejects_nonmonotonic_offsets() {
        let r = Csr::from_raw(vec![0, 3, 2, 4], vec![0, 1, 2, 0], vec![1; 4]);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_out_of_range_destination() {
        let r = Csr::from_edges(2, &[Edge::new(0, 5, 1)]);
        assert!(r.is_err());
    }

    #[test]
    fn empty_graph_is_valid() {
        let g = Csr::from_edges(0, &[]).unwrap();
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.max_degree(), 0);
    }
}
