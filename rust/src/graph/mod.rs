//! Graph substrate: storage formats, construction, generation, IO and
//! statistics.
//!
//! The paper contrasts two storage formats whose memory footprints drive its
//! evaluation:
//!
//! * **CSR** ([`Csr`]) — `N+1` row offsets + `E` column indices (+ `E`
//!   weights). Used by the node-based strategies (BS, WD, NS, HP).
//! * **COO** ([`Coo`]) — `2E` endpoint arrays (+ `E` weights). Required by
//!   edge-based processing (EP); the duplication of source endpoints is why
//!   EP runs out of memory on the Graph500 graphs (§II-B).
//!
//! All formats use `u32` node ids and `u32` integer weights (DIMACS
//! convention). The largest paper graph (335 M edges) fits comfortably in
//! `u32` index space.

pub mod builder;
pub mod coo;
pub mod csr;
pub mod generators;
pub mod io;
pub mod stats;
pub mod traversal;

pub use builder::GraphBuilder;
pub use coo::Coo;
pub use csr::Csr;
pub use stats::DegreeStats;

/// Node identifier. `u32` keeps CSR/COO arrays compact, matching the paper's
/// 4-byte-integer memory accounting (§II-B).
pub type NodeId = u32;

/// Common read interface over graph storages.
pub trait Graph {
    /// Number of nodes.
    fn num_nodes(&self) -> usize;
    /// Number of (directed) edges.
    fn num_edges(&self) -> usize;
    /// Device-memory footprint in bytes under the paper's accounting
    /// (4-byte elements; §II-B).
    fn memory_bytes(&self) -> u64;
}

/// A single weighted directed edge. The unit of work for edge-based (EP)
/// task distribution, and the tuple stored by the COO format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    pub wt: u32,
}

impl Edge {
    pub fn new(src: NodeId, dst: NodeId, wt: u32) -> Self {
        Edge { src, dst, wt }
    }
}
