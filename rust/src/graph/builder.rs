//! Incremental graph construction with de-duplication and weight policies.
//!
//! Generators and file loaders accumulate edges here; [`GraphBuilder::build`]
//! produces a validated [`Csr`].

use super::{Csr, Edge, NodeId};
use crate::error::Result;
use std::collections::HashMap;

/// What to do when the same `(src, dst)` pair is inserted twice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DuplicatePolicy {
    /// Keep every parallel edge (multigraph). GTgraph's RMAT output keeps
    /// duplicates; the Graph500 generator does too.
    #[default]
    Keep,
    /// Keep the first weight seen for the pair.
    First,
    /// Keep the minimum weight (useful for shortest-path inputs).
    MinWeight,
}

/// Accumulates edges and produces a CSR graph.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    num_nodes: usize,
    edges: Vec<Edge>,
    policy: DuplicatePolicy,
    drop_self_loops: bool,
    symmetric: bool,
}

impl GraphBuilder {
    /// Builder over `num_nodes` nodes with default policies.
    pub fn new(num_nodes: usize) -> Self {
        GraphBuilder {
            num_nodes,
            ..Default::default()
        }
    }

    /// Set duplicate-edge handling.
    pub fn duplicates(mut self, policy: DuplicatePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Drop `u -> u` edges on insert.
    pub fn drop_self_loops(mut self, yes: bool) -> Self {
        self.drop_self_loops = yes;
        self
    }

    /// Insert the reverse of every edge too (road networks are symmetric).
    pub fn symmetric(mut self, yes: bool) -> Self {
        self.symmetric = yes;
        self
    }

    /// Number of edges accumulated so far (before dedup).
    pub fn pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Grow the node count if needed to include `node`.
    pub fn ensure_node(&mut self, node: NodeId) {
        if node as usize >= self.num_nodes {
            self.num_nodes = node as usize + 1;
        }
    }

    /// Add one directed edge (and its reverse when symmetric).
    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, wt: u32) {
        self.ensure_node(src);
        self.ensure_node(dst);
        if self.drop_self_loops && src == dst {
            return;
        }
        self.edges.push(Edge::new(src, dst, wt));
        if self.symmetric && src != dst {
            self.edges.push(Edge::new(dst, src, wt));
        }
    }

    /// Finalize into CSR, applying the duplicate policy.
    pub fn build(mut self) -> Result<Csr> {
        match self.policy {
            DuplicatePolicy::Keep => {}
            DuplicatePolicy::First | DuplicatePolicy::MinWeight => {
                let mut seen: HashMap<(NodeId, NodeId), u32> = HashMap::new();
                for e in &self.edges {
                    seen.entry((e.src, e.dst))
                        .and_modify(|w| {
                            if self.policy == DuplicatePolicy::MinWeight {
                                *w = (*w).min(e.wt);
                            }
                        })
                        .or_insert(e.wt);
                }
                self.edges = seen
                    .into_iter()
                    .map(|((s, d), w)| Edge::new(s, d, w))
                    .collect();
            }
        }
        Csr::from_edges(self.num_nodes, &self.edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn keeps_parallel_edges_by_default() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 5);
        b.add_edge(0, 1, 7);
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn min_weight_policy_dedups() {
        let mut b = GraphBuilder::new(2).duplicates(DuplicatePolicy::MinWeight);
        b.add_edge(0, 1, 5);
        b.add_edge(0, 1, 7);
        b.add_edge(0, 1, 3);
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.edge_weights(0), &[3]);
    }

    #[test]
    fn symmetric_inserts_reverse() {
        let mut b = GraphBuilder::new(2).symmetric(true);
        b.add_edge(0, 1, 2);
        let g = b.build().unwrap();
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0]);
    }

    #[test]
    fn self_loops_dropped_when_requested() {
        let mut b = GraphBuilder::new(2).drop_self_loops(true);
        b.add_edge(0, 0, 1);
        b.add_edge(0, 1, 1);
        let g = b.build().unwrap();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn node_count_grows_on_demand() {
        let mut b = GraphBuilder::new(0);
        b.add_edge(3, 7, 1);
        let g = b.build().unwrap();
        assert_eq!(g.num_nodes(), 8);
    }
}
